"""Activation functions, including the paper's i-GELU polynomial (T5).

The paper avoids costly tanh/division on Snitch by using the I-BERT
second-order polynomial approximation of GELU (Kim et al. [46]).  On TPU the
VPU evaluates tanh natively, but the polynomial is still cheaper (2 mul + 2
add vs a transcendental) and we keep it as the optimized-path default so the
ablation benchmark can toggle exact vs i-GELU like the paper does.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# I-BERT constants: L(x) = sign(x) [a (clip(|x|, max=-b) + b)^2 + 1]
_A = -0.2888
_B = -1.769


def i_gelu(x):
    """Second-order polynomial GELU (I-BERT).  Max abs err ~0.01."""
    xf = x.astype(jnp.float32)
    arg = xf * (1.0 / jnp.sqrt(2.0).astype(jnp.float32))
    sgn = jnp.sign(arg)
    a = jnp.minimum(jnp.abs(arg), -_B)
    erf_approx = sgn * (_A * (a + _B) ** 2 + 1.0)
    return (0.5 * xf * (1.0 + erf_approx)).astype(x.dtype)


def gelu_exact(x):
    return jax.nn.gelu(x.astype(jnp.float32), approximate=False).astype(x.dtype)


def gelu_tanh(x):
    return jax.nn.gelu(x.astype(jnp.float32), approximate=True).astype(x.dtype)


def silu(x):
    return jax.nn.silu(x.astype(jnp.float32)).astype(x.dtype)


ACTIVATIONS = {
    "gelu": gelu_tanh,
    "gelu_exact": gelu_exact,
    "i_gelu": i_gelu,
    "silu": silu,
}


def get_activation(name: str):
    return ACTIVATIONS[name]
