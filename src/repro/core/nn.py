"""Shared numeric helpers for manual-SPMD layer code.

Precision rules (paper T6): GEMM operands in the policy compute dtype,
accumulation in fp32, softmax/normalization statistics in fp32.  Activations
are carried in `policy.act_dtype` (bf16 for fp8 policies — the paper's
pack/unpack conversions around low-precision GEMMs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import collectives as col
from repro.core.precision import Policy
from repro.kernels import ops


def act_dtype(policy: Policy):
    cd = jnp.dtype(policy.compute_dtype)
    if cd in (jnp.dtype(jnp.float8_e4m3fn), jnp.dtype(jnp.float8_e5m2)):
        return jnp.bfloat16
    return policy.compute_dtype


def pdot(x, w, policy: Policy, *, out_dtype=None):
    """x: [..., K] @ w: [K, N] in the policy compute dtype.

    The dot's element type is the OUTPUT dtype directly (no f32->cast pair):
    the MXU accumulates fp32 internally either way, and emitting the narrow
    dtype keeps the *backward* dots narrow too (the cast transpose would
    otherwise promote every cotangent to f32).  Paper T6: conversions sit at
    GEMM outputs.  Explicit out_dtype=f32 (CE logits) accumulates visibly.

    `w` may be a weight-only-int8 dict {"q", "scale"} (models/quantize):
    the dot runs on the int8 tensor cast to compute dtype (exact — |q| is
    <= 127) and the per-output-channel dequant applies to the fp32 result,
    matching kernels/ref.fused_matmul_ref bit-for-bit."""
    w, w_scale = ops.split_quantized(w)
    cd = policy.compute_dtype
    od = out_dtype or act_dtype(policy)
    y = jax.lax.dot_general(
        x.astype(cd), w.astype(cd),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=od)
    if w_scale is not None:
        y = (y.astype(jnp.float32)
             * w_scale.astype(jnp.float32)).astype(od)
    return y


def fused_pdot(x, w, policy: Policy, *, prologue=None, epilogue=None,
               out_dtype=None):
    """`pdot` with an optional fused norm prologue / bias-activation-
    residual epilogue (kernels/epilogue.py).  With both None this IS
    `pdot` — same dot, same dtypes — so call sites can thread the fusion
    specs unconditionally."""
    if prologue is None and epilogue is None:
        return pdot(x, w, policy, out_dtype=out_dtype)
    od = out_dtype or act_dtype(policy)
    return ops.fused_matmul(x, w, prologue=prologue, epilogue=epilogue,
                            compute_dtype=policy.compute_dtype, dot_dtype=od)


def gather_w(w, plan, *, fsdp_dim=0, tp_dim=None):
    """FSDP all-gather of a weight shard along `fsdp_dim`; when `tp_dim` is
    given also un-shards the tensor-parallel dim (seq_sp attention needs the
    full weight on every device).

    Weight-only-int8 dicts gather the int8 tensor as usual; the per-output-
    channel scale rides along, gathered only when the weight's LAST dim (the
    output channels it indexes) is among the gathered dims."""
    q, scale = ops.split_quantized(w)
    out_dim = q.ndim - 1
    g = col.all_gather(q, plan.fsdp_axes, axis=fsdp_dim)
    if tp_dim is not None:
        g = col.all_gather(g, plan.tp_axes, axis=tp_dim)
    if scale is None:
        return g
    if fsdp_dim == out_dim:
        scale = col.all_gather(scale, plan.fsdp_axes, axis=scale.ndim - 1)
    if tp_dim == out_dim:
        scale = col.all_gather(scale, plan.tp_axes, axis=scale.ndim - 1)
    return {"q": g, "scale": scale}


def sum_sq(x):
    xf = x.astype(jnp.float32)
    return jnp.sum(xf * xf)
