"""Axis-name-safe collective wrappers for manual-SPMD model code.

All model layers are written against these: when the axis tuple is empty
(no mesh / axis not present) every collective degrades to identity, so the
same layer code runs unsharded in unit tests and sharded under shard_map.

`psum_scatter` optionally routes through the literal binary-tree schedule
(core.tree_reduce) — the paper's log2-depth cluster-to-cluster reduction —
selected by `set_reduce_method("tree")` for the §Perf comparison.
"""
from __future__ import annotations

import threading
from typing import Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.tree_reduce import tree_psum_scatter

Axes = Union[str, Tuple[str, ...], None]

_STATE = threading.local()


def shard_map(fn, *, mesh, in_specs, out_specs):
    """`jax.shard_map` across jax versions.  Newer releases expose it at the
    top level with `check_vma=`; older ones at jax.experimental.shard_map
    with `check_rep=`.  Replication checking is off either way (manual-SPMD
    model code psums explicitly)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False)
        except TypeError:
            pass                              # pre-check_vma signature
    # check_rep=False: manual-SPMD code psums replication axes explicitly,
    # which the old rep checker cannot always infer (multi-pod grad sync)
    from jax.experimental.shard_map import shard_map as sm_exp
    return sm_exp(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def set_reduce_method(method: str) -> None:
    assert method in ("ring", "tree"), method
    _STATE.reduce_method = method


def get_reduce_method() -> str:
    return getattr(_STATE, "reduce_method", "ring")


def _norm(axes: Axes) -> Tuple[str, ...]:
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes)


def one_axis_size(a) -> int:
    """Static size of one named axis, across jax versions (`jax.lax.
    axis_size` is newer; older releases expose it via `jax.core.axis_frame`,
    which returns either the size or a frame carrying it)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(a)
    import jax.core as jcore
    fr = jcore.axis_frame(a)
    return fr if isinstance(fr, int) else fr.size


def axis_size(axes: Axes) -> int:
    n = 1
    for a in _norm(axes):
        n *= one_axis_size(a)
    return n


def axis_index(axes: Axes):
    """Linearized index over possibly-multiple axes (C order: first major)."""
    axes = _norm(axes)
    if not axes:
        return jnp.zeros((), jnp.int32)
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        idx = idx * one_axis_size(a) + jax.lax.axis_index(a)
    return idx


def psum(x, axes: Axes):
    axes = _norm(axes)
    return jax.lax.psum(x, axes) if axes else x


def pmax(x, axes: Axes):
    axes = _norm(axes)
    return jax.lax.pmax(x, axes) if axes else x


def all_gather(x, axes: Axes, *, axis: int = 0, tiled: bool = True):
    """Gather over possibly-multiple mesh axes along array dim `axis`.
    Multi-axis order matches `axis_index` (first listed = major)."""
    for a in reversed(_norm(axes)):
        x = jax.lax.all_gather(x, a, axis=axis, tiled=tiled)
    return x


def all_gather_fp8(x, axes: Axes, *, axis: int = 0):
    """Activation all-gather with fp8(E4M3) wire payloads (§Perf P3c): cast
    before the gather, restore the dtype after.  Halves the dominant
    Megatron-SP gather bytes; softmax/norm math upstream stays fp32."""
    if not _norm(axes):
        return x
    dt = x.dtype
    return all_gather(x.astype(jnp.float8_e4m3fn), axes,
                      axis=axis).astype(dt)


def psum_scatter(x, axes: Axes, *, scatter_dimension: int = 0,
                 tiled: bool = True):
    axes = _norm(axes)
    if not axes:
        return x
    if get_reduce_method() == "tree" and len(axes) == 1:
        return tree_psum_scatter(x, axes[0], scatter_dim=scatter_dimension)
    for a in axes:  # scatter over major axis first => index math matches
        x = jax.lax.psum_scatter(x, a, scatter_dimension=scatter_dimension,
                                 tiled=tiled)
    return x


def pargmax(values, axes: Axes, *, index_offset):
    """Global argmax over a sharded last dim.  `values`: [..., Nl] local;
    `index_offset`: scalar global offset of this shard's column 0.
    Returns (max [..." ], argmax-global-index [...])."""
    loc_max = values.max(axis=-1)
    loc_arg = values.argmax(axis=-1).astype(jnp.int32) + index_offset
    g_max = pmax(loc_max, axes)
    # tie-break to the lowest index among winners
    cand = jnp.where(loc_max >= g_max, loc_arg, jnp.iinfo(jnp.int32).max)
    axes_n = _norm(axes)
    g_arg = jax.lax.pmin(cand, axes_n) if axes_n else cand
    return g_max, g_arg
