"""Transformer blocks: one assembly per layer kind (configs.base docstring).

Every block is pre-norm residual.  `block_full` handles train / prefill /
encoder passes (sequence-sharded x); `block_decode` handles one AR step
(x: [B, E]).  Both are `lax.scan`-compatible: stacked layer params in,
stacked caches out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import attention as attn
from repro.core import mlp as mlp_mod
from repro.core import ssm as ssm_mod
from repro.kernels import ops
from repro.sharding.plan import Plan

ATTN_KINDS = ("attn", "local", "moe", "moe_local", "hybrid_attn",
              "hybrid_local", "enc", "dec", "vit")
SSM_KINDS = ("ssm", "hybrid_attn", "hybrid_local")
MOE_KINDS = ("moe", "moe_local")
MLP_KINDS = ("attn", "local", "hybrid_attn", "hybrid_local", "enc", "dec",
             "vit")
LOCAL_KINDS = ("local", "moe_local", "hybrid_local")
BIDIR_KINDS = ("enc", "vit")


def block_fused(plan: Plan) -> bool:
    """True when this block should emit the fused prologue/epilogue
    pipeline.  fp8 residual gathers (comm_fp8) pre-norm BEFORE quantizing
    the wire bytes — folding the norm behind the gather would move the
    quantization point — so fusion falls back to the discrete chain there."""
    return plan.fuse_epilogues and not plan.comm_fp8


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------

def _norm_shapes(cfg):
    E = cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": (E,)}
    return {"scale": (E,), "bias": (E,)}


def _norm_dims(cfg):
    return {k: (None,) for k in _norm_shapes(cfg)}


def _init_norm(cfg, dtype):
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def block_param_shapes(kind: str, cfg) -> dict:
    out = {"ln1": _norm_shapes(cfg)}
    if kind in ATTN_KINDS:
        out["attn"] = attn.attention_param_shapes(cfg)
    if kind in SSM_KINDS or kind == "ssm":
        out["ssm"] = ssm_mod.ssm_param_shapes(cfg)
    if kind == "dec":
        out["lnx"] = _norm_shapes(cfg)
        out["xattn"] = attn.attention_param_shapes(cfg)
    if kind in MOE_KINDS:
        out["ln2"] = _norm_shapes(cfg)
        out["moe"] = mlp_mod.moe_param_shapes(cfg)
    elif kind in MLP_KINDS:
        out["ln2"] = _norm_shapes(cfg)
        out["mlp"] = mlp_mod.mlp_param_shapes(cfg)
    return out


def block_param_dims(kind: str, cfg) -> dict:
    out = {"ln1": _norm_dims(cfg)}
    if kind in ATTN_KINDS:
        out["attn"] = attn.attention_param_dims(cfg)
    if kind in SSM_KINDS or kind == "ssm":
        out["ssm"] = ssm_mod.ssm_param_dims(cfg)
    if kind == "dec":
        out["lnx"] = _norm_dims(cfg)
        out["xattn"] = attn.attention_param_dims(cfg)
    if kind in MOE_KINDS:
        out["ln2"] = _norm_dims(cfg)
        out["moe"] = mlp_mod.moe_param_dims(cfg)
    elif kind in MLP_KINDS:
        out["ln2"] = _norm_dims(cfg)
        out["mlp"] = mlp_mod.mlp_param_dims(cfg)
    return out


def init_block(key, kind: str, cfg, dtype) -> dict:
    ks = jax.random.split(key, 4)
    out = {"ln1": _init_norm(cfg, dtype)}
    if kind in ATTN_KINDS:
        out["attn"] = attn.init_attention(ks[0], cfg, dtype)
    if kind in SSM_KINDS or kind == "ssm":
        out["ssm"] = ssm_mod.init_ssm(ks[1], cfg, dtype)
    if kind == "dec":
        out["lnx"] = _init_norm(cfg, dtype)
        out["xattn"] = attn.init_attention(ks[2], cfg, dtype)
    if kind in MOE_KINDS:
        out["ln2"] = _init_norm(cfg, dtype)
        out["moe"] = mlp_mod.init_moe(ks[3], cfg, dtype)
    elif kind in MLP_KINDS:
        out["ln2"] = _init_norm(cfg, dtype)
        out["mlp"] = mlp_mod.init_mlp(ks[3], cfg, dtype)
    return out


# --------------------------------------------------------------------------
# static per-kind attention attributes
# --------------------------------------------------------------------------

def kind_window(kind: str, cfg) -> int:
    return cfg.sliding_window if kind in LOCAL_KINDS else 0


def kind_causal(kind: str, cfg) -> bool:
    if kind in BIDIR_KINDS:
        return False
    return cfg.causal


def kind_cache_len(kind: str, cfg, max_seq: int) -> int:
    """Global KV-cache slots for this kind (ring caches: the window)."""
    w = kind_window(kind, cfg)
    return min(w, max_seq) if w > 0 and w < max_seq else max_seq


def kind_paged(kind: str, cfg, max_seq: int) -> bool:
    """True when this kind's self-attention KV cache is block-paged under a
    paged layout: full-context attention layers only — window/ring caches
    are already bounded and stay dense per-slot (as do SSM state and
    cross-attention memory)."""
    return (kind in ATTN_KINDS
            and kind_cache_len(kind, cfg, max_seq) == max_seq)


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def block_full(kind: str, p, x, *, plan: Plan, cfg, policy,
               with_cache: bool = False, max_seq: int = 0, memory=None,
               memory_len: int = 0, compact_kv: bool = False):
    """x: [B, S_loc, E] -> (x', cache | None, aux).

    `compact_kv`: emit full-context KV caches at the prompt's own length
    instead of padding to `max_seq` (paged prefill: the engine scatters the
    compact cache into pool blocks, so the B x max_seq dense buffer never
    materializes).  Ring/window caches keep their window-sized layout."""
    aux = jnp.zeros((), jnp.float32)
    cache = {}
    causal = kind_causal(kind, cfg)
    window = kind_window(kind, cfg)
    cache_len = kind_cache_len(kind, cfg, max_seq) if with_cache else 0
    if compact_kv and kind_paged(kind, cfg, max_seq):
        # compact cache at the sequence's own length, rounded up to the
        # cache-shard count (cache_slice cuts S // shards rows per device —
        # an indivisible S would silently drop the tail positions)
        S_tot = x.shape[1] * max(plan.sp, 1)
        shards = max(plan.cache_shards, 1)
        cache_len = -(-S_tot // shards) * shards

    fused = block_fused(plan)
    if kind == "ssm":
        h = ops.norm(x, p["ln1"], cfg.norm)
        y, sc = ssm_mod.ssm_full(p["ssm"], h, plan=plan, cfg=cfg,
                                 policy=policy, with_cache=with_cache)
        if with_cache:
            cache.update(sc)
        return x + y, (cache if with_cache else None), aux

    hybrid = kind in ("hybrid_attn", "hybrid_local")
    moe_like = kind in MOE_KINDS
    h = y = None
    if fused and not hybrid and not moe_like:
        # pre-norm folds into the Q/K/V projections, the residual add into
        # the out-projection epilogue: x' comes back as the updated stream
        x, kv = attn.attn_full(p["attn"], x, plan=plan, cfg=cfg,
                               policy=policy, causal=causal, window=window,
                               with_cache=with_cache, cache_len=cache_len,
                               norm=ops.norm_prologue(p["ln1"], cfg.norm),
                               residual=x)
    elif fused and moe_like:
        # keep the sub-layer output: its residual add fuses with ln2 below
        y, kv = attn.attn_full(p["attn"], x, plan=plan, cfg=cfg,
                               policy=policy, causal=causal, window=window,
                               with_cache=with_cache, cache_len=cache_len,
                               norm=ops.norm_prologue(p["ln1"], cfg.norm))
    else:
        h = ops.norm(x, p["ln1"], cfg.norm)
        y, kv = attn.attn_full(p["attn"], h, plan=plan, cfg=cfg,
                               policy=policy, causal=causal, window=window,
                               with_cache=with_cache, cache_len=cache_len)
    if with_cache:
        cache.update(kv)
    if hybrid:
        s, sc = ssm_mod.ssm_full(p["ssm"], h, plan=plan, cfg=cfg,
                                 policy=policy, with_cache=with_cache)
        y = (y + s) * 0.5
        if with_cache:
            cache.update(sc)
    if y is not None and not fused:
        x = x + y
        y = None
    # fused + (hybrid | moe): y still pending — it folds into the fused
    # residual_norm at the ln2 boundary below

    if kind == "dec":
        cl = memory.shape[1] * plan.sp if memory is not None else 0
        if fused:
            x, xkv = attn.attn_full(p["xattn"], x, plan=plan, cfg=cfg,
                                    policy=policy, causal=False, window=0,
                                    with_cache=with_cache, cache_len=cl,
                                    memory=memory, memory_len=memory_len,
                                    norm=ops.norm_prologue(p["lnx"],
                                                           cfg.norm),
                                    residual=x)
        else:
            hx = ops.norm(x, p["lnx"], cfg.norm)
            yx, xkv = attn.attn_full(p["xattn"], hx, plan=plan, cfg=cfg,
                                     policy=policy, causal=False, window=0,
                                     with_cache=with_cache, cache_len=cl,
                                     memory=memory, memory_len=memory_len)
            x = x + yx
        if with_cache:
            cache["ck"], cache["cv"] = xkv["k"], xkv["v"]

    if moe_like:
        if fused:       # add + norm in one pass (GEMMs can't absorb MoE's
            h2, x = ops.residual_norm(x, y, p["ln2"], cfg.norm)  # dispatch)
        else:
            h2 = ops.norm(x, p["ln2"], cfg.norm)
        y2, aux = mlp_mod.moe_full(p["moe"], h2, plan=plan, cfg=cfg,
                                   policy=policy)
        x = x + y2
    elif kind in MLP_KINDS:
        if fused and hybrid:
            h2, x = ops.residual_norm(x, y, p["ln2"], cfg.norm)
            x = mlp_mod.mlp_full(p["mlp"], h2, plan=plan, cfg=cfg,
                                 policy=policy, residual=x)
        elif fused:
            x = mlp_mod.mlp_full(p["mlp"], x, plan=plan, cfg=cfg,
                                 policy=policy,
                                 norm=ops.norm_prologue(p["ln2"], cfg.norm),
                                 residual=x)
        else:
            h2 = ops.norm(x, p["ln2"], cfg.norm)
            x = x + mlp_mod.mlp_full(p["mlp"], h2, plan=plan, cfg=cfg,
                                     policy=policy)
    return x, (cache if with_cache else None), aux


def block_chunk(kind: str, p, x, pos0, chunk_len, cache, block_tables, *,
                plan: Plan, cfg, policy, rope_pos=None, tree_mask=None):
    """One chunked-prefill piece through a block whose KV cache is paged.

    x: [B, C, E] — C consecutive prompt tokens starting at absolute position
    `pos0` [B] (`chunk_len` [B] of them real).  Only full-context attention
    kinds support chunking (their KV lives in the block pool, which carries
    the chunk state between engine steps); SSM / sliding-window / cross-attn
    kinds have recurrent or ring state a partial prefill would corrupt — the
    runner gates on `ModelRunner.supports_chunked` and falls back to
    whole-prompt prefill.  MLP / MoE run the decode path on the flattened
    [B*C, E] token batch (identical per-token math); only attention needs
    the chunk structure.  Returns (x', updated cache).

    `rope_pos` / `tree_mask` flow to `attn_chunk_paged` for tree-speculative
    verify (logical-depth rotation + ancestor attention mask); both None on
    the plain chunked-prefill path."""
    assert kind in ATTN_KINDS and kind not in SSM_KINDS and kind != "dec", (
        f"chunked prefill unsupported for kind {kind!r}")
    B, C, E = x.shape
    new_cache = dict(cache)
    fused = block_fused(plan)
    moe_like = kind in MOE_KINDS

    kv_in = {k: cache[k] for k in ("k", "v", "ks", "vs") if k in cache}
    tree_kw = dict(rope_pos=rope_pos, tree_mask=tree_mask)
    y = None
    if fused and not moe_like:
        x, kv = attn.attn_chunk_paged(p["attn"], x, pos0, chunk_len, kv_in,
                                      block_tables, plan=plan, cfg=cfg,
                                      policy=policy,
                                      norm=ops.norm_prologue(p["ln1"],
                                                             cfg.norm),
                                      residual=x, **tree_kw)
    elif fused:
        y, kv = attn.attn_chunk_paged(p["attn"], x, pos0, chunk_len, kv_in,
                                      block_tables, plan=plan, cfg=cfg,
                                      policy=policy,
                                      norm=ops.norm_prologue(p["ln1"],
                                                             cfg.norm),
                                      **tree_kw)
    else:
        h = ops.norm(x, p["ln1"], cfg.norm)
        y, kv = attn.attn_chunk_paged(p["attn"], h, pos0, chunk_len, kv_in,
                                      block_tables, plan=plan, cfg=cfg,
                                      policy=policy, **tree_kw)
        x = x + y
        y = None
    new_cache.update(kv)

    if moe_like:
        if fused:
            h2, x = ops.residual_norm(x, y, p["ln2"], cfg.norm)
        else:
            h2 = ops.norm(x, p["ln2"], cfg.norm)
        y2, _ = mlp_mod.moe_decode(p["moe"], h2.reshape(B * C, E), plan=plan,
                                   cfg=cfg, policy=policy)
        x = x + y2.reshape(B, C, E)
    elif fused:
        xf = mlp_mod.mlp_decode(p["mlp"], x.reshape(B * C, E), plan=plan,
                                cfg=cfg, policy=policy,
                                norm=ops.norm_prologue(p["ln2"], cfg.norm),
                                residual=x.reshape(B * C, E))
        x = xf.reshape(B, C, E)
    else:
        h2 = ops.norm(x, p["ln2"], cfg.norm).reshape(B * C, E)
        y2 = mlp_mod.mlp_decode(p["mlp"], h2, plan=plan, cfg=cfg,
                                policy=policy)
        x = x + y2.reshape(B, C, E)
    return x, new_cache


def block_decode(kind: str, p, x, pos, cache, *, plan: Plan, cfg, policy,
                 memory_len: int = 0, block_tables=None, paged: bool = False):
    """x: [B, E]; pos: [B]; cache: this layer's cache dict.
    Returns (x', updated cache).

    `paged`: this kind's self-attention KV lives in a block pool
    ([NB, BS, KV, hd] leaves) addressed through `block_tables` [B, MB]
    (core/attention.attn_decode_paged); SSM state, ring caches and
    cross-attention memory are per-slot dense either way."""
    window = kind_window(kind, cfg)
    new_cache = dict(cache)
    fused = block_fused(plan)

    if kind == "ssm":
        h = ops.norm(x, p["ln1"], cfg.norm)
        y, sc = ssm_mod.ssm_decode(p["ssm"], h,
                                   {k: cache[k] for k in ("h", "cx", "cbc")},
                                   plan=plan, cfg=cfg, policy=policy)
        new_cache.update(sc)
        return x + y, new_cache

    hybrid = kind in ("hybrid_attn", "hybrid_local")
    moe_like = kind in MOE_KINDS
    kv_in = {k: cache[k] for k in ("k", "v", "ks", "vs") if k in cache}
    attn_fused = fused and not hybrid
    nspec = (ops.norm_prologue(p["ln1"], cfg.norm) if attn_fused else None)
    res = x if attn_fused and not moe_like else None
    h = None
    if attn_fused:
        q_in = x
    else:
        h = ops.norm(x, p["ln1"], cfg.norm)
        q_in = h
    if paged:
        y, kv = attn.attn_decode_paged(p["attn"], q_in, pos, kv_in,
                                       block_tables, plan=plan, cfg=cfg,
                                       policy=policy, norm=nspec,
                                       residual=res)
    else:
        y, kv = attn.attn_decode(p["attn"], q_in, pos, kv_in, plan=plan,
                                 cfg=cfg, policy=policy, window=window,
                                 norm=nspec, residual=res)
    new_cache.update(kv)
    if res is not None:         # y IS the updated stream
        x, y = y, None
    if hybrid:
        s, sc = ssm_mod.ssm_decode(p["ssm"], h,
                                   {k: cache[k] for k in ("h", "cx", "cbc")},
                                   plan=plan, cfg=cfg, policy=policy)
        y = (y + s) * 0.5
        new_cache.update(sc)
    if y is not None and not fused:
        x = x + y
        y = None
    # fused + (hybrid | moe): y pending for the residual_norm below

    if kind == "dec":
        if fused:
            x, _ = attn.attn_decode(p["xattn"], x, pos,
                                    {"k": cache["ck"], "v": cache["cv"]},
                                    plan=plan, cfg=cfg, policy=policy,
                                    window=0, cross=True,
                                    memory_len=memory_len,
                                    norm=ops.norm_prologue(p["lnx"],
                                                           cfg.norm),
                                    residual=x)
        else:
            hx = ops.norm(x, p["lnx"], cfg.norm)
            yx, _ = attn.attn_decode(p["xattn"], hx, pos,
                                     {"k": cache["ck"], "v": cache["cv"]},
                                     plan=plan, cfg=cfg, policy=policy,
                                     window=0, cross=True,
                                     memory_len=memory_len)
            x = x + yx

    if moe_like:
        if fused:
            h2, x = ops.residual_norm(x, y, p["ln2"], cfg.norm)
        else:
            h2 = ops.norm(x, p["ln2"], cfg.norm)
        y2, _ = mlp_mod.moe_decode(p["moe"], h2, plan=plan, cfg=cfg,
                                   policy=policy)
        x = x + y2
    elif kind in MLP_KINDS:
        if fused and hybrid:
            h2, x = ops.residual_norm(x, y, p["ln2"], cfg.norm)
            x = mlp_mod.mlp_decode(p["mlp"], h2, plan=plan, cfg=cfg,
                                   policy=policy, residual=x)
        elif fused:
            x = mlp_mod.mlp_decode(p["mlp"], x, plan=plan, cfg=cfg,
                                   policy=policy,
                                   norm=ops.norm_prologue(p["ln2"],
                                                          cfg.norm),
                                   residual=x)
        else:
            h2 = ops.norm(x, p["ln2"], cfg.norm)
            x = x + mlp_mod.mlp_decode(p["mlp"], h2, plan=plan, cfg=cfg,
                                       policy=policy)
    return x, new_cache
