"""Vocabulary-sharded embedding, distributed cross-entropy, greedy sampling.

Embedding table [Vp, E]: vocab sharded over tp, embed dim over fsdp — lookup
takes locally-owned rows and psums over tp (exactly one owner per id).

CE (paper T4 generalized): the unembedding is vocab-sharded; the [*, V]
logits are never gathered — only fp32 scalar statistics (max, sum-exp, label
logit) cross the wire, chunked over the local sequence under `lax.scan`
with rematerialization so no logits chunk survives to the backward pass.

Vocabularies are padded to multiples of 256 (configs.base.padded_vocab);
padded columns are masked to -inf everywhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import collectives as col
from repro.core.nn import act_dtype, fused_pdot, pdot
from repro.kernels import ops
from repro.sharding.plan import Plan

NEG_INF = -1e30


def embedding_param_shapes(cfg) -> dict:
    Vp, E = cfg.padded_vocab, cfg.d_model
    return {"embed": (Vp, E), "unemb": (E, Vp)}


def embedding_param_dims(cfg) -> dict:
    return {"embed": ("tp", "fsdp"), "unemb": ("fsdp", "tp")}


def init_embedding(key, cfg, dtype):
    shapes = embedding_param_shapes(cfg)
    k1, k2 = jax.random.split(key)
    return {"embed": (jax.random.normal(k1, shapes["embed"]) * 0.02
                      ).astype(dtype),
            "unemb": (jax.random.normal(k2, shapes["unemb"]) * 0.02
                      ).astype(dtype)}


def _owned_rows(emb, ids, plan: Plan, policy):
    """Rows for locally-owned vocab ids, zero elsewhere.  Gathers the table's
    fsdp-sharded embed dim first (weight gather — batch-independent)."""
    w = col.all_gather(emb, plan.fsdp_axes, axis=1)            # [Vp/tp, E]
    v_loc = w.shape[0]
    off = col.axis_index(plan.tp_axes) * v_loc
    idx = ids - off
    owned = (idx >= 0) & (idx < v_loc)
    rows = jnp.take(w, jnp.clip(idx, 0, v_loc - 1), axis=0)
    return jnp.where(owned[..., None], rows, 0).astype(act_dtype(policy))


def embed_sequence(emb, ids, *, plan: Plan, policy):
    """emb: local [Vp/tp, E/fsdp]; ids: [B, S_tot] — the FULL sequence.
    Returns [B, S_loc, E] sequence-sharded.

    Megatron-SP embedding: every tp peer computes the rows its vocab shard
    owns for the *whole* sequence (exactly one owner per id, so the combine
    is exact even in bf16), then one reduce-scatter both sums the vocab
    partials and lands the result sequence-sharded."""
    rows = _owned_rows(emb, ids, plan, policy)                 # [B, S_tot, E]
    return col.psum_scatter(rows, plan.tp_axes, scatter_dimension=1)


def embed_token(emb, ids, *, plan: Plan, policy):
    """ids: [B] (decode) -> [B, E] replicated over tp."""
    rows = _owned_rows(emb, ids, plan, policy)                 # [B, E]
    return col.psum(rows.astype(jnp.float32),
                    plan.tp_axes).astype(act_dtype(policy))


def _pick_chunk(n: int, target: int) -> int:
    c = min(target, n)
    while n % c:
        c -= 1
    return max(c, 1)


def ce_loss(x, unemb, labels, valid, *, plan: Plan, cfg, policy,
            chunk: int = 2048):
    """x: [B, S_loc, E] sequence-sharded; unemb: local [E/fsdp, Vp/tp];
    labels/valid: [B, S_tot] — FULL sequence (vocab-parallel CE needs every
    tp peer on the same positions).  Returns (loss_sum, token_count), both
    fp32, replicated over tp; caller psums over the batch axes only."""
    x = col.all_gather(x, plan.seq_axes, axis=1)               # [B, S_tot, E]
    B, T, E = x.shape
    w = col.all_gather(unemb, plan.fsdp_axes, axis=0)          # [E, Vp/tp]
    v_loc = w.shape[1]
    v0 = col.axis_index(plan.tp_axes) * v_loc
    col_real = (jnp.arange(v_loc)[None, None, :] + v0) < cfg.vocab

    tc = _pick_chunk(T, chunk)
    nc = T // tc
    xs = (x.reshape(B, nc, tc, E).swapaxes(0, 1),
          labels.reshape(B, nc, tc).swapaxes(0, 1),
          valid.reshape(B, nc, tc).swapaxes(0, 1))

    def body(carry, inp):
        xc, lc, mc = inp
        with jax.named_scope("ce_f32"):
            z = pdot(xc, w, policy, out_dtype=jnp.float32)     # [B, tc, Vl]
        z = jnp.where(col_real, z, NEG_INF)
        m = z.max(axis=-1)
        # stabilizer only — exact lse gradient doesn't depend on it
        m_all = col.pmax(jax.lax.stop_gradient(m), plan.tp_axes)
        se = jnp.exp(z - m_all[..., None]).sum(-1)
        se_all = col.psum(se, plan.tp_axes)
        lse = m_all + jnp.log(se_all)
        lidx = lc - v0
        own = (lidx >= 0) & (lidx < v_loc)
        lab = jnp.take_along_axis(
            z, jnp.clip(lidx, 0, v_loc - 1)[..., None], axis=-1)[..., 0]
        lab = jnp.where(own, lab, 0.0)
        lab_all = col.psum(lab, plan.tp_axes)
        loss = jnp.where(mc, lse - lab_all, 0.0).sum()
        return carry + loss, None

    total, _ = jax.lax.scan(jax.checkpoint(body),
                            jnp.zeros((), jnp.float32), xs)
    count = valid.sum().astype(jnp.float32)
    return total, count


def logits_local(x, unemb, *, plan: Plan, cfg, policy, norm=None):
    """x: [B, E] -> (z [B, Vp/tp] fp32 with padded cols masked, v0).

    `norm` (kernels.epilogue.Prologue, optional): the model's final norm
    fused into the logits GEMM — x arrives as the raw residual and the
    normalization happens in-register ahead of the contraction.

    A weight-only-int8 head ({"q", "scale"}, models/quantize) gathers the
    int8 tensor over fsdp (the E contraction dim); the per-vocab-column
    scale is already tp-local and passes straight to the GEMM."""
    q, scale = ops.split_quantized(unemb)
    w = col.all_gather(q, plan.fsdp_axes, axis=0)
    if scale is not None:
        w = {"q": w, "scale": scale}
    v_loc = q.shape[1]
    v0 = col.axis_index(plan.tp_axes) * v_loc
    with jax.named_scope("ce_f32"):
        z = fused_pdot(x, w, policy, prologue=norm, out_dtype=jnp.float32)
    z = jnp.where((jnp.arange(v_loc)[None, :] + v0) < cfg.vocab, z, NEG_INF)
    return z, v0


def greedy_token(x, unemb, *, plan: Plan, cfg, policy, norm=None):
    """x: [B, E] -> next token ids [B] (global argmax over sharded vocab)."""
    z, v0 = logits_local(x, unemb, plan=plan, cfg=cfg, policy=policy,
                         norm=norm)
    _, tok = col.pargmax(z, plan.tp_axes, index_offset=v0)
    return tok


TOP_K_CAP = 64      # distributed top-k threshold search depth per tp shard


def sample_token(x, unemb, lane, *, plan: Plan, cfg, policy, norm=None):
    """x: [B, E] -> next token ids [B], sampled per row from softmax(z/T)
    with optional top-k truncation — all over the tp-sharded vocab, the
    logits never gathered.

    `lane` carries the per-row sampling state (all [B]):
      temperature  f32; rows with temperature <= 0 take the exact greedy path
      top_k        i32; 0 disables truncation (clamped to TOP_K_CAP)
      seed         i32; the request's RNG lane
      step         i32; the global position the sampled token will occupy

    Sampling is Gumbel-max — argmax(z/T + g) with g ~ Gumbel(0,1) — so the
    draw reuses the same distributed argmax as greedy decoding (pargmax over
    the vocab shards) instead of materializing a gathered distribution.  The
    top-k threshold is exact for k <= TOP_K_CAP (larger k clamps): each
    shard contributes its local top-TOP_K_CAP, so the union — gathered as
    O(tp*TOP_K_CAP) floats — is guaranteed to contain the global k-th
    largest logit only up to k = TOP_K_CAP, and k is clamped there.
    Noise keys fold (seed, step, shard) so a (seed, position) pair maps to
    one reproducible draw regardless of batch slot or engine schedule.
    `norm`: final-norm prologue fused into the logits GEMM (logits_local)."""
    z, v0 = logits_local(x, unemb, plan=plan, cfg=cfg, policy=policy,
                         norm=norm)
    score = _lane_scores(z, lane, plan=plan)
    _, tok = col.pargmax(score, plan.tp_axes, index_offset=v0)
    return tok


def _lane_scores(z, lane, *, plan: Plan):
    """The deterministic per-row sampling scores whose argmax IS the
    sampled token: greedy rows (temperature <= 0) keep the raw logits,
    sampled rows get top-k-masked, temperature-scaled, (seed, step)-keyed
    Gumbel-perturbed logits.  Shared verbatim by `sample_token` (argmax)
    and `sample_topn` (argmax + runners-up) so the two can never drift."""
    B, v_loc = z.shape
    t = lane["temperature"].astype(jnp.float32)
    k = lane["top_k"].astype(jnp.int32)
    sampled = t > 0.0

    kcap = min(TOP_K_CAP, v_loc)
    loc_top = jax.lax.top_k(z, kcap)[0]                      # [B, kcap] desc
    glob_top = col.all_gather(loc_top, plan.tp_axes, axis=-1)
    glob_top = -jnp.sort(-glob_top, axis=-1)                 # [B, tp*kcap]
    # the union holds the global k-th largest only for k <= kcap — unless
    # each shard contributed its ENTIRE local vocab, making the union the
    # full logit set and any k exact
    k_max = glob_top.shape[-1] if kcap == v_loc else kcap
    kth = jnp.clip(k, 1, k_max) - 1
    thresh = jnp.take_along_axis(glob_top, kth[:, None], axis=-1)
    keep = (k[:, None] <= 0) | (z >= thresh)

    shard = col.axis_index(plan.tp_axes)

    def gumbel_row(seed, step):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(seed), step), shard)
        return jax.random.gumbel(key, (v_loc,), jnp.float32)

    g = jax.vmap(gumbel_row)(lane["seed"], lane["step"])     # [B, v_loc]
    t_safe = jnp.where(sampled, jnp.maximum(t, 1e-6), 1.0)
    return jnp.where(sampled[:, None],
                     jnp.where(keep, z, NEG_INF) / t_safe[:, None] + g,
                     z)                                      # greedy rows: raw z


def sample_topn(x, unemb, lane, n, *, plan: Plan, cfg, policy, norm=None):
    """`sample_token` plus the score's runners-up: the tree-speculation
    proposer.  x: [B, E] -> (tok [B], alts [B, n]) with alts[:, 0] == tok
    (the chain token — bit-identical to what sample_token returns for the
    same (residual, lane)) and alts[:, 1:] the next-best distinct global
    ids of the SAME deterministic score, ranked value-descending with
    lowest-id tie-breaks (pargmax's rule).  Distributed like the top-k
    threshold search: each tp shard contributes its local top-(n-1), the
    O(tp*n) union is gathered, never the logits.  Rows whose top-k
    truncation keeps fewer than n ids pad with NEG_INF-scored ids — the
    verifier rejects them like any wrong guess, costing acceptance only."""
    z, v0 = logits_local(x, unemb, plan=plan, cfg=cfg, policy=policy,
                         norm=norm)
    score = _lane_scores(z, lane, plan=plan)
    _, tok = col.pargmax(score, plan.tp_axes, index_offset=v0)
    if n == 1:
        return tok, tok[:, None]
    B, v_loc = z.shape
    gid = jnp.arange(v_loc)[None, :] + v0                    # [B?, v_loc]
    rest = jnp.where(gid == tok[:, None], NEG_INF, score)
    vals, idx = jax.lax.top_k(rest, min(n - 1, v_loc))       # [B, n-1] desc
    ids = idx + v0
    vals_g = col.all_gather(vals, plan.tp_axes, axis=-1)
    ids_g = col.all_gather(ids, plan.tp_axes, axis=-1)
    # value-descending, id-ascending on ties (jnp.lexsort: last key primary)
    order = jnp.lexsort((ids_g, -vals_g), axis=-1)
    top_ids = jnp.take_along_axis(ids_g, order, axis=-1)[:, :n - 1]
    return tok, jnp.concatenate([tok[:, None], top_ids], axis=1)
