"""Distributed Softmax primitives (paper T4 / title claim).

Two cross-device online-softmax features built on one merge rule:

1. `merge_partials` — combine per-shard flash partials (o, m, l):
       m* = max_i m_i;  l* = sum_i l_i e^{m_i - m*};  o* = sum_i o_i e^{m_i-m*} / l*
   Used by the sequence-sharded KV-cache decode path: each device attends
   its cache chunk, partials meet over the tp axis (the paper distributes
   exactly these row statistics across clusters).

2. `distributed_cross_entropy` — vocabulary-sharded stable log-softmax CE:
   the logits all-gather never happens; only the scalar statistics cross
   the wire (max + sum-exp + the label logit).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import collectives as col

from repro.sharding.context import get_ctx

NEG_INF = -1e30


def merge_partials(o, m, l, axis_name: str):
    """o: [..., D] partial unnormalized output; m, l: [...] running max /
    sum-exp.  All shards return the merged, normalized output."""
    m_all = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_all)
    l_all = jax.lax.psum(l * corr, axis_name)
    o_all = jax.lax.psum(o * corr[..., None], axis_name)
    return o_all / jnp.maximum(l_all, 1e-30)[..., None]


def local_decode_partials(q, k_loc, v_loc, valid, *, sm_scale):
    """One-token attention over a local cache chunk -> (o, m, l) partials.

    q: [B, H, D] fp-any; k_loc/v_loc: [B, Sl, KV, D]; valid: [B, Sl] bool.
    fp32 statistics (paper invariant)."""
    B, H, D = q.shape
    KV = k_loc.shape[2]
    G = H // KV
    qf = (q.astype(jnp.float32) * sm_scale).reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k_loc.astype(jnp.float32))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)                                   # [B, KV, G]
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_loc.astype(jnp.float32))
    return o.reshape(B, H, D), m.reshape(B, H), l.reshape(B, H)


def distributed_decode_attention(q, k_cache, v_cache, pos, *, window=0,
                                 out_dtype=None):
    """Decode attention over a sequence-sharded KV cache (tp axis shards S).

    q: [B, H, D]; caches: [B, S, KV, D] (S sharded over tp); pos: [B] int32 —
    index of the *current* token (cache entries 0..pos are valid).
    Degrades to single-shard attention when no mesh."""
    ctx = get_ctx()
    out_dtype = out_dtype or q.dtype
    B, H, D = q.shape
    S = k_cache.shape[1]
    sm_scale = float(1.0 / (D ** 0.5))
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))

    def local(q, k_loc, v_loc, pos, s0):
        Sl = k_loc.shape[1]
        idx = jnp.arange(Sl)[None, :] + s0
        valid = idx <= pos[:, None]
        if window > 0:
            valid &= idx > pos[:, None] - window
        return local_decode_partials(q, k_loc, v_loc, valid,
                                     sm_scale=sm_scale)

    if ctx.mesh is None or ctx.tp == 1:
        o, m, l = local(q, k_cache, v_cache, pos, 0)
        return (o / jnp.maximum(l, 1e-30)[..., None]).astype(out_dtype)

    tp_axis = ctx.axis_names("tp")[0]
    dp_spec = ctx.pspec("dp")[0]

    def inner(q, k_loc, v_loc, pos):
        n = col.one_axis_size(tp_axis)
        i = jax.lax.axis_index(tp_axis)
        s0 = i * (S // n)
        o, m, l = local(q, k_loc, v_loc, pos, s0)
        merged = merge_partials(o, m, l, tp_axis)
        return merged.astype(out_dtype)

    return col.shard_map(
        inner, mesh=ctx.mesh,
        in_specs=(P(dp_spec, None, None), P(dp_spec, tp_axis, None, None),
                  P(dp_spec, tp_axis, None, None), P(dp_spec)),
        out_specs=P(dp_spec, None, None),
    )(q, k_cache, v_cache, pos)


def distributed_cross_entropy(x, unemb, labels, *, mask=None, chunk=1024,
                              logit_dtype=jnp.float32):
    """Mean CE over tokens with the vocabulary sharded over tp.

    x: [B, T, E] (residual stream, sequence-sharded is fine — the shard_map
    runs over tp with x's sequence gathered chunk-by-chunk);
    unemb: [E, V] sharded (fsdp, tp); labels: [B, T] int32.
    Returns (mean_loss, n_tokens).  Never materializes [B, T, V] at once:
    iterates sequence chunks of `chunk` tokens."""
    ctx = get_ctx()
    B, T, E = x.shape
    V = unemb.shape[1]
    if mask is None:
        mask = jnp.ones((B, T), bool)

    def ce_of_chunk(xc, lc, mc, w, v0):
        # xc: [B, C, E]; w: [E, Vl]; v0: local vocab offset
        z = jax.lax.dot_general(xc, w, (((2,), (0,)), ((), ())),
                                preferred_element_type=logit_dtype)
        m_loc = z.max(axis=-1)
        lse_loc_m = m_loc
        # label logit if owned by this shard
        owned = (lc >= v0) & (lc < v0 + w.shape[1])
        lidx = jnp.clip(lc - v0, 0, w.shape[1] - 1)
        lab = jnp.take_along_axis(z, lidx[..., None], axis=-1)[..., 0]
        lab = jnp.where(owned, lab, 0.0)
        return z, lse_loc_m, lab

    if ctx.mesh is None or ctx.tp == 1:
        def body(carry, xs):
            xc, lc, mc = xs
            z, _, _ = ce_of_chunk(xc, lc, mc, unemb, 0)
            lse = jax.nn.logsumexp(z, axis=-1)
            lab = jnp.take_along_axis(z, lc[..., None], axis=-1)[..., 0]
            loss = jnp.where(mc, lse - lab, 0.0).sum()
            return carry + loss, None

        nchunk = max(1, T // min(chunk, T))
        Tc = T // nchunk
        assert T % nchunk == 0, (T, nchunk)
        xs = (x.reshape(B, nchunk, Tc, E).swapaxes(0, 1),
              labels.reshape(B, nchunk, Tc).swapaxes(0, 1),
              mask.reshape(B, nchunk, Tc).swapaxes(0, 1))
        total, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32), xs)
        n = jnp.maximum(mask.sum(), 1)
        return total / n, n

    tp_axis = ctx.axis_names("tp")[0]
    dp_spec = ctx.pspec("dp")[0]
    fsdp_axis = (ctx.axis_names("fsdp") or (None,))[0]

    def inner(x, labels, mask, w):
        # w arrives (E, V/tp) but still sharded over fsdp on E -> gather it
        if fsdp_axis is not None:
            w = jax.lax.all_gather(w, fsdp_axis, axis=0, tiled=True)
        n = col.one_axis_size(tp_axis)
        i = jax.lax.axis_index(tp_axis)
        v0 = i * (V // n)

        def body(carry, xs):
            xc, lc, mc = xs
            z, m_loc, lab = ce_of_chunk(xc, lc, mc, w, v0)
            m_all = jax.lax.pmax(m_loc, tp_axis)
            se = jnp.exp(z - m_all[..., None]).sum(-1)
            se_all = jax.lax.psum(se, tp_axis)
            lab_all = jax.lax.psum(lab, tp_axis)
            lse = m_all + jnp.log(se_all)
            loss = jnp.where(mc, lse - lab_all, 0.0).sum()
            return carry + loss, None

        Bl, Tl = labels.shape
        nchunk = max(1, Tl // max(1, min(chunk, Tl)))
        Tc = Tl // nchunk
        xs = (x.reshape(Bl, nchunk, Tc, E).swapaxes(0, 1),
              labels.reshape(Bl, nchunk, Tc).swapaxes(0, 1),
              mask.reshape(Bl, nchunk, Tc).swapaxes(0, 1))
        total, _ = jax.lax.scan(jax.checkpoint(body),
                                jnp.zeros((), jnp.float32), xs)
        return total[None]

    totals = col.shard_map(
        inner, mesh=ctx.mesh,
        in_specs=(P(dp_spec, None, None), P(dp_spec, None), P(dp_spec, None),
                  P(fsdp_axis, tp_axis)),
        out_specs=P(dp_spec),
    )(x, labels, mask, unemb)
    total = totals.sum()
    n = jnp.maximum(mask.sum(), 1)
    return total / n, n
