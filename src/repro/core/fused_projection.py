"""Fused head-concat + output projection (paper T3) — first-class feature.

The paper computes the MHA output projection on per-cluster head shards
(K-dim spatial tiling of the GEMM) and combines the partial S x E tiles with
a logarithmic cluster-to-cluster reduction, never materializing the
concatenated head tensor in main memory.

TPU form: the contraction input lives head-sharded (or d_ff-sharded) over
the `tp` axis; each device contracts its local shard against its weight
slice and the partial outputs are combined with
  * ``reduce_scatter``  — psum_scatter over tp, output lands sequence-sharded
                          (Megatron-SP style; XLA lowers to ICI reduce-scatter)
  * ``tree``            — the paper's literal binary-tree schedule
                          (core.tree_reduce, recursive halving)
  * ``all_reduce``      — plain psum (the unfused upper bound; baseline)

Runs inside shard_map over the tp axis; degrades to a plain matmul when the
tp axis is absent/size-1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import collectives as col

from repro.core.tree_reduce import tree_psum_scatter
from repro.sharding.context import get_ctx


def _local_contract(x, w, accum_dtype=jnp.float32):
    """x: [..., Kl], w: [Kl, N] -> [..., N] partial (fp32 accum)."""
    y = jax.lax.dot_general(x, w, (((x.ndim - 1,), (0,)), ((), ())),
                            preferred_element_type=accum_dtype)
    return y


def fused_output_projection(x, w, *, method: str = "reduce_scatter",
                            seq_dim: int = 1, out_dtype=None):
    """y = concat_heads(x) @ w with the concat never materialized.

    x: [B, S, K] where K (= H*hd or d_ff) is logically sharded over tp;
    w: [K, E] sharded over tp on dim 0.  Returns y sequence-sharded over tp
    (spec: (dp, sp, None)) — the residual-stream layout.
    """
    ctx = get_ctx()
    out_dtype = out_dtype or x.dtype
    if ctx.mesh is None or ctx.tp == 1:
        y = _local_contract(x, w)
        return y.astype(out_dtype)

    tp_axes = ctx.axis_names("tp")
    tp_axis = tp_axes[0]
    dp_spec = ctx.pspec("dp")[0]

    def inner(xl, wl):
        part = _local_contract(xl, wl)          # [B, Sl(=S), E] partial
        if method == "all_reduce":
            full = jax.lax.psum(part, tp_axis)
            # slice this device's sequence chunk to land in (dp, sp, None)
            n = col.one_axis_size(tp_axis)
            idx = jax.lax.axis_index(tp_axis)
            chunk = part.shape[seq_dim] // n
            y = jax.lax.dynamic_slice_in_dim(full, idx * chunk, chunk, seq_dim)
        elif method == "reduce_scatter":
            y = jax.lax.psum_scatter(part, tp_axis, scatter_dimension=seq_dim,
                                     tiled=True)
        elif method == "tree":
            y = tree_psum_scatter(part, tp_axis, scatter_dim=seq_dim)
        else:
            raise ValueError(method)
        return y.astype(out_dtype)

    in_specs = (P(dp_spec, None, tp_axis), P(tp_axis, None))
    out_specs = P(dp_spec, tp_axis, None)
    return col.shard_map(inner, mesh=ctx.mesh, in_specs=in_specs,
                         out_specs=out_specs)(x, w)
