"""Rotary position embeddings (+ sinusoidal absolute for whisper/vit).

`rope_fraction` < 1 rotates only the leading fraction of head dims
(chatglm3's 2d-RoPE rotates half).  Positions are supplied explicitly so
sequence-parallel shards and decode steps rotate correctly.
"""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, fraction: float, theta: float):
    rot = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, *, theta: float, fraction: float = 1.0):
    """x: [B, S, H, D]; positions: [S] or [B, S] int32."""
    if theta <= 0:
        return x
    D = x.shape[-1]
    inv, rot = rope_freqs(D, fraction, theta)
    if rot == 0:
        return x
    pos = jnp.asarray(positions, jnp.float32)
    if pos.ndim == 1:
        ang = pos[:, None] * inv[None, :]              # [S, rot/2]
        ang = ang[None, :, None, :]                    # [1, S, 1, rot/2]
    else:
        ang = pos[:, :, None] * inv[None, None, :]     # [B, S, rot/2]
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    rotated = jnp.stack([r1, r2], axis=-1).reshape(x[..., :rot].shape)
    return jnp.concatenate(
        [rotated.astype(x.dtype), x[..., rot:]], axis=-1)


def sinusoidal_positions(n: int, d: int, offset=0):
    """Classic transformer sinusoidal table [n, d] (whisper-style)."""
    pos = jnp.arange(n, dtype=jnp.float32) + offset
    half = d // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                   / max(half - 1, 1))
    ang = pos[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:, :d]
