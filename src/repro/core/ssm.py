"""Mamba2 SSD block in manual-SPMD form (the attention-free arch's analogue
of the paper's flash/fusion stack — DESIGN.md §5).

The SSD head dimension is a pure batch dimension of the state recurrence, so
heads shard freely over tp without collectives; the out-projection produces
tp-partials that reduce-scatter back to the sequence-sharded residual — the
paper's fused-projection tree reduction (T3) applies unchanged to SSM heads.

Head padding: architectures whose head count doesn't divide the 16-way model
axis (hymba: 50 -> 64) run with padded heads whose out-projection rows are
zero — output-exact, noted in DESIGN.md §5.  The gated-RMSNorm statistics
mask the padded dims.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import collectives as col
from repro.core.nn import act_dtype, gather_w, pdot
from repro.kernels import ops
from repro.kernels.epilogue import RMS_EPS
from repro.sharding.plan import Plan

TP_PAD = 16     # heads padded to multiples of this (= production model axis)


def _dims(cfg):
    Hp = cfg.padded_ssm_heads(TP_PAD)
    P = cfg.ssm_head_dim
    return Hp, P, Hp * P, cfg.ssm_state, cfg.conv_width


def ssm_param_shapes(cfg) -> dict:
    E = cfg.d_model
    Hp, P, dip, N, cw = _dims(cfg)
    return {
        "w_x": (E, dip), "w_z": (E, dip), "w_bc": (E, 2 * N),
        "w_dt": (E, Hp), "dt_bias": (Hp,), "a_log": (Hp,), "d_skip": (Hp,),
        "conv_x": (cw, dip), "conv_bc": (cw, 2 * N),
        "norm_scale": (dip,), "w_out": (dip, E),
    }


def ssm_param_dims(cfg) -> dict:
    return {
        "w_x": ("fsdp", "tp"), "w_z": ("fsdp", "tp"), "w_bc": ("fsdp", None),
        "w_dt": ("fsdp", "tp"), "dt_bias": ("tp",), "a_log": ("tp",),
        "d_skip": ("tp",),
        "conv_x": (None, "tp"), "conv_bc": (None, None),
        "norm_scale": ("tp",), "w_out": ("tp", "fsdp"),
    }


def init_ssm(key, cfg, dtype):
    E = cfg.d_model
    Hp, P, dip, N, cw = _dims(cfg)
    real_dip = cfg.ssm_heads * P
    ks = jax.random.split(key, 8)
    w_out = (jax.random.normal(ks[0], (dip, E)) * 0.02)
    if real_dip < dip:          # zero pad-head rows => output exact
        w_out = w_out.at[real_dip:].set(0.0)
    # dt in [1e-3, 0.1] at init (standard mamba)
    dt = jnp.exp(jax.random.uniform(ks[1], (Hp,),
                                    minval=jnp.log(1e-3), maxval=jnp.log(0.1)))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))      # inverse softplus
    a_log = jnp.log(jax.random.uniform(ks[2], (Hp,), minval=1.0, maxval=16.0))
    return {
        "w_x": (jax.random.normal(ks[3], (E, dip)) * 0.02).astype(dtype),
        "w_z": (jax.random.normal(ks[4], (E, dip)) * 0.02).astype(dtype),
        "w_bc": (jax.random.normal(ks[5], (E, 2 * N)) * 0.02).astype(dtype),
        "w_dt": (jax.random.normal(ks[6], (E, Hp)) * 0.02).astype(dtype),
        "dt_bias": dt_bias.astype(dtype),
        "a_log": a_log.astype(dtype),
        "d_skip": jnp.ones((Hp,), dtype),
        "conv_x": (jax.random.normal(ks[7], (cw, dip)) * 0.1).astype(dtype),
        "conv_bc": jnp.zeros((cw, 2 * N), dtype).at[-1].set(1.0),
        "norm_scale": jnp.ones((dip,), dtype),
        "w_out": w_out.astype(dtype),
    }


def _causal_conv(x, w):
    """Depthwise causal conv.  x: [B, S, D]; w: [cw, D]."""
    cw = w.shape[0]
    S = x.shape[1]
    xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    wf = w.astype(jnp.float32)
    y = sum(xp[:, j:j + S].astype(jnp.float32) * wf[j] for j in range(cw))
    return jax.nn.silu(y).astype(x.dtype)


def _conv_step(x_t, state, w):
    """x_t: [B, D]; state: [B, cw-1, D] (previous inputs).  Returns
    (y_t [B, D], new_state)."""
    window = jnp.concatenate([state, x_t[:, None]], axis=1)      # [B, cw, D]
    y = jnp.einsum("bcd,cd->bd", window.astype(jnp.float32),
                   w.astype(jnp.float32))
    return jax.nn.silu(y).astype(x_t.dtype), window[:, 1:]


def _masked_rmsnorm(y, z, scale, plan: Plan, real_dip: int, *, eps=RMS_EPS):
    """Gated RMSNorm over the (tp-sharded, possibly padded) d_inner dim:
    y <- rmsnorm(y * silu(z)) * scale with statistics over real dims only,
    psum'd across tp shards."""
    dip_loc = y.shape[-1]
    start = col.axis_index(plan.tp_axes) * dip_loc
    real = (jnp.arange(dip_loc) + start) < real_dip
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    g = jnp.where(real, g, 0.0)
    ssq = col.psum(jnp.sum(g * g, axis=-1, keepdims=True), plan.tp_axes)
    var = ssq / real_dip
    out = g * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(y.dtype)


def _shard_state_scan(D, h, axes):
    """Exclusive associative scan of the SSD state recurrence across seq
    shards (beyond-paper, §Perf P2).

    Per shard: h_out = D * h_in + h_local, where D [B, H] is the shard's
    total decay and h_local [B, H, P, N] its zero-init state.  The combine
    op((Da,ha),(Db,hb)) = (Da*Db, Db*ha + hb) is associative, so a
    Hillis-Steele scan over the (single) seq axis costs log2(n) ppermutes of
    a few MB — replacing the full-sequence all-gather.  Returns h_in."""
    if not axes:
        return jnp.zeros_like(h)
    assert len(axes) == 1, "seq-parallel SSD expects one mesh axis"
    axis = axes[0]
    n = col.one_axis_size(axis)
    idx = jax.lax.axis_index(axis)
    Dc, hc = D, h                         # running inclusive scan
    k = 1
    while k < n:
        perm = [(i, i + k) for i in range(n - k)]
        D_l = jax.lax.ppermute(Dc, axis, perm)     # from idx-k (0 at edges)
        h_l = jax.lax.ppermute(hc, axis, perm)
        take = idx >= k
        # combine(left, self): D = D_l*D_self; h = D_self ⊙ h_l + h_self
        h_new = Dc[..., None, None] * h_l + hc
        D_new = D_l * Dc
        Dc = jnp.where(take, D_new, Dc)
        hc = jnp.where(take, h_new, hc)
        k *= 2
    # exclusive: shift the inclusive scan right by one shard
    return jax.lax.ppermute(hc, axis, [(i, i + 1) for i in range(n - 1)])


def ssm_full(p, x, *, plan: Plan, cfg, policy, with_cache: bool = False):
    """x: [B, S_loc, E] sequence-sharded.  Returns (y [B, S_loc, E],
    cache | None) where cache = {"h", "cx", "cbc"} local shards."""
    if plan.ssm_seq_parallel and plan.sp > 1:
        return _ssm_full_seqp(p, x, plan=plan, cfg=cfg, policy=policy,
                              with_cache=with_cache)
    Hp, P, dip, N, cw = _dims(cfg)
    tp = plan.tp
    H_loc, dip_loc = Hp // tp, dip // tp
    ad = act_dtype(policy)

    x_full = col.all_gather(x, plan.seq_axes, axis=1)            # [B, S, E]
    B, S, E = x_full.shape

    xs_raw = pdot(x_full, gather_w(p["w_x"], plan), policy)      # [B,S,dip/tp]
    z = pdot(x_full, gather_w(p["w_z"], plan), policy)
    bc_raw = pdot(x_full, gather_w(p["w_bc"], plan), policy)     # [B,S,2N]
    dt_raw = pdot(x_full, gather_w(p["w_dt"], plan), policy,
                  out_dtype=jnp.float32)

    xs = _causal_conv(xs_raw, p["conv_x"])
    bc = _causal_conv(bc_raw, p["conv_bc"])
    Bm, Cm = bc[..., :N], bc[..., N:]
    dt = jax.nn.softplus(dt_raw + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))                 # [H_loc]

    y, h = ops.ssd(xs.reshape(B, S, H_loc, P).astype(ad), dt, A,
                   Bm.astype(ad), Cm.astype(ad),
                   p["d_skip"].astype(jnp.float32))
    y = y.reshape(B, S, dip_loc)

    y = _masked_rmsnorm(y, z, p["norm_scale"], plan,
                        real_dip=cfg.ssm_heads * P)
    part = pdot(y, gather_w(p["w_out"], plan, fsdp_dim=1), policy)
    out = col.psum_scatter(part, plan.tp_axes, scatter_dimension=1)   # T3

    cache = None
    if with_cache:
        # conv state = the last cw-1 *pre-conv* inputs of each stream
        cache = {"h": h.astype(jnp.float32),                     # [B,H_loc,P,N]
                 "cx": xs_raw[:, S - (cw - 1):].astype(ad),
                 "cbc": bc_raw[:, S - (cw - 1):].astype(ad)}
    return out, cache


def _ssm_full_seqp(p, x, *, plan: Plan, cfg, policy, with_cache: bool):
    """Sequence-parallel SSD (beyond-paper, §Perf P2).

    x stays sequence-sharded: every shard computes ALL heads over its local
    chunk (weights un-sharded at use — tens of MB), the state recurrence
    crosses shards via `_shard_state_scan` (log2(sp) ppermutes of [B,Hp,P,N]
    states), the boundary conv taps come from one neighbour ppermute, and
    the out-projection needs NO collective (full d_inner locally).  Replaces
    ~650 MB/layer of all-gather + reduce-scatter with ~25 MB/layer."""
    Hp, P, dip, N, cw = _dims(cfg)
    ad = act_dtype(policy)
    B, S_loc, E = x.shape
    sp_ax = plan.seq_axes
    sp = plan.sp
    idx = col.axis_index(sp_ax)

    w_x = gather_w(p["w_x"], plan, tp_dim=1)         # full [E, dip]
    w_z = gather_w(p["w_z"], plan, tp_dim=1)
    w_bc = gather_w(p["w_bc"], plan)
    w_dt = gather_w(p["w_dt"], plan, tp_dim=1)       # full [E, Hp]
    dt_bias = col.all_gather(p["dt_bias"], plan.tp_axes, axis=0)
    a_log = col.all_gather(p["a_log"], plan.tp_axes, axis=0)
    d_skip = col.all_gather(p["d_skip"], plan.tp_axes, axis=0)
    conv_x = col.all_gather(p["conv_x"], plan.tp_axes, axis=1)
    norm_scale = col.all_gather(p["norm_scale"], plan.tp_axes, axis=0)
    w_out = gather_w(p["w_out"], plan, fsdp_dim=1, tp_dim=0)   # [dip, E]

    xs_raw = pdot(x, w_x, policy)                    # [B, S_loc, dip]
    z = pdot(x, w_z, policy)
    bc_raw = pdot(x, w_bc, policy)                   # [B, S_loc, 2N]
    dt_raw = pdot(x, w_dt, policy, out_dtype=jnp.float32)

    # boundary conv: prepend the left neighbour's last cw-1 raw inputs
    def conv_with_halo(raw, w):
        tail = raw[:, S_loc - (cw - 1):]
        halo = jax.lax.ppermute(tail, sp_ax[0],
                                [(i, i + 1) for i in range(sp - 1)])
        ext = jnp.concatenate([halo, raw], axis=1)   # [B, S_loc+cw-1, D]
        return _causal_conv(ext, w)[:, cw - 1:]
    xs = conv_with_halo(xs_raw, conv_x)
    bc = conv_with_halo(bc_raw, p["conv_bc"])
    Bm, Cm = bc[..., :N], bc[..., N:]
    dt = jax.nn.softplus(dt_raw + dt_bias.astype(jnp.float32))
    A = -jnp.exp(a_log.astype(jnp.float32))          # [Hp]

    # local SSD with zero inbound state + cross-shard state composition
    y, h_local = ops.ssd(xs.reshape(B, S_loc, Hp, P).astype(ad), dt, A,
                         Bm.astype(ad), Cm.astype(ad),
                         d_skip.astype(jnp.float32))
    cum = jnp.cumsum(dt * A[None, None, :], axis=1)  # [B, S_loc, Hp]
    D_shard = jnp.exp(cum[:, -1])                    # [B, Hp]
    h_in = _shard_state_scan(D_shard, h_local, sp_ax)
    # inbound-state contribution: y_t += C_t . (exp(cum_t) * h_in)
    y = y + jnp.einsum("bln,blh,bhpn->blhp", Cm.astype(jnp.float32),
                       jnp.exp(cum), h_in).astype(y.dtype)
    y = y.reshape(B, S_loc, dip)

    # gated norm (full dip locally -> plain, unmasked-psum-free stats over
    # real dims only)
    real = jnp.arange(dip) < cfg.ssm_heads * P
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    g = jnp.where(real, g, 0.0)
    var = jnp.sum(g * g, axis=-1, keepdims=True) / (cfg.ssm_heads * P)
    y = (g * jax.lax.rsqrt(var + RMS_EPS)
         * norm_scale.astype(jnp.float32)).astype(ad)

    out = pdot(y, w_out, policy)                     # stays seq-sharded

    cache = None
    if with_cache:
        # final state (decode layout: heads tp-sharded): last shard owns the
        # inclusive total; broadcast and slice this device's head range
        h_tot = D_shard[..., None, None] * h_in + h_local
        last = jnp.where(idx == sp - 1, 1.0, 0.0).astype(jnp.float32)
        h_fin = col.psum(h_tot * last, sp_ax)
        tail_x = col.psum(xs_raw[:, S_loc - (cw - 1):].astype(jnp.float32)
                          * last, sp_ax)
        tail_bc = col.psum(bc_raw[:, S_loc - (cw - 1):].astype(jnp.float32)
                           * last, sp_ax)
        tp_i = col.axis_index(plan.tp_axes)
        H_loc = Hp // plan.tp
        dip_loc = dip // plan.tp
        cache = {
            "h": jax.lax.dynamic_slice_in_dim(h_fin, tp_i * H_loc, H_loc,
                                              axis=1),
            "cx": jax.lax.dynamic_slice_in_dim(
                tail_x, tp_i * dip_loc, dip_loc, axis=2).astype(ad),
            "cbc": tail_bc.astype(ad),
        }
    return out, cache


def ssm_decode(p, x, cache, *, plan: Plan, cfg, policy):
    """One decode step.  x: [B, E]; cache: {"h","cx","cbc"} local shards.
    Returns (y [B, E], updated cache)."""
    Hp, P, dip, N, cw = _dims(cfg)
    tp = plan.tp
    H_loc = Hp // tp
    ad = act_dtype(policy)
    B = x.shape[0]

    xs = pdot(x, gather_w(p["w_x"], plan), policy)               # [B, dip/tp]
    z = pdot(x, gather_w(p["w_z"], plan), policy)
    bc = pdot(x, gather_w(p["w_bc"], plan), policy)
    dt_raw = pdot(x, gather_w(p["w_dt"], plan), policy,
                  out_dtype=jnp.float32)

    xs, cx = _conv_step(xs, cache["cx"], p["conv_x"])
    bc, cbc = _conv_step(bc, cache["cbc"], p["conv_bc"])
    Bm, Cm = bc[..., :N], bc[..., N:]
    dt = jax.nn.softplus(dt_raw + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))

    y, h = ops.ssd_decode(xs.reshape(B, H_loc, P).astype(jnp.float32), dt, A,
                          Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                          p["d_skip"].astype(jnp.float32),
                          cache["h"])
    y = y.reshape(B, dip // tp).astype(ad)

    y = _masked_rmsnorm(y, z, p["norm_scale"], plan,
                        real_dip=cfg.ssm_heads * P)
    part = pdot(y, gather_w(p["w_out"], plan, fsdp_dim=1), policy,
                out_dtype=jnp.float32)
    out = col.psum(part, plan.tp_axes).astype(ad)
    return out, {"h": h, "cx": cx, "cbc": cbc}
