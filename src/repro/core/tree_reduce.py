"""Binary-tree cross-device reduction (paper T3/T9, literal form).

The paper aggregates partial output-projection tiles with a log2(C*G)-depth
binary reduction over the cluster-to-cluster interconnect, never touching
HBM.  XLA's `psum`/`psum_scatter` already lower to near-optimal ICI
ring/tree collectives; this module provides the *literal* recursive-halving
tree built from `ppermute` so §Perf can compare the two schedules on equal
terms (the dry-run counts their link bytes separately).

recursive halving (reduce-scatter flavor): at step d each device exchanges
half of its working segment with a partner 2^d away and accumulates —
log2(N) steps, (N-1)/N of the data volume total, the same asymptotics as a
ring reduce-scatter but with log-depth latency (the paper's argument).
Must run inside shard_map over `axis_name`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_psum_scatter(x, axis_name: str, *, scatter_dim: int = 0):
    """Reduce-scatter via recursive halving.  x: identical-shape partial on
    every device; returns the device's 1/N chunk of sum(x) along
    `scatter_dim` (size must divide by the axis size)."""
    from repro.core.collectives import one_axis_size
    n = one_axis_size(axis_name)
    if n == 1:
        return x
    assert n & (n - 1) == 0, f"tree reduction needs power-of-two axis, got {n}"
    idx = jax.lax.axis_index(axis_name)
    size = x.shape[scatter_dim]
    assert size % n == 0, (size, n)

    # work on the full buffer, halving the active window each step
    buf = x
    offset = jnp.zeros((), jnp.int32)          # window start (dynamic)
    width = size
    step = n // 2
    while step >= 1:
        width //= 2
        partner_delta = step
        # devices whose bit is 0 keep the low half, bit-1 devices the high half
        bit = (idx // step) % 2
        my_off = offset + bit * width
        their_off = offset + (1 - bit) * width
        send = jax.lax.dynamic_slice_in_dim(buf, their_off, width, scatter_dim)
        perm = []
        for i in range(n):
            b = (i // step) % 2
            perm.append((i, i + partner_delta if b == 0 else i - partner_delta))
        recv = jax.lax.ppermute(send, axis_name, perm)
        mine = jax.lax.dynamic_slice_in_dim(buf, my_off, width, scatter_dim)
        buf = jax.lax.dynamic_update_slice_in_dim(
            buf, mine + recv, my_off, scatter_dim)
        offset = my_off
        step //= 2
    return jax.lax.dynamic_slice_in_dim(buf, offset, width, scatter_dim)


def tree_psum(x, axis_name: str):
    """All-reduce as recursive halving + recursive doubling (allgather).
    Exposed for completeness; psum_scatter covers the fused-projection use."""
    from repro.core.collectives import one_axis_size
    n = one_axis_size(axis_name)
    if n == 1:
        return x
    shape = x.shape
    flat = x.reshape(-1)
    pad = -flat.shape[0] % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunk = tree_psum_scatter(flat, axis_name, scatter_dim=0)
    full = jax.lax.all_gather(chunk, axis_name, axis=0, tiled=True)
    if pad:
        full = full[: flat.shape[0] - pad]
    return full.reshape(shape)
