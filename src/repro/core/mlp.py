"""Dense MLP and Mixture-of-Experts FFN in manual-SPMD form.

Dense (paper T1 + T5): Megatron-SP — x all-gathered over the sequence axis,
d_ff sharded over tp, activation fused into the first GEMM (i-GELU / SwiGLU,
paper T5), second GEMM produces partials that reduce-scatter back to the
sequence-sharded residual (paper T3 again — same primitive as attention).

MoE (Mixtral): router + capacity-based scatter dispatch per data shard.
Experts are replicated over tp with d_ff sharded *inside* each expert
(8 experts don't divide the 16-way model axis — DESIGN.md §5): after the
residual all-gather every tp peer sees the same tokens, so dispatch is
collective-free and the expert GEMMs are plain d_ff tensor parallelism.
Token chunks are processed under `lax.scan` to bound the dispatch buffers.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import collectives as col
from repro.core.activations import get_activation
from repro.core.nn import act_dtype, fused_pdot, gather_w, pdot
from repro.kernels import ops
from repro.kernels.epilogue import Epilogue
from repro.sharding.plan import Plan

MOE_CHUNK = 8192       # max tokens dispatched at once (bounds buffer memory)


# --------------------------------------------------------------------------
# dense MLP
# --------------------------------------------------------------------------

def mlp_param_shapes(cfg) -> dict:
    E, F = cfg.d_model, cfg.d_ff
    if cfg.mlp_act == "swiglu":
        return {"wg": (E, F), "wu": (E, F), "w2": (F, E)}
    return {"w1": (E, F), "w2": (F, E)}


def mlp_param_dims(cfg) -> dict:
    if cfg.mlp_act == "swiglu":
        return {"wg": ("fsdp", "tp"), "wu": ("fsdp", "tp"),
                "w2": ("tp", "fsdp")}
    return {"w1": ("fsdp", "tp"), "w2": ("tp", "fsdp")}


def init_mlp(key, cfg, dtype):
    shapes = mlp_param_shapes(cfg)
    ks = jax.random.split(key, len(shapes))
    return {n: (jax.random.normal(k, s) * 0.02).astype(dtype)
            for (n, s), k in zip(sorted(shapes.items()), ks)}


def _wcast(w, cd):
    """Cast a gathered weight to the compute dtype — weight-only-int8
    dicts pass through untouched (the GEMM entry points dequantize)."""
    return w if isinstance(w, dict) else w.astype(cd)


def _first_gemm(xt, p, plan: Plan, cfg, policy, *, norm=None, tp_dim=None):
    """First FFN GEMM(s) with the pre-norm fused as a prologue and the
    activation as the epilogue: xt [T, E] -> h [T, F(/tp)] at act dtype."""
    ad = act_dtype(policy)
    cd = policy.compute_dtype
    if cfg.mlp_act == "swiglu":
        wg = gather_w(p["wg"], plan, tp_dim=tp_dim)
        wu = gather_w(p["wu"], plan, tp_dim=tp_dim)
        if norm is None:
            return ops.matmul_swiglu(xt.astype(cd), _wcast(wg, cd),
                                     _wcast(wu, cd), out_dtype=ad)
        return ops.fused_matmul_swiglu(xt, wg, wu, prologue=norm,
                                       compute_dtype=cd, out_dtype=ad)
    w1 = gather_w(p["w1"], plan, tp_dim=tp_dim)
    if norm is None:
        h = pdot(xt, w1, policy)
        h = get_activation(plan.gelu_impl)(h).astype(ad)  # T5 fused epilogue
        return h
    return fused_pdot(xt, w1, policy, prologue=norm,
                      epilogue=Epilogue(activation=plan.gelu_impl,
                                        out_dtype=ad))


def _ffn_local(xt, p, plan: Plan, cfg, policy, *, norm=None, residual=None,
               tp_dim=None, w2_tp_dim=None):
    """xt: [T, E] -> [T, E] partial (d_ff sharded over tp).  2-D so the
    Pallas fused-GEMM kernels apply directly.

    `norm`: fused pre-norm prologue on the first GEMM (xt un-normalized);
    `residual`: [T, E] folded into the second GEMM's epilogue — only legal
    when the caller has no tp-partial reduction pending."""
    h = _first_gemm(xt, p, plan, cfg, policy, norm=norm, tp_dim=tp_dim)
    w2 = gather_w(p["w2"], plan, fsdp_dim=1, tp_dim=w2_tp_dim)
    if residual is not None:
        return fused_pdot(h, w2, policy,
                          epilogue=Epilogue(residual=residual,
                                            out_dtype=act_dtype(policy)))
    return pdot(h, w2, policy)                               # partial over tp


def mlp_full(p, x, *, plan: Plan, cfg, policy, norm=None, residual=None):
    """x: [B, S_loc, E] sequence-sharded -> same.

    Fused operands (plan.fuse_epilogues): `norm` folds the pre-norm into
    the first GEMM; `residual` [B, S_loc, E] folds the residual add into
    the second GEMM (or after the reduce-scatter when tp > 1).  With
    `residual` given the return value is the UPDATED residual stream."""
    B, S_loc, E = x.shape
    if plan.mlp_weight_stationary and plan.tp > 1:
        # §Perf P3d: x never moves — gather the weights across tp instead
        # (cheap at fp8) and compute the whole FFN on the local seq chunk
        xt = x.reshape(B * S_loc, E)
        res2 = (residual.reshape(B * S_loc, E)
                if residual is not None else None)
        y = _ffn_local(xt, p, plan, cfg, policy, norm=norm, residual=res2,
                       tp_dim=1, w2_tp_dim=0)
        return y.reshape(B, S_loc, E)
    gather = col.all_gather_fp8 if plan.comm_fp8 else col.all_gather
    x_full = gather(x, plan.seq_axes, axis=1)
    B, S, E = x_full.shape
    fuse_res = residual is not None and not plan.tp_axes and not plan.seq_axes
    part = _ffn_local(x_full.reshape(B * S, E), p, plan, cfg, policy,
                      norm=norm,
                      residual=(residual.reshape(B * S, E) if fuse_res
                                else None))
    part = part.reshape(B, S, E)
    if fuse_res:
        return part
    y = col.psum_scatter(part, plan.tp_axes, scatter_dimension=1)
    return y if residual is None else residual + y


def mlp_decode(p, x, *, plan: Plan, cfg, policy, norm=None, residual=None):
    """x: [B, E] replicated over tp -> same.  `norm`/`residual` as in
    `mlp_full` (with `residual` the return is the updated stream)."""
    if residual is not None and not plan.tp_axes:
        return _ffn_local(x, p, plan, cfg, policy, norm=norm,
                          residual=residual)
    part = _ffn_local(x, p, plan, cfg, policy, norm=norm)
    y = col.psum(part.astype(jnp.float32), plan.tp_axes).astype(
        act_dtype(policy))
    return y if residual is None else residual + y


# --------------------------------------------------------------------------
# MoE FFN
# --------------------------------------------------------------------------

def moe_param_shapes(cfg) -> dict:
    E, F, NE = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {"wr": (E, NE), "wg": (NE, E, F), "wu": (NE, E, F),
            "w2": (NE, F, E)}


def moe_param_dims(cfg) -> dict:
    return {"wr": (None, None), "wg": (None, "fsdp", "tp"),
            "wu": (None, "fsdp", "tp"), "w2": (None, "tp", "fsdp")}


def init_moe(key, cfg, dtype):
    shapes = moe_param_shapes(cfg)
    ks = jax.random.split(key, len(shapes))
    return {n: (jax.random.normal(k, s) * 0.02).astype(dtype)
            for (n, s), k in zip(sorted(shapes.items()), ks)}


def _bdot(a, b, policy, *, out_dtype=None):
    """Batched expert GEMM: a [NE, C, K] @ b [NE, K, N] (MXU fp32 accum)."""
    cd = policy.compute_dtype
    return jax.lax.dot_general(a.astype(cd), b.astype(cd),
                               (((2,), (1,)), ((0,), (0,))),
                               preferred_element_type=(out_dtype
                                                       or act_dtype(policy)))


def moe_ffn_chunk(xc, p, *, plan: Plan, cfg, policy, capacity: int):
    """xc: [Tc, E] -> ([Tc, E] partial over tp, aux loss scalar).

    Scatter-based capacity dispatch: each (token, k) computes its slot in the
    expert buffer via a running per-expert count; overflow drops (standard
    Switch semantics).  No [T, NE, C] one-hot tensor is materialized.
    """
    Tc, E = xc.shape
    NE, K = cfg.n_experts, cfg.top_k
    ad = act_dtype(policy)

    logits = pdot(xc, p["wr"], policy, out_dtype=jnp.float32)   # [Tc, NE]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    flat_e = top_i.reshape(-1)                                  # [Tc*K]
    flat_w = top_w.reshape(-1)
    onehot = (flat_e[:, None] == jnp.arange(NE)[None, :]).astype(jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot              # exclusive
    slot = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    slot = jnp.where(slot < capacity, slot, capacity)           # cap -> OOB

    x_rep = jnp.repeat(xc, K, axis=0)                           # [Tc*K, E]
    xe = jnp.zeros((NE, capacity, E), ad).at[flat_e, slot].add(
        x_rep.astype(ad), mode="drop")

    wg = gather_w(p["wg"], plan, fsdp_dim=1)                    # [NE,E,F/tp]
    wu = gather_w(p["wu"], plan, fsdp_dim=1)
    w2 = gather_w(p["w2"], plan, fsdp_dim=2)                    # [NE,F/tp,E]
    if plan.fuse_epilogues:
        # batched per-expert gated GEMMs with the silu-mul kept in VMEM
        # (kernels/ops.expert_swiglu: vmapped fused swiglu kernel on TPU)
        h = ops.expert_swiglu(xe, wg, wu,
                              compute_dtype=policy.compute_dtype,
                              out_dtype=ad)
    else:
        g = _bdot(xe, wg, policy)
        u = _bdot(xe, wu, policy)
        h = (jax.nn.silu(g.astype(jnp.float32))
             * u.astype(jnp.float32)).astype(ad)
    ye = _bdot(h, w2, policy)                                   # [NE, C, E]

    y_tok = ye.at[flat_e, slot].get(mode="fill", fill_value=0)  # [Tc*K, E]
    y = (y_tok.astype(jnp.float32) * flat_w[:, None]).reshape(Tc, K, E).sum(1)

    # Switch load-balance loss: NE * sum_e f_e * p_e
    f_e = onehot.astype(jnp.float32).mean(0) * (Tc * K) / Tc / K
    p_e = probs.mean(0)
    aux = NE * jnp.sum(f_e * p_e)
    return y.astype(ad), aux


def _chunks(T: int) -> int:
    nc = max(1, math.ceil(T / MOE_CHUNK))
    while T % nc:
        nc += 1
    return nc


def moe_full(p, x, *, plan: Plan, cfg, policy):
    """x: [B, S_loc, E] -> ([B, S_loc, E], aux)."""
    gather = col.all_gather_fp8 if plan.comm_fp8 else col.all_gather
    x_full = gather(x, plan.seq_axes, axis=1)
    B, S, E = x_full.shape
    T = B * S
    nc = _chunks(T)
    Tc = T // nc
    capacity = int(math.ceil(Tc * cfg.top_k / cfg.n_experts
                             * cfg.capacity_factor))
    xs = x_full.reshape(nc, Tc, E)

    def body(carry, xc):
        y, aux = moe_ffn_chunk(xc, p, plan=plan, cfg=cfg, policy=policy,
                               capacity=capacity)
        return carry + aux, y

    aux, ys = jax.lax.scan(body, jnp.zeros((), jnp.float32), xs)
    part = ys.reshape(B, S, E)
    y = col.psum_scatter(part, plan.tp_axes, scatter_dimension=1)
    return y, aux / nc


def moe_decode(p, x, *, plan: Plan, cfg, policy):
    """x: [B, E] -> ([B, E], aux)."""
    B = x.shape[0]
    capacity = max(1, int(math.ceil(B * cfg.top_k / cfg.n_experts
                                    * cfg.capacity_factor)))
    y, aux = moe_ffn_chunk(x, p, plan=plan, cfg=cfg, policy=policy,
                           capacity=capacity)
    y = col.psum(y.astype(jnp.float32), plan.tp_axes)
    return y.astype(act_dtype(policy)), aux
