"""Precision policies (paper T6).

The paper sweeps FP64 -> FP8 with two invariants we preserve on TPU:
  * GEMMs run at the policy compute dtype but ACCUMULATE at >= fp32
    (Snitch's SIMD widening dot-products; TPU: preferred_element_type=f32).
  * Softmax / normalization statistics always run in fp32.

TPU v5e has no fp64 MXU path, so the sweep here is fp32 -> bf16 -> fp8
(E4M3 / E5M2), matching the 2x-per-halving peak-FLOP scaling the paper
exploits.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class Policy:
    name: str
    param_dtype: jnp.dtype      # storage dtype of the weights
    compute_dtype: jnp.dtype    # GEMM operand dtype
    accum_dtype: jnp.dtype      # GEMM accumulation dtype
    softmax_dtype: jnp.dtype    # softmax / norm statistics dtype
    # peak MXU throughput multiplier vs bf16 on v5e (for roofline)
    flops_scale: float

    def cast_params(self, tree):
        import jax
        return jax.tree.map(
            lambda x: x.astype(self.param_dtype)
            if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)
            else x,
            tree)


FP32 = Policy("fp32", jnp.float32, jnp.float32, jnp.float32, jnp.float32, 0.5)
BF16 = Policy("bf16", jnp.bfloat16, jnp.bfloat16, jnp.float32, jnp.float32, 1.0)
FP16 = Policy("fp16", jnp.float16, jnp.float16, jnp.float32, jnp.float32, 1.0)
FP8_E4M3 = Policy("fp8_e4m3", jnp.bfloat16, jnp.float8_e4m3fn, jnp.float32,
                  jnp.float32, 2.0)
FP8_E5M2 = Policy("fp8_e5m2", jnp.bfloat16, jnp.float8_e5m2, jnp.float32,
                  jnp.float32, 2.0)
# fp8 *storage* — what makes mixtral-8x22b decode fit the 16-chip TP column
# (141B params x 1B / 16 = 8.7 GB/chip vs 17.3 GB in bf16); paper T6 applied
# as a deployability lever.
FP8_SERVE = Policy("fp8_serve", jnp.float8_e4m3fn, jnp.float8_e4m3fn,
                   jnp.float32, jnp.float32, 2.0)

POLICIES = {p.name: p for p in (FP32, BF16, FP16, FP8_E4M3, FP8_E5M2,
                                FP8_SERVE)}
POLICIES["fp8"] = FP8_E4M3


def get_policy(name: str) -> Policy:
    return POLICIES[name]
