"""Multi-head attention in manual-SPMD form (paper T2 + T3 + T4).

All functions run *inside* the step's `shard_map` (launch/steps.py) and
consult the static `Plan` for axis names.  With an empty plan (no mesh) every
collective degrades to identity, so the same code runs unsharded in tests.

Sharding schemes (train / prefill):
  head_tp   residual seq-sharded -> all-gather x over `model` (Megatron-SP),
            Q heads sharded over `model`, K/V computed column-sharded and
            re-gathered (cheap under GQA), flash attention per head shard,
            out-projection contracted on local heads -> reduce-scatter back
            to sequence-sharded.  The concatenated head tensor never exists
            (paper T3); the reduce-scatter *is* the paper's log-tree
            cluster-to-cluster reduction (a literal binary-tree schedule is
            selectable via core.collectives.set_reduce_method("tree")).
  seq_sp    for n_heads % tp != 0 (phi4 24H, hymba 25H, whisper 8H):
            Q stays sequence-sharded with full weights, K/V all-gathered over
            the sequence axis, flash attention with a query-position offset.

Decode (AR): the KV cache is *sequence-sharded* over `plan.cache_axes`; every
device attends its cache chunk producing online-softmax partials (m, l, o)
which are merged with the cross-device distributed-softmax rule (paper T4).
Weights stay tensor-parallel; only O(B·H·hd) activations cross the wire.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import collectives as col
from repro.core.nn import act_dtype, fused_pdot, gather_w, pdot
from repro.core.precision import Policy
from repro.core.rope import apply_rope
from repro.kernels import ops
from repro.kernels.epilogue import Epilogue
from repro.sharding.plan import Plan

NEG_INF = -1e30
CACHE_DTYPE = jnp.bfloat16


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------

def attention_param_dims(cfg) -> dict:
    return {"wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"),
            "wv": ("fsdp", "tp"), "wo": ("tp", "fsdp")}


def attention_param_shapes(cfg) -> dict:
    E, H, hd, KV = cfg.d_model, cfg.n_heads, cfg.head_dim, cfg.n_kv_heads
    return {"wq": (E, H * hd), "wk": (E, KV * hd),
            "wv": (E, KV * hd), "wo": (H * hd, E)}


def init_attention(key, cfg, dtype):
    shapes = attention_param_shapes(cfg)
    ks = jax.random.split(key, len(shapes))
    return {n: (jax.random.normal(k, s) * 0.02).astype(dtype)
            for (n, s), k in zip(sorted(shapes.items()), ks)}


# --------------------------------------------------------------------------
# static head layout
# --------------------------------------------------------------------------

class KVLayout(NamedTuple):
    n_kv_loc: int       # kv heads each device holds for attention
    aligned: bool       # True: the column shard of wk IS the local kv heads


def kv_layout(cfg, tp: int) -> KVLayout:
    H, KV, G = cfg.n_heads, cfg.n_kv_heads, cfg.q_per_kv
    if tp == 1:
        return KVLayout(KV, True)
    assert H % tp == 0, (H, tp)
    if KV % tp == 0:
        return KVLayout(KV // tp, True)
    h_loc = H // tp
    n_loc = max(1, h_loc // G)
    for i in range(tp):           # no q-head group may straddle kv shards
        lo, hi = (i * h_loc) // G, (i * h_loc + h_loc - 1) // G
        assert hi - lo + 1 <= n_loc, (
            f"kv heads straddle shards: tp={tp} H={H} KV={KV}")
    return KVLayout(n_loc, False)


def _first_kv(cfg, tp, tp_axes):
    """Traced index of this device's first kv head (unaligned layout)."""
    h_loc = cfg.n_heads // tp
    return (col.axis_index(tp_axes) * h_loc) // cfg.q_per_kv


def _attention_fn(plan: Plan):
    """Flash kernel (optimized, T2) or naive full-materialization reference
    (the paper's baseline implementation — benchmarks/ablation)."""
    if plan.naive_attention:
        from repro.kernels.ref import attention_ref
        return attention_ref
    return ops.flash_attention


# --------------------------------------------------------------------------
# distributed softmax merge (T4) — manual-SPMD variant
# --------------------------------------------------------------------------

def merge_partials(o, m, l, axes):
    """Merge per-shard online-softmax partials across `axes`.
    o: [..., D] unnormalized; m, l: [...] running max / sum-exp (fp32)."""
    if not axes:
        return o / jnp.maximum(l, 1e-30)[..., None]
    m_all = col.pmax(jax.lax.stop_gradient(m), axes)   # stabilizer only
    corr = jnp.exp(m - m_all)
    l_all = col.psum(l * corr, axes)
    o_all = col.psum(o * corr[..., None], axes)
    return o_all / jnp.maximum(l_all, 1e-30)[..., None]


def decode_partials(q, k_loc, v_loc, valid, *, sm_scale):
    """One-token attention over a local cache chunk -> (o, m, l) partials.
    q: [B, H, D]; k/v_loc: [B, Sl, KV, D]; valid: [B, Sl] bool.  GEMMs in
    operand dtype (fp32 accumulation), statistics fp32 (paper T6)."""
    B, H, D = q.shape
    KV = k_loc.shape[2]
    G = H // KV
    qr = q.reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qr, k_loc.astype(q.dtype),
                   preferred_element_type=jnp.float32) * sm_scale
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(q.dtype),
                   v_loc.astype(q.dtype),
                   preferred_element_type=jnp.float32)
    return o.reshape(B, H, D), m.reshape(B, H), l.reshape(B, H)


# --------------------------------------------------------------------------
# KV-cache construction (prefill)
# --------------------------------------------------------------------------

def ring_from_full(k_full, window: int):
    """Arrange the last `window` positions of [B, S, KV, hd] into ring-buffer
    order (slot = pos % window).  S < window pads at the tail (masked by pos
    validity at decode)."""
    B, S = k_full.shape[:2]
    if S >= window:
        tail = k_full[:, S - window:]
        return jnp.roll(tail, shift=S % window, axis=1)
    pad = window - S
    return jnp.pad(k_full, ((0, 0), (0, pad), (0, 0), (0, 0)))


def cache_slice(k_full, plan: Plan):
    """Slice this device's cache-sequence chunk from a fully-gathered
    [B, W, KV, hd] tensor."""
    W = k_full.shape[1]
    shards = plan.cache_shards
    chunk = W // shards
    start = col.axis_index(plan.cache_axes) * chunk
    return jax.lax.dynamic_slice_in_dim(k_full, start, chunk, axis=1)


def build_cache(k_full, v_full, plan: Plan, *, window: int, cache_len: int):
    """-> {"k","v"} local shards [B, cache_len/shards, KV, hd]
    (plan.kv_cache_dtype).  window > 0 => ring cache of `window` slots."""
    S = k_full.shape[1]
    if window > 0:
        k_full = ring_from_full(k_full, window)
        v_full = ring_from_full(v_full, window)
    elif S < cache_len:
        pad = cache_len - S
        k_full = jnp.pad(k_full, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_full = jnp.pad(v_full, ((0, 0), (0, pad), (0, 0), (0, 0)))
    cd = jnp.dtype(plan.kv_cache_dtype)
    return {"k": cache_slice(k_full.astype(cd), plan),
            "v": cache_slice(v_full.astype(cd), plan)}


# --------------------------------------------------------------------------
# full-sequence attention (train / prefill / encoder)
# --------------------------------------------------------------------------

def attn_full(p, x, *, plan: Plan, cfg, policy: Policy, causal: bool,
              window: int, with_cache: bool = False, cache_len: int = 0,
              memory=None, memory_len: int = 0, norm=None, residual=None):
    """x: [B, S_loc, E] sequence-sharded.  `memory`: cross-attention source
    [B, Sm_loc, E] (whisper decoder).  Returns (y [B, S_loc, E], cache|None).

    Fused pipeline operands (plan.fuse_epilogues):
      `norm`      kernels.epilogue.Prologue — x arrives UN-normalized and
                  the pre-norm fuses into the Q/K/V projection GEMMs (the
                  cross-attention memory is never normalized, matching the
                  unfused chain).
      `residual`  [B, S_loc, E] residual stream — folded into the output
                  projection's epilogue when no tp-partial reduction is
                  pending, added after the collective otherwise.  When
                  given, the first return value is the UPDATED residual
                  stream (residual + attn out), not the raw sub-layer out.
    """
    scheme = plan.attention_sharding
    if memory is not None or scheme == "seq_sp" or plan.tp == 1:
        return _attn_seq_sp(p, x, plan=plan, cfg=cfg, policy=policy,
                            causal=causal, window=window,
                            with_cache=with_cache, cache_len=cache_len,
                            memory=memory, memory_len=memory_len,
                            norm=norm, residual=residual)
    return _attn_head_tp(p, x, plan=plan, cfg=cfg, policy=policy,
                         causal=causal, window=window,
                         with_cache=with_cache, cache_len=cache_len,
                         norm=norm, residual=residual)


def _attn_head_tp(p, x, *, plan, cfg, policy, causal, window,
                  with_cache, cache_len, norm=None, residual=None):
    tp, tp_ax = plan.tp, plan.tp_axes
    B, S_loc, E = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h_loc = H // tp
    ad = act_dtype(policy)
    lay = kv_layout(cfg, tp)

    gather = col.all_gather_fp8 if plan.comm_fp8 else col.all_gather
    x_full = gather(x, plan.seq_axes, axis=1)                  # [B, S, E]
    S = x_full.shape[1]
    positions = jnp.arange(S)

    wq = gather_w(p["wq"], plan)                               # [E, h_loc*hd]
    q = fused_pdot(x_full, wq, policy,
                   prologue=norm).reshape(B, S, h_loc, hd)
    q = apply_rope(q, positions, theta=cfg.rope_theta,
                   fraction=cfg.rope_fraction)

    kp = fused_pdot(x_full, gather_w(p["wk"], plan), policy,
                    prologue=norm)                             # [B,S,KVhd/tp]
    vp = fused_pdot(x_full, gather_w(p["wv"], plan), policy, prologue=norm)
    need_full_kv = with_cache or not lay.aligned
    if need_full_kv and tp > 1:
        k_full = col.all_gather(kp, tp_ax, axis=-1).reshape(B, S, KV, hd)
        v_full = col.all_gather(vp, tp_ax, axis=-1).reshape(B, S, KV, hd)
    else:
        k_full = kp.reshape(B, S, -1, hd)
        v_full = vp.reshape(B, S, -1, hd)
    k_full = apply_rope(k_full, positions, theta=cfg.rope_theta,
                        fraction=cfg.rope_fraction)

    if lay.aligned and not (need_full_kv and tp > 1):
        k_loc, v_loc = k_full, v_full
    elif lay.aligned:
        i = col.axis_index(tp_ax)
        k_loc = jax.lax.dynamic_slice_in_dim(
            k_full, i * lay.n_kv_loc, lay.n_kv_loc, axis=2)
        v_loc = jax.lax.dynamic_slice_in_dim(
            v_full, i * lay.n_kv_loc, lay.n_kv_loc, axis=2)
    else:
        first = _first_kv(cfg, tp, tp_ax)
        k_loc = jax.lax.dynamic_slice_in_dim(k_full, first, lay.n_kv_loc, axis=2)
        v_loc = jax.lax.dynamic_slice_in_dim(v_full, first, lay.n_kv_loc, axis=2)

    out = _attention_fn(plan)(q.astype(ad), k_loc.astype(ad),
                              v_loc.astype(ad), causal=causal, window=window)
    o = out.reshape(B, S, h_loc * hd)

    wo = gather_w(p["wo"], plan, fsdp_dim=1)                   # [h_loc*hd, E]
    # head_tp only runs with tp > 1 (attn_full routes tp == 1 to seq_sp),
    # so a tp-partial reduction is always pending: the residual add lands
    # after the reduce-scatter, never in the GEMM epilogue
    part = pdot(o, wo, policy)                                 # partial over tp
    y = col.psum_scatter(part, tp_ax, scatter_dimension=1)     # T3
    if residual is not None:
        y = residual + y

    cache = None
    if with_cache:
        cache = build_cache(k_full, v_full, plan, window=window,
                            cache_len=cache_len)
    return y, cache


def _attn_seq_sp(p, x, *, plan, cfg, policy, causal, window, with_cache,
                 cache_len, memory=None, memory_len=0, norm=None,
                 residual=None):
    sp_ax = plan.seq_axes
    B, S_loc, E = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ad = act_dtype(policy)

    off = col.axis_index(sp_ax) * S_loc
    q_pos = jnp.arange(S_loc) + off

    wq = gather_w(p["wq"], plan, tp_dim=1)                     # full [E, H*hd]
    q = fused_pdot(x, wq, policy, prologue=norm).reshape(B, S_loc, H, hd)
    q = apply_rope(q, q_pos, theta=cfg.rope_theta, fraction=cfg.rope_fraction)

    src = x if memory is None else memory
    src_norm = norm if memory is None else None   # memory is never normed
    Sm_loc = src.shape[1]
    k_loc = fused_pdot(src, gather_w(p["wk"], plan, tp_dim=1), policy,
                       prologue=src_norm)
    v_loc = fused_pdot(src, gather_w(p["wv"], plan, tp_dim=1), policy,
                       prologue=src_norm)
    k_loc = k_loc.reshape(B, Sm_loc, KV, hd)
    v_loc = v_loc.reshape(B, Sm_loc, KV, hd)
    if memory is None:
        k_loc = apply_rope(k_loc, q_pos, theta=cfg.rope_theta,
                           fraction=cfg.rope_fraction)
    gather = col.all_gather_fp8 if plan.comm_fp8 else col.all_gather
    k_full = gather(k_loc, sp_ax, axis=1)                      # [B, Sm, KV, hd]
    v_full = gather(v_loc, sp_ax, axis=1)

    out = _attention_fn(plan)(q.astype(ad), k_full.astype(ad),
                              v_full.astype(ad), causal=causal,
                              window=window, q_offset=off)
    o = out.reshape(B, S_loc, H * hd)

    wo = gather_w(p["wo"], plan, fsdp_dim=1, tp_dim=0)         # full [H*hd, E]
    if residual is not None:    # no collective pending: fuse the residual
        y = fused_pdot(o, wo, policy,
                       epilogue=Epilogue(residual=residual, out_dtype=ad))
    else:
        y = pdot(o, wo, policy)                                # stays sharded

    cache = None
    if with_cache:
        cache = build_cache(k_full, v_full, plan, window=window,
                            cache_len=cache_len)
    return y, cache


# --------------------------------------------------------------------------
# AR decode (T4: sequence-sharded cache + distributed softmax)
# --------------------------------------------------------------------------

def _decode_q(p, x, pos, *, plan: Plan, cfg, policy: Policy, norm=None):
    """Projected + rotated query for one decode step: [B, H, hd].
    `norm`: fused pre-norm prologue (x arrives un-normalized)."""
    B = x.shape[0]
    H, hd = cfg.n_heads, cfg.head_dim
    qp = fused_pdot(x, gather_w(p["wq"], plan), policy,
                    prologue=norm)                             # [B, Hhd/tp]
    q = col.all_gather(qp, plan.tp_axes, axis=-1).reshape(B, H, hd)
    return apply_rope(q[:, None], pos[:, None], theta=cfg.rope_theta,
                      fraction=cfg.rope_fraction)[:, 0]


def _decode_kv_new(p, x, pos, *, plan: Plan, cfg, policy: Policy, norm=None):
    """This step's K/V rows ([B, KV, hd] each; K rotated)."""
    B = x.shape[0]
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    kp = fused_pdot(x, gather_w(p["wk"], plan), policy, prologue=norm)
    vp = fused_pdot(x, gather_w(p["wv"], plan), policy, prologue=norm)
    k_new = col.all_gather(kp, plan.tp_axes, axis=-1).reshape(B, KV, hd)
    v_new = col.all_gather(vp, plan.tp_axes, axis=-1).reshape(B, KV, hd)
    k_new = apply_rope(k_new[:, None], pos[:, None], theta=cfg.rope_theta,
                       fraction=cfg.rope_fraction)[:, 0]
    return k_new, v_new


def _decode_out_proj(p, merged, *, plan: Plan, policy: Policy,
                     residual=None):
    """Contract the merged [B, H*hd] head tensor with wo (tp-partial +
    psum) -> [B, E] at activation dtype.  `residual` folds into the GEMM
    epilogue when no tp reduction is pending (added after the psum
    otherwise); when given, the result is the updated residual stream."""
    tp_ax = plan.tp_axes
    ad = act_dtype(policy)
    rows_loc = merged.shape[1] // plan.tp
    i = col.axis_index(tp_ax)
    o_loc = jax.lax.dynamic_slice_in_dim(
        merged.astype(ad), i * rows_loc, rows_loc, axis=1)
    wo = gather_w(p["wo"], plan, fsdp_dim=1)                   # [Hhd/tp, E]
    if residual is not None and not tp_ax:
        return fused_pdot(o_loc, wo, policy,
                          epilogue=Epilogue(residual=residual, out_dtype=ad),
                          out_dtype=jnp.float32)
    part = pdot(o_loc, wo, policy, out_dtype=jnp.float32)
    y = col.psum(part, tp_ax).astype(ad)
    return y if residual is None else residual + y


def attn_decode(p, x, pos, cache, *, plan: Plan, cfg, policy: Policy,
                window: int, cross: bool = False, memory_len: int = 0,
                norm=None, residual=None):
    """One decode step.  x: [B, E] (replicated over tp); pos: [B] int32 —
    position index of the token being written; cache: {"k","v"} local shards
    [B, W_loc, KV, hd].  Returns (y [B, E], updated cache).

    `norm` / `residual`: fused prologue/epilogue — see `attn_full` (with
    `residual` the first return value is the updated stream)."""
    c_ax = plan.cache_axes
    B, E = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    ad = act_dtype(policy)
    sm_scale = float(1.0 / (hd ** 0.5))

    W_loc = cache["k"].shape[1]
    W = W_loc * plan.cache_shards                  # global cache slots
    ring = window > 0 and W == window

    q = _decode_q(p, x, pos, plan=plan, cfg=cfg, policy=policy, norm=norm)

    if not cross:
        k_new, v_new = _decode_kv_new(p, x, pos, plan=plan, cfg=cfg,
                                      policy=policy, norm=norm)
        slot = pos % W if ring else pos
        start = col.axis_index(c_ax) * W_loc
        loc = slot - start
        # negative indices WRAP in .at[] before mode="drop" applies — route
        # non-owned slots to an out-of-bounds positive index instead
        loc = jnp.where((loc >= 0) & (loc < W_loc), loc, W_loc)
        rows = jnp.arange(B)
        cache = {
            "k": cache["k"].at[rows, loc].set(
                k_new.astype(cache["k"].dtype), mode="drop"),
            "v": cache["v"].at[rows, loc].set(
                v_new.astype(cache["v"].dtype), mode="drop"),
        }
    else:
        start = col.axis_index(c_ax) * W_loc

    # validity of local slots
    gidx = jnp.arange(W_loc)[None, :] + start                  # [1, W_loc]
    if cross:
        valid = jnp.broadcast_to(gidx < memory_len, (B, W_loc))
    elif ring:
        # slot s holds abs position pos - ((pos - s) mod W); valid if >= 0
        valid = (pos[:, None] + 1 >= W) | (gidx <= pos[:, None])
    else:
        valid = gidx <= pos[:, None]
        if window > 0:
            valid &= gidx > (pos[:, None] - window)

    o, m, l = decode_partials(q.astype(ad), cache["k"], cache["v"], valid,
                              sm_scale=sm_scale)
    merged = merge_partials(o, m, l, c_ax).reshape(B, H * hd)  # T4 merge
    return _decode_out_proj(p, merged, plan=plan, policy=policy,
                            residual=residual), cache


SCALE_EPS = 1e-30      # guards zero-amax blocks and unwritten scale slots


def _quantized_kv(cache) -> bool:
    """True when the paged pools store int8 K/V with per-block-per-head
    scales ({"ks","vs"} [NB_loc, KV] fp32 leaves alongside {"k","v"})."""
    return "ks" in cache


def _append_quantized(pool, scales, x_new, loc, off):
    """Quantize-on-write for a single-token paged append.  x_new: [B, KV, hd]
    fp-valued rows; loc [B] local block ids (out-of-range => dropped);
    off [B] in-block offsets.  Blocks fill front-to-back, so a token landing
    at offset 0 is the block's first (re)use: it (re)sets the block scale
    from its own amax.  Later offsets reuse the stored scale and clip —
    entries already in the block never move, which is what makes speculative
    rollback (a fill-count rewind) and COW sharing safe."""
    NB_loc = pool.shape[0]
    xf = x_new.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)                       # [B, KV]
    s_new = jnp.maximum(amax, SCALE_EPS) / 127.0
    fresh = off == 0
    s_old = scales[jnp.clip(loc, 0, NB_loc - 1)]               # [B, KV]
    s = jnp.where(fresh[:, None], s_new, jnp.maximum(s_old, SCALE_EPS))
    q = jnp.clip(jnp.round(xf / s[..., None]), -127, 127).astype(jnp.int8)
    sloc = jnp.where(fresh, loc, NB_loc)     # only fresh blocks write scale
    return (pool.at[loc, off].set(q, mode="drop"),
            scales.at[sloc].set(s_new, mode="drop"))


def _scatter_quantized(pool, scales, x_new, loc, off, fresh):
    """Quantize-on-write for a multi-token chunk scatter.  x_new:
    [B, C, KV, hd]; loc/off [B, C] (non-owned / pad tokens routed to
    loc == NB_loc => dropped); `fresh` [B, C] marks tokens whose block's
    offset 0 lies inside this write.  Fresh blocks take their scale from a
    scatter-max over the chunk's own token amaxes (exactly the per-block
    amax of what lands in them); stale blocks keep their stored scale and
    this chunk's tokens clip against it — same invariant as
    `_append_quantized`, vectorized."""
    NB_loc = pool.shape[0]
    xf = x_new.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)                       # [B, C, KV]
    zloc = jnp.where(fresh & (off == 0), loc, NB_loc)
    scales = scales.at[zloc].set(0.0, mode="drop")             # reset fresh
    floc = jnp.where(fresh, loc, NB_loc)
    scales = scales.at[floc].max(jnp.maximum(amax, SCALE_EPS) / 127.0,
                                 mode="drop")
    s = jnp.maximum(scales[jnp.clip(loc, 0, NB_loc - 1)], SCALE_EPS)
    q = jnp.clip(jnp.round(xf / s[..., None]), -127, 127).astype(jnp.int8)
    return pool.at[loc, off].set(q, mode="drop"), scales


def attn_chunk_paged(p, x, pos0, chunk_len, cache, block_tables, *,
                     plan: Plan, cfg, policy: Policy, norm=None,
                     residual=None, rope_pos=None, tree_mask=None):
    """One chunked-prefill piece against a block-paged KV cache.

    x: [B, C, E] — C consecutive prompt tokens per row, starting at absolute
    position `pos0` [B]; `chunk_len` [B] is the true token count this chunk
    carries (<= C; the tail is padding whose KV is never scattered and whose
    outputs the caller discards); cache: {"k","v"} pool shards
    [NB_loc, BS, KV, hd]; block_tables: [B, MB] global pool indices.

    The chunk's KV rows are scattered into their blocks FIRST, then the
    chunk queries attend the pool (prefix + this chunk) under a per-query
    causal mask — so one code path covers both the first chunk (empty
    prefix) and every later one.  Per-shard partials merge with the same T4
    rule as decode; projections reuse the decode helpers on the flattened
    [B*C] token batch.  Returns (y [B, C, E], updated cache).

    Tree-speculative verify reuses this path with two overrides: the chunk
    then carries a token *tree* whose node i is scattered at pos0+i as
    usual, but `rope_pos` [B, C] rotates q/k at each node's *logical* depth
    (pos0 + depth, shared by sibling branches) so the winning path's KV is
    correctly rotated for its final position, and `tree_mask` [B, C, C]
    replaces the intra-chunk causal mask with the ancestor matrix."""
    c_ax = plan.cache_axes
    B, C, E = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ad = act_dtype(policy)

    NB_loc, BS = cache["k"].shape[0], cache["k"].shape[1]
    start = col.axis_index(c_ax) * NB_loc
    pos = pos0[:, None] + jnp.arange(C)[None, :]               # [B, C]

    # projections: decode math on B*C tokens, reshaped back to chunks
    flat = x.reshape(B * C, E)
    pflat = (pos if rope_pos is None else rope_pos).reshape(B * C)
    q = _decode_q(p, flat, pflat, plan=plan, cfg=cfg,
                  policy=policy, norm=norm).reshape(B, C, H, hd)
    k_new, v_new = _decode_kv_new(p, flat, pflat, plan=plan, cfg=cfg,
                                  policy=policy, norm=norm)
    k_new = k_new.reshape(B, C, KV, hd)
    v_new = v_new.reshape(B, C, KV, hd)

    # scatter the chunk KV into its blocks (pad tail / non-owned dropped)
    real = jnp.arange(C)[None, :] < chunk_len[:, None]         # [B, C]
    MB = block_tables.shape[1]
    entry = jnp.clip(pos // BS, 0, MB - 1)
    gb = jnp.take_along_axis(block_tables, entry, axis=1)      # [B, C]
    loc = gb - start
    owned = real & (gb >= 0) & (loc >= 0) & (loc < NB_loc)
    loc = jnp.where(owned, loc, NB_loc)      # out of range => mode="drop"
    off = pos % BS
    if _quantized_kv(cache):
        fresh = (pos - off) >= pos0[:, None]   # block's offset 0 is ours
        kp, ks = _scatter_quantized(cache["k"], cache["ks"], k_new,
                                    loc, off, fresh)
        vp, vs = _scatter_quantized(cache["v"], cache["vs"], v_new,
                                    loc, off, fresh)
        cache = {"k": kp, "v": vp, "ks": ks, "vs": vs}
    else:
        cache = {
            "k": cache["k"].at[loc, off].set(
                k_new.astype(cache["k"].dtype), mode="drop"),
            "v": cache["v"].at[loc, off].set(
                v_new.astype(cache["v"].dtype), mode="drop"),
        }

    # local table view (entries this shard owns, local ids)
    length = pos0 + chunk_len                  # valid tokens incl. the chunk
    loc_tab = block_tables - start
    present = (block_tables >= 0) & (loc_tab >= 0) & (loc_tab < NB_loc)
    loc_tab = jnp.where(present, loc_tab, -1)

    o, m, l = ops.paged_chunk_partials(q.astype(ad), cache["k"], cache["v"],
                                       loc_tab, pos, length,
                                       k_scale=cache.get("ks"),
                                       v_scale=cache.get("vs"),
                                       tree_mask=tree_mask)
    merged = merge_partials(o, m, l, c_ax).reshape(B * C, H * hd)
    y = _decode_out_proj(p, merged, plan=plan, policy=policy,
                         residual=residual.reshape(B * C, E)
                         if residual is not None else None)
    return y.reshape(B, C, E), cache


def attn_decode_paged(p, x, pos, cache, block_tables, *, plan: Plan, cfg,
                      policy: Policy, norm=None, residual=None):
    """One decode step against a block-paged KV cache (full-context layers
    only — window/ring layers keep the dense per-slot ring, `attn_decode`).

    x: [B, E]; pos: [B] — position index of the token being written;
    cache: {"k","v"} pool shards [NB_loc, BS, KV, hd], block-sharded over
    `plan.cache_axes`; block_tables: [B, MB] int32 *global* pool indices in
    sequence order (< 0 = unallocated).  Returns (y [B, E], updated cache).

    The new token's KV lands in block table[pos // BS] at offset pos % BS —
    a single per-block scatter.  Attention dispatches to the paged split-KV
    partials kernel (kernels/ops.paged_decode_partials) over the blocks this
    shard owns (absent / non-owned table entries masked), and the per-shard
    online-softmax partials merge across cache shards with the same T4 rule
    as the dense path — the pool is never gathered."""
    c_ax = plan.cache_axes
    B, E = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    ad = act_dtype(policy)

    NB_loc, BS = cache["k"].shape[0], cache["k"].shape[1]
    start = col.axis_index(c_ax) * NB_loc          # first owned global block

    q = _decode_q(p, x, pos, plan=plan, cfg=cfg, policy=policy, norm=norm)
    k_new, v_new = _decode_kv_new(p, x, pos, plan=plan, cfg=cfg,
                                  policy=policy, norm=norm)

    # scatter the new token into its block (absent / non-owned -> dropped;
    # negative ids wrap in .at[], so route them out of bounds instead)
    gb = jnp.take_along_axis(block_tables, (pos // BS)[:, None],
                             axis=1)[:, 0]                       # [B]
    loc = gb - start
    owned = (gb >= 0) & (loc >= 0) & (loc < NB_loc)
    loc = jnp.where(owned, loc, NB_loc)
    off = pos % BS
    if _quantized_kv(cache):
        kp, ks = _append_quantized(cache["k"], cache["ks"], k_new, loc, off)
        vp, vs = _append_quantized(cache["v"], cache["vs"], v_new, loc, off)
        cache = {"k": kp, "v": vp, "ks": ks, "vs": vs}
    else:
        cache = {
            "k": cache["k"].at[loc, off].set(
                k_new.astype(cache["k"].dtype), mode="drop"),
            "v": cache["v"].at[loc, off].set(
                v_new.astype(cache["v"].dtype), mode="drop"),
        }

    # local view of the table: entries this shard owns, local ids
    length = pos + 1                               # incl. the token just cached
    loc_tab = block_tables - start
    present = (block_tables >= 0) & (loc_tab >= 0) & (loc_tab < NB_loc)
    loc_tab = jnp.where(present, loc_tab, -1)

    o, m, l = ops.paged_decode_partials(q.astype(ad), cache["k"], cache["v"],
                                        loc_tab, length,
                                        k_scale=cache.get("ks"),
                                        v_scale=cache.get("vs"))
    merged = merge_partials(o, m, l, c_ax).reshape(B, H * hd)  # T4 merge
    return _decode_out_proj(p, merged, plan=plan, policy=policy,
                            residual=residual), cache
