"""Static sharding plan per (arch, shape, mesh, mode).

The plan is the single source of truth for how logical dimensions map to
physical mesh axes.  Model code is written in manual-SPMD style (inside one
`shard_map` per step) and consults only the plan:

  logical dim     train (mode="train")        serve (mode="serve")
  -----------     ---------------------       ---------------------
  batch           ("pod", "data")             ("data",)  [() when B == 1]
  seq (resid)     ("model",)                  ("model",)  [decode: unsharded]
  fsdp (weights)  ("data",)                   ()  — weights replicated on data
  tp (heads/d_ff) ("model",)                  ("model",)
  cache seq       n/a                         ("model",)  [("data","model")
                                               when batch == 1 — long_500k]

Weight PartitionSpecs are derived from per-parameter *logical dim tuples*
declared next to the parameter schema (core/layers.py): e.g. wq has logical
dims ("fsdp", "tp") -> train spec P("data", "model"), serve spec
P(None, "model").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


def _present(mesh: Optional[Mesh], axes: Tuple[str, ...]) -> Tuple[str, ...]:
    if mesh is None:
        return ()
    return tuple(a for a in axes if a in mesh.axis_names)


@dataclass(frozen=True)
class Plan:
    mesh: Optional[Mesh]
    mode: str                        # train | serve
    batch_axes: Tuple[str, ...]      # residual-stream batch dim
    seq_axes: Tuple[str, ...]        # residual-stream sequence dim
    fsdp_axes: Tuple[str, ...]       # weight row-sharding (gathered at use)
    tp_axes: Tuple[str, ...]         # heads / d_ff / vocab sharding
    cache_axes: Tuple[str, ...]      # KV-cache sequence sharding (decode)
    attention_sharding: str = "head_tp"   # head_tp | seq_sp (train/prefill)
    reduce_method: str = "ring"           # ring | tree  (T3 schedule)
    gelu_impl: str = "i_gelu"             # i_gelu | gelu | gelu_exact (T5)
    naive_attention: bool = False         # paper-baseline: no flash fusion
    # fused prologue/epilogue pipeline (paper T5 generalized): pre-norms,
    # bias/activations and residual adds fold into the GEMM kernels that
    # consume/produce them (kernels/epilogue.py), so the [T, E] norm and
    # residual intermediates never round-trip HBM.  Off = the discrete
    # ops.norm -> matmul -> add chain (A/B parity baseline).  On the
    # reference dispatch path the fused pipeline is bit-identical.
    fuse_epilogues: bool = True
    # beyond-paper (§Perf P2): sequence-parallel SSD — the state recurrence
    # crosses seq shards via a log2(tp)-step associative scan of tiny
    # (decay, state) pairs instead of gathering the full sequence
    ssm_seq_parallel: bool = False
    # beyond-paper (§Perf P1): fp8 KV-cache storage (halves the decode
    # cache stream; scores upcast to fp32 for softmax stats as always)
    kv_cache_dtype: str = "bfloat16"
    # beyond-paper (§Perf P3c): fp8 residual-stream all-gathers (halves the
    # dominant Megatron-SP gather wire bytes; math still runs at act dtype)
    comm_fp8: bool = False
    # beyond-paper (§Perf P3d): weight-stationary MLP — keep the sequence
    # sharded and gather the (fp8) weights instead of gathering x and
    # reduce-scattering the output.  Wins when tokens/device * E exceeds
    # the per-layer FFN weight bytes (long-prefill serving).
    mlp_weight_stationary: bool = False
    # beyond-paper (§Perf P1b): weight-only int8 serving — dense GEMM
    # weights stored as {q: int8, scale: fp32 per-output-channel}
    # (models/quantize.quantize_params); the dequant scale folds into the
    # fp32-accumulator epilogue of the fused kernels.  Halves the weight
    # bytes streamed per decode step; "bfloat16" = lossless default.
    weight_dtype: str = "bfloat16"

    # ---- sizes ---------------------------------------------------------
    def size(self, axes: Tuple[str, ...]) -> int:
        n = 1
        for a in axes:
            n *= self.mesh.shape[a] if self.mesh else 1
        return n

    @property
    def dp(self) -> int:
        return self.size(self.batch_axes)

    @property
    def tp(self) -> int:
        return self.size(self.tp_axes)

    @property
    def sp(self) -> int:
        return self.size(self.seq_axes)

    @property
    def fsdp(self) -> int:
        return self.size(self.fsdp_axes)

    @property
    def cache_shards(self) -> int:
        return self.size(self.cache_axes)

    # ---- logical -> physical -------------------------------------------
    def _axes_of(self, logical: Optional[str]):
        if logical is None:
            return None
        phys = {
            "batch": self.batch_axes,
            "seq": self.seq_axes,
            "fsdp": self.fsdp_axes,
            "tp": self.tp_axes,
            "cache": self.cache_axes,
        }[logical]
        if not phys:
            return None
        return phys if len(phys) > 1 else phys[0]

    def pspec(self, *logical) -> P:
        return P(*(self._axes_of(l) for l in logical))

    def sharding(self, *logical) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.pspec(*logical))

    def named(self, spec: P) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, spec)


def make_plan(cfg: ModelConfig, shape: Optional[ShapeConfig],
              mesh: Optional[Mesh], *, mode: str = "train",
              reduce_method: str = "ring") -> Plan:
    """Build the sharding plan for one benchmark cell."""
    if mode == "train":
        batch = _present(mesh, ("pod", "data"))
        fsdp = _present(mesh, ("data",))
    else:
        batch = _present(mesh, ("pod", "data"))
        fsdp = ()                    # serve: weights replicated over data
    seq = _present(mesh, ("model",))
    tp = _present(mesh, ("model",))
    cache = _present(mesh, ("model",))

    gb = shape.global_batch if shape is not None else 0
    if mode == "serve" and shape is not None and gb == 1:
        # long_500k: no batch to shard -> spread the cache over the full mesh
        batch = ()
        cache = _present(mesh, ("pod", "data", "model"))
    elif mesh is not None and gb:
        # drop batch axes the batch size cannot fill
        kept = []
        rem = gb
        for a in batch:
            s = mesh.shape[a]
            if rem % s == 0 and rem >= s:
                kept.append(a)
                rem //= s
        batch = tuple(kept)

    return Plan(
        mesh=mesh, mode=mode,
        batch_axes=batch, seq_axes=seq, fsdp_axes=fsdp, tp_axes=tp,
        cache_axes=cache,
        attention_sharding=cfg.attention_sharding,
        reduce_method=reduce_method,
    )


UNSHARDED = Plan(mesh=None, mode="train", batch_axes=(), seq_axes=(),
                 fsdp_axes=(), tp_axes=(), cache_axes=())
