"""Mesh context: logical-axis resolution shared by the whole framework.

Logical axes
------------
``dp``    data parallel (batch)           -> physical ("pod", "data")
``fsdp``  fully-sharded parameter axis    -> physical "data"
``tp``    tensor parallel (heads / d_ff)  -> physical "model"
``sp``    sequence parallel (activations) -> physical "model"

Model code never names physical axes; it asks the active `MeshContext`.
With no mesh (unit tests, single-CPU benchmarks) every operation degrades
to the unsharded path: constraints become no-ops and the explicit-collective
features (fused projection, distributed softmax, MoE dispatch) run their
single-shard branch.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DEFAULT_RULES = {
    "dp": ("pod", "data"),
    "fsdp": ("data",),
    "tp": ("model",),
    "sp": ("model",),
}


@dataclass(frozen=True)
class MeshContext:
    mesh: Optional[Mesh] = None
    rules: dict = field(default_factory=lambda: dict(DEFAULT_RULES))

    # -- resolution -----------------------------------------------------
    def _axes(self, logical: Optional[str]):
        if logical is None or self.mesh is None:
            return None
        phys = tuple(a for a in self.rules.get(logical, ())
                     if a in self.mesh.axis_names)
        if not phys:
            return None
        return phys if len(phys) > 1 else phys[0]

    def pspec(self, *logical) -> P:
        return P(*(self._axes(l) for l in logical))

    def sharding(self, *logical) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.pspec(*logical))

    def constraint(self, x, *logical):
        """with_sharding_constraint that degrades to identity without a mesh."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.pspec(*logical)))

    # -- queries ---------------------------------------------------------
    def axis_size(self, logical: str) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.rules.get(logical, ()):
            if a in self.mesh.axis_names:
                n *= self.mesh.shape[a]
        return n

    def axis_names(self, logical: str) -> Tuple[str, ...]:
        if self.mesh is None:
            return ()
        return tuple(a for a in self.rules.get(logical, ())
                     if a in self.mesh.axis_names)

    @property
    def tp(self) -> int:
        return self.axis_size("tp")

    @property
    def dp(self) -> int:
        return self.axis_size("dp")


_STATE = threading.local()


def get_ctx() -> MeshContext:
    return getattr(_STATE, "ctx", None) or MeshContext()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = MeshContext(mesh, dict(rules or DEFAULT_RULES))
    try:
        if mesh is not None:
            with mesh:
                yield _STATE.ctx
        else:
            yield _STATE.ctx
    finally:
        _STATE.ctx = prev
