"""Post-optimization HLO text analysis (trip-count-aware).

`compiled.cost_analysis()` counts `while` bodies ONCE, under-reporting deep
scanned models by ~n_layers x (verified in DESIGN.md).  This parser walks the
scheduled per-partition HLO module instead:

  * builds the computation call graph (while bodies weighted by
    `known_trip_count`, fusions/calls by call-site count),
  * accumulates dot FLOPs from output shape x contracting dims, keyed by
    operand dtype (the MXU peak differs per dtype),
  * accumulates per-collective *wire bytes per device* with
    replica-group-aware ring-traffic conversion,
  * accumulates fusion-level memory traffic (operands + outputs of scheduled
    top-level ops) as the HBM-bytes proxy.

All shapes in an SPMD module are per-partition, so every number this module
reports is PER DEVICE.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1,
    "f8e4m3fnuz": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2,
    "u16": 2, "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"([\w\-]+)\((.*)$")
_COMP_NAME_RE = re.compile(r"^(ENTRY\s+)?%?([\w.$\-]+)")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*(\([^)]*\)|[a-z0-9]+"
                       r"\[[0-9,]*\](?:\{[^}]*\})?)")


def _split_header(line: str):
    """Computation header: '%name (params...) -> ret {' (params may contain
    nested tuple types).  Returns (is_entry, name, params_str) or None."""
    s = line.strip()
    if not s.endswith("{") or "->" not in s:
        return None
    m = _COMP_NAME_RE.match(s)
    if not m:
        return None
    i = s.find("(")
    if i < 0:
        return None
    depth, j = 0, i
    for j in range(i, len(s)):
        if s[j] == "(":
            depth += 1
        elif s[j] == ")":
            depth -= 1
            if depth == 0:
                break
    return bool(m.group(1)), m.group(2), s[i + 1:j]
_TRIP_RE = re.compile(r'known_trip_count":\{"n":"(\d+)"')
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_CALL_RE = re.compile(r"(?:calls|to_apply|body|computation)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_DOT_LHS_C = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _parse_type(t: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """'f32[2,3]{...}' or '(f32[2], bf16[3,4])' -> [(dtype, dims), ...]."""
    out = []
    for m in _SHAPE_RE.finditer(t):
        dims = tuple(int(x) for x in m.group(2).split(",") if x)
        out.append((m.group(1), dims))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _nelems(dims: Tuple[int, ...]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


@dataclass
class CompStats:
    """Per-computation local (un-weighted) statistics."""
    dot_flops: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    dot_flops_by_tag: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    coll_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    mem_bytes: float = 0.0
    # HBM traffic the kernel fusions eliminate: bytes a vmemk-scoped op
    # would have been charged had it streamed through HBM like the naive
    # lowering (norm/residual/activation intermediates, flash score tiles)
    elided_bytes: float = 0.0
    # (callee, multiplier, counts_mem): fusion bodies execute in VMEM/regs —
    # their HBM traffic is the fusion call site's operands+outputs, so
    # fusion-edge mem doesn't propagate (counts_mem=False)
    calls: List[Tuple[str, float, bool]] = field(default_factory=list)


@dataclass
class HloSummary:
    """Whole-module totals, per device."""
    flops_by_dtype: Dict[str, float]
    flops_by_tag: Dict[str, float]
    collective_bytes: Dict[str, float]     # per collective kind
    mem_bytes: float
    elided_bytes: float = 0.0              # fusion-eliminated HBM traffic
    debug_items: Optional[list] = None     # (bytes, comp, op, name) rows

    @property
    def total_flops(self) -> float:
        return sum(self.flops_by_dtype.values())

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _tag_of(op_name: str) -> str:
    """Coarse layer attribution for the kernel-breakdown benchmark."""
    s = op_name.lower()
    for key, tag in (("flash", "attention"), ("attention", "attention"),
                     ("ssd", "ssm"), ("ssm", "ssm"), ("moe", "moe"),
                     ("ffn", "mlp"), ("mlp", "mlp"), ("swiglu", "mlp"),
                     ("norm", "norm"), ("gelu", "mlp"), ("embed", "embed"),
                     ("ce_", "ce"), ("logits", "ce"), ("unemb", "ce")):
        if key in s:
            return tag
    return "other"


_SLICING_OPS = ("dynamic-slice", "gather", "slice")
_ELEMENTWISE = ("copy", "transpose", "reshape", "convert", "reduce",
                "select", "add", "multiply", "subtract", "divide",
                "exponential", "pad", "concatenate", "rsqrt", "tanh")
# pure data movement: a fusion made only of these streams its data once
_MOVEMENT_OPS = {"dynamic-slice", "slice", "bitcast", "convert", "copy",
                 "transpose", "reshape", "broadcast", "parameter",
                 "get-tuple-element", "tuple", "gather", "pad", "iota",
                 "constant", "concatenate"}


def _operands(rest: str):
    return re.findall(r"%([\w.\-]+)", rest.split(")")[0])


def parse_hlo(text: str, default_dot_dtype: Optional[str] = None,
              debug: bool = False, debug_min_bytes: float = 8e6,
              act_bytes: Optional[int] = None,
              param_bytes: Optional[int] = None,
              gather_act_bytes: Optional[int] = None) -> HloSummary:
    """`default_dot_dtype`: attribute every dot to this dtype (the policy's
    compute dtype) except dots inside a `ce_f32` named scope.  Needed because
    the CPU backend's float-normalization pass rewrites bf16 dots as
    convert+f32-dot+convert, erasing the dtype the TPU backend would use.

    HBM-traffic accounting reads *effective* operand bytes: an operand that
    is only sliced (dynamic-slice/gather — e.g. one layer's weights out of a
    scan's stacked parameter) costs its slice outputs, not its full size;
    dynamic-update-slice costs 2x the update, not the whole buffer.  Fusion
    call sites charge operands via the fused computation's per-parameter
    access costs."""
    # ---- pass 1: collect computations --------------------------------------
    comp_instrs: Dict[str, list] = {}
    comp_params: Dict[str, list] = {}
    comp_syms: Dict[str, dict] = {}
    comp_producer: Dict[str, dict] = {}
    entry: Optional[str] = None
    cur_name: Optional[str] = None

    for raw in text.splitlines():
        line = raw.rstrip()
        if not line or line.startswith("HloModule"):
            continue
        hdr = _split_header(line)
        if hdr is not None:
            is_entry, name, params = hdr
            cur_name = name
            comp_instrs[name] = []
            comp_params[name] = []
            comp_syms[name] = {}
            if is_entry:
                entry = name
            for pm in _PARAM_RE.finditer(params):
                comp_syms[name][pm.group(1)] = _parse_type(pm.group(2))
                comp_params[name].append(pm.group(1))
            continue
        if cur_name is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        nm, type_str, opcode, rest = mi.groups()
        shapes = _parse_type(type_str)
        comp_syms[cur_name][nm] = shapes
        comp_instrs[cur_name].append((nm, shapes, opcode, rest))
        callee = None
        if opcode == "fusion":
            mcal = re.search(r"calls=%?([\w.\-]+)", rest)
            callee = mcal.group(1) if mcal else None
        ops0 = _operands(rest)
        comp_producer.setdefault(cur_name, {})[nm] = (
            opcode, callee, ops0[0] if ops0 else None)

    if entry is None:
        raise ValueError("no ENTRY computation found")

    # ---- per-computation parameter access costs ----------------------------
    # param accessed only through slicing ops -> cost = sum of slice outputs;
    # param used only as a dynamic-update-slice BUFFER (aliased in-place,
    # e.g. the KV cache) -> 2 x the update size, not the whole buffer
    param_cost: Dict[str, Dict[str, float]] = {}
    pure_movement: Dict[str, bool] = {}
    dus_bytes: Dict[str, float] = {}       # in-place update fusions (caches)
    for cname, instrs in comp_instrs.items():
        syms = comp_syms[cname]
        pure_movement[cname] = all(op in _MOVEMENT_OPS
                                   for _, _, op, _ in instrs)
        dus = [(shapes, rest) for _, shapes, op, rest in instrs
               if op == "dynamic-update-slice"]
        if dus:
            total = 0.0
            for _, rest_ in dus:
                ops_ = _operands(rest_)
                upd = ops_[1] if len(ops_) > 1 else None
                total += 2.0 * _nbytes(syms.get(upd, []))
            dus_bytes[cname] = total
        uses: Dict[str, list] = {p: [] for p in comp_params[cname]}
        for nm, shapes, opcode, rest in instrs:
            for on in _operands(rest):
                if on in uses:
                    uses[on].append((opcode, shapes, rest))
        costs = {}
        for p in comp_params[cname]:
            full = float(_nbytes(syms.get(p, [])))
            cheap = 0.0
            ok = bool(uses[p])
            for op, sh, rest_ in uses[p]:
                ops_ = _operands(rest_)
                if op in _SLICING_OPS and ops_[:1] == [p]:
                    cheap += _nbytes(sh)
                elif op == "dynamic-update-slice" and ops_[:1] == [p]:
                    upd = ops_[1] if len(ops_) > 1 else None
                    cheap += 2.0 * _nbytes(syms.get(upd, []))
                else:
                    ok = False
                    break
            costs[p] = min(full, cheap) if ok else full
        param_cost[cname] = costs

    def movement_root(cname, on):
        """Follow `on`'s movement chain (convert / slice / reshape,
        movement-only fusions) back to the stored symbol it reads from."""
        prod = comp_producer.get(cname, {})
        seen = set()
        cur = on
        while cur not in seen:
            seen.add(cur)
            po = prod.get(cur)
            if po is None:
                break
            op, callee, src = po
            if src is None or op == "parameter":
                break
            if op in _MOVEMENT_OPS or (op == "fusion"
                                       and pure_movement.get(callee, False)):
                cur = src
                continue
            break
        return cur

    def roots_at_param(cname, on):
        po = comp_producer.get(cname, {}).get(movement_root(cname, on))
        return po is None or po[0] == "parameter"

    def stored_width(cname, on):
        """Bytes/elem of the tensor dot operand `on` actually streams from:
        the dtype of its movement-chain root.  The CPU backend widens
        narrow dot operands (bf16, and int8 weights) to f32 before the dot
        — the TPU streams them at storage width, so an s8 weight tile must
        charge 1 byte/elem no matter what the lowered operand says.
        Returns None when the chain dead-ends."""
        sh = comp_syms.get(cname, {}).get(movement_root(cname, on), [])
        if not sh:
            return None
        return max(_DTYPE_BYTES.get(dt, 4) for dt, _ in sh)

    def operand_cost(cname, rest, syms):
        """Effective operand bytes at a fusion/dot call site."""
        callee = None
        mc = re.search(r"calls=%?([\w.\-]+)", rest)
        if mc:
            callee = mc.group(1)
        total = 0.0
        ops = _operands(rest)
        for j, on in enumerate(ops):
            full = _nbytes(syms.get(on, []))
            if callee and callee in param_cost:
                pnames = comp_params.get(callee, [])
                if j < len(pnames):
                    total += min(full, param_cost[callee][pnames[j]])
                    continue
            total += full
        return total

    # per-computation majority vmemk vote: optimization strips metadata from
    # some instructions; inside a kernel-scoped loop body they inherit it
    comp_vmemk: Dict[str, bool] = {}
    for cname, instrs in comp_instrs.items():
        votes = []
        for _, _, _, rest in instrs:
            mon = _OPNAME_RE.search(rest)
            if mon:
                votes.append("vmemk" in mon.group(1))
        comp_vmemk[cname] = bool(votes) and sum(votes) > len(votes) / 2

    # ---- pass 2: accounting -------------------------------------------------
    comps: Dict[str, CompStats] = {}
    debug_items: list = []
    for cname, instrs in comp_instrs.items():
        cur = comps.setdefault(cname, CompStats())
        syms = comp_syms[cname]
        for nm, shapes, opcode, rest in instrs:
            mem_before = cur.mem_bytes
            # CPU float-normalization artifact: the backend upcasts every
            # bf16/fp8 parameter to f32 via "wrapped_convert" fusions before
            # use.  The TPU backend computes natively — skip the artifact.
            if nm.startswith("wrapped_convert"):
                continue
            mon = _OPNAME_RE.search(rest)
            op_name = mon.group(1) if mon else ""
            # "vmemk_*" scopes: math the Pallas kernels keep in VMEM — FLOPs
            # count, HBM traffic doesn't (dots still stream their operands)
            vmemk = ("vmemk" in op_name) if op_name else comp_vmemk[cname]

            if opcode == "dot":
                ml = _DOT_LHS_C.search(rest)
                cdims = ([int(x) for x in ml.group(1).split(",") if x]
                         if ml else [])
                # lhs operand: first %-symbol in the operand list (call sites
                # may carry operand type annotations — 'dot(f32[..] %a, ...)'
                # — so the first bare token is not necessarily the symbol)
                lhs_ops = _operands(rest)
                if lhs_ops:
                    lhs_shapes = syms.get(lhs_ops[0], [])
                else:
                    mo = re.match(r"%?([\w.\-]+)", rest)
                    lhs_shapes = syms.get(mo.group(1), []) if mo else []
                k = 1
                if lhs_shapes:
                    ldims = lhs_shapes[0][1]
                    for c in cdims:
                        if c < len(ldims):
                            k *= ldims[c]
                out_elems = sum(_nelems(d) for _, d in shapes)
                lhs_dt = lhs_shapes[0][0] if lhs_shapes else shapes[0][0]
                if default_dot_dtype is not None:
                    lhs_dt = ("f32" if "ce_f32" in op_name
                              else default_dot_dtype)
                flops = 2.0 * out_elems * k
                cur.dot_flops[lhs_dt] += flops
                cur.dot_flops_by_tag[_tag_of(op_name)] += flops
                # width correction: the CPU backend normalized narrow dots to
                # f32; count the traffic at the dtype the TPU would stream
                lowered_dt = lhs_shapes[0][0] if lhs_shapes else "f32"
                scale = min(1.0, _DTYPE_BYTES.get(lhs_dt, 4)
                            / max(_DTYPE_BYTES.get(lowered_dt, 4), 1))

                def op_scale(on):
                    # per-operand width: an operand whose movement chain
                    # roots in a NARROWER stored tensor than the policy dtype
                    # (int8 weight tiles) streams at that storage width
                    ow = max((_DTYPE_BYTES.get(dt, 4)
                              for dt, _ in syms.get(on, [])), default=4)
                    rw = stored_width(cname, on)
                    if rw is None:
                        return scale
                    return min(scale, rw / max(ow, 1))

                if not vmemk:
                    cur.mem_bytes += _nbytes(shapes) * scale
                    for on in _operands(rest):
                        cur.mem_bytes += (_nbytes(syms.get(on, []))
                                          * op_scale(on))
                else:
                    # kernel-interior dot: operands stream from HBM only if
                    # they come from outside the kernel (params / slices of
                    # outside tensors); tensors produced by scoped compute
                    # (probabilities, decay masks, accumulators) are VMEM
                    naive = (_nbytes(shapes)
                             + operand_cost(cname, rest, syms)) * scale
                    charged = 0.0
                    prod = comp_producer.get(cname, {})
                    for on in _operands(rest):
                        po = prod.get(on)
                        streams = (
                            po is None or po[0] == "parameter"
                            or po[0] in _MOVEMENT_OPS
                            or (po[0] == "fusion"
                                and pure_movement.get(po[1], False)))
                        if streams:
                            charged += (_nbytes(syms.get(on, []))
                                        * op_scale(on))
                    cur.mem_bytes += charged
                    cur.elided_bytes += max(naive - charged, 0.0)
            elif opcode in COLLECTIVES:
                g = 1
                mg = _GROUPS_RE.search(rest)
                if mg:
                    g = len(mg.group(1).split(","))
                else:
                    mgi = _GROUPS_IOTA_RE.search(rest)
                    if mgi:
                        g = int(mgi.group(2))
                # effective-width correction: the CPU backend carries the
                # whole program float-normalized (f32), but the TPU moves
                # activations at act_bytes and weights at param_bytes.
                # Weights are rank<=2, activations rank>=3 (batch, seq, ...).
                own_w = max((_DTYPE_BYTES.get(dt, 4) for dt, _ in shapes),
                            default=4)
                eff_w = own_w
                rank = max((len(dims) for _, dims in shapes), default=0)
                if rank <= 2:
                    hint = param_bytes
                elif opcode == "all-gather" and gather_act_bytes:
                    hint = gather_act_bytes      # deliberate fp8 gathers
                else:
                    hint = act_bytes
                if hint:
                    eff_w = min(own_w, hint)
                size = _nbytes(shapes) * (eff_w / max(own_w, 1))
                if opcode == "all-gather":
                    wire = size * (g - 1) / max(g, 1)
                elif opcode == "reduce-scatter":
                    wire = size * (g - 1)
                elif opcode == "all-reduce":
                    wire = 2.0 * size * (g - 1) / max(g, 1)
                elif opcode == "all-to-all":
                    wire = size * (g - 1) / max(g, 1)
                else:                           # collective-permute
                    wire = size
                cur.coll_bytes[opcode] += wire
                cur.mem_bytes += size
            elif opcode == "while":
                mt = _TRIP_RE.search(rest)
                trips = float(mt.group(1)) if mt else 1.0
                mb = re.search(r"body=%?([\w.\-]+)", rest)
                if mb:
                    cur.calls.append((mb.group(1), trips, True))
                mcond = _COND_RE.search(rest)
                if mcond:
                    cur.calls.append((mcond.group(1), trips + 1, True))
            elif opcode in ("fusion", "call", "conditional", "async-start"):
                counts_mem = opcode != "fusion"
                callee = None
                for mc2 in re.finditer(_CALL_RE, rest):
                    callee = mc2.group(1)
                    cur.calls.append((callee, 1.0, counts_mem))
                if opcode == "fusion":
                    if callee in dus_bytes:
                        # in-place update (KV cache / scan-stacked outputs):
                        # the buffer is aliased — charge the update twice
                        charge = dus_bytes[callee]
                    elif (callee and pure_movement.get(callee)
                          and all(_nbytes(syms.get(on, [])) <= 64
                                  for on in _operands(rest))):
                        # broadcast-from-scalar (zeros init): fuses into its
                        # consumer on TPU; no stream
                        charge = 0.0
                    elif callee and pure_movement.get(callee):
                        # slice/convert-only fusion (e.g. the CPU backend's
                        # weight upcast): one stream at the narrowest width
                        widths = [
                            _DTYPE_BYTES.get(dt, 4)
                            for dt, _ in shapes] + [
                            _DTYPE_BYTES.get(dt, 4)
                            for p in comp_params.get(callee, [])
                            for dt, _ in comp_syms[callee].get(p, [])]
                        narrow = min(widths) if widths else 4
                        elems = sum(_nelems(d) for _, d in shapes)
                        charge = elems * narrow
                    else:
                        charge = (_nbytes(shapes)
                                  + operand_cost(cname, rest, syms))
                    if not vmemk:
                        cur.mem_bytes += charge
                    else:
                        cur.elided_bytes += charge
            elif opcode in _SLICING_OPS or opcode == "broadcast":
                if not vmemk:
                    cur.mem_bytes += 2 * _nbytes(shapes)   # read slice + write
                else:
                    cur.elided_bytes += 2 * _nbytes(shapes)
            elif opcode in ("dynamic-update-slice", "scatter"):
                ops_ = _operands(rest)
                upd = ops_[1] if len(ops_) > 1 else None
                charge = (2 * _nbytes(syms.get(upd, [])) if upd
                          else _nbytes(shapes))
                if not vmemk:
                    cur.mem_bytes += charge
                else:
                    cur.elided_bytes += charge
            elif opcode in _ELEMENTWISE:
                if opcode == "copy" and cname == entry:
                    # entry-level copies are donation/output-aliasing
                    # plumbing the TPU backend elides (input_output_alias
                    # is declared for state/caches) — CPU artifact
                    continue
                if opcode == "convert":
                    # bare convert whose movement chain roots at a parameter:
                    # the inlined form of the CPU float-normalization upcast
                    # (see the wrapped_convert skip above).  The TPU reads
                    # params at storage dtype — consumers charge the stream.
                    ops_ = _operands(rest)
                    if ops_ and roots_at_param(cname, ops_[0]):
                        continue
                if not vmemk:
                    cur.mem_bytes += _nbytes(shapes)
                    cur.mem_bytes += operand_cost(cname, rest, syms)
                else:
                    cur.elided_bytes += (_nbytes(shapes)
                                         + operand_cost(cname, rest, syms))
            if debug and cur.mem_bytes - mem_before > debug_min_bytes:
                debug_items.append((cur.mem_bytes - mem_before, cname,
                                    opcode, nm))

    if entry is None:
        raise ValueError("no ENTRY computation found")

    # roll up through the call graph (memoized; weights multiply)
    memo: Dict[str, Tuple[Dict, Dict, Dict, float, float]] = {}

    def visit(name: str, stack=()):
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return ({}, {}, {}, 0.0, 0.0)
        c = comps[name]
        fd = defaultdict(float, c.dot_flops)
        ft = defaultdict(float, c.dot_flops_by_tag)
        cb = defaultdict(float, c.coll_bytes)
        mb = c.mem_bytes
        eb = c.elided_bytes
        for callee, mult, counts_mem in c.calls:
            sfd, sft, scb, smb, seb = visit(callee, stack + (name,))
            for k, v in sfd.items():
                fd[k] += v * mult
            for k, v in sft.items():
                ft[k] += v * mult
            for k, v in scb.items():
                cb[k] += v * mult
            if counts_mem:
                mb += smb * mult
                eb += seb * mult
        memo[name] = (dict(fd), dict(ft), dict(cb), mb, eb)
        return memo[name]

    fd, ft, cb, mb, eb = visit(entry)
    return HloSummary(flops_by_dtype=fd, flops_by_tag=ft,
                      collective_bytes=cb, mem_bytes=mb, elided_bytes=eb,
                      debug_items=sorted(debug_items, reverse=True)
                      if debug else None)
