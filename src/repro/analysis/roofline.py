"""Three-term roofline model for TPU v5e from the dry-run's compiled HLO.

  compute term    = dtype-weighted dot FLOPs / per-chip peak
  memory term     = HBM-traffic proxy / per-chip HBM bandwidth
  collective term = per-device collective wire bytes / per-chip ICI bandwidth

All inputs are per-device (the SPMD module is the per-device program); with
the spec's convention (totals / (chips x unit-rate)) the chip count cancels.
The dominant term is the projected step-time lower bound; the compute
fraction = compute_term / max(all terms) is the MFU-style score (§Perf).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

from repro.analysis.hlo import HloSummary

# TPU v5e hardware constants (assignment spec)
PEAK_BF16 = 197e12          # FLOP/s per chip
PEAK_FP8 = 394e12
PEAK_FP32 = 98.5e12         # bf16 peak / 2 (fp32 via MXU passes)
HBM_BW = 819e9              # B/s per chip
ICI_BW = 50e9               # B/s per link (1-link conservative convention)

_PEAK_BY_DTYPE = {
    "bf16": PEAK_BF16, "f16": PEAK_BF16, "f32": PEAK_FP32, "f64": PEAK_FP32 / 4,
    "f8e4m3fn": PEAK_FP8, "f8e5m2": PEAK_FP8, "f8e4m3": PEAK_FP8,
}


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float                    # per device
    mem_bytes: float                # per device
    coll_bytes: float               # per device
    flops_by_dtype: Dict[str, float]
    flops_by_tag: Dict[str, float]
    collective_bytes: Dict[str, float]
    # HBM traffic the fused kernels keep in VMEM (per device): what the
    # norm/residual/activation intermediates and kernel-interior tiles
    # would have cost under the naive op-by-op lowering
    mem_bytes_elided: float = 0.0

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower bound assuming perfect overlap of the three engines."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def compute_fraction(self) -> float:
        """Fraction of the step the MXU is the binding constraint (the
        roofline score: 1.0 = perfectly compute-bound)."""
        t = self.step_time_s
        return self.compute_s / t if t > 0 else 0.0

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bound": self.bound,
            "step_time_s": self.step_time_s,
            "compute_fraction": self.compute_fraction,
            "flops_per_device": self.flops,
            "mem_bytes_per_device": self.mem_bytes,
            "mem_bytes_elided_per_device": self.mem_bytes_elided,
            "coll_bytes_per_device": self.coll_bytes,
            "flops_by_dtype": self.flops_by_dtype,
            "flops_by_tag": self.flops_by_tag,
            "collective_bytes": self.collective_bytes,
        }


def roofline_from_summary(s: HloSummary) -> Roofline:
    compute = sum(v / _PEAK_BY_DTYPE.get(dt, PEAK_BF16)
                  for dt, v in s.flops_by_dtype.items())
    memory = s.mem_bytes / HBM_BW
    collective = s.total_collective_bytes / ICI_BW
    return Roofline(
        compute_s=compute, memory_s=memory, collective_s=collective,
        flops=s.total_flops, mem_bytes=s.mem_bytes,
        coll_bytes=s.total_collective_bytes,
        flops_by_dtype=dict(s.flops_by_dtype),
        flops_by_tag=dict(s.flops_by_tag),
        collective_bytes=dict(s.collective_bytes),
        mem_bytes_elided=s.elided_bytes)


def decoder_flops_per_token(cfg) -> float:
    """Analytic forward FLOPs per token position: 2 * N_active, embedding
    tables excluded — the per-token factor `model_flops` scales by token
    count, exposed on its own for the serving engine's per-phase MFU
    attribution (serving/trace.py, EngineStats.phase_util)."""
    n = cfg.n_active_params()
    n -= cfg.padded_vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return 2.0 * n


def utilization(flops: float, mem_bytes: float, time_s: float, *,
                peak_flops: float = PEAK_BF16,
                hbm_bw: float = HBM_BW) -> Tuple[float, float]:
    """(MFU, MBU) for an interval: achieved FLOP/s and HBM byte/s as a
    fraction of the chip peaks.  MFU = model FLOPs utilization (analytic
    useful FLOPs / peak compute); MBU = memory-bandwidth utilization
    (weight + KV traffic / peak HBM bandwidth).  (0, 0) on empty
    intervals."""
    if time_s <= 0:
        return 0.0, 0.0
    return flops / (time_s * peak_flops), mem_bytes / (time_s * hbm_bw)


def model_flops(cfg, shape) -> float:
    """Analytic 'useful' FLOPs for the whole step (all devices).

    train: 6*N_active*D tokens; prefill: 2*N_active*D; decode: 2*N_active*B
    (one token per sequence).  N excludes embedding tables."""
    n = decoder_flops_per_token(cfg) / 2.0
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch
