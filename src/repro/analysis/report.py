"""Roofline report generation from dry-run artifacts (EXPERIMENTS.md §Roofline).

    PYTHONPATH=src python -m repro.analysis.report [--dir artifacts/dryrun]

Emits the per-(arch x shape) baseline table (all three terms, dominant
bottleneck, useful-FLOPs ratio, HBM/device) and flags the three hillclimb
candidates: worst compute fraction, most collective-bound, most
representative of the paper's technique.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from collections import defaultdict

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dir_: str, mesh: str = "single", tag: str = "baseline"):
    recs = {}
    for f in glob.glob(os.path.join(dir_, "*.json")):
        r = json.load(open(f))
        if r.get("mesh") != mesh or r.get("tag") != tag:
            continue
        recs[(r["arch"], r["shape"])] = r
    return recs


def fmt_row(r):
    if r.get("skipped"):
        return None
    rf = r["roofline"]
    note = ""
    return [
        r["arch"], r["shape"],
        f"{rf['compute_s']*1e3:.1f}", f"{rf['memory_s']*1e3:.1f}",
        f"{rf['collective_s']*1e3:.1f}", rf["bound"],
        f"{rf['compute_fraction']:.2f}",
        f"{r['useful_flops_ratio']:.2f}",
        f"{r['hbm_per_device_gb']:.1f}",
    ]


HEADER = ["arch", "shape", "compute(ms)", "memory(ms)", "collective(ms)",
          "bound", "comp-frac", "useful/HLO", "HBM GB/dev"]


def markdown_table(rows):
    out = ["| " + " | ".join(HEADER) + " |",
           "|" + "|".join("---" for _ in HEADER) + "|"]
    for row in rows:
        out.append("| " + " | ".join(row) + " |")
    return "\n".join(out)


def pick_hillclimb(recs):
    """(worst compute fraction, most collective-bound, most representative)."""
    live = [(k, r) for k, r in recs.items() if r.get("ok")]
    worst = min(live, key=lambda kv: kv[1]["roofline"]["compute_fraction"])
    coll = max(live, key=lambda kv: (kv[1]["roofline"]["collective_s"]
                                     / max(kv[1]["roofline"]["step_time_s"],
                                           1e-12)))
    # most representative of the paper: decoder-only GQA dense prefill (the
    # paper's NAR GPT benchmark at scale) — deepseek-67b prefill_32k
    rep = recs.get(("deepseek-67b", "prefill_32k"))
    return worst, coll, (("deepseek-67b", "prefill_32k"), rep)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()
    recs = load(args.dir, args.mesh, args.tag)
    rows = []
    for (arch, shape) in sorted(recs, key=lambda k: (k[0],
                                                     SHAPE_ORDER.index(k[1])
                                                     if k[1] in SHAPE_ORDER
                                                     else 9)):
        row = fmt_row(recs[(arch, shape)])
        if row:
            rows.append(row)
    print(markdown_table(rows))
    skipped = [(a, s) for (a, s), r in sorted(recs.items())
               if r.get("skipped")]
    if skipped:
        print("\nskipped (long_500k needs sub-quadratic attention): "
              + ", ".join(f"{a}" for a, _ in skipped))
    worst, coll, rep = pick_hillclimb(recs)
    print("\nhillclimb candidates:")
    print(f"  worst compute fraction: {worst[0]} "
          f"({worst[1]['roofline']['compute_fraction']:.3f})")
    print(f"  most collective-bound:  {coll[0]} "
          f"(coll {coll[1]['roofline']['collective_s']*1e3:.1f}ms of "
          f"{coll[1]['roofline']['step_time_s']*1e3:.1f}ms)")
    print(f"  paper-representative:   {rep[0]}")


if __name__ == "__main__":
    main()
