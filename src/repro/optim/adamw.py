"""AdamW on parameter shards (manual-SPMD).

State (m, v) is sharded exactly like the fp32 master parameters, so the
optimizer is a pure elementwise map over local shards.  Global-norm clipping
is shard-aware: each leaf's local sum-of-squares is psum'd over the mesh
axes *its spec shards* (replication axes contribute once).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import collectives as col


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def global_grad_norm(grads, shard_axes):
    """shard_axes: flat list aligned with jax.tree.leaves(grads); each entry
    is the tuple of mesh axes the leaf is *sharded* over (psum over those
    sums distinct shards; replication axes contribute once)."""
    leaves = jax.tree.leaves(grads)
    assert len(leaves) == len(shard_axes), (len(leaves), len(shard_axes))
    total = jnp.zeros((), jnp.float32)
    for g, ax in zip(leaves, shard_axes):
        gf = g.astype(jnp.float32)
        total = total + col.psum(jnp.sum(gf * gf), ax)
    return jnp.sqrt(total)


def clip_by_global_norm(grads, shard_axes, max_norm: float):
    norm = global_grad_norm(grads, shard_axes)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(params, grads, opt, *, step, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    """params/grads/opt['m'|'v']: matching trees of fp32 shards.
    Returns (new_params, new_opt)."""
    stepf = step.astype(jnp.float32) + 1.0
    c1 = 1.0 - b1 ** stepf
    c2 = 1.0 - b2 ** stepf

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mh = m2 / c1
        vh = v2 / c2
        step_ = mh / (jnp.sqrt(vh) + eps) + weight_decay * p
        return p - lr * step_, m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        p2, m2, v2 = upd(p, g, m, v)
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    return (jax.tree.unflatten(tdef, new_p),
            {"m": jax.tree.unflatten(tdef, new_m),
             "v": jax.tree.unflatten(tdef, new_v)})
