"""LR schedules (pure jnp functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        s = step.astype(jnp.float32)
        wu = jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
        decay = jnp.maximum(0.0, 1.0 - jnp.maximum(s - warmup, 0)
                            / jnp.maximum(total - warmup, 1))
        return base_lr * wu * decay
    return lr


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        wu = jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
        t = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return base_lr * wu * cos
    return lr
