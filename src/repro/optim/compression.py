"""Int8 gradient compression with error feedback (cross-pod DP reduction).

At 1000+ nodes the pod-level data-parallel all-reduce crosses the slowest
links (DCN / optical inter-pod).  This module provides an explicit int8
recursive-halving all-reduce built on `ppermute`: every hop ships int8
payloads + one fp32 scale (≈4x fewer wire bytes than fp32, 2x vs bf16);
accumulation stays fp32 locally.  The initial quantization error is returned
for error feedback (carried in optimizer state, added to the next step's
gradient) — the standard EF-SGD/1-bit-Adam trick that restores convergence.

Used by launch/steps.py when `grad_compression="int8"`; the quantizers are
hypothesis-tested in tests/test_compression.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import collectives as col


def quantize_int8_axiswise(x, axis=None):
    """Symmetric int8 with one fp32 scale per index along `axis`.

    `axis=None` collapses to per-tensor (scalar scale); an int or tuple of
    ints keeps those axes and reduces the amax over all others.  The shared
    core for the gradient path (per-tensor), weight quantization
    (per-output-channel), and the paged-KV pool (per-block-per-head).
    -> (q int8 same shape as x, scale fp32 with x.shape restricted to
    `axis` dims).
    """
    xf = x.astype(jnp.float32)
    if axis is None:
        reduce_axes = None
    else:
        keep = {a % xf.ndim for a in
                (axis if isinstance(axis, tuple) else (axis,))}
        reduce_axes = tuple(a for a in range(xf.ndim) if a not in keep)
    amax = jnp.max(jnp.abs(xf), axis=reduce_axes)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    if axis is None:
        s_b = scale
    else:
        s_b = jnp.expand_dims(scale, reduce_axes)
    q = jnp.clip(jnp.round(xf / s_b), -127, 127).astype(jnp.int8)
    return q, scale


def quantize_int8(x):
    """Per-tensor symmetric int8.  -> (q int8, scale fp32 scalar)."""
    return quantize_int8_axiswise(x, axis=None)


def dequantize_int8(q, scale, axis=None):
    """Inverse of `quantize_int8_axiswise`: broadcast `scale` back over the
    reduced axes (scalar scale broadcasts trivially; per-axis scales need
    `axis` to say which dims they live on)."""
    qf = q.astype(jnp.float32)
    if axis is None or jnp.ndim(scale) == 0:
        return qf * scale
    keep = {a % qf.ndim for a in
            (axis if isinstance(axis, tuple) else (axis,))}
    expand = tuple(a for a in range(qf.ndim) if a not in keep)
    return qf * jnp.expand_dims(scale, expand)


def _halving_exchange(x_send, axis: str, step: int, n: int):
    perm = []
    for i in range(n):
        b = (i // step) % 2
        perm.append((i, i + step if b == 0 else i - step))
    return jax.lax.ppermute(x_send, axis, perm)


def int8_allreduce(x, axis: str):
    """All-reduce over `axis` with int8 wire payloads.

    Recursive-halving reduce-scatter (each hop quantizes the outgoing half)
    followed by an int8 recursive-doubling all-gather.  Returns fp32.
    Requires a power-of-two axis; falls back to psum for size 1.
    """
    n = col.one_axis_size(axis)
    if n == 1:
        return x.astype(jnp.float32)
    assert n & (n - 1) == 0, f"int8_allreduce needs power-of-two axis, got {n}"
    shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    pad = -flat.shape[0] % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    idx = jax.lax.axis_index(axis)
    size = flat.shape[0]

    # reduce-scatter by recursive halving, int8 on the wire
    buf = flat
    offset = jnp.zeros((), jnp.int32)
    width = size
    step = n // 2
    while step >= 1:
        width //= 2
        bit = (idx // step) % 2
        my_off = offset + bit * width
        their_off = offset + (1 - bit) * width
        send = jax.lax.dynamic_slice(buf, (their_off,), (width,))
        q, s = quantize_int8(send)
        q_r = _halving_exchange(q, axis, step, n)
        s_r = _halving_exchange(s, axis, step, n)
        mine = jax.lax.dynamic_slice(buf, (my_off,), (width,))
        buf = jax.lax.dynamic_update_slice(
            buf, mine + dequantize_int8(q_r, s_r), (my_off,))
        offset = my_off
        step //= 2
    chunk = jax.lax.dynamic_slice(buf, (offset,), (width,))

    # all-gather the reduced chunks, int8 on the wire
    q, s = quantize_int8(chunk)
    q_all = jax.lax.all_gather(q, axis, axis=0, tiled=True)
    s_all = jax.lax.all_gather(s[None], axis, axis=0)
    full = (q_all.reshape(n, width).astype(jnp.float32)
            * s_all.reshape(n, 1)).reshape(-1)
    if pad:
        full = full[:size - pad]
    return full.reshape(shape)


def ef_compressed_psum(g, err, axis: str):
    """Error-feedback wrapper: reduce (g + err) in int8, return the reduced
    gradient and the new local error (what quantization dropped)."""
    gf = g.astype(jnp.float32) + err
    q, s = quantize_int8(gf)
    new_err = gf - dequantize_int8(q, s)
    reduced = int8_allreduce(dequantize_int8(q, s), axis)
    return reduced, new_err
