"""Int8 gradient compression with error feedback (cross-pod DP reduction).

At 1000+ nodes the pod-level data-parallel all-reduce crosses the slowest
links (DCN / optical inter-pod).  This module provides an explicit int8
recursive-halving all-reduce built on `ppermute`: every hop ships int8
payloads + one fp32 scale (≈4x fewer wire bytes than fp32, 2x vs bf16);
accumulation stays fp32 locally.  The initial quantization error is returned
for error feedback (carried in optimizer state, added to the next step's
gradient) — the standard EF-SGD/1-bit-Adam trick that restores convergence.

Used by launch/steps.py when `grad_compression="int8"`; the quantizers are
hypothesis-tested in tests/test_compression.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import collectives as col


def quantize_int8(x):
    """Per-tensor symmetric int8.  -> (q int8, scale fp32 scalar)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def _halving_exchange(x_send, axis: str, step: int, n: int):
    perm = []
    for i in range(n):
        b = (i // step) % 2
        perm.append((i, i + step if b == 0 else i - step))
    return jax.lax.ppermute(x_send, axis, perm)


def int8_allreduce(x, axis: str):
    """All-reduce over `axis` with int8 wire payloads.

    Recursive-halving reduce-scatter (each hop quantizes the outgoing half)
    followed by an int8 recursive-doubling all-gather.  Returns fp32.
    Requires a power-of-two axis; falls back to psum for size 1.
    """
    n = col.one_axis_size(axis)
    if n == 1:
        return x.astype(jnp.float32)
    assert n & (n - 1) == 0, f"int8_allreduce needs power-of-two axis, got {n}"
    shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    pad = -flat.shape[0] % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    idx = jax.lax.axis_index(axis)
    size = flat.shape[0]

    # reduce-scatter by recursive halving, int8 on the wire
    buf = flat
    offset = jnp.zeros((), jnp.int32)
    width = size
    step = n // 2
    while step >= 1:
        width //= 2
        bit = (idx // step) % 2
        my_off = offset + bit * width
        their_off = offset + (1 - bit) * width
        send = jax.lax.dynamic_slice(buf, (their_off,), (width,))
        q, s = quantize_int8(send)
        q_r = _halving_exchange(q, axis, step, n)
        s_r = _halving_exchange(s, axis, step, n)
        mine = jax.lax.dynamic_slice(buf, (my_off,), (width,))
        buf = jax.lax.dynamic_update_slice(
            buf, mine + dequantize_int8(q_r, s_r), (my_off,))
        offset = my_off
        step //= 2
    chunk = jax.lax.dynamic_slice(buf, (offset,), (width,))

    # all-gather the reduced chunks, int8 on the wire
    q, s = quantize_int8(chunk)
    q_all = jax.lax.all_gather(q, axis, axis=0, tiled=True)
    s_all = jax.lax.all_gather(s[None], axis, axis=0)
    full = (q_all.reshape(n, width).astype(jnp.float32)
            * s_all.reshape(n, 1)).reshape(-1)
    if pad:
        full = full[:size - pad]
    return full.reshape(shape)


def ef_compressed_psum(g, err, axis: str):
    """Error-feedback wrapper: reduce (g + err) in int8, return the reduced
    gradient and the new local error (what quantization dropped)."""
    gf = g.astype(jnp.float32) + err
    q, s = quantize_int8(gf)
    new_err = gf - dequantize_int8(q, s)
    reduced = int8_allreduce(dequantize_int8(q, s), axis)
    return reduced, new_err
