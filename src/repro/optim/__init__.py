from repro.optim.adamw import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.schedule import cosine_schedule, linear_schedule
from repro.optim.compression import int8_allreduce, quantize_int8, dequantize_int8
