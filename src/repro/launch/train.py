"""End-to-end fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b \
        --reduced --steps 200 --global-batch 8 --seq 128 \
        --checkpoint-dir /tmp/ckpt [--resume] [--compress int8]

Runs on whatever devices exist (CPU: reduced configs; TPU pod: full).
Features wired in: step-indexed resumable data pipeline, async sharded
checkpoints, SIGTERM -> checkpoint -> exit 42, straggler watchdog,
optional int8 cross-pod gradient compression, XLA latency-hiding flags.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# compute/comm overlap: latency-hiding scheduler (effective on TPU; harmless
# on CPU).  Must be set before jax initializes.
os.environ.setdefault(
    "LIBTPU_INIT_ARGS",
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_enable_async_collective_fusion=true")

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data import make_stream
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_mesh_for
from repro.runtime import PreemptionGuard, StragglerWatchdog
from repro.runtime.fault_tolerance import RESTART_EXIT_CODE


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--data", default="", help="binary token file (optional)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compress", default="", choices=["", "int8"])
    ap.add_argument("--single-device", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeConfig("cli_train", "train", args.seq, args.global_batch)
    mesh = None if args.single_device else make_mesh_for(len(jax.devices()))
    from repro.optim import cosine_schedule
    bundle = steps_mod.make_train_step(
        cfg, shape, mesh,
        lr_fn=cosine_schedule(args.lr, min(100, args.steps // 10 + 1),
                              args.steps),
        grad_compression=args.compress or None)
    stream = make_stream(cfg, global_batch=args.global_batch,
                         seq_len=args.seq + (cfg.n_patches or 0),
                         path=args.data or None, seed=args.seed)

    ckpt = Checkpointer(args.checkpoint_dir) if args.checkpoint_dir else None
    start = 0
    if ckpt and args.resume and ckpt.latest_step() is not None:
        shardings = steps_mod.to_shardings(bundle.aux["state_specs"], mesh)
        state = ckpt.restore(bundle.in_structs[0], shardings=shardings)
        start = int(ckpt.latest_step())
        print(f"resumed from step {start}")
    else:
        state = bundle.aux["init_state"](args.seed)

    watchdog = StragglerWatchdog(
        on_straggler=lambda s, dt, mu: print(
            f"[straggler] step {s}: {dt*1e3:.0f}ms vs mean {mu*1e3:.0f}ms"))

    with PreemptionGuard() as guard:
        for step in range(start, args.steps):
            watchdog.start()
            state, metrics = bundle.fn(state, stream.batch(step))
            jax.block_until_ready(metrics["loss"])
            watchdog.stop(step)
            if (step + 1) % args.log_every == 0 or step == start:
                print(f"step {step+1:6d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e}")
            if ckpt and (step + 1) % args.checkpoint_every == 0:
                ckpt.save_async(state, step + 1)
            if guard.should_stop:
                print("preempted: checkpointing and exiting for restart")
                if ckpt:
                    ckpt.save(state, step + 1)
                return RESTART_EXIT_CODE
    if ckpt:
        ckpt.save(state, args.steps)
        ckpt.wait()
    print(f"done: {args.steps} steps, final loss "
          f"{float(metrics['loss']):.4f}, stragglers {len(watchdog.events)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
