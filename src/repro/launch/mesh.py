"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* first init.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """`jax.make_mesh` across jax versions: `axis_types` (and
    `jax.sharding.AxisType`) only exist on newer releases; every axis here
    is Auto either way, which is also the old default."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = ("data", "model") — 256 chips.
    Multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (forced host devices)."""
    return _make_mesh(shape, axes)


def make_mesh_for(n_devices: int):
    """Best-effort (data, model) mesh over whatever devices exist."""
    model = 1
    for cand in (16, 8, 4, 2, 1):
        if n_devices % cand == 0:
            model = cand
            break
    return make_test_mesh((n_devices // model, model), ("data", "model"))
