"""Cache key for dry-run cell records — importable without side effects.

`repro.launch.dryrun` sets XLA_FLAGS at import time (it must land before
jax initializes in its subprocesses), so cache *readers* that never touch
jax — `benchmarks/common.cell()` in particular — import the key from here
instead.  Keep every result-affecting `run_cell` knob in this dict: a
cached record whose ``variant`` differs from the requested flags (tag
collision, legacy record, changed default) must be recomputed, never
returned verbatim.
"""
from __future__ import annotations

# Single source of the knob defaults: variant_key()'s signature, dryrun's
# argparse defaults, and run_cell()'s signature all derive from this dict —
# a default that drifts in one copy would make every cached record's
# variant mismatch and silently recompile every cell on every bench run.
DEFAULTS = {"policy": "", "naive": False, "reduce": "ring", "nofuse": False,
            "ssm_seqp": False, "kv_cache_dtype": "bfloat16",
            "weight_dtype": "bfloat16", "attn_sharding": "",
            "comm_fp8": False, "mlp_ws": False}


def variant_key(*, policy: str = DEFAULTS["policy"],
                naive: bool = DEFAULTS["naive"],
                reduce_method: str = DEFAULTS["reduce"],
                fuse: bool = not DEFAULTS["nofuse"],
                ssm_seqp: bool = DEFAULTS["ssm_seqp"],
                kv_cache_dtype: str = DEFAULTS["kv_cache_dtype"],
                weight_dtype: str = DEFAULTS["weight_dtype"],
                attn_sharding: str = DEFAULTS["attn_sharding"],
                comm_fp8: bool = DEFAULTS["comm_fp8"],
                mlp_ws: bool = DEFAULTS["mlp_ws"]) -> dict:
    return {"policy": policy, "naive": naive, "reduce": reduce_method,
            "nofuse": not fuse, "ssm_seqp": ssm_seqp,
            "kv_cache_dtype": kv_cache_dtype, "weight_dtype": weight_dtype,
            "attn_sharding": attn_sharding,
            "comm_fp8": comm_fp8, "mlp_ws": mlp_ws}
