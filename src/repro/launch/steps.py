"""Step builders: jit(shard_map(...)) train / prefill / decode steps.

This is the distribution boundary.  Each builder:
  1. derives the `Plan` for (arch, shape, mesh, mode),
  2. resolves every parameter / cache / batch leaf's logical dims to a
     PartitionSpec,
  3. wraps the manual-SPMD model forward in one `shard_map`,
  4. returns a `StepBundle` with the jitted fn + fully-sharded
     ShapeDtypeStructs — exactly what the dry-run `.lower().compile()`s and
     what train.py / serve.py execute.

Gradient synchronization (train): gradients are taken *inside* the
shard_map, so collective transposes handle the fsdp/tp reductions and the
remaining replication axes (the pure-DP `pod` axis, tp-replicated scalars)
are reduced explicitly by `grad_sync` — the hook where int8 error-feedback
compression applies to the cross-pod hop (optim/compression.py).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import blocks
from repro.core import collectives as col
from repro.core.attention import CACHE_DTYPE
from repro.core.nn import act_dtype
from repro.core.precision import BF16, FP8_SERVE, Policy, get_policy
from repro.models import frontends, lm
from repro.models.quantize import quantize_param_dims, quantize_params
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_schedule)
from repro.optim.compression import ef_compressed_psum
from repro.sharding.plan import Plan, make_plan

IS_DIMS = lambda x: isinstance(x, tuple) and all(
    isinstance(d, (str, type(None))) for d in x)

# Sharding-invariant RNG: on jax versions where threefry_partitionable still
# defaults False, RNG lowered under sharded outputs (init_state's
# out_shardings) produces different bits than the same program run eagerly /
# unsharded — the multi-device equivalence contract (tests/_distributed_prog)
# needs identical params either way.  Partitionable threefry guarantees it.
# NOTE this is a PROCESS-GLOBAL flag flipped at import: any program that
# imports this module (directly or via repro.serving) gets partitionable
# threefry bits everywhere, which differ from the legacy algorithm's.  It
# lives here rather than per-entrypoint because every step builder, test
# subprocess and serving engine funnels through this module, and a path
# that missed the flag would silently break cross-sharding determinism.
jax.config.update("jax_threefry_partitionable", True)


# --------------------------------------------------------------------------
# spec resolution
# --------------------------------------------------------------------------

def resolve_pspecs(dims_tree, plan: Plan):
    return jax.tree.map(lambda d: plan.pspec(*d), dims_tree, is_leaf=IS_DIMS)


def to_shardings(spec_tree, mesh: Optional[Mesh]):
    if mesh is None:
        return jax.tree.map(lambda s: None, spec_tree,
                            is_leaf=lambda x: isinstance(x, P))
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def with_shardings(struct_tree, spec_tree, mesh: Optional[Mesh]):
    """Attach NamedShardings to ShapeDtypeStructs (dry-run inputs)."""
    if mesh is None:
        return struct_tree
    return jax.tree.map(
        lambda st, sp: jax.ShapeDtypeStruct(st.shape, st.dtype,
                                            sharding=NamedSharding(mesh, sp)),
        struct_tree, spec_tree)


def _sharded_axes(dims, plan: Plan):
    spec = plan.pspec(*dims)
    out = []
    for entry in spec:
        if entry is None:
            continue
        out.extend(entry if isinstance(entry, tuple) else (entry,))
    return tuple(out)


def shard_axes_list(dims_tree, plan: Plan):
    """Flat list (aligned with jax.tree.leaves of the params) of mesh-axis
    tuples each leaf is SHARDED over."""
    return [_sharded_axes(d, plan)
            for d in jax.tree.leaves(dims_tree, is_leaf=IS_DIMS)]


def replication_axes_list(dims_tree, plan: Plan):
    """Flat list of mesh-axis tuples each leaf is REPLICATED over."""
    if plan.mesh is None:
        return [() for _ in jax.tree.leaves(dims_tree, is_leaf=IS_DIMS)]
    all_axes = tuple(plan.mesh.axis_names)
    return [tuple(a for a in all_axes if a not in sh)
            for sh in shard_axes_list(dims_tree, plan)]


# --------------------------------------------------------------------------
# policies
# --------------------------------------------------------------------------

def default_policy(cfg: ModelConfig, mode: str) -> Policy:
    if mode == "train":
        return BF16
    if cfg.name == "mixtral-8x22b":
        return FP8_SERVE          # fp8 storage: fits the 16-chip TP column
    return BF16


# --------------------------------------------------------------------------
# cache layout
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class PagedLayout:
    """Static description of a block-paged KV cache layout.

    The pools are [count, num_blocks, block_size, KV, hd] per full-attention
    segment (block dim sharded over `plan.cache_axes`); one [B, max_blocks]
    block table addresses every layer — block b of a sequence lives in pool
    slot table[b] of EVERY paged segment.  `segments` marks which schedule
    entries are paged (window/ring, SSM and cross-attention caches stay
    dense per-slot)."""
    num_blocks: int
    block_size: int
    max_blocks: int                     # table width: ceil(max_seq / bs)
    segments: tuple                     # per-segment bool: k/v are pools

    @property
    def any_paged(self) -> bool:
        return any(self.segments)


def serve_dp(cfg: ModelConfig, shape: ShapeConfig,
             mesh: Optional[Mesh]) -> int:
    """Batch-shard count the serve plan for this shape uses — the single
    probe for "can this batch be paged?" (a paged pool is shared across
    slots, so the engine needs dp == 1; make_decode_step asserts the same
    plan-derived value)."""
    return make_plan(cfg, shape, mesh, mode="serve").dp


def make_paged_layout(cfg: ModelConfig, plan: Plan, max_seq: int,
                      num_blocks: int, block_size: int) -> PagedLayout:
    """Round the pool up to the cache-shard count and mark paged segments."""
    shards = max(plan.cache_shards, 1)
    nb = -(-num_blocks // shards) * shards
    return PagedLayout(
        num_blocks=nb, block_size=block_size,
        max_blocks=-(-max_seq // block_size),
        segments=tuple(blocks.kind_paged(kind, cfg, max_seq)
                       for kind, _ in cfg.schedule))


def cache_layout(cfg: ModelConfig, plan: Plan, global_batch: int,
                 max_seq: int, policy: Policy,
                 paged: Optional[PagedLayout] = None):
    """(struct tree, logical-dims tree) mirroring the prefill cache pytree.
    With `paged`, full-attention k/v leaves become block pools.

    kv_cache_dtype="int8" applies to the PAGED pools only (per-block-
    per-head fp32 scale leaves "ks"/"vs" ride alongside "k"/"v"; the
    scatter/append paths quantize on write and the paged kernels dequantize
    in-register).  Dense ring / cross-attention caches have no block
    granularity to hang scales off and stay bf16 — lossless."""
    B = global_batch
    kv_dtype = jnp.dtype(plan.kv_cache_dtype)
    int8_kv = kv_dtype == jnp.dtype(jnp.int8)
    dense_dtype = jnp.dtype(CACHE_DTYPE) if int8_kv else kv_dtype
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    Hp, Pd, N = cfg.padded_ssm_heads(), cfg.ssm_head_dim, cfg.ssm_state
    cw, dip = cfg.conv_width, cfg.padded_d_inner()
    ad = act_dtype(policy)
    structs, dims = [], []
    for kind, count in cfg.schedule:
        d, dm = {}, {}
        if kind in blocks.ATTN_KINDS:
            W = blocks.kind_cache_len(kind, cfg, max_seq)
            kv_dims = (None, "batch", "cache", None, None)
            if paged is not None and blocks.kind_paged(kind, cfg, max_seq):
                shape = (count, paged.num_blocks, paged.block_size, KV, hd)
                kv_dims = (None, "cache", None, None, None)
                d["k"] = jax.ShapeDtypeStruct(shape, kv_dtype)
                d["v"] = jax.ShapeDtypeStruct(shape, kv_dtype)
                dm["k"] = dm["v"] = kv_dims
                if int8_kv:
                    sshape = (count, paged.num_blocks, KV)
                    d["ks"] = jax.ShapeDtypeStruct(sshape, jnp.float32)
                    d["vs"] = jax.ShapeDtypeStruct(sshape, jnp.float32)
                    dm["ks"] = dm["vs"] = (None, "cache", None)
            else:
                d["k"] = jax.ShapeDtypeStruct((count, B, W, KV, hd),
                                              dense_dtype)
                d["v"] = jax.ShapeDtypeStruct((count, B, W, KV, hd),
                                              dense_dtype)
                dm["k"] = dm["v"] = kv_dims
            if kind == "dec":
                We = cfg.enc_seq_padded
                d["ck"] = jax.ShapeDtypeStruct((count, B, We, KV, hd),
                                               dense_dtype)
                d["cv"] = jax.ShapeDtypeStruct((count, B, We, KV, hd),
                                               dense_dtype)
                # cross-attn memory is per-slot dense even under paging
                dm["ck"] = dm["cv"] = (None, "batch", "cache", None, None)
        if kind in blocks.SSM_KINDS or kind == "ssm":
            d["h"] = jax.ShapeDtypeStruct((count, B, Hp, Pd, N), jnp.float32)
            dm["h"] = (None, "batch", "tp", None, None)
            d["cx"] = jax.ShapeDtypeStruct((count, B, cw - 1, dip), ad)
            dm["cx"] = (None, "batch", None, "tp")
            d["cbc"] = jax.ShapeDtypeStruct((count, B, cw - 1, 2 * N), ad)
            dm["cbc"] = (None, "batch", None, None)
        structs.append(d)
        dims.append(dm)
    return tuple(structs), tuple(dims)


def batch_dims(cfg: ModelConfig, shape_kind: str):
    out = {"tokens": ("batch", None)}
    if shape_kind == "train":
        out["labels"] = ("batch", None)
    if cfg.n_patches:
        out["patches"] = ("batch", None, None)
    if cfg.enc_schedule:
        out["frames"] = ("batch", None, None)
    return out


def lane_dims(with_prompt_len: bool):
    """Logical dims of the per-request sampling lane ([B] leaves)."""
    out = {"temperature": ("batch",), "top_k": ("batch",),
           "seed": ("batch",)}
    if with_prompt_len:
        out["prompt_len"] = ("batch",)
    return out


def lane_struct(global_batch: int, with_prompt_len: bool):
    B = global_batch
    out = {"temperature": jax.ShapeDtypeStruct((B,), jnp.float32),
           "top_k": jax.ShapeDtypeStruct((B,), jnp.int32),
           "seed": jax.ShapeDtypeStruct((B,), jnp.int32)}
    if with_prompt_len:
        out["prompt_len"] = jax.ShapeDtypeStruct((B,), jnp.int32)
    return out


# --------------------------------------------------------------------------
# bundles
# --------------------------------------------------------------------------

@dataclass
class StepBundle:
    fn: Any                       # jitted step function
    plan: Plan
    policy: Policy
    cfg: ModelConfig
    in_structs: tuple             # ShapeDtypeStructs with shardings (dry-run)
    in_specs: tuple = ()
    aux: dict = field(default_factory=dict)

    def lower(self):
        return self.fn.lower(*self.in_structs)


def _maybe_shard_map(fn, mesh, in_specs, out_specs):
    if mesh is None:
        return fn
    return col.shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs)


def _param_struct(cfg, dtype, weight_dtype: str = "bfloat16"):
    init = functools.partial(lm.init_lm, cfg=cfg, dtype=dtype)
    fn = init if weight_dtype != "int8" else (
        lambda key: quantize_params(init(key)))
    return jax.eval_shape(fn, jax.random.key(0))


def _serve_param_layout(cfg, policy, weight_dtype: str):
    """(dims, struct) for the serving param tree — weight-only int8 swaps
    every dense GEMM leaf for its {"q", "scale"} pair (models/quantize)."""
    p_dims = lm.lm_param_dims(cfg)
    if weight_dtype == "int8":
        p_dims = quantize_param_dims(p_dims)
    return p_dims, _param_struct(cfg, policy.param_dtype, weight_dtype)


# --------------------------------------------------------------------------
# train step
# --------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, shape: ShapeConfig,
                    mesh: Optional[Mesh], *,
                    policy: Optional[Policy] = None,
                    lr_fn: Optional[Callable] = None,
                    max_grad_norm: float = 1.0,
                    grad_compression: Optional[str] = None,
                    reduce_method: str = "ring",
                    gelu_impl: str = "i_gelu",
                    naive_attention: bool = False,
                    ssm_seq_parallel: bool = False,
                    fuse_epilogues: bool = True) -> StepBundle:
    import dataclasses
    policy = policy or default_policy(cfg, "train")
    lr_fn = lr_fn or cosine_schedule(3e-4, 100, 10_000)
    plan = make_plan(cfg, shape, mesh, mode="train",
                     reduce_method=reduce_method)
    plan = dataclasses.replace(plan, gelu_impl=gelu_impl,
                               naive_attention=naive_attention,
                               ssm_seq_parallel=ssm_seq_parallel,
                               fuse_epilogues=fuse_epilogues)

    p_dims = lm.lm_param_dims(cfg)
    p_specs = resolve_pspecs(p_dims, plan)
    p_struct = _param_struct(cfg, jnp.float32)
    rep_axes = replication_axes_list(p_dims, plan)
    shard_axes = shard_axes_list(p_dims, plan)
    compress_pod = (grad_compression == "int8"
                    and plan.mesh is not None
                    and "pod" in plan.mesh.axis_names)

    state_specs = {"step": P(), "params": p_specs,
                   "opt": {"m": p_specs, "v": p_specs}}
    if compress_pod:
        state_specs["ef"] = p_specs
    b_dims = batch_dims(cfg, "train")
    b_specs = resolve_pspecs(b_dims, plan)
    b_struct = frontends.batch_struct(cfg, "train", shape.global_batch,
                                      shape.seq_len)
    metric_specs = {"loss": P(), "ce": P(), "grad_norm": P(), "lr": P(),
                    "tokens": P()}
    if cfg.n_experts:
        metric_specs["aux"] = P()

    def body(state, batch):
        col.set_reduce_method(plan.reduce_method)   # T3 schedule selection

        def loss_fn(params_f32):
            params_c = jax.tree.map(
                lambda x: x.astype(policy.param_dtype)
                if jnp.issubdtype(x.dtype, jnp.floating) else x, params_f32)
            loss, metrics = lm.forward_train(params_c, batch, plan=plan,
                                             cfg=cfg, policy=policy)
            return loss, metrics

        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"])

        # explicit sync over replication axes (pod DP hop optionally int8)
        flat_g, tdef = jax.tree.flatten(grads)
        flat_rep = rep_axes
        assert len(flat_g) == len(flat_rep), (len(flat_g), len(flat_rep))
        flat_ef = (jax.tree.leaves(state["ef"]) if compress_pod
                   else [None] * len(flat_g))
        new_g, new_ef = [], []
        for g, rep, ef in zip(flat_g, flat_rep, flat_ef):
            if compress_pod and "pod" in rep:
                g, ef = ef_compressed_psum(g, ef, "pod")
                rep = tuple(a for a in rep if a != "pod")
            new_ef.append(ef)
            new_g.append(col.psum(g.astype(jnp.float32), rep))
        grads = jax.tree.unflatten(tdef, new_g)
        grads, gnorm = clip_by_global_norm(grads, shard_axes, max_grad_norm)
        lr = lr_fn(state["step"])
        new_params, new_opt = adamw_update(state["params"], grads,
                                           state["opt"], step=state["step"],
                                           lr=lr)
        new_state = {"step": state["step"] + 1, "params": new_params,
                     "opt": new_opt}
        if compress_pod:
            new_state["ef"] = jax.tree.unflatten(tdef, new_ef)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return new_state, metrics

    sm = _maybe_shard_map(body, mesh,
                          in_specs=(state_specs, b_specs),
                          out_specs=(state_specs, metric_specs))
    fn = jax.jit(sm, donate_argnums=(0,))

    opt_struct = jax.eval_shape(adamw_init, p_struct)
    state_struct = {"step": jax.ShapeDtypeStruct((), jnp.int32),
                    "params": p_struct, "opt": opt_struct}
    if compress_pod:
        state_struct["ef"] = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), p_struct)
    in_structs = (with_shardings(state_struct, state_specs, mesh),
                  with_shardings(b_struct, b_specs, mesh))

    def init_state(seed: int = 0):
        def build():
            params = lm.init_lm(jax.random.key(seed), cfg, jnp.float32)
            return {"step": jnp.zeros((), jnp.int32), "params": params,
                    "opt": adamw_init(params),
                    **({"ef": jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params)}
                       if compress_pod else {})}
        if mesh is None:
            return build()
        shardings = to_shardings(state_specs, mesh)
        return jax.jit(build, out_shardings=shardings)()

    return StepBundle(fn=fn, plan=plan, policy=policy, cfg=cfg,
                      in_structs=in_structs,
                      in_specs=(state_specs, b_specs),
                      aux={"init_state": init_state,
                           "state_specs": state_specs,
                           "batch_specs": b_specs,
                           "param_dims": p_dims})


# --------------------------------------------------------------------------
# prefill step (NAR)
# --------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, shape: ShapeConfig,
                      mesh: Optional[Mesh], *,
                      policy: Optional[Policy] = None,
                      max_seq: Optional[int] = None,
                      reduce_method: str = "ring",
                      naive_attention: bool = False,
                      ssm_seq_parallel: bool = False,
                      kv_cache_dtype: str = "bfloat16",
                      weight_dtype: str = "bfloat16",
                      attention_sharding: str = "",
                      comm_fp8: bool = False,
                      mlp_weight_stationary: bool = False,
                      with_sampling: bool = False,
                      compact_kv: bool = False,
                      fuse_epilogues: bool = True) -> StepBundle:
    """`compact_kv`: emit full-context KV caches at the batch's own
    sequence length instead of padded to `max_seq` — paged admission
    scatters them into pool blocks, so the dense B x max_seq buffer never
    materializes (ring/window caches keep their window layout)."""
    import dataclasses
    policy = policy or default_policy(cfg, "serve")
    plan = make_plan(cfg, shape, mesh, mode="serve",
                     reduce_method=reduce_method)
    plan = dataclasses.replace(
        plan, naive_attention=naive_attention,
        ssm_seq_parallel=ssm_seq_parallel, kv_cache_dtype=kv_cache_dtype,
        weight_dtype=weight_dtype,
        attention_sharding=attention_sharding or plan.attention_sharding,
        comm_fp8=comm_fp8, mlp_weight_stationary=mlp_weight_stationary,
        fuse_epilogues=fuse_epilogues)
    max_seq = max_seq or shape.seq_len

    p_dims, p_struct = _serve_param_layout(cfg, policy, weight_dtype)
    p_specs = resolve_pspecs(p_dims, plan)
    b_dims = batch_dims(cfg, "prefill")
    b_specs = resolve_pspecs(b_dims, plan)
    b_struct = frontends.batch_struct(cfg, "prefill", shape.global_batch,
                                      shape.seq_len)
    c_struct, c_dims = cache_layout(cfg, plan, shape.global_batch, max_seq,
                                    policy)
    c_specs = resolve_pspecs(c_dims, plan)
    tok_spec = plan.pspec("batch")

    def run(params, batch, lane):
        col.set_reduce_method(plan.reduce_method)   # T3 schedule selection
        if lane is None:
            return lm.forward_prefill(params, batch, plan=plan, cfg=cfg,
                                      policy=policy, max_seq=max_seq,
                                      compact_kv=compact_kv)
        # per-request lane: sampling params + true prompt length (the batch
        # may be right-padded to a length bucket)
        lane = dict(lane)
        return lm.forward_prefill(params, batch, plan=plan, cfg=cfg,
                                  policy=policy, max_seq=max_seq,
                                  prompt_len=lane.pop("prompt_len"),
                                  lane=lane, compact_kv=compact_kv)

    body = run if with_sampling else (lambda params, batch:
                                      run(params, batch, None))
    in_specs = (p_specs, b_specs)
    in_structs = (with_shardings(p_struct, p_specs, mesh),
                  with_shardings(b_struct, b_specs, mesh))
    if with_sampling:
        l_specs = resolve_pspecs(lane_dims(True), plan)
        in_specs += (l_specs,)
        in_structs += (with_shardings(lane_struct(shape.global_batch, True),
                                      l_specs, mesh),)
    sm = _maybe_shard_map(body, mesh, in_specs=in_specs,
                          out_specs=(tok_spec, c_specs, tok_spec))
    fn = jax.jit(sm)
    return StepBundle(fn=fn, plan=plan, policy=policy, cfg=cfg,
                      in_structs=in_structs, in_specs=in_specs,
                      aux={"param_specs": p_specs, "cache_struct": c_struct,
                           "cache_specs": c_specs, "max_seq": max_seq,
                           "param_dims": p_dims})


# --------------------------------------------------------------------------
# encode step (encoder-only NAR)
# --------------------------------------------------------------------------

def make_encode_step(cfg: ModelConfig, shape: ShapeConfig,
                     mesh: Optional[Mesh], *,
                     policy: Optional[Policy] = None,
                     pooling: str = "last",
                     reduce_method: str = "ring",
                     naive_attention: bool = False,
                     weight_dtype: str = "bfloat16",
                     fuse_epilogues: bool = True) -> StepBundle:
    """Encoder-only serving step: one full-sequence forward, no KV cache,
    returning a pooled [B, d_model] float32 embedding per row (the paper's
    encoder topology — ViT/BERT-style configs — served through the same
    engine as decoder LMs).  Reuses the prefill bundle machinery: same plan
    derivation, same batch specs, same lane-carried true lengths for
    right-padded length buckets; there is just no cache tree and no token.

    fn(params, batch, prompt_len [B] int32) -> pooled [B, E] float32."""
    import dataclasses
    policy = policy or default_policy(cfg, "serve")
    plan = make_plan(cfg, shape, mesh, mode="serve",
                     reduce_method=reduce_method)
    plan = dataclasses.replace(plan, naive_attention=naive_attention,
                               weight_dtype=weight_dtype,
                               fuse_epilogues=fuse_epilogues)

    p_dims, p_struct = _serve_param_layout(cfg, policy, weight_dtype)
    p_specs = resolve_pspecs(p_dims, plan)
    b_dims = batch_dims(cfg, "encode")
    b_specs = resolve_pspecs(b_dims, plan)
    b_struct = frontends.batch_struct(cfg, "encode", shape.global_batch,
                                      shape.seq_len)
    out_spec = plan.pspec("batch", None)

    def body(params, batch, prompt_len):
        col.set_reduce_method(plan.reduce_method)   # T3 schedule selection
        return lm.forward_encode(params, batch, plan=plan, cfg=cfg,
                                 policy=policy, prompt_len=prompt_len,
                                 pooling=pooling)

    len_spec = plan.pspec("batch")
    in_specs = (p_specs, b_specs, len_spec)
    in_structs = (with_shardings(p_struct, p_specs, mesh),
                  with_shardings(b_struct, b_specs, mesh),
                  with_shardings(jax.ShapeDtypeStruct(
                      (shape.global_batch,), jnp.int32), len_spec, mesh))
    sm = _maybe_shard_map(body, mesh, in_specs=in_specs, out_specs=out_spec)
    fn = jax.jit(sm)
    return StepBundle(fn=fn, plan=plan, policy=policy, cfg=cfg,
                      in_structs=in_structs, in_specs=in_specs,
                      aux={"param_specs": p_specs, "pooling": pooling})


# --------------------------------------------------------------------------
# chunked-prefill step
# --------------------------------------------------------------------------

def chunk_support_reason(cfg: ModelConfig,
                         layout: Optional[PagedLayout]) -> Optional[str]:
    """Why this (cfg, layout) cannot run the chunk-shaped stack — None when
    it can.  The chunk stack underpins chunked prefill, speculative verify,
    AND the prefix cache's suffix-only prefill (all three resume encoding at
    an arbitrary `pos0` against the paged decode caches), so the runner,
    spec gating, and prefix-cache gating all consult this one predicate."""
    if layout is None or not layout.any_paged or not all(layout.segments):
        return ("every KV segment must be block-paged (dense, ring, and SSM "
                "caches cannot carry resumable chunk state)")
    if cfg.has_ssm:
        return "recurrent SSM state absorbs chunk boundaries"
    if cfg.enc_schedule:
        return "cross-attention memory is not paged"
    if cfg.n_patches:
        return "patch prefixes occupy unpaged cache positions"
    if cfg.rope_theta <= 0:
        return "the chunk stack requires rotary positions"
    return None


def _chunk_scaffold(cfg: ModelConfig, shape: ShapeConfig,
                    mesh: Optional[Mesh], *, layout: PagedLayout,
                    width: int, policy: Optional[Policy],
                    max_seq: Optional[int], reduce_method: str,
                    kv_cache_dtype: str, weight_dtype: str,
                    fuse_epilogues: bool, kind: str):
    """Shared plan/spec/struct scaffolding for the chunk-shaped steps —
    chunked prefill and speculative verify both run lm's paged chunk stack
    over `width` consecutive tokens per row against the decode cache
    layout, with the same operand schema:

      (params, tokens [n, width], pos0 [n], chunk_len [n], caches,
       tables [n, MB][, lane])

    Returns (plan, policy, max_seq, p_specs, row_spec, tok_spec, c_struct,
    c_specs, in_specs, in_structs) with the lane NOT yet appended (the two
    builders differ in whether sampling is optional)."""
    import dataclasses
    policy = policy or default_policy(cfg, "serve")
    plan = make_plan(cfg, shape, mesh, mode="serve",
                     reduce_method=reduce_method)
    plan = dataclasses.replace(plan, kv_cache_dtype=kv_cache_dtype,
                               weight_dtype=weight_dtype,
                               fuse_epilogues=fuse_epilogues)
    max_seq = max_seq or shape.seq_len
    assert plan.dp == 1, (
        f"{kind} requires an unsharded decode batch: dp={plan.dp}")
    assert all(layout.segments), (
        f"{kind} requires every segment's KV to be paged "
        f"(segments={layout.segments})")

    p_dims, p_struct = _serve_param_layout(cfg, policy, weight_dtype)
    p_specs = resolve_pspecs(p_dims, plan)
    c_struct, c_dims = cache_layout(cfg, plan, shape.global_batch, max_seq,
                                    policy, paged=layout)
    c_specs = resolve_pspecs(c_dims, plan)
    n = shape.global_batch
    row_spec = plan.pspec("batch")
    tok_spec = plan.pspec("batch", None)
    in_specs = (p_specs, tok_spec, row_spec, row_spec, c_specs, tok_spec)
    in_structs = (
        with_shardings(p_struct, p_specs, mesh),
        with_shardings(jax.ShapeDtypeStruct((n, width), jnp.int32),
                       tok_spec, mesh),
        with_shardings(jax.ShapeDtypeStruct((n,), jnp.int32), row_spec,
                       mesh),
        with_shardings(jax.ShapeDtypeStruct((n,), jnp.int32), row_spec,
                       mesh),
        with_shardings(c_struct, c_specs, mesh),
        with_shardings(jax.ShapeDtypeStruct((n, layout.max_blocks),
                                            jnp.int32), tok_spec, mesh))
    return (plan, policy, max_seq, p_specs, row_spec, tok_spec, c_struct,
            c_specs, in_specs, in_structs)


def make_chunk_prefill_step(cfg: ModelConfig, shape: ShapeConfig,
                            mesh: Optional[Mesh], *,
                            layout: PagedLayout,
                            chunk_tokens: int,
                            policy: Optional[Policy] = None,
                            max_seq: Optional[int] = None,
                            reduce_method: str = "ring",
                            kv_cache_dtype: str = "bfloat16",
                            weight_dtype: str = "bfloat16",
                            with_sampling: bool = False,
                            fuse_epilogues: bool = True) -> StepBundle:
    """One chunked-prefill piece over the *decode* cache layout: encodes up
    to `chunk_tokens` consecutive prompt tokens per row straight into the
    paged KV pools, so a long admission interleaves with decode steps
    instead of stalling them (chunk state is just the block tables + `pos`).

    `shape` must be the decode shape the engine's decode step was built
    with — the cache pytree (and its shardings) is shared between the two
    steps, and caches are donated here for the same in-place update.

    fn(params, tokens [n, C], pos0 [n], chunk_len [n], caches,
       tables [n, MB][, lane]) -> (token [n], caches, pos [n])

    The returned token is meaningful only for rows whose chunk completes
    the prompt (it then equals the unchunked prefill's sample; see
    lm.forward_chunk).

    `pos0` is an arbitrary per-row start offset: besides mid-prompt chunk
    resumption, the prefix cache's warm admissions reuse this step with
    pos0 = cached-prefix length to prefill only the uncached suffix against
    blocks already holding the shared prefix's KV."""
    (plan, policy, max_seq, p_specs, row_spec, tok_spec, c_struct, c_specs,
     in_specs, in_structs) = _chunk_scaffold(
        cfg, shape, mesh, layout=layout, width=chunk_tokens, policy=policy,
        max_seq=max_seq, reduce_method=reduce_method,
        kv_cache_dtype=kv_cache_dtype, weight_dtype=weight_dtype,
        fuse_epilogues=fuse_epilogues, kind="chunked prefill")

    def run(params, tokens, pos0, chunk_len, caches, tables, lane):
        col.set_reduce_method(plan.reduce_method)   # T3 schedule selection
        return lm.forward_chunk(params, tokens, pos0, chunk_len, caches,
                                tables, plan=plan, cfg=cfg, policy=policy,
                                lane=lane, paged_segments=layout.segments)

    body = (run if with_sampling
            else (lambda params, tokens, pos0, chunk_len, caches, tables:
                  run(params, tokens, pos0, chunk_len, caches, tables,
                      None)))
    if with_sampling:
        l_specs = resolve_pspecs(lane_dims(False), plan)
        in_specs += (l_specs,)
        in_structs += (with_shardings(lane_struct(shape.global_batch, False),
                                      l_specs, mesh),)
    sm = _maybe_shard_map(body, mesh, in_specs=in_specs,
                          out_specs=(row_spec, c_specs, row_spec))
    fn = jax.jit(sm, donate_argnums=(4,))
    return StepBundle(fn=fn, plan=plan, policy=policy, cfg=cfg,
                      in_structs=in_structs, in_specs=in_specs,
                      aux={"param_specs": p_specs, "cache_struct": c_struct,
                           "cache_specs": c_specs, "max_seq": max_seq,
                           "paged": layout, "chunk_tokens": chunk_tokens})


# --------------------------------------------------------------------------
# speculative verify step (multi-token AR)
# --------------------------------------------------------------------------

def make_verify_step(cfg: ModelConfig, shape: ShapeConfig,
                     mesh: Optional[Mesh], *,
                     layout: PagedLayout,
                     num_tokens: int,
                     policy: Optional[Policy] = None,
                     max_seq: Optional[int] = None,
                     reduce_method: str = "ring",
                     kv_cache_dtype: str = "bfloat16",
                     weight_dtype: str = "bfloat16",
                     fuse_epilogues: bool = True) -> StepBundle:
    """Speculative-decoding verification: one target forward over
    `num_tokens` = k+1 consecutive tokens per decode slot (the pending
    token + k draft proposals), writing their KV straight into the slot's
    paged blocks and returning the target's own next-token choice at EVERY
    position (lm.forward_verify) — the chunked-prefill machinery pointed
    at decode-time positions, with a per-position sampling head instead of
    a final-position one.

    `shape` must be the decode shape the engine's decode step was built
    with: the cache pytree (and its shardings) is shared across
    decode / chunk / verify steps, and caches are donated here for the
    same in-place update.

    fn(params, tokens [B, C], pos0 [B], chunk_len [B], caches,
       tables [B, MB], lane) -> (choices [B, C], caches, pos [B])

    Rows whose chunk_len is 0 (empty / still-prefilling slots) write
    nothing (their table rows are -1, so scatters drop) and their choices
    are garbage the caller ignores."""
    (plan, policy, max_seq, p_specs, row_spec, tok_spec, c_struct, c_specs,
     in_specs, in_structs) = _chunk_scaffold(
        cfg, shape, mesh, layout=layout, width=num_tokens, policy=policy,
        max_seq=max_seq, reduce_method=reduce_method,
        kv_cache_dtype=kv_cache_dtype, weight_dtype=weight_dtype,
        fuse_epilogues=fuse_epilogues, kind="speculative verify")

    def body(params, tokens, pos0, chunk_len, caches, tables, lane):
        col.set_reduce_method(plan.reduce_method)   # T3 schedule selection
        return lm.forward_verify(params, tokens, pos0, chunk_len, caches,
                                 tables, plan=plan, cfg=cfg, policy=policy,
                                 lane=lane, paged_segments=layout.segments)

    l_specs = resolve_pspecs(lane_dims(False), plan)
    in_specs += (l_specs,)
    in_structs += (with_shardings(lane_struct(shape.global_batch, False),
                                  l_specs, mesh),)
    sm = _maybe_shard_map(body, mesh, in_specs=in_specs,
                          out_specs=(tok_spec, c_specs, row_spec))
    fn = jax.jit(sm, donate_argnums=(4,))
    return StepBundle(fn=fn, plan=plan, policy=policy, cfg=cfg,
                      in_structs=in_structs, in_specs=in_specs,
                      aux={"param_specs": p_specs, "cache_struct": c_struct,
                           "cache_specs": c_specs, "max_seq": max_seq,
                           "paged": layout, "num_tokens": num_tokens})


def make_tree_verify_step(cfg: ModelConfig, shape: ShapeConfig,
                          mesh: Optional[Mesh], *,
                          layout: PagedLayout,
                          num_tokens: int,
                          policy: Optional[Policy] = None,
                          max_seq: Optional[int] = None,
                          reduce_method: str = "ring",
                          kv_cache_dtype: str = "bfloat16",
                          weight_dtype: str = "bfloat16",
                          fuse_epilogues: bool = True) -> StepBundle:
    """Tree-speculative verification: `make_verify_step` generalized from a
    token *chain* to a flattened token *tree* of `num_tokens` = 1 + k*b
    nodes per slot (lm.forward_verify_tree).  Two extra operands after
    chunk_len carry the per-slot tree shape — `depth` [B, C] int32 (each
    node's tree depth; rope + the sampling step key off pos0 + depth) and
    `anc` [B, C, C] bool (ancestor-or-self matrix; the intra-chunk
    attention mask) — while node KV still scatters at pos0 + node index.

    fn(params, tokens [B, C], pos0 [B], chunk_len [B], depth [B, C],
       anc [B, C, C], caches, tables [B, MB], lane)
      -> (choices [B, C], caches, pos [B])

    Rows are free to carry any ancestor-closed flatten-order prefix —
    full trees, shallower trees, or a plain chain (depth == node index,
    anc lower triangular, which reduces bit-exactly to make_verify_step's
    math) — so one compiled step serves per-slot tree truncation and the
    scheduler's shrink-to-chain degrade rung without recompiling."""
    (plan, policy, max_seq, p_specs, row_spec, tok_spec, c_struct, c_specs,
     in_specs, in_structs) = _chunk_scaffold(
        cfg, shape, mesh, layout=layout, width=num_tokens, policy=policy,
        max_seq=max_seq, reduce_method=reduce_method,
        kv_cache_dtype=kv_cache_dtype, weight_dtype=weight_dtype,
        fuse_epilogues=fuse_epilogues, kind="tree speculative verify")

    def body(params, tokens, pos0, chunk_len, depth, anc, caches, tables,
             lane):
        col.set_reduce_method(plan.reduce_method)   # T3 schedule selection
        return lm.forward_verify_tree(params, tokens, pos0, chunk_len,
                                      depth, anc, caches, tables, plan=plan,
                                      cfg=cfg, policy=policy, lane=lane,
                                      paged_segments=layout.segments)

    n = shape.global_batch
    anc_spec = plan.pspec("batch", None, None)
    l_specs = resolve_pspecs(lane_dims(False), plan)
    # splice depth + anc in after chunk_len (scaffold order: params, tokens,
    # pos0, chunk_len, caches, tables)
    in_specs = (in_specs[:4] + (tok_spec, anc_spec) + in_specs[4:]
                + (l_specs,))
    in_structs = (
        in_structs[:4]
        + (with_shardings(jax.ShapeDtypeStruct((n, num_tokens), jnp.int32),
                          tok_spec, mesh),
           with_shardings(jax.ShapeDtypeStruct(
               (n, num_tokens, num_tokens), jnp.bool_), anc_spec, mesh))
        + in_structs[4:]
        + (with_shardings(lane_struct(n, False), l_specs, mesh),))
    sm = _maybe_shard_map(body, mesh, in_specs=in_specs,
                          out_specs=(tok_spec, c_specs, row_spec))
    fn = jax.jit(sm, donate_argnums=(6,))
    return StepBundle(fn=fn, plan=plan, policy=policy, cfg=cfg,
                      in_structs=in_structs, in_specs=in_specs,
                      aux={"param_specs": p_specs, "cache_struct": c_struct,
                           "cache_specs": c_specs, "max_seq": max_seq,
                           "paged": layout, "num_tokens": num_tokens})


# --------------------------------------------------------------------------
# decode step (AR)
# --------------------------------------------------------------------------

def make_decode_step(cfg: ModelConfig, shape: ShapeConfig,
                     mesh: Optional[Mesh], *,
                     policy: Optional[Policy] = None,
                     max_seq: Optional[int] = None,
                     reduce_method: str = "ring",
                     kv_cache_dtype: str = "bfloat16",
                     weight_dtype: str = "bfloat16",
                     with_sampling: bool = False,
                     paged: Optional[Tuple[int, int]] = None,
                     fuse_epilogues: bool = True) -> StepBundle:
    """`paged`: (num_blocks, block_size) — build the step against a
    block-paged KV cache: full-attention k/v leaves become global pools and
    the step takes a [B, max_blocks] block-table operand after the caches
    (`pos` carries the per-slot valid lengths).  The resolved `PagedLayout`
    (pool rounded up to the cache-shard count) lands in aux["paged"].
    Cache buffers are donated either way, so each step updates them in
    place instead of allocating a fresh B x max_seq (or pool-sized) copy."""
    import dataclasses
    policy = policy or default_policy(cfg, "serve")
    plan = make_plan(cfg, shape, mesh, mode="serve",
                     reduce_method=reduce_method)
    plan = dataclasses.replace(plan, kv_cache_dtype=kv_cache_dtype,
                               weight_dtype=weight_dtype,
                               fuse_epilogues=fuse_epilogues)
    max_seq = max_seq or shape.seq_len

    layout = None
    if paged is not None:
        layout = make_paged_layout(cfg, plan, max_seq, *paged)
        assert plan.dp == 1, (
            "paged KV cache requires an unsharded decode batch (the pool is "
            f"shared across slots): dp={plan.dp}")

    p_dims, p_struct = _serve_param_layout(cfg, policy, weight_dtype)
    p_specs = resolve_pspecs(p_dims, plan)
    c_struct, c_dims = cache_layout(cfg, plan, shape.global_batch, max_seq,
                                    policy, paged=layout)
    c_specs = resolve_pspecs(c_dims, plan)
    tok_spec = plan.pspec("batch")
    d_struct = frontends.decode_struct(shape.global_batch)

    def run(params, token, pos, caches, tables, lane):
        tok, caches = lm.forward_decode(
            params, token, pos, caches, plan=plan, cfg=cfg, policy=policy,
            lane=lane, block_tables=tables,
            paged_segments=layout.segments if layout else None)
        return tok, pos + 1, caches

    if layout is not None:
        body = (run if with_sampling
                else (lambda params, token, pos, caches, tables:
                      run(params, token, pos, caches, tables, None)))
    elif with_sampling:
        body = (lambda params, token, pos, caches, lane:
                run(params, token, pos, caches, None, lane))
    else:
        body = (lambda params, token, pos, caches:
                run(params, token, pos, caches, None, None))
    in_specs = (p_specs, tok_spec, tok_spec, c_specs)
    in_structs = (with_shardings(p_struct, p_specs, mesh),
                  with_shardings(d_struct["token"], tok_spec, mesh),
                  with_shardings(d_struct["pos"], tok_spec, mesh),
                  with_shardings(c_struct, c_specs, mesh))
    if layout is not None:
        t_spec = plan.pspec("batch", None)
        t_struct = jax.ShapeDtypeStruct(
            (shape.global_batch, layout.max_blocks), jnp.int32)
        in_specs += (t_spec,)
        in_structs += (with_shardings(t_struct, t_spec, mesh),)
    if with_sampling:
        l_specs = resolve_pspecs(lane_dims(False), plan)
        in_specs += (l_specs,)
        in_structs += (with_shardings(lane_struct(shape.global_batch, False),
                                      l_specs, mesh),)
    sm = _maybe_shard_map(body, mesh, in_specs=in_specs,
                          out_specs=(tok_spec, tok_spec, c_specs))
    fn = jax.jit(sm, donate_argnums=(3,))
    return StepBundle(fn=fn, plan=plan, policy=policy, cfg=cfg,
                      in_structs=in_structs,
                      in_specs=in_specs,
                      aux={"param_specs": p_specs, "cache_struct": c_struct,
                           "cache_specs": c_specs, "max_seq": max_seq,
                           "param_dims": p_dims, "paged": layout})


def make_draft_topk_step(cfg: ModelConfig, shape: ShapeConfig,
                         mesh: Optional[Mesh], *,
                         branches: int,
                         policy: Optional[Policy] = None,
                         max_seq: Optional[int] = None,
                         reduce_method: str = "ring",
                         weight_dtype: str = "bfloat16",
                         fuse_epilogues: bool = True) -> StepBundle:
    """Draft decode step for tree speculation: `make_decode_step`'s dense
    sampling variant, except each call also returns the row's top
    `branches` token candidates (candidate 0 == the sampled/greedy token,
    so the draft chain itself is unchanged — siblings are a free byproduct
    of the same unembedding matmul).

    fn(params, token [B], pos [B], caches, lane)
      -> (tok [B], alts [B, branches], pos + 1, caches)

    Dense (non-paged) only, matching the draft cache the runner keeps."""
    import dataclasses
    policy = policy or default_policy(cfg, "serve")
    plan = make_plan(cfg, shape, mesh, mode="serve",
                     reduce_method=reduce_method)
    plan = dataclasses.replace(plan, weight_dtype=weight_dtype,
                               fuse_epilogues=fuse_epilogues)
    max_seq = max_seq or shape.seq_len

    p_dims, p_struct = _serve_param_layout(cfg, policy, weight_dtype)
    p_specs = resolve_pspecs(p_dims, plan)
    c_struct, c_dims = cache_layout(cfg, plan, shape.global_batch, max_seq,
                                    policy, paged=None)
    c_specs = resolve_pspecs(c_dims, plan)
    tok_spec = plan.pspec("batch")
    alt_spec = plan.pspec("batch", None)
    d_struct = frontends.decode_struct(shape.global_batch)

    def body(params, token, pos, caches, lane):
        tok, alts, caches = lm.forward_decode_topk(
            params, token, pos, caches, n=branches, plan=plan, cfg=cfg,
            policy=policy, lane=lane)
        return tok, alts, pos + 1, caches

    l_specs = resolve_pspecs(lane_dims(False), plan)
    in_specs = (p_specs, tok_spec, tok_spec, c_specs, l_specs)
    in_structs = (with_shardings(p_struct, p_specs, mesh),
                  with_shardings(d_struct["token"], tok_spec, mesh),
                  with_shardings(d_struct["pos"], tok_spec, mesh),
                  with_shardings(c_struct, c_specs, mesh),
                  with_shardings(lane_struct(shape.global_batch, False),
                                 l_specs, mesh))
    sm = _maybe_shard_map(body, mesh, in_specs=in_specs,
                          out_specs=(tok_spec, alt_spec, tok_spec, c_specs))
    fn = jax.jit(sm, donate_argnums=(3,))
    return StepBundle(fn=fn, plan=plan, policy=policy, cfg=cfg,
                      in_structs=in_structs,
                      in_specs=in_specs,
                      aux={"param_specs": p_specs, "cache_struct": c_struct,
                           "cache_specs": c_specs, "max_seq": max_seq,
                           "param_dims": p_dims, "paged": None,
                           "branches": branches})
