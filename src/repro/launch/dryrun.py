import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) cell this lowers + compiles the real
jitted step (train_step for train shapes, prefill/serve_step for inference
shapes) against the production mesh — (16,16)=256 chips single-pod and
(2,16,16)=512 chips multi-pod — and records:

  * compiled.memory_analysis()  (bytes per device: proves it fits)
  * compiled.cost_analysis()    (XLA's own flops/bytes, while-bodies once)
  * trip-count-weighted HLO totals + the 3-term roofline (analysis/)

Usage:
  python -m repro.launch.dryrun --arch phi4-mini-3.8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out artifacts/dryrun]

--all spawns one subprocess per cell (fresh XLA, bounded memory, isolated
failures) and writes one JSON per cell plus a summary table.
"""
import argparse
import json
import subprocess
import sys
import time

from repro.launch.cell_variant import DEFAULTS, variant_key

CELL_TIMEOUT_S = 1500


def _parse_shape(shape_name: str):
    """SHAPES name or ad-hoc 'kind:seq:batch' (benchmark variants)."""
    from repro.configs import SHAPES
    from repro.configs.base import ShapeConfig
    if shape_name in SHAPES:
        return SHAPES[shape_name]
    kind, seq, batch = shape_name.split(":")
    return ShapeConfig(shape_name, kind, int(seq), int(batch))


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             reduce_method: str = DEFAULTS["reduce"], policy: str = "",
             tag: str = "baseline", naive: bool = False,
             ssm_seqp: bool = False,
             kv_cache_dtype: str = DEFAULTS["kv_cache_dtype"],
             weight_dtype: str = DEFAULTS["weight_dtype"],
             attn_sharding: str = "", comm_fp8: bool = False,
             mlp_ws: bool = False, fuse: bool = True) -> dict:
    import jax
    from repro.analysis.hlo import parse_hlo
    from repro.analysis.roofline import model_flops, roofline_from_summary
    from repro.configs import get_config, supports_shape
    from repro.core.precision import get_policy
    from repro.launch import steps
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    shape = _parse_shape(shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
           "variant": variant_key(policy=policy, naive=naive,
                                  reduce_method=reduce_method, fuse=fuse,
                                  ssm_seqp=ssm_seqp,
                                  kv_cache_dtype=kv_cache_dtype,
                                  weight_dtype=weight_dtype,
                                  attn_sharding=attn_sharding,
                                  comm_fp8=comm_fp8, mlp_ws=mlp_ws),
           "ok": False}
    if not supports_shape(cfg, shape):
        rec.update(skipped=True, reason="shape unsupported for this arch "
                   "(DESIGN.md §5: long_500k needs sub-quadratic attention)")
        return rec

    mesh = (None if mesh_kind == "none"
            else make_production_mesh(multi_pod=(mesh_kind == "multi")))
    pol = get_policy(policy) if policy else None
    t0 = time.time()
    if shape.kind == "train":
        bundle = steps.make_train_step(cfg, shape, mesh, policy=pol,
                                       reduce_method=reduce_method,
                                       naive_attention=naive,
                                       ssm_seq_parallel=ssm_seqp,
                                       fuse_epilogues=fuse)
    elif shape.kind == "prefill":
        bundle = steps.make_prefill_step(cfg, shape, mesh, policy=pol,
                                         reduce_method=reduce_method,
                                         naive_attention=naive,
                                         ssm_seq_parallel=ssm_seqp,
                                         kv_cache_dtype=kv_cache_dtype,
                                         weight_dtype=weight_dtype,
                                         attention_sharding=attn_sharding,
                                         comm_fp8=comm_fp8,
                                         mlp_weight_stationary=mlp_ws,
                                         fuse_epilogues=fuse)
    else:
        bundle = steps.make_decode_step(cfg, shape, mesh, policy=pol,
                                        reduce_method=reduce_method,
                                        kv_cache_dtype=kv_cache_dtype,
                                        weight_dtype=weight_dtype,
                                        fuse_epilogues=fuse)
    lowered = bundle.lower()
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    ma = compiled.memory_analysis()
    mem = {k: int(getattr(ma, k, 0)) for k in
           ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")}
    ca = compiled.cost_analysis() or {}
    cost = {k: float(ca[k]) for k in ("flops", "bytes accessed") if k in ca}

    import gzip
    import numpy as np
    dt_name = {"float32": "f32", "bfloat16": "bf16", "float16": "f16",
               "float8_e4m3fn": "f8e4m3fn", "float8_e5m2": "f8e5m2"}[
                   np.dtype(bundle.policy.compute_dtype).name]
    hlo_text = compiled.as_text()
    if out_dir:                         # archive for offline re-analysis
        hdir = os.path.join(out_dir, "hlo")
        os.makedirs(hdir, exist_ok=True)
        hname = f"{arch}__{shape_name.replace(':', '-')}__{mesh_kind}__{tag}"
        hfile = os.path.join(hdir, hname + ".hlo.gz")
        with gzip.open(hfile, "wt") as f:
            f.write(hlo_text)
        # Record repo-relative so cached cells stay valid across checkouts.
        repo_root = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "..", ".."))
        hrel = os.path.relpath(os.path.abspath(hfile), repo_root)
        rec["hlo_path"] = (os.path.abspath(hfile)
                           if hrel.startswith("..") else hrel)
    from repro.core.nn import act_dtype as _ad
    summary = parse_hlo(
        hlo_text, default_dot_dtype=dt_name,
        act_bytes=np.dtype(_ad(bundle.policy)).itemsize,
        param_bytes=np.dtype(bundle.policy.param_dtype).itemsize,
        gather_act_bytes=1 if comm_fp8 else None)
    roof = roofline_from_summary(summary)
    mf = model_flops(cfg, shape)
    n_dev = mesh.devices.size if mesh is not None else 1
    hlo_total = roof.flops * n_dev
    rec.update(
        ok=True, lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
        devices=n_dev, policy=bundle.policy.name,
        memory_analysis=mem, cost_analysis=cost,
        roofline=roof.as_dict(),
        model_flops=mf,
        useful_flops_ratio=(mf / hlo_total if hlo_total else 0.0),
        hbm_per_device_gb=round((mem["argument_size_in_bytes"]
                                 + mem["temp_size_in_bytes"]) / 2**30, 3),
    )
    return rec


def cell_list():
    from repro.configs import ASSIGNED, SHAPES
    return [(a, s) for a in sorted(ASSIGNED) for s in
            ("train_4k", "prefill_32k", "decode_32k", "long_500k")]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both", "none"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--reduce", default=DEFAULTS["reduce"],
                    choices=["ring", "tree"])
    ap.add_argument("--policy", default="")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--naive", action="store_true")
    ap.add_argument("--ssm-seqp", action="store_true")
    ap.add_argument("--kv-dtype", default=DEFAULTS["kv_cache_dtype"])
    ap.add_argument("--weight-dtype", default=DEFAULTS["weight_dtype"],
                    choices=["bfloat16", "int8"])
    ap.add_argument("--attn-sharding", default="",
                    choices=["", "head_tp", "seq_sp"])
    ap.add_argument("--comm-fp8", action="store_true")
    ap.add_argument("--mlp-ws", action="store_true")
    ap.add_argument("--no-fuse", action="store_true",
                    help="disable the fused prologue/epilogue pipeline "
                         "(A/B baseline for the fusion benchmark)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if not args.all:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mk in meshes:
            rec = run_cell(args.arch, args.shape, mk, args.out,
                           reduce_method=args.reduce, policy=args.policy,
                           tag=args.tag, naive=args.naive,
                           ssm_seqp=args.ssm_seqp,
                           kv_cache_dtype=args.kv_dtype,
                           weight_dtype=args.weight_dtype,
                           attn_sharding=args.attn_sharding,
                           comm_fp8=args.comm_fp8, mlp_ws=args.mlp_ws,
                           fuse=not args.no_fuse)
            safe = args.shape.replace(":", "-")
            fname = os.path.join(
                args.out, f"{args.arch}__{safe}__{mk}__{args.tag}.json")
            with open(fname, "w") as f:
                json.dump(rec, f, indent=1)
            print(json.dumps(rec, indent=1))
        return 0

    # orchestrate: one subprocess per cell
    want = variant_key(policy=args.policy, naive=args.naive,
                       reduce_method=args.reduce, fuse=not args.no_fuse,
                       ssm_seqp=args.ssm_seqp, kv_cache_dtype=args.kv_dtype,
                       weight_dtype=args.weight_dtype,
                       attn_sharding=args.attn_sharding,
                       comm_fp8=args.comm_fp8, mlp_ws=args.mlp_ws)
    results = []
    for arch, shape in cell_list():
        for mk in meshes:
            fname = os.path.join(
                args.out, f"{arch}__{shape}__{mk}__{args.tag}.json")
            if os.path.exists(fname):
                cached = json.load(open(fname))
                if cached.get("variant") == want:
                    results.append(cached)
                    print(f"[cached] {arch} {shape} {mk}")
                    continue
                os.remove(fname)   # tag collision or legacy cache
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mk,
                   "--out", args.out, "--reduce", args.reduce,
                   "--tag", args.tag, "--kv-dtype", args.kv_dtype,
                   "--weight-dtype", args.weight_dtype]
            if args.policy:
                cmd += ["--policy", args.policy]
            if args.attn_sharding:
                cmd += ["--attn-sharding", args.attn_sharding]
            for flag, on in [("--naive", args.naive),
                             ("--ssm-seqp", args.ssm_seqp),
                             ("--comm-fp8", args.comm_fp8),
                             ("--mlp-ws", args.mlp_ws),
                             ("--no-fuse", args.no_fuse)]:
                if on:
                    cmd += [flag]
            t0 = time.time()
            try:
                p = subprocess.run(cmd, capture_output=True, text=True,
                                   timeout=CELL_TIMEOUT_S)
                ok = p.returncode == 0 and os.path.exists(fname)
                rec = (json.load(open(fname)) if ok else
                       {"arch": arch, "shape": shape, "mesh": mk, "ok": False,
                        "error": (p.stderr or "")[-2000:]})
            except subprocess.TimeoutExpired:
                rec = {"arch": arch, "shape": shape, "mesh": mk, "ok": False,
                       "error": "timeout"}
            results.append(rec)
            status = ("SKIP" if rec.get("skipped")
                      else "ok" if rec.get("ok") else "FAIL")
            print(f"[{status:4s}] {arch:18s} {shape:12s} {mk:6s} "
                  f"({time.time()-t0:.0f}s)")
            if status == "FAIL":
                print("      ", rec.get("error", "")[-500:].replace("\n", " ")[-300:])
    n_ok = sum(1 for r in results if r.get("ok"))
    n_skip = sum(1 for r in results if r.get("skipped"))
    n_fail = len(results) - n_ok - n_skip
    print(f"\n{n_ok} ok / {n_skip} skipped / {n_fail} FAILED "
          f"of {len(results)} cells")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
