"""Serving driver: session-based continuous-batching engine demo.

    PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
        --reduced --requests 8 --batch 4 --prompt-len 48 --min-prompt-len 8 \
        --max-new 16 --temperature 0.7 --top-k 40

Drives a mixed-length request trace through `InferenceEngine` and reports
the paper's two serving metrics from `engine.stats()`: NAR prompt-encoding
throughput and AR decode throughput (tokens/s, counted from true per-request
prompt lengths, not padded buckets), plus TTFT / queue-wait percentiles,
decode-slot occupancy, and prefill bucket hits.

Scheduler/runner split knobs:
  --policy {fcfs,priority,chunked,deadline}
                                     scheduling policy (fcfs = classic;
                                     deadline = EDF with SLO shedding and
                                     degrade, serving/scheduler.py)
  --prefill-chunk N                  chunk budget for --policy chunked
  --task {generate,encode}           decoder AR traffic vs encoder-only
                                     pooled-embedding traffic (EncodeTask)
  --overlap                          overlapped host loop: dispatch decode
                                     step N+1 before fetching step N's
                                     tokens (token-identical to the sync
                                     loop; engine.py)
  --deadline-ms MS                   per-request TTFT budget stamped onto
                                     every generated request (0 = none) —
                                     the deadline policy schedules, sheds,
                                     and scores SLO attainment on it

Speculative decoding (serving/spec.py):
  --spec-draft NAME                  turn on speculation: "self" (the
                                     target proposes for itself — the
                                     zero-risk upper bound), "auto"
                                     (derive a 2-layer draft), or a
                                     registered draft config (e.g.
                                     "gpt-j-draft")
  --spec-k K                         draft tokens proposed per verify step
  --spec-branches B                  token-tree width: B > 1 proposes the
                                     draft's top-B candidates per depth
                                     and verifies the whole tree in one
                                     tree-masked target pass (still
                                     token-identical; 1 = classic chain)
  --draft-checkpoint DIR             load draft params from a
                                     checkpoint/checkpointer.py directory
                                     instead of seeded init

Prefix caching (serving/prefix_cache.py, on by default):
  --no-prefix-cache                  cold prefills: no KV block sharing
                                     across requests
  --cache-blocks N                   cap the radix index at N pool blocks
                                     (default: bounded by pool pressure —
                                     lazy LRU eviction on alloc failure)

Observability (serving/trace.py):
  --trace-out PATH                   record a structured trace and write
                                     it as Chrome trace-event JSON (open
                                     at https://ui.perfetto.dev); tracing
                                     is opt-in and token-identical
  --trace-buffer N                   tracer ring-buffer capacity (events)
  --metrics-out PATH                 write a Prometheus-style text
                                     snapshot of the final engine stats

Low-precision serving (models/quantize.py; both default to lossless bf16):
  --weight-dtype int8                weight-only int8: per-output-channel
                                     quantization, dequant fused into the
                                     GEMM epilogues
  --kv-dtype int8                    int8 paged KV pools with per-block-
                                     per-head scales (quantize-on-write,
                                     dequant-on-read in the paged kernels)
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_mesh_for
from repro.models import lm
from repro.serving import (EncodeTask, InferenceEngine, Request,
                           SamplingParams, SpecConfig, Tracer, make_policy,
                           prometheus_text)


def build_trace(cfg, args) -> list:
    """Mixed-length request trace; lengths uniform in
    [min_prompt_len, prompt_len] (fixed-length when min == max).
    --task encode emits EncodeTasks (pooled embeddings) instead."""
    rng = np.random.default_rng(args.seed)
    lo = args.min_prompt_len or args.prompt_len
    reqs = []
    for uid in range(args.requests):
        n = int(rng.integers(lo, args.prompt_len + 1))
        prompt = rng.integers(0, cfg.vocab, n, dtype=np.int32)
        deadline = args.deadline_ms or None
        if args.task == "encode":
            reqs.append(EncodeTask(uid=uid, prompt=prompt,
                                   pooling=args.pooling,
                                   priority=uid % 3,
                                   deadline_ms=deadline))
            continue
        sampling = (SamplingParams(temperature=args.temperature,
                                   top_k=args.top_k, seed=uid)
                    if args.temperature > 0 else SamplingParams())
        reqs.append(Request(
            uid=uid,
            prompt=prompt,
            max_new_tokens=args.max_new,
            priority=uid % 3,
            deadline_ms=deadline,
            sampling=sampling))
    return reqs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="max prompt length")
    ap.add_argument("--min-prompt-len", type=int, default=0,
                    help="min prompt length (0 => fixed at --prompt-len)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 => greedy")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--policy",
                    choices=("fcfs", "priority", "chunked", "deadline"),
                    default="fcfs", help="scheduling policy")
    ap.add_argument("--overlap", action="store_true",
                    help="overlapped host loop: dispatch the next decode "
                         "step before fetching the previous step's tokens "
                         "(token-identical to the sync loop)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request TTFT SLO budget in ms (0 = no "
                         "deadline); pairs with --policy deadline")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="chunked prefill token budget (--policy chunked)")
    ap.add_argument("--task", choices=("generate", "encode"),
                    default="generate",
                    help="generate: AR decode requests; encode: "
                         "encoder-only pooled-embedding requests")
    ap.add_argument("--pooling", choices=("last", "mean"), default="last",
                    help="EncodeTask pooling (--task encode)")
    ap.add_argument("--spec-draft", default="",
                    help="speculative decoding draft: 'self', 'auto', or a "
                         "registered draft config name (empty = off)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="speculation length: draft tokens proposed per "
                         "verify step (--spec-draft)")
    ap.add_argument("--spec-branches", type=int, default=1,
                    help="token-tree width: candidates proposed per "
                         "speculation depth (1 = single-chain rounds)")
    ap.add_argument("--draft-checkpoint", default="",
                    help="checkpoint directory to load draft params from "
                         "(default: seeded init; requires --spec-draft)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV pool block size (tokens)")
    ap.add_argument("--kv-pool-blocks", type=int, default=0,
                    help="KV pool capacity in blocks (0 => engine default); "
                         "undersize it to exercise preemption")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="share cached prompt-prefix KV blocks across "
                         "requests (serving/prefix_cache.py); "
                         "--no-prefix-cache restores cold prefills")
    ap.add_argument("--cache-blocks", type=int, default=0,
                    help="cap on pool blocks the prefix-cache index may "
                         "hold (0 => bounded by pool pressure alone)")
    ap.add_argument("--weight-dtype", choices=("bfloat16", "int8"),
                    default="bfloat16",
                    help="GEMM weight storage: int8 quantizes per output "
                         "channel once at startup (models/quantize) and "
                         "dequantizes inside the fused fp32 epilogues")
    ap.add_argument("--kv-dtype", choices=("bfloat16", "int8"),
                    default="bfloat16",
                    help="paged KV pool storage: int8 quantizes on write "
                         "with per-block-per-head scales (dense fallback "
                         "layouts stay bf16)")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome trace-event JSON of the run "
                         "(Perfetto-viewable); empty = tracing off")
    ap.add_argument("--trace-buffer", type=int, default=65536,
                    help="tracer ring-buffer capacity in events "
                         "(oldest dropped beyond it)")
    ap.add_argument("--metrics-out", default="",
                    help="write a Prometheus-style text snapshot of the "
                         "final stats (empty = off)")
    ap.add_argument("--no-fuse", action="store_true",
                    help="disable the fused prologue/epilogue GEMM "
                         "pipeline (A/B parity baseline)")
    ap.add_argument("--single-device", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.min_prompt_len > args.prompt_len:
        ap.error(f"--min-prompt-len {args.min_prompt_len} exceeds "
                 f"--prompt-len {args.prompt_len}")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = None if args.single_device else make_mesh_for(len(jax.devices()))
    params = lm.init_lm(jax.random.key(args.seed), cfg, jnp.bfloat16)

    spec = (SpecConfig(draft=args.spec_draft, k=args.spec_k,
                       branches=args.spec_branches)
            if args.spec_draft else None)
    tracer = Tracer(capacity=args.trace_buffer) if args.trace_out else None
    engine = InferenceEngine(
        cfg, params, batch_size=args.batch, max_seq=args.max_seq, mesh=mesh,
        block_size=args.block_size,
        kv_pool_blocks=args.kv_pool_blocks or None,
        scheduler=make_policy(args.policy, chunk_tokens=args.prefill_chunk,
                              cache_aware=args.prefix_cache),
        fuse_epilogues=not args.no_fuse, spec=spec,
        draft_checkpoint=args.draft_checkpoint or None,
        prefix_cache=args.prefix_cache,
        cache_blocks=args.cache_blocks or None,
        weight_dtype=args.weight_dtype, kv_dtype=args.kv_dtype,
        overlap=args.overlap, tracer=tracer)
    if (args.policy == "chunked"
            and not engine.runner.supports_chunked):
        print(f"note: {cfg.name} cannot chunk prefills "
              f"(recurrent/ring/cross-attn cache state) — "
              f"falling back to whole-prompt admission")
    if args.prefix_cache and engine.prefix_cache is None:
        print(f"note: prefix cache disabled for {cfg.name} — "
              f"{engine.runner.prefix_cache_reason}")
    for req in build_trace(cfg, args):
        engine.submit(req)

    t0 = time.perf_counter()
    done = engine.run()
    wall = time.perf_counter() - t0
    stats = engine.stats()

    print(f"served {len(done)} requests in {wall:.2f}s over "
          f"{engine.steps_run} AR steps "
          f"[policy={args.policy}"
          f"{', overlap' if args.overlap else ''}] "
          f"({stats.prefill_compiles} prefill buckets compiled: "
          f"{sorted(stats.bucket_hits)})")
    print(stats.summary())
    if spec is not None:
        print(f"  spec: draft={engine.runner.draft_cfg.name} k={args.spec_k}"
              f" | {stats.spec_acceptance_rate:.0%} of "
              f"{stats.spec_proposed_tokens} proposals accepted, "
              f"{stats.spec_tokens_per_step:.2f} tokens/target-step, "
              f"draft p50 {stats.draft_time_ms_p50:.1f}ms p95 "
              f"{stats.draft_time_ms_p95:.1f}ms")
        if engine.runner.tree_branches > 1:
            print(f"  tree: b={engine.runner.tree_branches} | "
                  f"{stats.spec_tree_nodes} nodes verified, accepted-path "
                  f"depth p50 {stats.spec_path_depth_p50:.1f} p95 "
                  f"{stats.spec_path_depth_p95:.1f}, branch utilization "
                  f"{stats.spec_branch_utilization:.0%}")
    util = stats.phase_util()
    if util:
        print("  util: " + " | ".join(
            f"{ph} MFU {row['mfu']:.2%} MBU {row['mbu']:.2%} "
            f"({row['time_s'] * 1e3:.0f}ms)"
            for ph, row in util.items()))
    if tracer is not None:
        n_ev = tracer.write(args.trace_out)
        print(f"  trace: {n_ev} events -> {args.trace_out} "
              f"({tracer.dropped} dropped; open at https://ui.perfetto.dev)")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(prometheus_text(stats.to_dict()))
        print(f"  metrics: -> {args.metrics_out}")
    for r in sorted(done, key=lambda r: r.uid)[:3]:
        if isinstance(r, EncodeTask):
            e = np.asarray(r.embedding)
            print(f"  enc {r.uid}: prompt {r.prompt_len} (bucket "
                  f"{r.bucket}), {r.encode_ms:.0f}ms, |emb|="
                  f"{float(np.linalg.norm(e)):.3f} [{e[0]:+.4f} "
                  f"{e[1]:+.4f} ...]")
        else:
            print(f"  req {r.uid}: prompt {r.prompt_len} (bucket "
                  f"{r.bucket}), prefill {r.prefill_ms:.0f}ms, "
                  f"{len(r.output)} tokens, first: {r.output[:8]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
