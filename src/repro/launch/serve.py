"""Serving driver: continuous-batching engine demo.

    PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \
        --reduced --requests 8 --batch 4 --prompt-len 32 --max-new 16

Reports the paper's two serving metrics: NAR prefill throughput (tokens/s
of prompt encoding) and AR decode throughput (tokens/s of generation).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_mesh_for
from repro.models import lm
from repro.serving import Request, ServingEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--single-device", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = None if args.single_device else make_mesh_for(len(jax.devices()))
    params = lm.init_lm(jax.random.key(args.seed), cfg, jnp.bfloat16)

    engine = ServingEngine(cfg, params, batch_size=args.batch,
                           max_seq=args.max_seq, prompt_len=args.prompt_len,
                           mesh=mesh)
    rng = np.random.default_rng(args.seed)
    for uid in range(args.requests):
        engine.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab, args.prompt_len,
                                dtype=np.int32),
            max_new_tokens=args.max_new))

    t0 = time.perf_counter()
    done = engine.run()
    wall = time.perf_counter() - t0
    prompt_toks = len(done) * args.prompt_len
    new_toks = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests in {wall:.2f}s over "
          f"{engine.steps_run} AR steps")
    print(f"NAR prefill: {prompt_toks} prompt tokens; "
          f"AR decode: {new_toks} tokens "
          f"({new_toks / max(wall, 1e-9):.1f} tok/s end-to-end)")
    for r in done[:3]:
        print(f"  req {r.uid}: prefill {r.prefill_ms:.0f}ms, "
              f"{len(r.output)} tokens, first: {r.output[:8]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
