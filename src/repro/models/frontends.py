"""Modality frontend STUBS + `input_specs()` builders (assignment contract).

[vlm]/[audio] entries specify the transformer BACKBONE only: the InternViT
patch encoder and the Whisper conv/mel frontend are stubs — `input_specs()`
supplies precomputed patch/frame embeddings as ShapeDtypeStructs (dry-run)
and `make_batch()` materializes deterministic synthetic ones (tests/bench).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig

EMBED_DTYPE = jnp.bfloat16


def batch_struct(cfg: ModelConfig, shape_kind: str, global_batch: int,
                 seq_len: int) -> dict:
    """ShapeDtypeStruct stand-ins for one full-sequence step's data batch.
    `seq_len` counts the TOTAL sequence (VLM patch prefix included).
    `shape_kind`: "train" adds labels; "prefill" and "encode" (the
    encoder-only serving step, launch/steps.make_encode_step) are
    tokens-only plus any modality inputs."""
    s_text = seq_len - (cfg.n_patches or 0)
    assert s_text > 0, (seq_len, cfg.n_patches)
    out = {"tokens": jax.ShapeDtypeStruct((global_batch, s_text), jnp.int32)}
    if shape_kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((global_batch, s_text),
                                             jnp.int32)
    if cfg.n_patches:
        out["patches"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.n_patches, cfg.d_model), EMBED_DTYPE)
    if cfg.enc_schedule:
        out["frames"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.enc_seq_padded, cfg.d_model), EMBED_DTYPE)
    return out


def decode_struct(global_batch: int) -> dict:
    return {"token": jax.ShapeDtypeStruct((global_batch,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((global_batch,), jnp.int32)}


def make_batch(cfg: ModelConfig, shape_kind: str, global_batch: int,
               seq_len: int, *, seed: int = 0) -> dict:
    """Deterministic synthetic batch matching `batch_struct` (smoke tests)."""
    rng = np.random.default_rng(seed)
    s_text = seq_len - (cfg.n_patches or 0)
    toks = rng.integers(0, max(cfg.vocab, 2), (global_batch, s_text + 1),
                        dtype=np.int32)
    out = {"tokens": jnp.asarray(toks[:, :-1])}
    if shape_kind == "train":
        out["labels"] = jnp.asarray(toks[:, 1:])
    if cfg.n_patches:
        out["patches"] = jnp.asarray(
            rng.standard_normal((global_batch, cfg.n_patches, cfg.d_model),
                                dtype=np.float32), EMBED_DTYPE)
    if cfg.enc_schedule:
        frames = np.zeros((global_batch, cfg.enc_seq_padded, cfg.d_model),
                          np.float32)
        frames[:, :cfg.enc_seq] = rng.standard_normal(
            (global_batch, cfg.enc_seq, cfg.d_model), dtype=np.float32)
        out["frames"] = jnp.asarray(frames, EMBED_DTYPE)
    return out
