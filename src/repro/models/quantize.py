"""Weight-only int8 quantization of the LM parameter tree (§Perf P1b).

One-shot, post-load transform for serving: every dense GEMM weight —
attention projections (wq/wk/wv/wo, incl. cross-attention), MLP weights
(wg/wu/w1/w2) and the logit head (unemb) — is replaced by

    {"q": int8 (same shape), "scale": fp32 per-output-channel}

with symmetric per-output-channel scales (optim/compression.py's
`quantize_int8_axiswise` over everything but the contraction dim).  The
GEMM entry points in kernels/ops.py accept the dict transparently
(`split_quantized`) and fold the dequant multiply into the fp32-accumulator
epilogue of the fused kernels, so the int8 tensor is what streams from HBM.

Deliberately left in bf16: the embedding table (a gather, not a GEMM),
norm scales/biases, MoE experts + router (capacity-dispatch batched GEMMs
don't route through the fused kernels) and SSM state parameters.  The
transform is pure jnp — `jax.eval_shape(quantize_params, params)` gives the
quantized structure for donation layouts, and `quantize_param_dims` maps
the logical-dim tree (models/lm.lm_param_dims) alongside it.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.optim.compression import quantize_int8_axiswise

# dense-GEMM leaf names inside a (stacked) block param dict; MoE leaves
# reuse wg/wu/w2 but are 4-D stacked [L, NE, ., .] and excluded by rank
QUANT_KEYS = frozenset({"wq", "wk", "wv", "wo", "wg", "wu", "w1", "w2"})
_STACKED_RANK = 3          # [L, K, N] — scanned dense weights


def _quantize_leaf(w):
    """[.., K, N] -> {"q": int8 same shape, "scale": fp32 [.., N]} —
    per-output-channel: one scale per column of the GEMM, amax'd over the
    contraction dim K (axis -2)."""
    keep = tuple(a for a in range(w.ndim) if a != w.ndim - 2)
    q, scale = quantize_int8_axiswise(w, axis=keep)
    return {"q": q, "scale": scale}


def _quantize_block(node, name=None):
    if isinstance(node, dict):
        return {k: _quantize_block(v, k) for k, v in node.items()}
    if (name in QUANT_KEYS and getattr(node, "ndim", 0) == _STACKED_RANK
            and jnp.issubdtype(node.dtype, jnp.floating)):
        return _quantize_leaf(node)
    return node


def quantize_params(params: dict) -> dict:
    """LM param tree (models/lm.init_lm layout) -> same tree with every
    dense GEMM weight replaced by its {"q", "scale"} pair.  Pure jnp —
    jit/eval_shape friendly."""
    out = dict(params)
    emb = dict(params["embedding"])
    emb["unemb"] = _quantize_leaf(params["embedding"]["unemb"])
    out["embedding"] = emb
    for key in ("segments", "enc_segments"):
        if key in params:
            out[key] = tuple(_quantize_block(seg) for seg in params[key])
    return out


def _dims_leaf(d):
    """Logical dims of a quantized leaf: q keeps the weight's dims; the
    per-output-channel scale drops the contraction dim (index -2)."""
    d = tuple(d)
    return {"q": d, "scale": d[:-2] + (d[-1],)}


def _dims_block(node, name=None):
    if isinstance(node, dict):
        return {k: _dims_block(v, k) for k, v in node.items()}
    if name in QUANT_KEYS and len(node) == _STACKED_RANK:
        return _dims_leaf(node)
    return node


def quantize_param_dims(dims: dict) -> dict:
    """Map models/lm.lm_param_dims output through the same transform as
    `quantize_params`, so sharding specs stay aligned leaf-for-leaf."""
    out = dict(dims)
    emb = dict(dims["embedding"])
    emb["unemb"] = _dims_leaf(dims["embedding"]["unemb"])
    out["embedding"] = emb
    for key in ("segments", "enc_segments"):
        if key in dims:
            out[key] = tuple(_dims_block(seg) for seg in dims[key])
    return out
