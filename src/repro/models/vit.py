"""Encoder-only ViT classifier (the paper's own model family, Table II).

Real patchify: the paper's stride=kernel conv frontend is mathematically a
linear map on flattened 16x16x3 patches — implemented exactly so (one GEMM),
plus cls token, learned positions, `vit` blocks and the classifier head.
Used by the faithful-reproduction benchmarks (Figs. 8/9/10) and paper-model
smoke tests; the assigned-architecture grid runs through models/lm.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import blocks
from repro.core import collectives as col
from repro.core.nn import act_dtype, pdot
from repro.kernels import ops
from repro.sharding.plan import Plan, UNSHARDED

PATCH_DIM = 16 * 16 * 3


def vit_param_dims(cfg) -> dict:
    seg_dims = [jax.tree.map(lambda d: (None,) + tuple(d),
                             blocks.block_param_dims(kind, cfg),
                             is_leaf=lambda x: isinstance(x, tuple))
                for kind, _ in cfg.schedule]
    return {
        "patch": (None, None), "cls": (None, None), "pos": (None, None),
        "head": (None, None), "head_b": (None,),
        "final_norm": blocks._norm_dims(cfg),
        "segments": tuple(seg_dims),
    }


def init_vit(key, cfg, dtype=jnp.float32) -> dict:
    E = cfg.d_model
    ks = jax.random.split(key, 6)

    def init_segment(k, kind, count):
        kk = jax.random.split(k, count)
        return jax.vmap(lambda q: blocks.init_block(q, kind, cfg, dtype))(kk)

    segs = tuple(init_segment(jax.random.fold_in(ks[0], i), kind, count)
                 for i, (kind, count) in enumerate(cfg.schedule))
    return {
        "patch": (jax.random.normal(ks[1], (PATCH_DIM, E)) * 0.02
                  ).astype(dtype),
        "cls": (jax.random.normal(ks[2], (1, E)) * 0.02).astype(dtype),
        "pos": (jax.random.normal(ks[3], (cfg.image_seq, E)) * 0.02
                ).astype(dtype),
        "head": (jax.random.normal(ks[4], (E, cfg.n_classes)) * 0.02
                 ).astype(dtype),
        "head_b": jnp.zeros((cfg.n_classes,), dtype),
        "final_norm": blocks._init_norm(cfg, dtype),
        "segments": segs,
    }


def forward_vit(params, patches, *, cfg, policy, plan: Plan = UNSHARDED):
    """patches: [B, n_patches, PATCH_DIM] raw pixels -> logits [B, classes].
    One network pass per classification (the paper's image/s metric)."""
    B = patches.shape[0]
    ad = act_dtype(policy)
    x = pdot(patches, params["patch"], policy)          # linear patchify
    cls = jnp.broadcast_to(params["cls"][None], (B, 1, x.shape[-1]))
    x = jnp.concatenate([cls.astype(x.dtype), x], axis=1)
    x = x + params["pos"][None, :x.shape[1]].astype(x.dtype)
    for (kind, _), p_seg in zip(cfg.schedule, params["segments"]):
        def body(h, p_layer, _kind=kind):
            h2, _, _ = blocks.block_full(_kind, p_layer, h, plan=plan,
                                         cfg=cfg, policy=policy)
            return h2, None
        x, _ = jax.lax.scan(body, x, p_seg)
    x = ops.norm(x, params["final_norm"], cfg.norm)
    logits = pdot(x[:, 0], params["head"], policy, out_dtype=jnp.float32)
    return logits + params["head_b"].astype(jnp.float32)


def vit_loss(params, patches, labels, *, cfg, policy, plan: Plan = UNSHARDED):
    logits = forward_vit(params, patches, cfg=cfg, policy=policy, plan=plan)
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    acc = (logits.argmax(-1) == labels).mean()
    return loss, {"loss": loss, "acc": acc}
