"""Decoder-only / encoder-decoder language model over the segment schedule.

The model is a list of *segments* (configs.base): each segment is one
`lax.scan` over `count` stacked layers of one kind, keeping the HLO compact
for deep models (deepseek-67b: one 95-trip while loop).  All forwards run
inside the step's `shard_map` (launch/steps.py) and follow the manual-SPMD
contract: x is [B_loc, S_loc, E] (batch over `plan.batch_axes`, sequence
over `plan.seq_axes`).

Modes
-----
train     full sequence, remat per layer, distributed-CE loss (NAR math)
prefill   full sequence + KV-cache construction, next token (NAR); supports
          right-padded length buckets (`prompt_len`) and in-jit sampling
decode    one token per call against the sequence-sharded cache (AR, T4)

Modality frontends are stubs per the assignment: VLM patch embeddings and
audio frames arrive as precomputed [*, E] inputs (models/frontends.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import blocks
from repro.core import collectives as col
from repro.core.embedding import (ce_loss, embed_sequence, embed_token,
                                  embedding_param_dims,
                                  embedding_param_shapes, greedy_token,
                                  init_embedding, sample_token, sample_topn)
from repro.core.nn import act_dtype
from repro.core.rope import sinusoidal_positions
from repro.kernels import ops
from repro.sharding.plan import Plan

AUX_WEIGHT = 0.01      # MoE load-balance loss weight


# --------------------------------------------------------------------------
# parameters
# --------------------------------------------------------------------------

def _stack_dims(dims):
    return jax.tree.map(lambda d: (None,) + tuple(d), dims,
                        is_leaf=lambda x: isinstance(x, tuple))


def lm_param_dims(cfg) -> dict:
    out = {
        "embedding": embedding_param_dims(cfg),
        "final_norm": blocks._norm_dims(cfg),
        "segments": tuple(_stack_dims(blocks.block_param_dims(kind, cfg))
                          for kind, _ in cfg.schedule),
    }
    if cfg.enc_schedule:
        out["enc_segments"] = tuple(
            _stack_dims(blocks.block_param_dims(kind, cfg))
            for kind, _ in cfg.enc_schedule)
        out["enc_final_norm"] = blocks._norm_dims(cfg)
    return out


def init_lm(key, cfg, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, 4)

    def init_segment(k, kind, count):
        ks = jax.random.split(k, count)
        return jax.vmap(lambda kk: blocks.init_block(kk, kind, cfg, dtype))(ks)

    segs = []
    for i, (kind, count) in enumerate(cfg.schedule):
        segs.append(init_segment(jax.random.fold_in(keys[0], i), kind, count))
    out = {
        "embedding": init_embedding(keys[1], cfg, dtype),
        "final_norm": blocks._init_norm(cfg, dtype),
        "segments": tuple(segs),
    }
    if cfg.enc_schedule:
        enc = []
        for i, (kind, count) in enumerate(cfg.enc_schedule):
            enc.append(init_segment(jax.random.fold_in(keys[2], i), kind,
                                    count))
        out["enc_segments"] = tuple(enc)
        out["enc_final_norm"] = blocks._init_norm(cfg, dtype)
    return out


# --------------------------------------------------------------------------
# sequence assembly
# --------------------------------------------------------------------------

def total_seq(cfg, s_text: int) -> int:
    return s_text + (cfg.n_patches or 0)


def _embed_sequence(params, batch, *, plan: Plan, cfg, policy,
                    with_labels: bool):
    """Local residual stream [B_loc, S_loc, E]; labels/valid cover the FULL
    sequence (vocab-parallel CE contract, core/embedding.py)."""
    tokens = batch["tokens"]                       # [B_loc, S_text]
    B, S_text = tokens.shape
    n_p = cfg.n_patches if (cfg.n_patches and "patches" in batch) else 0
    S_tot = S_text + n_p
    ids_full = tokens
    if n_p:                                        # patch positions: dummy id
        ids_full = jnp.concatenate(
            [jnp.zeros((B, n_p), tokens.dtype), tokens], axis=1)
    x = embed_sequence(params["embedding"]["embed"], ids_full, plan=plan,
                       policy=policy)              # [B, S_loc, E]

    S_loc = S_tot // plan.sp
    off = col.axis_index(plan.seq_axes) * S_loc
    gpos = jnp.arange(S_loc) + off                 # [S_loc] global positions
    if n_p:                                        # overwrite patch prefix
        prow = jnp.take(batch["patches"], jnp.clip(gpos, 0, n_p - 1), axis=1)
        x = jnp.where((gpos < n_p)[None, :, None], prow.astype(x.dtype), x)
    if cfg.rope_theta == 0:                        # whisper: sinusoidal abs
        pos_tab = sinusoidal_positions(S_tot, cfg.d_model)
        x = x + jnp.take(pos_tab, gpos, axis=0)[None].astype(x.dtype)

    if not with_labels:
        return x, None, None
    labels = batch["labels"]
    valid = jnp.ones((B, S_text), bool)
    if "valid" in batch:
        valid &= batch["valid"]
    if n_p:
        labels = jnp.concatenate(
            [jnp.zeros((B, n_p), labels.dtype), labels], axis=1)
        valid = jnp.concatenate([jnp.zeros((B, n_p), bool), valid], axis=1)
    return x, labels, valid


def _run_encoder(params, batch, *, plan: Plan, cfg, policy):
    """Whisper encoder over stub frame embeddings -> [B, S_enc_loc, E]."""
    frames = batch["frames"]                       # [B, S_enc_pad, E]
    S_enc = frames.shape[1]
    S_loc = S_enc // plan.sp
    off = col.axis_index(plan.seq_axes) * S_loc
    x = jax.lax.dynamic_slice_in_dim(frames, off, S_loc, axis=1)
    pos_tab = sinusoidal_positions(S_enc, cfg.d_model)
    x = (x + jnp.take(pos_tab, jnp.arange(S_loc) + off, axis=0)[None]
         ).astype(act_dtype(policy))
    for (kind, _), p_seg in zip(cfg.enc_schedule, params["enc_segments"]):
        def body(carry, p_layer):
            y, _, _ = blocks.block_full(kind, p_layer, carry, plan=plan,
                                        cfg=cfg, policy=policy)
            return y, None
        x, _ = jax.lax.scan(body, x, p_seg)
    return ops.norm(x, params["enc_final_norm"], cfg.norm)


# --------------------------------------------------------------------------
# segment runners
# --------------------------------------------------------------------------

def _run_segments_train(params, x, *, plan, cfg, policy, memory, memory_len):
    aux = jnp.zeros((), jnp.float32)
    for (kind, _), p_seg in zip(cfg.schedule, params["segments"]):
        def body(carry, p_layer, _kind=kind):
            h, a = carry
            h2, _, da = blocks.block_full(_kind, p_layer, h, plan=plan,
                                          cfg=cfg, policy=policy,
                                          memory=memory,
                                          memory_len=memory_len)
            return (h2, a + da), None
        (x, aux), _ = jax.lax.scan(jax.checkpoint(body), (x, aux), p_seg)
    return x, aux


def _run_segments_prefill(params, x, *, plan, cfg, policy, max_seq,
                          memory, memory_len, compact_kv=False,
                          with_cache=True):
    """`with_cache=False` runs the same full-sequence stack cache-free (the
    encoder-only serving pass) — one segment pipeline, not two."""
    caches = []
    for (kind, _), p_seg in zip(cfg.schedule, params["segments"]):
        def body(h, p_layer, _kind=kind):
            h2, cache, _ = blocks.block_full(_kind, p_layer, h, plan=plan,
                                             cfg=cfg, policy=policy,
                                             with_cache=with_cache,
                                             max_seq=max_seq,
                                             memory=memory,
                                             memory_len=memory_len,
                                             compact_kv=compact_kv)
            return h2, cache
        x, seg_cache = jax.lax.scan(body, x, p_seg)
        caches.append(seg_cache)
    return x, tuple(caches)


def _run_segments_decode(params, x, pos, caches, *, plan, cfg, policy,
                         memory_len, block_tables=None, paged_segments=None):
    new_caches = []
    paged_segments = paged_segments or (False,) * len(cfg.schedule)
    for (kind, _), p_seg, c_seg, pgd in zip(cfg.schedule, params["segments"],
                                            caches, paged_segments):
        def body(h, inp, _kind=kind, _paged=pgd):
            p_layer, c_layer = inp
            h2, c2 = blocks.block_decode(_kind, p_layer, h, pos, c_layer,
                                         plan=plan, cfg=cfg, policy=policy,
                                         memory_len=memory_len,
                                         block_tables=block_tables,
                                         paged=_paged)
            return h2, c2
        x, c_new = jax.lax.scan(body, x, (p_seg, c_seg))
        new_caches.append(c_new)
    return x, tuple(new_caches)


# --------------------------------------------------------------------------
# entry points (called inside shard_map)
# --------------------------------------------------------------------------

def forward_train(params, batch, *, plan: Plan, cfg, policy):
    """-> (loss_for_grad, metrics).

    `loss_for_grad` is THIS DEVICE's contribution to the global mean loss
    (manual-SPMD contract: differentiating a psum'd scalar inside shard_map
    would scale every gradient by the device count, since each device would
    return the same global sum).  The psum'd global loss lives in
    `metrics["loss"]`.  With no mesh the two coincide."""
    x, labels, valid = _embed_sequence(params, batch, plan=plan, cfg=cfg,
                                       policy=policy, with_labels=True)
    memory = memory_len = None
    if cfg.enc_schedule:
        memory = _run_encoder(params, batch, plan=plan, cfg=cfg,
                              policy=policy)
        memory_len = cfg.enc_seq_padded
    x, aux = _run_segments_train(params, x, plan=plan, cfg=cfg, policy=policy,
                                 memory=memory, memory_len=memory_len)
    x = ops.norm(x, params["final_norm"], cfg.norm)
    loss_sum, cnt = ce_loss(x, params["embedding"]["unemb"], labels, valid,
                            plan=plan, cfg=cfg, policy=policy)
    # CE is computed redundantly on every tp peer (x gathered over seq): each
    # copy is scaled by 1/tp so that, summed over devices, the total counts
    # every token exactly once — the manual-SPMD "loss = sum of per-device
    # contributions" contract that makes the collective transposes exact.
    sp = max(plan.sp, 1)
    tok_axes = plan.batch_axes + plan.seq_axes
    n = jnp.maximum(col.psum(cnt / sp, tok_axes), 1.0)
    contrib = (loss_sum / sp) / n               # this device's share
    ce = col.psum(contrib, tok_axes)            # global mean (metrics)
    loss_for_grad = contrib
    metrics = {"ce": ce, "tokens": n}
    if cfg.n_experts:
        n_moe = sum(c for k, c in cfg.schedule if k in blocks.MOE_KINDS)
        aux_share = aux / sp / max(plan.dp, 1) / max(n_moe, 1)
        loss_for_grad = loss_for_grad + AUX_WEIGHT * aux_share
        metrics["aux"] = col.psum(aux_share, tok_axes)
    metrics["loss"] = ce + (AUX_WEIGHT * metrics["aux"]
                            if cfg.n_experts else 0.0)
    return loss_for_grad, metrics


def _head_norm(params, plan: Plan, cfg):
    """Final-norm prologue for the logits head when the fused pipeline is
    on (None = apply ops.norm separately, the unfused chain)."""
    if not blocks.block_fused(plan):
        return None
    return ops.norm_prologue(params["final_norm"], cfg.norm)


def _last_position(x, plan: Plan):
    """x: [B, S_loc, E] sequence-sharded -> [B, E] residual of the final
    global position (fixed-length convenience over `_residual_at`)."""
    B, S_loc = x.shape[0], x.shape[1]
    S_tot = S_loc * max(plan.sp, 1)
    return _residual_at(x, jnp.full((B,), S_tot - 1, jnp.int32), plan)


def _residual_at(x, idx, plan: Plan):
    """x: [B, S_loc, E] sequence-sharded; idx: [B] global positions ->
    [B, E] residual at each row's position (psum'd from the owner shard)."""
    S_loc = x.shape[1]
    off = col.axis_index(plan.seq_axes) * S_loc
    loc = idx.astype(jnp.int32) - off
    rows = jnp.take_along_axis(
        x, jnp.clip(loc, 0, S_loc - 1)[:, None, None], axis=1)[:, 0]
    if not plan.seq_axes:
        return rows
    mine = ((loc >= 0) & (loc < S_loc))[:, None].astype(jnp.float32)
    return col.psum(rows.astype(jnp.float32) * mine,
                    plan.seq_axes).astype(x.dtype)


def forward_prefill(params, batch, *, plan: Plan, cfg, policy, max_seq: int,
                    prompt_len=None, lane=None, compact_kv: bool = False):
    """NAR prompt pass.  -> (next_token [B], caches, pos [B]).

    `prompt_len` ([B] int32, optional): true per-row text length when
    `batch["tokens"]` is right-padded to a length bucket — the next token is
    read at each row's true last position and `pos` starts at its true
    length (pad cache entries beyond it are never attended: decode masks
    positions > pos, and causality masks them during the prefill itself).
    `lane` (optional): per-row sampling state (core.embedding.sample_token,
    sans "step"); greedy decoding when None.
    `compact_kv`: emit full-context KV caches at prompt length instead of
    padded to `max_seq` (paged admission scatters them into pool blocks)."""
    x, _, _ = _embed_sequence(params, batch, plan=plan, cfg=cfg,
                              policy=policy, with_labels=False)
    memory = None
    memory_len = 0
    if cfg.enc_schedule:
        memory = _run_encoder(params, batch, plan=plan, cfg=cfg,
                              policy=policy)
        memory_len = cfg.enc_seq_padded
    x, caches = _run_segments_prefill(params, x, plan=plan, cfg=cfg,
                                      policy=policy, max_seq=max_seq,
                                      memory=memory, memory_len=memory_len,
                                      compact_kv=compact_kv)
    head_norm = _head_norm(params, plan, cfg)
    if head_norm is None:
        x = ops.norm(x, params["final_norm"], cfg.norm)
    B = batch["tokens"].shape[0]
    if prompt_len is None:
        pos = jnp.full((B,), total_seq(cfg, batch["tokens"].shape[1]),
                       jnp.int32)
    else:
        pos = (cfg.n_patches or 0) + prompt_len.astype(jnp.int32)
    # fused head: select the raw residual row first (norm is row-wise, so
    # select-then-norm == norm-then-select) and fold the final norm into
    # the logits GEMM — the full-sequence normalized copy never exists
    x_last = _residual_at(x, pos - 1, plan)
    if lane is None:
        tok = greedy_token(x_last, params["embedding"]["unemb"], plan=plan,
                           cfg=cfg, policy=policy, norm=head_norm)
    else:
        tok = sample_token(x_last, params["embedding"]["unemb"],
                           dict(lane, step=pos), plan=plan, cfg=cfg,
                           policy=policy, norm=head_norm)
    return tok, caches, pos


def forward_encode(params, batch, *, plan: Plan, cfg, policy,
                   prompt_len=None, pooling: str = "last"):
    """Encoder-only NAR pass: one full-sequence forward, no KV cache, no
    sampling — the paper's encoder topology served as a first-class task.
    -> pooled [B, E] float32.

    `prompt_len` ([B] int32, optional): true per-row text length when the
    batch is right-padded to a length bucket.  Padding is output-exact only
    for causal schedules (bidirectional kinds attend pad positions); the
    runner pads only when every kind is causal and encodes at exact length
    otherwise.
    `pooling`: "last" — residual at the final true position (what a prefill
    would sample from); "mean" — masked mean over the true positions."""
    x, _, _ = _embed_sequence(params, batch, plan=plan, cfg=cfg,
                              policy=policy, with_labels=False)
    memory = None
    memory_len = 0
    if cfg.enc_schedule:
        memory = _run_encoder(params, batch, plan=plan, cfg=cfg,
                              policy=policy)
        memory_len = cfg.enc_seq_padded
    x, _ = _run_segments_prefill(params, x, plan=plan, cfg=cfg,
                                 policy=policy, max_seq=0, memory=memory,
                                 memory_len=memory_len, with_cache=False)
    fused_head = blocks.block_fused(plan) and pooling == "last"
    if not fused_head:
        # mean pooling needs every position normalized — norm of the mean
        # is not the mean of the norms, so the full-seq norm stays
        x = ops.norm(x, params["final_norm"], cfg.norm)

    B, S_loc = x.shape[0], x.shape[1]
    n_p = cfg.n_patches or 0
    if prompt_len is None:
        pos = jnp.full((B,), S_loc * max(plan.sp, 1), jnp.int32)
    else:
        pos = n_p + prompt_len.astype(jnp.int32)
    if pooling == "last":
        if fused_head:      # select the raw row, then norm just that row
            row = _residual_at(x, pos - 1, plan)
            return ops.norm(row, params["final_norm"],
                            cfg.norm).astype(jnp.float32)
        return _residual_at(x, pos - 1, plan).astype(jnp.float32)
    # masked mean over true text positions (patch prefix excluded)
    off = col.axis_index(plan.seq_axes) * S_loc
    gpos = jnp.arange(S_loc)[None, :] + off                    # [1, S_loc]
    keep = (gpos >= n_p) & (gpos < pos[:, None])               # [B, S_loc]
    s = jnp.sum(x.astype(jnp.float32) * keep[..., None], axis=1)
    s = col.psum(s, plan.seq_axes)
    n = jnp.maximum((pos - n_p).astype(jnp.float32), 1.0)
    return s / n[:, None]


def _run_chunk_stack(params, tokens, pos0, chunk_len, caches, block_tables,
                     *, plan: Plan, cfg, policy, paged_segments,
                     rope_pos=None, tree_mask=None):
    """The shared chunk body: embed C consecutive tokens per row, run every
    segment's `block_chunk` (KV scattered into the paged blocks), apply the
    final norm unless the fused head will fold it.  `forward_chunk`
    (chunked prefill: sample the last position), `forward_verify`
    (speculative decoding: sample every position), and
    `forward_verify_tree` (tree speculation: `rope_pos`/`tree_mask`
    overrides) all sit on THIS stack — the verify paths' losslessness
    rests on them sharing one body.
    -> (x [B, C, E], caches, head_norm-or-None)."""
    B, C = tokens.shape
    x = embed_token(params["embedding"]["embed"], tokens.reshape(B * C),
                    plan=plan, policy=policy).reshape(B, C, -1)
    paged_segments = paged_segments or (True,) * len(cfg.schedule)
    new_caches = []
    for (kind, _), p_seg, c_seg, pgd in zip(cfg.schedule, params["segments"],
                                            caches, paged_segments):
        assert pgd, f"chunk forward requires paged segments: {kind}"
        def body(h, inp, _kind=kind):
            p_layer, c_layer = inp
            h2, c2 = blocks.block_chunk(_kind, p_layer, h, pos0, chunk_len,
                                        c_layer, block_tables, plan=plan,
                                        cfg=cfg, policy=policy,
                                        rope_pos=rope_pos,
                                        tree_mask=tree_mask)
            return h2, c2
        x, c_new = jax.lax.scan(body, x, (p_seg, c_seg))
        new_caches.append(c_new)
    head_norm = _head_norm(params, plan, cfg)
    if head_norm is None:
        x = ops.norm(x, params["final_norm"], cfg.norm)
    return x, tuple(new_caches), head_norm


def forward_chunk(params, tokens, pos0, chunk_len, caches, block_tables, *,
                  plan: Plan, cfg, policy, lane=None, paged_segments=None):
    """One chunked-prefill piece: encode C consecutive prompt tokens into
    the paged KV cache.  tokens: [B, C]; pos0: [B] absolute start position;
    chunk_len: [B] real tokens this chunk (<= C; tail is padding).
    -> (next_token [B], caches, pos [B]).

    Every call also samples a token at each row's last real chunk position
    — the caller uses it only when the chunk completes the prompt, where it
    equals what `forward_prefill` samples (same residual, same (seed, step)
    draw).  Requires every segment paged (ModelRunner.supports_chunked);
    `lane` as in forward_prefill (sans prompt_len); greedy when None."""
    B, C = tokens.shape
    x, new_caches, head_norm = _run_chunk_stack(
        params, tokens, pos0, chunk_len, caches, block_tables, plan=plan,
        cfg=cfg, policy=policy, paged_segments=paged_segments)

    pos = pos0 + chunk_len.astype(jnp.int32)
    last = jnp.clip(chunk_len - 1, 0, C - 1)
    x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]
    if lane is None:
        tok = greedy_token(x_last, params["embedding"]["unemb"], plan=plan,
                           cfg=cfg, policy=policy, norm=head_norm)
    else:
        tok = sample_token(x_last, params["embedding"]["unemb"],
                           dict(lane, step=pos), plan=plan, cfg=cfg,
                           policy=policy, norm=head_norm)
    return tok, new_caches, pos


def forward_verify(params, tokens, pos0, chunk_len, caches, block_tables, *,
                   plan: Plan, cfg, policy, lane=None, paged_segments=None):
    """Multi-token verification pass for speculative decoding: one target
    forward over C = k+1 consecutive tokens (the pending token + k draft
    proposals) straight into the paged KV cache, returning the target's
    OWN next-token choice at every position.  tokens: [B, C]; pos0: [B]
    absolute start position (== the slot's decode pos); chunk_len: [B]
    real tokens this row carries (<= C; tail is padding).
    -> (choices [B, C], caches, pos [B]).

    choices[b, j] is the token the target would emit after the prefix
    ending at absolute position pos0[b] + j — i.e. exactly what a
    non-speculative decode step at that state would produce: greedy rows
    take the argmax, sampled rows the (seed, step)-keyed Gumbel-max draw
    with step = pos0 + j + 1, matching forward_decode's step = pos + 1.
    The host accepts the longest prefix where the draft's proposal equals
    the target's choice (serving/spec.py), so committed outputs are
    token-identical to step-by-step decoding.  KV for every chunk position
    is scattered into the slot's blocks; rejected positions sit beyond the
    committed `pos` and are masked / overwritten — rollback is a
    fill-count rewind, not a cache edit.  Requires every segment paged
    (same gate as forward_chunk, whose stack this shares)."""
    B, C = tokens.shape
    x, new_caches, head_norm = _run_chunk_stack(
        params, tokens, pos0, chunk_len, caches, block_tables, plan=plan,
        cfg=cfg, policy=policy, paged_segments=paged_segments)

    # every position samples: flatten [B, C, E] -> [B*C, E] and draw with
    # step = pos0 + j + 1 per position (the decode-step contract: the token
    # occupying position p is drawn with step p)
    E = x.shape[-1]
    x_flat = x.reshape(B * C, E)
    steps = (pos0[:, None] + 1 + jnp.arange(C)[None, :]).astype(jnp.int32)
    if lane is None:
        tok = greedy_token(x_flat, params["embedding"]["unemb"], plan=plan,
                           cfg=cfg, policy=policy, norm=head_norm)
    else:
        lane_flat = {k: jnp.repeat(v, C) for k, v in lane.items()}
        tok = sample_token(x_flat, params["embedding"]["unemb"],
                           dict(lane_flat, step=steps.reshape(B * C)),
                           plan=plan, cfg=cfg, policy=policy, norm=head_norm)
    return (tok.reshape(B, C), new_caches,
            pos0 + chunk_len.astype(jnp.int32))


def forward_verify_tree(params, tokens, pos0, chunk_len, depth, anc, caches,
                        block_tables, *, plan: Plan, cfg, policy, lane=None,
                        paged_segments=None):
    """Tree-speculative verification: one target forward over C flattened
    token-tree nodes per row (node 0 = the pending token, then the draft's
    branches in an ancestor-closed flatten order), returning the target's
    own next-token choice at every node.  tokens: [B, C] node tokens;
    pos0: [B] the slot's decode pos; chunk_len: [B] real nodes (<= C);
    depth: [B, C] int32 each node's tree depth (node 0 -> 0); anc: [B, C, C]
    bool ancestor-or-self matrix (anc[b, i, j] <=> node j is on node i's
    root path).  -> (choices [B, C], caches, pos [B]).

    Node i's KV scatters at position pos0 + i (unique per node — the same
    scatter mechanics as forward_verify), while rope and the sampling step
    use the node's *logical* position pos0 + depth[i]: sibling branches at
    one depth share a rotation and a (seed, step) draw key, exactly the
    state a step-by-step decode would have after committing that node's
    root path — so choices[b, i] is bit-identical to what non-speculative
    decode (or forward_verify on the same chain) would emit there, greedy
    or sampled, and the winning path's KV bytes are already rotated for
    their final positions (commit is a pure row move, serving/kv_cache.py).
    The ancestor mask keeps each node blind to its sibling branches:
    attention sees the committed prefix (< pos0) plus its own root path
    only.  With a single-branch chain (depth == node index, anc lower
    triangular) every override reduces to forward_verify's causal math."""
    B, C = tokens.shape
    rope_pos = pos0[:, None] + depth                           # [B, C]
    x, new_caches, head_norm = _run_chunk_stack(
        params, tokens, pos0, chunk_len, caches, block_tables, plan=plan,
        cfg=cfg, policy=policy, paged_segments=paged_segments,
        rope_pos=rope_pos, tree_mask=anc)

    E = x.shape[-1]
    x_flat = x.reshape(B * C, E)
    steps = (rope_pos + 1).astype(jnp.int32)     # token after node i's path
    if lane is None:
        tok = greedy_token(x_flat, params["embedding"]["unemb"], plan=plan,
                           cfg=cfg, policy=policy, norm=head_norm)
    else:
        lane_flat = {k: jnp.repeat(v, C) for k, v in lane.items()}
        tok = sample_token(x_flat, params["embedding"]["unemb"],
                           dict(lane_flat, step=steps.reshape(B * C)),
                           plan=plan, cfg=cfg, policy=policy, norm=head_norm)
    return (tok.reshape(B, C), new_caches,
            pos0 + chunk_len.astype(jnp.int32))


def forward_decode(params, token, pos, caches, *, plan: Plan, cfg, policy,
                   lane=None, block_tables=None, paged_segments=None):
    """One AR step.  token/pos: [B] -> (next_token [B], caches).

    `lane` (optional): per-row sampling state (core.embedding.sample_token,
    sans "step"); greedy decoding when None.
    `block_tables` / `paged_segments` (optional): block-paged KV cache —
    [B, MB] int32 pool indices per slot plus a static per-segment tuple
    marking which segments' k/v leaves are pools (launch/steps builds both;
    `pos` doubles as the per-slot valid length)."""
    x = embed_token(params["embedding"]["embed"], token, plan=plan,
                    policy=policy)                              # [B, E]
    if cfg.rope_theta == 0:
        pos_tab = sinusoidal_positions(cfg.max_seq, cfg.d_model)
        x = x + jnp.take(pos_tab, jnp.clip(pos, 0, cfg.max_seq - 1),
                         axis=0).astype(x.dtype)
    memory_len = cfg.enc_seq_padded if cfg.enc_schedule else 0
    x, caches = _run_segments_decode(params, x, pos, caches, plan=plan,
                                     cfg=cfg, policy=policy,
                                     memory_len=memory_len,
                                     block_tables=block_tables,
                                     paged_segments=paged_segments)
    head_norm = _head_norm(params, plan, cfg)
    if head_norm is None:
        x = ops.norm(x, params["final_norm"], cfg.norm)
    if lane is None:
        tok = greedy_token(x, params["embedding"]["unemb"], plan=plan,
                           cfg=cfg, policy=policy, norm=head_norm)
    else:
        tok = sample_token(x, params["embedding"]["unemb"],
                           dict(lane, step=pos + 1), plan=plan, cfg=cfg,
                           policy=policy, norm=head_norm)
    return tok, caches


def forward_decode_topk(params, token, pos, caches, *, n, plan: Plan, cfg,
                        policy, lane, block_tables=None, paged_segments=None):
    """One AR step that also surfaces the sampler's runners-up: the tree
    proposer's draft step.  token/pos: [B] -> (next_token [B],
    alts [B, n], caches) where alts[:, 0] == next_token (the chain token —
    the exact `forward_decode` choice) and alts[:, 1:] are the next-best
    distinct ids of the SAME deterministic score `sample_token` ranks
    (greedy rows: raw logits; sampled rows: the (seed, step)-keyed
    Gumbel-perturbed top-k-masked scores).  The draft's cache advances one
    position regardless — only the chain is ever fed back."""
    x = embed_token(params["embedding"]["embed"], token, plan=plan,
                    policy=policy)                              # [B, E]
    if cfg.rope_theta == 0:
        pos_tab = sinusoidal_positions(cfg.max_seq, cfg.d_model)
        x = x + jnp.take(pos_tab, jnp.clip(pos, 0, cfg.max_seq - 1),
                         axis=0).astype(x.dtype)
    memory_len = cfg.enc_seq_padded if cfg.enc_schedule else 0
    x, caches = _run_segments_decode(params, x, pos, caches, plan=plan,
                                     cfg=cfg, policy=policy,
                                     memory_len=memory_len,
                                     block_tables=block_tables,
                                     paged_segments=paged_segments)
    head_norm = _head_norm(params, plan, cfg)
    if head_norm is None:
        x = ops.norm(x, params["final_norm"], cfg.norm)
    tok, alts = sample_topn(x, params["embedding"]["unemb"],
                            dict(lane, step=pos + 1), n, plan=plan, cfg=cfg,
                            policy=policy, norm=head_norm)
    return tok, alts, caches
