"""Elastic scaling: resume a checkpoint onto a different mesh.

Checkpoints store logical arrays (checkpoint/checkpointer.py), so scaling
down/up is: build the new mesh -> rebuild the step bundle (the Plan resolves
the same logical dims onto the new axes) -> restore with the new shardings.
The batch axes re-divide automatically as long as global_batch still divides
the new dp size (asserted here).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.launch import steps as steps_mod
from repro.launch.mesh import make_mesh_for


def elastic_restore(checkpointer, cfg, shape, *, n_devices: Optional[int] = None,
                    mesh=None, step: Optional[int] = None, **step_kwargs):
    """-> (bundle, state) on the new mesh, restored from the checkpoint."""
    if mesh is None:
        n = n_devices or len(jax.devices())
        mesh = make_mesh_for(n)
    bundle = steps_mod.make_train_step(cfg, shape, mesh, **step_kwargs)
    plan = bundle.plan
    assert shape.global_batch % max(plan.dp, 1) == 0, (
        f"global_batch {shape.global_batch} must divide the new dp "
        f"{plan.dp}")
    state_struct = bundle.in_structs[0]
    shardings = steps_mod.to_shardings(bundle.aux["state_specs"], mesh)
    state = checkpointer.restore(state_struct, step=step,
                                 shardings=shardings)
    return bundle, state
