from repro.runtime.fault_tolerance import PreemptionGuard, run_with_restarts
from repro.runtime.straggler import StragglerWatchdog
from repro.runtime.elastic import elastic_restore
