"""Straggler detection: per-step wall-clock watchdog.

On a large fleet a single slow host stretches every synchronous collective.
The watchdog keeps an EMA + variance of step time; a step slower than
`mean + k*sigma` (and `min_ratio` x mean) is flagged, counted, and reported
to a callback — the hook where production deployments trigger mitigation
(re-shard away from the slow host, swap in a hot spare, or turn on backup
steps).  The detector itself is deterministic and unit-tested.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional


class StragglerWatchdog:
    def __init__(self, *, k_sigma: float = 3.0, min_ratio: float = 1.5,
                 warmup: int = 5,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.k_sigma = k_sigma
        self.min_ratio = min_ratio
        self.warmup = warmup
        self.on_straggler = on_straggler
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.events: List[dict] = []
        self._t0 = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> bool:
        dt = time.perf_counter() - self._t0
        return self.observe(step, dt)

    def observe(self, step: int, dt: float) -> bool:
        """Feed one step duration; returns True if flagged as straggler."""
        flagged = False
        if self.n >= self.warmup:
            sigma = max(self.var, 1e-12) ** 0.5
            thresh = max(self.mean + self.k_sigma * sigma,
                         self.mean * self.min_ratio)
            if dt > thresh:
                flagged = True
                self.events.append({"step": step, "dt": dt,
                                    "mean": self.mean, "thresh": thresh})
                if self.on_straggler:
                    self.on_straggler(step, dt, self.mean)
        if not flagged:            # don't poison the EMA with outliers
            alpha = 0.1 if self.n else 1.0
            delta = dt - self.mean
            self.mean += alpha * delta
            self.var = (1 - alpha) * (self.var + alpha * delta * delta)
        self.n += 1
        return flagged
