"""Preemption handling and restart-with-restore (1000+-node contract).

At fleet scale the training binary WILL be preempted and nodes WILL fail.
The loop contract here:

  * SIGTERM/SIGINT -> finish the in-flight step -> blocking checkpoint ->
    exit with RESTART_EXIT_CODE (the scheduler relaunches).
  * On relaunch, the driver restores the latest checkpoint and the
    step-indexed data pipeline resumes bitwise-exactly.
  * `run_with_restarts` is the in-process harness used by tests: it runs the
    step loop, injects/absorbs failures, restores, and continues — proving
    the restart path end-to-end without a cluster scheduler.
"""
from __future__ import annotations

import signal
import sys
from typing import Callable, Optional

RESTART_EXIT_CODE = 42


class PreemptionGuard:
    """Installs SIGTERM/SIGINT handlers that set a flag instead of killing
    the process mid-step.  Check `should_stop` at step boundaries."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.should_stop = False
        self._prev = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def _handler(self, signum, frame):
        self.should_stop = True

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            signal.signal(s, h)
        return False


def run_with_restarts(make_state: Callable[[], object],
                      step_fn: Callable[[object, int], object],
                      checkpointer, *, total_steps: int,
                      checkpoint_every: int = 10,
                      max_restarts: int = 3,
                      fail_at: Optional[Callable[[int], bool]] = None):
    """Fault-tolerant loop: run steps, checkpoint periodically, and on any
    exception restore from the latest checkpoint and continue (up to
    `max_restarts`).  `fail_at(step)` injects failures for tests.
    Returns (final_state, steps_executed, restarts)."""
    restarts = 0
    executed = 0
    while True:
        try:
            start = checkpointer.latest_step()
            if start is None:
                state, start = make_state(), 0
            else:
                state = checkpointer.restore(make_state())
            step = start
            while step < total_steps:
                if fail_at is not None and fail_at(step):
                    raise RuntimeError(f"injected failure at step {step}")
                state = step_fn(state, step)
                executed += 1
                step += 1
                if step % checkpoint_every == 0 or step == total_steps:
                    checkpointer.save(state, step)
            return state, executed, restarts
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
