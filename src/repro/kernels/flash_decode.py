"""Split-KV decode attention kernel — the paper's *distributed Softmax
primitive* (T4) at chip scope.

AR decode computes one query token against a long KV cache: a pure
memory-bound matrix-vector pass (the paper's <10%-FPU-utilization regime).
The cache is split into chunks; every chunk produces partial online-softmax
statistics (m, l, o) which are merged in a second stage — the same
max/rescale/sum tree the paper distributes across clusters.  The identical
merge rule combines *cross-chip* partials in core/distributed_softmax.py,
so chip-local and pod-level softmax use one primitive.

Grid: (B, KV, n_chunks) with the chunk dim innermost; partials are merged
in-kernel through VMEM scratch (single pass over the cache)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, block_kv: int, window: int, sm_scale: float):
    """q_ref: [1, 1, G, D]; k/v_ref: [1, block_kv, 1, D];
    len_ref: scalar-prefetch [B] valid lengths; o_ref: [1, 1, G, D]."""
    b = pl.program_id(0)
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g, d = q_ref.shape[2], q_ref.shape[3]
    q = q_ref[0, 0]                                     # [G, D]
    k = k_ref[:, :, 0, :][0]                            # [block_kv, D]
    v = v_ref[:, :, 0, :][0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    length = len_ref[b]
    pos = jax.lax.broadcasted_iota(jnp.int32, (g, block_kv), 1) + ci * block_kv
    mask = pos < length
    if window > 0:
        mask &= pos >= length - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ci == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_kv", "interpret"))
def decode_attention(q, k_cache, v_cache, length, *, window=0, block_kv=512,
                     interpret=False):
    """q: [B, H, D]; caches: [B, S, KV, D]; length: [B] or scalar valid
    lengths.  Returns [B, H, D]."""
    B, H, D = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    block_kv = min(block_kv, S)
    sm_scale = float(1.0 / (D ** 0.5))
    pad = -S % block_kv
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = k_cache.shape[1] // block_kv
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))
    qr = q.reshape(B, KV, G, D)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, nc),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, c, len_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, block_kv, 1, D),
                         lambda b, h, c, len_ref: (b, c, h, 0)),
            pl.BlockSpec((1, block_kv, 1, D),
                         lambda b, h, c, len_ref: (b, c, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, c, len_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_kv=block_kv, window=window,
                          sm_scale=sm_scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        interpret=interpret,
    )(length, qr, k_cache, v_cache)
    return out.reshape(B, H, D)
