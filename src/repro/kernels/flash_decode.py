"""Split-KV decode attention kernel — the paper's *distributed Softmax
primitive* (T4) at chip scope.

AR decode computes one query token against a long KV cache: a pure
memory-bound matrix-vector pass (the paper's <10%-FPU-utilization regime).
The cache is split into chunks; every chunk produces partial online-softmax
statistics (m, l, o) which are merged in a second stage — the same
max/rescale/sum tree the paper distributes across clusters.  The identical
merge rule combines *cross-chip* partials in core/distributed_softmax.py,
so chip-local and pod-level softmax use one primitive.

Grid: (B, KV, n_chunks) with the chunk dim innermost; partials are merged
in-kernel through VMEM scratch (single pass over the cache).

`paged_decode_attention` is the block-paged variant of the same primitive:
the cache is a global pool of fixed-size KV blocks and each slot owns an
ordered *block table* of pool indices.  The table (and the per-slot valid
lengths) ride in as scalar-prefetch operands: the grid's innermost
dimension walks table entries, the BlockSpec index map dereferences the
table to DMA the named block, and absent entries (unallocated / non-owned
shard) skip their fold — the grid still spans max_blocks cells per slot,
but the dot work (and, via consecutive-index pipelining, the block
fetches) tracks the blocks a slot actually owns, while the pool *capacity*
is decoupled from B x max_seq entirely."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _online_merge(ci, q_ref, k_ref, v_ref, m_ref, l_ref, acc_ref, *,
                  mask, live, sm_scale: float, k_scale=None, v_scale=None):
    """Shared split-KV cell body: fold one masked KV chunk's scores into the
    (m, l, acc) scratch with the online-softmax rescale rule, initializing
    the scratch on the first chunk.  `mask`: [G, chunk] validity of this
    chunk's positions; `live`: scalar — False when the whole chunk is
    masked, skipping its dot work entirely (a fully-masked chunk is a
    no-op: corr = 1, p = 0).  The caller writes the output on the last
    chunk.

    `k_scale`/`v_scale`: per-(block, head) fp32 dequant scalars for int8
    K/V chunks.  The scale is constant over this chunk's tokens and dims,
    so it commutes past both dots: scores pick up `k_scale` after Q.K^T
    and the P.V contribution picks up `v_scale` — exact dequantization
    without ever materializing fp K/V tiles."""
    @pl.when(ci == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live)
    def _fold():
        q = q_ref[0, 0]                                 # [G, D]
        k = k_ref[:, :, 0, :][0]                        # [chunk, D]
        v = v_ref[:, :, 0, :][0]
        if k_scale is not None:
            # int8 chunk: run the dots in fp32 (int8 values are exact there)
            q = q.astype(jnp.float32)
            k = k.astype(jnp.float32)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale
        if k_scale is not None:
            s = s * k_scale
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        m_ref[...] = m_new
        if v_scale is not None:
            pv = jax.lax.dot_general(
                p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32) * v_scale
        else:
            pv = jax.lax.dot_general(
                p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, block_kv: int, window: int, sm_scale: float):
    """q_ref: [1, 1, G, D]; k/v_ref: [1, block_kv, 1, D];
    len_ref: scalar-prefetch [B] valid lengths; o_ref: [1, 1, G, D]."""
    b = pl.program_id(0)
    ci = pl.program_id(2)
    g = q_ref.shape[2]
    length = len_ref[b]
    pos = jax.lax.broadcasted_iota(jnp.int32, (g, block_kv), 1) + ci * block_kv
    mask = pos < length
    live = ci * block_kv < length
    if window > 0:
        mask &= pos >= length - window
        live &= (ci + 1) * block_kv > length - window
    _online_merge(ci, q_ref, k_ref, v_ref, m_ref, l_ref, acc_ref,
                  mask=mask, live=live, sm_scale=sm_scale)

    @pl.when(ci == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_kv", "interpret"))
def decode_attention(q, k_cache, v_cache, length, *, window=0, block_kv=512,
                     interpret=False):
    """q: [B, H, D]; caches: [B, S, KV, D]; length: [B] or scalar valid
    lengths.  Returns [B, H, D]."""
    B, H, D = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    block_kv = min(block_kv, S)
    sm_scale = float(1.0 / (D ** 0.5))
    pad = -S % block_kv
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = k_cache.shape[1] // block_kv
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))
    qr = q.reshape(B, KV, G, D)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, nc),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, c, len_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, block_kv, 1, D),
                         lambda b, h, c, len_ref: (b, c, h, 0)),
            pl.BlockSpec((1, block_kv, 1, D),
                         lambda b, h, c, len_ref: (b, c, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, c, len_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_kv=block_kv, window=window,
                          sm_scale=sm_scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        interpret=interpret,
    )(length, qr, k_cache, v_cache)
    return out.reshape(B, H, D)


# --------------------------------------------------------------------------
# paged split-KV decode
# --------------------------------------------------------------------------

def _paged_mask(tab_ref, len_ref, b, e, g: int, block_size: int):
    """-> (mask [G, BS], live scalar) for pool-block entry `e` of slot `b`:
    token t of entry e holds absolute position e*BS + t, masked when past
    the slot's length or when the table entry is absent (< 0: unallocated /
    non-owned shard).  `live` is False when the whole entry is masked —
    absent entries skip their fold (and their DMA collapses onto block 0,
    which consecutive-index pipelining fetches once), so per-step work
    tracks the blocks a slot actually owns."""
    pos = (jax.lax.broadcasted_iota(jnp.int32, (g, block_size), 1)
           + e * block_size)
    live = (tab_ref[b, e] >= 0) & (e * block_size < len_ref[b])
    return (pos < len_ref[b]) & (tab_ref[b, e] >= 0), live


def _paged_scales(tab_ref, ks_ref, vs_ref, b, h, e):
    """Dequant scalars for this grid step's (block, head) — one pool block
    per step, so a single SMEM lookup each.  Absent entries (t < 0) read
    block 0's scale; their fold is dead (`live` is False)."""
    if ks_ref is None:
        return None, None
    t = jnp.maximum(tab_ref[b, e], 0)
    return ks_ref[t, h], vs_ref[t, h]


def _paged_decode_kernel(*refs, block_size: int, sm_scale: float,
                         quantized: bool):
    """q_ref: [1, 1, G, D]; k/v_ref: [1, block_size, 1, D] — the pool block
    the slot's table names for entry `e` (the index map dereferenced it);
    tab_ref: scalar-prefetch [B, MB] block tables (< 0 = absent);
    len_ref: scalar-prefetch [B] valid lengths.  Quantized pools add
    ks/vs_ref: scalar-prefetch [NB, KV] fp32 per-block-per-head scales."""
    if quantized:
        (tab_ref, len_ref, ks_ref, vs_ref, q_ref, k_ref, v_ref, o_ref,
         m_ref, l_ref, acc_ref) = refs
    else:
        (tab_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
         m_ref, l_ref, acc_ref) = refs
        ks_ref = vs_ref = None
    b = pl.program_id(0)
    h = pl.program_id(1)
    e = pl.program_id(2)
    mask, live = _paged_mask(tab_ref, len_ref, b, e, q_ref.shape[2],
                             block_size)
    ks, vs = _paged_scales(tab_ref, ks_ref, vs_ref, b, h, e)
    _online_merge(e, q_ref, k_ref, v_ref, m_ref, l_ref, acc_ref,
                  mask=mask, live=live, sm_scale=sm_scale,
                  k_scale=ks, v_scale=vs)

    @pl.when(e == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def _paged_partials_kernel(*refs, block_size: int, sm_scale: float,
                           quantized: bool):
    """As _paged_decode_kernel but emits the raw (o, m, l) online-softmax
    partials instead of normalizing — the cross-shard T4 merge
    (core/attention.merge_partials) combines per-device pool shards."""
    if quantized:
        (tab_ref, len_ref, ks_ref, vs_ref, q_ref, k_ref, v_ref, o_ref,
         mo_ref, lo_ref, m_ref, l_ref, acc_ref) = refs
    else:
        (tab_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
         mo_ref, lo_ref, m_ref, l_ref, acc_ref) = refs
        ks_ref = vs_ref = None
    b = pl.program_id(0)
    h = pl.program_id(1)
    e = pl.program_id(2)
    mask, live = _paged_mask(tab_ref, len_ref, b, e, q_ref.shape[2],
                             block_size)
    ks, vs = _paged_scales(tab_ref, ks_ref, vs_ref, b, h, e)
    _online_merge(e, q_ref, k_ref, v_ref, m_ref, l_ref, acc_ref,
                  mask=mask, live=live, sm_scale=sm_scale,
                  k_scale=ks, v_scale=vs)

    @pl.when(e == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0, 0] = acc_ref[...]
        mo_ref[0, 0] = m_ref[...]
        lo_ref[0, 0] = l_ref[...]


def _paged_call(kernel, q, k_pool, v_pool, block_tables, lengths, out_shape,
                out_specs, interpret, k_scale=None, v_scale=None):
    """Shared pallas_call plumbing for the paged kernels: grid (slot,
    kv_head, table entry) with scalar-prefetched tables dereferenced by the
    k/v index maps — each step DMAs exactly one owned pool block.  Int8
    pools additionally prefetch the [NB, KV] dequant scale tables."""
    B, KV, G, D = q.shape
    _, BS, _, _ = k_pool.shape
    MB = block_tables.shape[1]
    sm_scale = float(1.0 / (D ** 0.5))
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
    block_tables = block_tables.astype(jnp.int32)
    quantized = k_scale is not None

    def kv_index(b, h, e, tab_ref, *_pref):
        t = tab_ref[b, e]
        return (jnp.where(t < 0, 0, t), 0, h, 0)   # absent -> any block, masked

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4 if quantized else 2,
        grid=(B, KV, MB),
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, e, *_pref: (b, h, 0, 0)),
            pl.BlockSpec((1, BS, 1, D), kv_index),
            pl.BlockSpec((1, BS, 1, D), kv_index),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    prefetch = (block_tables, lengths)
    if quantized:
        prefetch += (k_scale.astype(jnp.float32),
                     v_scale.astype(jnp.float32))
    return pl.pallas_call(
        functools.partial(kernel, block_size=BS, sm_scale=sm_scale,
                          quantized=quantized),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(*prefetch, q, k_pool, v_pool)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           k_scale=None, v_scale=None, interpret=False):
    """Paged split-KV decode.  q: [B, H, D]; k/v_pool: [NB, BS, KV, D] —
    global pool of fixed-size KV blocks; block_tables: [B, MB] int32 pool
    indices in sequence order (< 0 = absent entry); lengths: [B] valid
    tokens per slot.  Returns [B, H, D], softmax fully normalized
    (single-pool case; sharded pools use `paged_decode_partials`).

    `k_scale`/`v_scale` ([NB, KV] fp32): per-block-per-head dequant scales
    for int8 pools (quantize-on-write lives in the cache scatters)."""
    B, H, D = q.shape
    KV = k_pool.shape[2]
    G = H // KV
    out = _paged_call(
        _paged_decode_kernel, q.reshape(B, KV, G, D), k_pool, v_pool,
        block_tables, lengths,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, e, *_pref: (b, h, 0, 0)),
        interpret=interpret, k_scale=k_scale, v_scale=v_scale)
    return out.reshape(B, H, D)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_partials(q, k_pool, v_pool, block_tables, lengths, *,
                          k_scale=None, v_scale=None, interpret=False):
    """Paged split-KV decode emitting unnormalized online-softmax partials:
    -> (o [B, H, D] fp32 unnormalized, m [B, H], l [B, H]).  Each cache
    shard runs this over its *local* pool (non-owned table entries < 0) and
    the T4 merge rule combines the shards — the pool is never gathered."""
    B, H, D = q.shape
    KV = k_pool.shape[2]
    G = H // KV
    hw = pl.BlockSpec((1, 1, G),
                      lambda b, h, e, *_pref: (b, h, 0))
    o, m, l = _paged_call(
        _paged_partials_kernel, q.reshape(B, KV, G, D), k_pool, v_pool,
        block_tables, lengths,
        out_shape=[jax.ShapeDtypeStruct((B, KV, G, D), jnp.float32),
                   jax.ShapeDtypeStruct((B, KV, G), jnp.float32),
                   jax.ShapeDtypeStruct((B, KV, G), jnp.float32)],
        out_specs=[pl.BlockSpec((1, 1, G, D),
                                lambda b, h, e, *_pref: (b, h, 0, 0)),
                   hw, hw],
        interpret=interpret, k_scale=k_scale, v_scale=v_scale)
    return o.reshape(B, H, D), m.reshape(B, H), l.reshape(B, H)
