"""Spatio-temporally tiled GEMM Pallas kernel (paper T1) with fused
activation epilogues (paper T5).

Paper mapping (Snitch -> TPU):
  * spatial M-tiling across clusters  -> handled one level up by sharding;
    inside a chip the M/N grid dims are "parallel" grid cells.
  * temporal K-tiling into 128 kB SPM -> K as the innermost ("arbitrary")
    grid dim accumulating into an fp32 VMEM scratch tile — the exact
    partial-C-sum dataflow of Fig. 5-B.
  * 8x unrolled FREP innermost loop   -> the MXU consumes full 128-aligned
    tiles; block shapes default to (128, 128, 512).
  * GELU fused into the linear        -> epilogue applied to the fp32
    accumulator before the single write-back (no HBM round trip).
  * SIMD widening dot products        -> low-precision operands with
    preferred_element_type=f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _epilogue(acc, activation):
    if activation == "none":
        return acc
    if activation == "gelu":
        return jax.nn.gelu(acc, approximate=True)
    if activation == "silu":
        return jax.nn.silu(acc)
    raise ValueError(activation)


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, activation):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        o_ref[...] = _epilogue(acc_ref[...], activation).astype(o_ref.dtype)


def _mm_gated_kernel(a_ref, bg_ref, bu_ref, o_ref, accg_ref, accu_ref):
    """SwiGLU-fused GEMM: o = silu(A @ Bg) * (A @ Bu) in one pass — the
    gated analogue of the paper's GELU-fused linear."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    a = a_ref[...]
    accg_ref[...] += jax.lax.dot_general(
        a, bg_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    accu_ref[...] += jax.lax.dot_general(
        a, bu_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        o_ref[...] = (jax.nn.silu(accg_ref[...]) * accu_ref[...]).astype(o_ref.dtype)


def _pad2(x, m, n):
    pm = -x.shape[0] % m
    pn = -x.shape[1] % n
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


@functools.partial(jax.jit, static_argnames=(
    "activation", "block_m", "block_n", "block_k", "out_dtype", "interpret"))
def matmul(a, b, *, activation="none", block_m=128, block_n=128, block_k=512,
           out_dtype=None, interpret=False):
    """C = act(A @ B); A: [M, K], B: [K, N].  fp32 accumulation in VMEM."""
    out_dtype = out_dtype or a.dtype
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    block_m = min(block_m, max(8, M))
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    ap = _pad2(a, block_m, block_k)
    bp = _pad2(b, block_k, block_n)
    gm, gn, gk = (ap.shape[0] // block_m, bp.shape[1] // block_n,
                  ap.shape[1] // block_k)
    out = pl.pallas_call(
        functools.partial(_mm_kernel, activation=activation),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * block_m, gn * block_n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(ap, bp)
    return out[:M, :N]


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_n", "block_k", "out_dtype", "interpret"))
def matmul_swiglu(a, b_gate, b_up, *, block_m=128, block_n=128, block_k=512,
                  out_dtype=None, interpret=False):
    """o = silu(A @ Bg) * (A @ Bu) — single fused pass (paper T5 for gated MLPs)."""
    out_dtype = out_dtype or a.dtype
    M, K = a.shape
    _, N = b_gate.shape
    assert b_gate.shape == b_up.shape == (K, N)
    block_m = min(block_m, max(8, M))
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    ap = _pad2(a, block_m, block_k)
    bg = _pad2(b_gate, block_k, block_n)
    bu = _pad2(b_up, block_k, block_n)
    gm, gn, gk = (ap.shape[0] // block_m, bg.shape[1] // block_n,
                  ap.shape[1] // block_k)
    out = pl.pallas_call(
        _mm_gated_kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * block_m, gn * block_n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32),
                        pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(ap, bg, bu)
    return out[:M, :N]
