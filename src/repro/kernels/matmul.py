"""Spatio-temporally tiled GEMM Pallas kernel (paper T1) with fused
norm prologues and activation/residual epilogues (paper T5).

Paper mapping (Snitch -> TPU):
  * spatial M-tiling across clusters  -> handled one level up by sharding;
    inside a chip the M/N grid dims are "parallel" grid cells.
  * temporal K-tiling into 128 kB SPM -> K as the innermost ("arbitrary")
    grid dim accumulating into an fp32 VMEM scratch tile — the exact
    partial-C-sum dataflow of Fig. 5-B.
  * 8x unrolled FREP innermost loop   -> the MXU consumes full 128-aligned
    tiles; block shapes default to (128, 128, 512).
  * GELU fused into the linear        -> epilogue applied to the fp32
    accumulator before the single write-back (no HBM round trip).
  * SIMD widening dot products        -> low-precision operands with
    preferred_element_type=f32.

Fused prologue (norm="rmsnorm"|"layernorm"): the `a` tile is normalized
in-register before it feeds the MXU.  RMSNorm commutes with the
contraction — ``norm(x) @ W = rsqrt(mean(x^2)+eps) * ((x*gamma) @ W)`` —
so the K-loop streams each `a` tile once, accumulating row sum-of-squares
next to the partial products, and the per-row scale is applied once in the
accumulator at the last K step.  LayerNorm adds a row-sum accumulator plus
two streamed [1, N] vectors (``gamma @ W``, ``beta @ W``):
``ln(x) @ W = rstd * ((x*gamma) @ W - mu * (gamma @ W)) + beta @ W``.

Fused epilogue: bias + activation + residual-add + output cast applied to
the fp32 accumulator before the single output store — the pre-norm,
activation, and residual of a transformer sub-layer never round-trip HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.activations import get_activation
from repro.kernels.epilogue import RMS_EPS


def _apply_activation(acc, activation):
    if activation == "none":
        return acc
    return get_activation(activation)(acc)


def _row2d(v):
    """[K] vector -> [1, K] so it tiles along the K/N grid dims."""
    return v.reshape(1, -1)


def _pad2(x, m, n):
    pm = -x.shape[0] % m
    pn = -x.shape[1] % n
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def _finalize_norm(acc, *, norm, k_true, eps, s1, s2, gacc):
    """Apply the deferred per-row norm scale to a streamed accumulator.
    acc: [bm, bn]; s1/s2: [bm, 1] row sums; gacc: [1, bn] (gamma @ W)."""
    if norm == "rmsnorm":
        rstd = jax.lax.rsqrt(s2 / k_true + eps)
        return acc * rstd
    if norm == "layernorm":
        mu = s1 / k_true
        var = s2 / k_true - mu * mu
        rstd = jax.lax.rsqrt(var + eps)
        return (acc - mu * gacc) * rstd
    return acc


def _fused_mm_kernel(*refs, norm, activation, has_bias, has_res, has_scale,
                     eps, k_true):
    """refs: a, b, [gamma], [nbeta], [scale], [bias], [residual], o,
             acc, [s2], [s1], [gacc], [bacc]."""
    it = iter(refs)
    a_ref = next(it)
    b_ref = next(it)
    g_ref = next(it) if norm != "none" else None
    nb_ref = next(it) if norm == "layernorm" else None
    scale_ref = next(it) if has_scale else None
    bias_ref = next(it) if has_bias else None
    res_ref = next(it) if has_res else None
    o_ref = next(it)
    acc_ref = next(it)
    s2_ref = next(it) if norm != "none" else None
    s1_ref = next(it) if norm == "layernorm" else None
    gacc_ref = next(it) if norm == "layernorm" else None
    bacc_ref = next(it) if norm == "layernorm" else None

    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        if norm != "none":
            s2_ref[...] = jnp.zeros_like(s2_ref)
        if norm == "layernorm":
            s1_ref[...] = jnp.zeros_like(s1_ref)
            gacc_ref[...] = jnp.zeros_like(gacc_ref)
            bacc_ref[...] = jnp.zeros_like(bacc_ref)

    a = a_ref[...]
    b = b_ref[...]
    if norm != "none":
        af = a.astype(jnp.float32)
        s2_ref[...] += jnp.sum(af * af, axis=1, keepdims=True)
        g = g_ref[...].astype(jnp.float32)                    # [1, bk]
        bf = b.astype(jnp.float32)
        if norm == "layernorm":
            s1_ref[...] += jnp.sum(af, axis=1, keepdims=True)
            gacc_ref[...] += jax.lax.dot_general(
                g, bf, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            bacc_ref[...] += jax.lax.dot_general(
                nb_ref[...].astype(jnp.float32), bf,
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
        acc_ref[...] += jax.lax.dot_general(
            af * g, bf, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    else:
        if has_scale:
            # int8 weight tiles: values fit bf16 exactly (|q| <= 127), so
            # the cast is lossless and keeps the MXU dot single-dtype
            b = b.astype(a.dtype)
        acc_ref[...] += jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        y = _finalize_norm(
            acc_ref[...], norm=norm, k_true=k_true, eps=eps,
            s1=s1_ref[...] if s1_ref is not None else None,
            s2=s2_ref[...] if s2_ref is not None else None,
            gacc=gacc_ref[...] if gacc_ref is not None else None)
        if norm == "layernorm":
            y = y + bacc_ref[...]
        if has_scale:
            # per-output-channel dequant: every term so far (acc, gacc,
            # bacc) is linear in the quantized W, so one multiply here is
            # the exact dequantization — bias/activation/residual are
            # unquantized and come after
            y = y * scale_ref[...].astype(jnp.float32)
        if has_bias:
            y = y + bias_ref[...].astype(jnp.float32)
        y = _apply_activation(y, activation)
        if has_res:
            y = y + res_ref[...].astype(jnp.float32)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "activation", "norm", "eps", "block_m", "block_n", "block_k",
    "out_dtype", "interpret"))
def matmul(a, b, *, activation="none", norm="none", gamma=None, nbeta=None,
           b_scale=None, bias=None, residual=None, eps=RMS_EPS, block_m=128,
           block_n=128, block_k=512, out_dtype=None, interpret=False):
    """C = act(norm(A) @ B + bias) + residual;  A: [M, K], B: [K, N].

    fp32 accumulation in VMEM; the optional norm prologue and
    bias/activation/residual epilogue run entirely in-register (see module
    docstring) — one read of A/B (+gamma/beta/bias/residual), one write of C.

    `b_scale` ([N] fp32): per-output-channel dequant scale for int8 `b`
    (weight-only quantization).  The kernel streams the int8 weight tiles
    straight off HBM and applies the scale once in the fp32 accumulator
    epilogue — exact, since every accumulated term is linear in B.
    """
    out_dtype = out_dtype or (residual.dtype if residual is not None
                              else a.dtype)
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    block_m = min(block_m, max(8, M))
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    ap = _pad2(a, block_m, block_k)
    bp = _pad2(b, block_k, block_n)
    gm, gn, gk = (ap.shape[0] // block_m, bp.shape[1] // block_n,
                  ap.shape[1] // block_k)

    operands = [ap, bp]
    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
        pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
    ]
    if norm != "none":
        operands.append(_pad2(_row2d(gamma), 1, block_k))
        in_specs.append(pl.BlockSpec((1, block_k), lambda i, j, k: (0, k)))
    if norm == "layernorm":
        operands.append(_pad2(_row2d(nbeta), 1, block_k))
        in_specs.append(pl.BlockSpec((1, block_k), lambda i, j, k: (0, k)))
    if b_scale is not None:
        operands.append(_pad2(_row2d(b_scale), 1, block_n))
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)))
    if bias is not None:
        operands.append(_pad2(_row2d(bias), 1, block_n))
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)))
    if residual is not None:
        operands.append(_pad2(residual, block_m, block_n))
        in_specs.append(pl.BlockSpec((block_m, block_n),
                                     lambda i, j, k: (i, j)))

    scratch = [pltpu.VMEM((block_m, block_n), jnp.float32)]
    if norm != "none":
        scratch.append(pltpu.VMEM((block_m, 1), jnp.float32))     # s2
    if norm == "layernorm":
        scratch.append(pltpu.VMEM((block_m, 1), jnp.float32))     # s1
        scratch.append(pltpu.VMEM((1, block_n), jnp.float32))     # gamma @ W
        scratch.append(pltpu.VMEM((1, block_n), jnp.float32))     # beta @ W

    out = pl.pallas_call(
        functools.partial(_fused_mm_kernel, norm=norm, activation=activation,
                          has_bias=bias is not None,
                          has_res=residual is not None,
                          has_scale=b_scale is not None, eps=eps, k_true=K),
        grid=(gm, gn, gk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * block_m, gn * block_n),
                                       out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(*operands)
    return out[:M, :N]


def _fused_gated_kernel(*refs, norm, has_res, has_scale, eps, k_true):
    """SwiGLU-fused GEMM: o = silu(norm(A) @ Bg) * (norm(A) @ Bu) + residual
    in one pass — the gated analogue of the paper's GELU-fused linear.
    refs: a, bg, bu, [gamma], [nbeta], [sg], [su], [residual], o,
          accg, accu, [s2], [s1], [gaccg], [baccg], [gaccu], [baccu]."""
    it = iter(refs)
    a_ref, bg_ref, bu_ref = next(it), next(it), next(it)
    g_ref = next(it) if norm != "none" else None
    nb_ref = next(it) if norm == "layernorm" else None
    sg_ref = next(it) if has_scale else None
    su_ref = next(it) if has_scale else None
    res_ref = next(it) if has_res else None
    o_ref = next(it)
    accg_ref, accu_ref = next(it), next(it)
    s2_ref = next(it) if norm != "none" else None
    s1_ref = next(it) if norm == "layernorm" else None
    gaccg_ref = next(it) if norm == "layernorm" else None
    baccg_ref = next(it) if norm == "layernorm" else None
    gaccu_ref = next(it) if norm == "layernorm" else None
    baccu_ref = next(it) if norm == "layernorm" else None

    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)
        if norm != "none":
            s2_ref[...] = jnp.zeros_like(s2_ref)
        if norm == "layernorm":
            for r in (s1_ref, gaccg_ref, baccg_ref, gaccu_ref, baccu_ref):
                r[...] = jnp.zeros_like(r)

    a = a_ref[...]
    bg = bg_ref[...]
    bu = bu_ref[...]
    if norm != "none":
        # normalized `a` tile is f32 — keep the weight tiles' dtype matched
        # (a mixed-dtype dot would fail/promote on the MXU)
        af = a.astype(jnp.float32)
        bg = bg.astype(jnp.float32)
        bu = bu.astype(jnp.float32)
        s2_ref[...] += jnp.sum(af * af, axis=1, keepdims=True)
        g = g_ref[...].astype(jnp.float32)
        if norm == "layernorm":
            s1_ref[...] += jnp.sum(af, axis=1, keepdims=True)
            nb = nb_ref[...].astype(jnp.float32)
            for bf, ga, ba in ((bg, gaccg_ref, baccg_ref),
                               (bu, gaccu_ref, baccu_ref)):
                ga[...] += jax.lax.dot_general(
                    g, bf, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
                ba[...] += jax.lax.dot_general(
                    nb, bf, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
        a = af * g
    elif has_scale:
        # int8 weight tiles (lossless in bf16, |q| <= 127); see matmul
        bg = bg.astype(a.dtype)
        bu = bu.astype(a.dtype)
    accg_ref[...] += jax.lax.dot_general(
        a, bg, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    accu_ref[...] += jax.lax.dot_general(
        a, bu, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        s1 = s1_ref[...] if s1_ref is not None else None
        s2 = s2_ref[...] if s2_ref is not None else None
        g = _finalize_norm(accg_ref[...], norm=norm, k_true=k_true, eps=eps,
                           s1=s1, s2=s2,
                           gacc=gaccg_ref[...] if gaccg_ref is not None
                           else None)
        u = _finalize_norm(accu_ref[...], norm=norm, k_true=k_true, eps=eps,
                           s1=s1, s2=s2,
                           gacc=gaccu_ref[...] if gaccu_ref is not None
                           else None)
        if norm == "layernorm":
            g = g + baccg_ref[...]
            u = u + baccu_ref[...]
        if has_scale:
            # exact per-channel dequant (all terms linear in Bg/Bu)
            g = g * sg_ref[...].astype(jnp.float32)
            u = u * su_ref[...].astype(jnp.float32)
        y = jax.nn.silu(g) * u
        if has_res:
            y = y + res_ref[...].astype(jnp.float32)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "norm", "eps", "block_m", "block_n", "block_k", "out_dtype",
    "interpret"))
def matmul_swiglu(a, b_gate, b_up, *, norm="none", gamma=None, nbeta=None,
                  bg_scale=None, bu_scale=None, residual=None, eps=RMS_EPS,
                  block_m=128, block_n=128, block_k=512, out_dtype=None,
                  interpret=False):
    """o = silu(norm(A) @ Bg) * (norm(A) @ Bu) + residual — single fused
    pass (paper T5 for gated MLPs, with the prologue/epilogue extensions).

    `bg_scale`/`bu_scale` ([N] fp32): per-output-channel dequant scales for
    int8 Bg/Bu, applied in the fp32 accumulators before the silu gate."""
    assert (bg_scale is None) == (bu_scale is None)
    out_dtype = out_dtype or (residual.dtype if residual is not None
                              else a.dtype)
    M, K = a.shape
    _, N = b_gate.shape
    assert b_gate.shape == b_up.shape == (K, N)
    block_m = min(block_m, max(8, M))
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    ap = _pad2(a, block_m, block_k)
    bg = _pad2(b_gate, block_k, block_n)
    bu = _pad2(b_up, block_k, block_n)
    gm, gn, gk = (ap.shape[0] // block_m, bg.shape[1] // block_n,
                  ap.shape[1] // block_k)

    operands = [ap, bg, bu]
    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
        pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
    ]
    if norm != "none":
        operands.append(_pad2(_row2d(gamma), 1, block_k))
        in_specs.append(pl.BlockSpec((1, block_k), lambda i, j, k: (0, k)))
    if norm == "layernorm":
        operands.append(_pad2(_row2d(nbeta), 1, block_k))
        in_specs.append(pl.BlockSpec((1, block_k), lambda i, j, k: (0, k)))
    if bg_scale is not None:
        for sc in (bg_scale, bu_scale):
            operands.append(_pad2(_row2d(sc), 1, block_n))
            in_specs.append(pl.BlockSpec((1, block_n),
                                         lambda i, j, k: (0, j)))
    if residual is not None:
        operands.append(_pad2(residual, block_m, block_n))
        in_specs.append(pl.BlockSpec((block_m, block_n),
                                     lambda i, j, k: (i, j)))

    scratch = [pltpu.VMEM((block_m, block_n), jnp.float32),
               pltpu.VMEM((block_m, block_n), jnp.float32)]
    if norm != "none":
        scratch.append(pltpu.VMEM((block_m, 1), jnp.float32))
    if norm == "layernorm":
        scratch.append(pltpu.VMEM((block_m, 1), jnp.float32))
        scratch += [pltpu.VMEM((1, block_n), jnp.float32) for _ in range(4)]

    out = pl.pallas_call(
        functools.partial(_fused_gated_kernel, norm=norm,
                          has_res=residual is not None,
                          has_scale=bg_scale is not None, eps=eps, k_true=K),
        grid=(gm, gn, gk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * block_m, gn * block_n),
                                       out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(*operands)
    return out[:M, :N]
