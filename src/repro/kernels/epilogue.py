"""Declarative prologue/epilogue specs for the fused GEMM pipeline.

The paper's headline speedups come from keeping operands streaming through
the FPU instead of round-tripping every intermediate through main memory:
the pre-norm, bias/activation and residual-add of a transformer sub-layer
are folded into the GEMM that consumes / produces them (VEXP 2025; Full
Stack Optimization of Transformer Inference 2023).  These two dataclasses
are the repo-wide vocabulary for that folding:

  ``Prologue``   normalize the GEMM's `a` operand in-register before the
                 K-loop.  RMSNorm commutes with the contraction —
                 ``norm(x) @ W == rsqrt(mean(x^2)+eps) * ((x*gamma) @ W)``
                 — so the kernel accumulates row sum-of-squares alongside
                 the partial products and applies the per-row scale once in
                 the accumulator.  LayerNorm decomposes the same way with
                 two extra streamed accumulators (`gamma @ W`, `beta @ W`).
  ``Epilogue``   bias + activation + residual-add + output cast applied to
                 the fp32 accumulator before the single output store.

Both are plain containers: the *static* fields (norm kind, activation name,
eps, presence of optional operands) select the kernel variant; the array
fields ride along as ordinary operands.  `kernels/ops.py` dispatches them to
the Pallas kernels (`kernels/matmul.py`) or the bit-matched jnp oracles
(`kernels/ref.py`) under the usual ``auto/pallas/interpret/ref`` modes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

NORM_KINDS = ("rmsnorm", "layernorm")
ACTIVATION_KINDS = ("none", "gelu", "gelu_exact", "i_gelu", "silu")

# Canonical norm-statistics epsilons.  ops/ref/rmsnorm/matmul all default
# their eps arguments to these, so fused and unfused paths cannot drift.
RMS_EPS = 1e-6
LN_EPS = 1e-5


@dataclass(frozen=True)
class Prologue:
    """Fused pre-norm of the GEMM's `a` operand.

    kind   "rmsnorm" | "layernorm"
    scale  [K] norm gain (gamma)
    bias   [K] norm shift (beta, layernorm only)
    eps    statistics epsilon — defaults follow ops.rmsnorm/ops.layernorm
    """
    kind: str
    scale: Any
    bias: Any = None
    eps: float = RMS_EPS

    def __post_init__(self):
        assert self.kind in NORM_KINDS, self.kind
        if self.kind == "layernorm":
            assert self.bias is not None, "layernorm prologue needs beta"


@dataclass(frozen=True)
class Epilogue:
    """Fused accumulator epilogue: ``cast(act(acc + bias)) + residual``.

    activation  "none" | "gelu" | "gelu_exact" | "i_gelu" | "silu"
    bias        [N], added before the activation
    residual    [..., N], added after the activation and output cast —
                the residual-stream add that otherwise costs a full HBM
                read+write of the activation
    out_dtype   dtype of the single output store (None: `a`'s dtype, or the
                residual's dtype when one is given)
    """
    activation: str = "none"
    bias: Any = None
    residual: Any = None
    out_dtype: Any = None

    def __post_init__(self):
        assert self.activation in ACTIVATION_KINDS, self.activation


def norm_prologue(params: dict, kind: str) -> Prologue:
    """Prologue from a block's norm parameter dict ({"scale"[, "bias"]})."""
    if kind == "rmsnorm":
        return Prologue("rmsnorm", params["scale"], eps=RMS_EPS)
    return Prologue("layernorm", params["scale"], params["bias"], eps=LN_EPS)
