"""Kernel dispatch layer: Pallas TPU kernels <-> pure-jnp references.

Every model-facing op goes through this module.  Dispatch modes:

  ``auto``    (default) Pallas on TPU, reference elsewhere.  The reference
              implementations compute the *same math in the same precision*
              (fp32 accumulation / fp32 softmax), so CPU dry-run lowering
              produces representative FLOP/byte counts while TPU execution
              hits the hand-tiled kernels.
  ``pallas``            force compiled Pallas (TPU only).
  ``interpret``         force Pallas interpret mode (CPU correctness runs).
  ``ref``               force the jnp oracle.

Set with `repro.kernels.ops.set_mode(...)` or env `REPRO_KERNEL_MODE`.
"""
from __future__ import annotations

import contextlib
import os
import threading

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import flash_attention as _fa
from repro.kernels import flash_decode as _fd
from repro.kernels import matmul as _mm
from repro.kernels import rmsnorm as _norm
from repro.kernels import ssd as _ssd

_STATE = threading.local()
_VALID = ("auto", "pallas", "interpret", "ref")


def _default_mode() -> str:
    return os.environ.get("REPRO_KERNEL_MODE", "auto")


def get_mode() -> str:
    return getattr(_STATE, "mode", None) or _default_mode()


def set_mode(mode: str) -> None:
    assert mode in _VALID, mode
    _STATE.mode = mode


@contextlib.contextmanager
def kernel_mode(mode: str):
    prev = getattr(_STATE, "mode", None)
    set_mode(mode)
    try:
        yield
    finally:
        _STATE.mode = prev


def _use_pallas() -> tuple[bool, bool]:
    """-> (use_pallas, interpret)"""
    mode = get_mode()
    if mode == "ref":
        return False, False
    if mode == "pallas":
        return True, False
    if mode == "interpret":
        return True, True
    on_tpu = jax.default_backend() == "tpu"
    return (True, False) if on_tpu else (False, False)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    block_q=128, block_kv=128):
    """q: [B, Sq, H, D]; k, v: [B, Skv, KV, D] -> [B, Sq, H, D].

    `q_offset` may be a traced scalar (sequence-parallel shards); the Pallas
    kernel requires it static, so traced offsets route to the online-softmax
    reference — which mirrors the FA-2 dataflow (KV-block scan, no S^2
    materialization), keeping dry-run FLOP/byte counts representative.
    """
    use, interp = _use_pallas()
    if use and isinstance(q_offset, int):
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset, block_q=block_q,
                                   block_kv=block_kv, interpret=interp)
    # "vmemk": score/probability intermediates live in VMEM in the Pallas
    # kernel — analysis/hlo.py zeroes their HBM-traffic contribution
    with jax.named_scope("vmemk_flash"):
        return _ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                        q_offset=q_offset,
                                        block_kv=max(block_kv, 512))


def decode_attention(q, k_cache, v_cache, length, *, window=0, block_kv=512):
    """q: [B, H, D]; caches: [B, S, KV, D]; length: [B] valid entries."""
    use, interp = _use_pallas()
    if use:
        return _fd.decode_attention(q, k_cache, v_cache, length,
                                    window=window, block_kv=block_kv,
                                    interpret=interp)
    return _ref.decode_attention_ref(q, k_cache, v_cache, length,
                                     window=window)


def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths):
    """Block-paged decode.  q: [B, H, D]; k/v_pool: [NB, BS, KV, D];
    block_tables: [B, MB] int32 pool indices (< 0 = absent entry);
    lengths: [B] valid tokens per slot.  Fully normalized output."""
    use, interp = _use_pallas()
    if use:
        return _fd.paged_decode_attention(q, k_pool, v_pool, block_tables,
                                          lengths, interpret=interp)
    return _ref.paged_decode_attention_ref(q, k_pool, v_pool, block_tables,
                                           lengths)


def paged_decode_partials(q, k_pool, v_pool, block_tables, lengths):
    """Block-paged decode partials -> (o unnormalized [B, H, D] fp32,
    m [B, H], l [B, H]) for the cross-shard online-softmax merge
    (core/attention.merge_partials); same operands as
    `paged_decode_attention`, run per cache shard on its local pool."""
    use, interp = _use_pallas()
    if use:
        return _fd.paged_decode_partials(q, k_pool, v_pool, block_tables,
                                         lengths, interpret=interp)
    return _ref.paged_decode_partials_ref(q, k_pool, v_pool, block_tables,
                                          lengths)


def paged_chunk_partials(q, k_pool, v_pool, block_tables, q_pos, lengths):
    """Chunked-prefill partials -> (o unnormalized [B, C, H, D] fp32,
    m [B, C, H], l [B, C, H]); q_pos [B, C] gives each query's absolute
    position for causal masking.  Run per cache shard on its local pool,
    merged with core/attention.merge_partials like the decode partials.

    No hand kernel yet: a prefill chunk is GEMM-throughput-bound on the
    same projections the dense prefill runs, and the score/probability
    intermediates are bounded by C x S — the reference keeps math and
    precision identical to the paged decode oracle (vmemk scope: the
    intermediates live in VMEM once a Pallas chunk kernel lands)."""
    with jax.named_scope("vmemk_chunk"):
        return _ref.paged_chunk_partials_ref(q, k_pool, v_pool, block_tables,
                                             q_pos, lengths)


# --------------------------------------------------------------------------
# GEMM + fused epilogues (T1/T5)
# --------------------------------------------------------------------------

def matmul(a, b, *, activation="none", out_dtype=None,
           block_m=128, block_n=128, block_k=512):
    use, interp = _use_pallas()
    if use and a.ndim == 2:
        return _mm.matmul(a, b, activation=activation, out_dtype=out_dtype,
                          block_m=block_m, block_n=block_n, block_k=block_k,
                          interpret=interp)
    return _ref.matmul_ref(a, b, activation=activation, out_dtype=out_dtype)


def matmul_swiglu(a, b_gate, b_up, *, out_dtype=None,
                  block_m=128, block_n=128, block_k=512):
    """o = silu(A @ Bg) * (A @ Bu), single fused pass."""
    use, interp = _use_pallas()
    if use and a.ndim == 2:
        return _mm.matmul_swiglu(a, b_gate, b_up, out_dtype=out_dtype,
                                 block_m=block_m, block_n=block_n,
                                 block_k=block_k, interpret=interp)
    out_dtype = out_dtype or a.dtype
    with jax.named_scope("vmemk_mlp"):
        g = _ref.matmul_ref(a, b_gate, activation="none", out_dtype=out_dtype)
        u = _ref.matmul_ref(a, b_up, activation="none", out_dtype=out_dtype)
        return (jax.nn.silu(g.astype(jnp.float32))
                * u.astype(jnp.float32)).astype(out_dtype)


# --------------------------------------------------------------------------
# normalization
# --------------------------------------------------------------------------

def rmsnorm(x, gamma, *, eps=1e-6):
    use, interp = _use_pallas()
    if use:
        return _norm.rmsnorm(x, gamma, eps=eps, interpret=interp)
    return _ref.rmsnorm_ref(x, gamma, eps=eps)


def layernorm(x, gamma, beta, *, eps=1e-5):
    use, interp = _use_pallas()
    if use:
        return _norm.layernorm(x, gamma, beta, eps=eps, interpret=interp)
    return _ref.layernorm_ref(x, gamma, beta, eps=eps)


def norm(x, params, kind: str):
    """Dispatch on the config's norm kind; params: {"scale": ...[, "bias"]}"""
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"])
    return layernorm(x, params["scale"], params["bias"])


# --------------------------------------------------------------------------
# Mamba2 SSD
# --------------------------------------------------------------------------

def ssd(x, dt, A, B, C, D, *, chunk=128):
    """x: [Bt, S, H, P] -> (y, h_final).  Chunked state-space-duality scan.

    TPU dispatch prefers the v2 multi-head kernel (grid (B, chunks), all
    heads per cell: B/C stream once per chunk — §Perf P2 kernel design);
    falls back to the per-head grid when the [H,P,N] state would overflow
    VMEM."""
    use, interp = _use_pallas()
    if use and x.shape[1] % min(chunk, x.shape[1]) == 0:
        H, P = x.shape[2], x.shape[3]
        N = B.shape[-1]
        c = min(chunk, x.shape[1])
        vmem = 4 * (H * P * N + c * c * H + 2 * c * H * P)
        if vmem < 12 * 2**20:
            return _ssd.ssd_multihead(x, dt, A, B, C, D, chunk=c,
                                      interpret=interp)
        return _ssd.ssd(x, dt, A, B, C, D, chunk=c, interpret=interp)
    with jax.named_scope("vmemk_ssd"):
        return _ref.ssd_chunked_ref(x, dt, A, B, C, D,
                                    chunk=_best_chunk(x.shape[1], chunk))


def _best_chunk(S: int, chunk: int) -> int:
    c = min(chunk, S)
    while S % c:
        c -= 1
    return max(c, 1)


def ssd_decode(x, dt, A, B, C, D, h):
    """Single-step SSD state update (no kernel needed — pure VPU math)."""
    return _ref.ssd_decode_ref(x, dt, A, B, C, D, h)
