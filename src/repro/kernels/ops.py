"""Kernel dispatch layer: Pallas TPU kernels <-> pure-jnp references.

Every model-facing op goes through this module.  Dispatch modes:

  ``auto``    (default) Pallas on TPU, reference elsewhere.  The reference
              implementations compute the *same math in the same precision*
              (fp32 accumulation / fp32 softmax), so CPU dry-run lowering
              produces representative FLOP/byte counts while TPU execution
              hits the hand-tiled kernels.
  ``pallas``            force compiled Pallas (TPU only).
  ``interpret``         force Pallas interpret mode (CPU correctness runs).
  ``ref``               force the jnp oracle.

Set with `repro.kernels.ops.set_mode(...)` or env `REPRO_KERNEL_MODE`
(validated at read time — a typo'd mode raises instead of silently falling
through dispatch).

Fused entry points (the prologue/epilogue pipeline)
---------------------------------------------------
The block stack emits *fused* GEMM pipelines by default (`fuse_epilogues`
on the sharding `Plan`): the pre-norm, bias/activation, and residual-add of
each transformer sub-layer fold into the GEMM that consumes/produces them,
so those [T, E] intermediates never round-trip HBM.  The declarative specs
live in `kernels/epilogue.py` (`Prologue`, `Epilogue`); the entry points:

  ``fused_matmul(x, w, prologue=, epilogue=)``     norm -> GEMM -> bias/
                                                   act/residual/cast
  ``fused_matmul_swiglu(x, wg, wu, prologue=, residual=)``
                                                   norm -> gated GEMM pair
                                                   -> silu-mul -> residual
  ``residual_norm(x, y, params, kind)``            r = x + y; h = norm(r)
                                                   in one pass (the spot a
                                                   GEMM can't absorb)
  ``expert_swiglu(xe, wg, wu)``                    batched per-expert gated
                                                   GEMMs (MoE), silu-mul
                                                   kept in VMEM

On the reference path these compose the standalone oracles in exactly the
unfused order/casts (bit-identical — greedy decode is token-identical when
fusion toggles); on the Pallas path they hit the streamed-statistics fused
kernels in `kernels/matmul.py` / `kernels/rmsnorm.py`.  Reference-path
fused pipelines run under ``vmemk_*`` named scopes so the HLO-based
roofline (analysis/hlo.py) attributes their eliminated intermediate
traffic correctly.
"""
from __future__ import annotations

import contextlib
import os
import threading

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import flash_attention as _fa
from repro.kernels import flash_decode as _fd
from repro.kernels import matmul as _mm
from repro.kernels import rmsnorm as _norm
from repro.kernels import ssd as _ssd
from repro.kernels.epilogue import (LN_EPS, RMS_EPS, Epilogue, Prologue,
                                    norm_prologue)

__all__ = [
    "Epilogue", "Prologue", "norm_prologue", "get_mode", "set_mode",
    "kernel_mode", "flash_attention", "decode_attention",
    "paged_decode_attention", "paged_decode_partials",
    "paged_chunk_partials", "split_quantized", "matmul", "matmul_swiglu",
    "fused_matmul",
    "fused_matmul_swiglu", "expert_swiglu", "residual_norm", "rmsnorm",
    "layernorm", "norm", "ssd", "ssd_decode",
]

_STATE = threading.local()
_VALID = ("auto", "pallas", "interpret", "ref")


def _default_mode() -> str:
    mode = os.environ.get("REPRO_KERNEL_MODE", "auto")
    if mode not in _VALID:
        raise ValueError(
            f"REPRO_KERNEL_MODE={mode!r} is not a valid kernel mode; "
            f"expected one of {_VALID}")
    return mode


def get_mode() -> str:
    return getattr(_STATE, "mode", None) or _default_mode()


def set_mode(mode: str) -> None:
    assert mode in _VALID, mode
    _STATE.mode = mode


@contextlib.contextmanager
def kernel_mode(mode: str):
    prev = getattr(_STATE, "mode", None)
    set_mode(mode)
    try:
        yield
    finally:
        _STATE.mode = prev


def _use_pallas() -> tuple[bool, bool]:
    """-> (use_pallas, interpret)"""
    mode = get_mode()
    if mode == "ref":
        return False, False
    if mode == "pallas":
        return True, False
    if mode == "interpret":
        return True, True
    on_tpu = jax.default_backend() == "tpu"
    return (True, False) if on_tpu else (False, False)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    block_q=128, block_kv=128):
    """q: [B, Sq, H, D]; k, v: [B, Skv, KV, D] -> [B, Sq, H, D].

    `q_offset` may be a traced scalar (sequence-parallel shards); the Pallas
    kernel requires it static, so traced offsets route to the online-softmax
    reference — which mirrors the FA-2 dataflow (KV-block scan, no S^2
    materialization), keeping dry-run FLOP/byte counts representative.
    """
    use, interp = _use_pallas()
    if use and isinstance(q_offset, int):
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset, block_q=block_q,
                                   block_kv=block_kv, interpret=interp)
    # "vmemk": score/probability intermediates live in VMEM in the Pallas
    # kernel — analysis/hlo.py zeroes their HBM-traffic contribution
    with jax.named_scope("vmemk_flash"):
        return _ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                        q_offset=q_offset,
                                        block_kv=max(block_kv, 512))


def decode_attention(q, k_cache, v_cache, length, *, window=0, block_kv=512):
    """q: [B, H, D]; caches: [B, S, KV, D]; length: [B] valid entries."""
    use, interp = _use_pallas()
    if use:
        return _fd.decode_attention(q, k_cache, v_cache, length,
                                    window=window, block_kv=block_kv,
                                    interpret=interp)
    return _ref.decode_attention_ref(q, k_cache, v_cache, length,
                                     window=window)


def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           k_scale=None, v_scale=None):
    """Block-paged decode.  q: [B, H, D]; k/v_pool: [NB, BS, KV, D];
    block_tables: [B, MB] int32 pool indices (< 0 = absent entry);
    lengths: [B] valid tokens per slot.  Fully normalized output.
    `k_scale`/`v_scale` ([NB, KV] fp32): dequant scales for int8 pools."""
    use, interp = _use_pallas()
    if use:
        return _fd.paged_decode_attention(q, k_pool, v_pool, block_tables,
                                          lengths, k_scale=k_scale,
                                          v_scale=v_scale, interpret=interp)
    return _ref.paged_decode_attention_ref(q, k_pool, v_pool, block_tables,
                                           lengths, k_scale=k_scale,
                                           v_scale=v_scale)


def paged_decode_partials(q, k_pool, v_pool, block_tables, lengths, *,
                          k_scale=None, v_scale=None):
    """Block-paged decode partials -> (o unnormalized [B, H, D] fp32,
    m [B, H], l [B, H]) for the cross-shard online-softmax merge
    (core/attention.merge_partials); same operands as
    `paged_decode_attention`, run per cache shard on its local pool."""
    use, interp = _use_pallas()
    if use:
        return _fd.paged_decode_partials(q, k_pool, v_pool, block_tables,
                                         lengths, k_scale=k_scale,
                                         v_scale=v_scale, interpret=interp)
    return _ref.paged_decode_partials_ref(q, k_pool, v_pool, block_tables,
                                          lengths, k_scale=k_scale,
                                          v_scale=v_scale)


def paged_chunk_partials(q, k_pool, v_pool, block_tables, q_pos, lengths, *,
                         k_scale=None, v_scale=None, tree_mask=None):
    """Chunked-prefill partials -> (o unnormalized [B, C, H, D] fp32,
    m [B, C, H], l [B, C, H]); q_pos [B, C] gives each query's absolute
    position for causal masking.  Run per cache shard on its local pool,
    merged with core/attention.merge_partials like the decode partials.
    `tree_mask` ([B, C, C] bool, optional) switches the intra-chunk mask
    from causal to an explicit ancestor matrix for tree-speculative verify
    (committed prefix + own ancestors only; see the reference oracle).

    No hand kernel yet: a prefill chunk is GEMM-throughput-bound on the
    same projections the dense prefill runs, and the score/probability
    intermediates are bounded by C x S — the reference keeps math and
    precision identical to the paged decode oracle (vmemk scope: the
    intermediates live in VMEM once a Pallas chunk kernel lands)."""
    with jax.named_scope("vmemk_chunk"):
        return _ref.paged_chunk_partials_ref(q, k_pool, v_pool, block_tables,
                                             q_pos, lengths, k_scale=k_scale,
                                             v_scale=v_scale,
                                             tree_mask=tree_mask)


# --------------------------------------------------------------------------
# GEMM + fused prologues/epilogues (T1/T5)
# --------------------------------------------------------------------------

def split_quantized(w):
    """Unpack a weight-only-int8 param (models/quantize.quantize_params):
    {"q": int8 [K, N], "scale": fp32 [N]} -> (q, scale); a plain array
    passes through as (w, None).  Every GEMM entry point accepts either."""
    if isinstance(w, dict):
        return w["q"], w["scale"]
    return w, None


def matmul(a, b, *, activation="none", out_dtype=None,
           block_m=128, block_n=128, block_k=512):
    b, b_scale = split_quantized(b)
    use, interp = _use_pallas()
    if use and a.ndim == 2:
        return _mm.matmul(a, b, activation=activation, b_scale=b_scale,
                          out_dtype=out_dtype, block_m=block_m,
                          block_n=block_n, block_k=block_k,
                          interpret=interp)
    if b_scale is not None:
        return _ref.fused_matmul_ref(a, b, w_scale=b_scale,
                                     activation=activation,
                                     compute_dtype=a.dtype,
                                     dot_dtype=out_dtype,
                                     out_dtype=out_dtype or a.dtype)
    return _ref.matmul_ref(a, b, activation=activation, out_dtype=out_dtype)


def matmul_swiglu(a, b_gate, b_up, *, out_dtype=None,
                  block_m=128, block_n=128, block_k=512):
    """o = silu(A @ Bg) * (A @ Bu), single fused pass."""
    b_gate, g_scale = split_quantized(b_gate)
    b_up, u_scale = split_quantized(b_up)
    use, interp = _use_pallas()
    if use and a.ndim == 2:
        return _mm.matmul_swiglu(a, b_gate, b_up, bg_scale=g_scale,
                                 bu_scale=u_scale, out_dtype=out_dtype,
                                 block_m=block_m, block_n=block_n,
                                 block_k=block_k, interpret=interp)
    out_dtype = out_dtype or a.dtype
    with jax.named_scope("vmemk_mlp"):
        if g_scale is not None:
            return _ref.fused_matmul_swiglu_ref(
                a, b_gate, b_up, wg_scale=g_scale, wu_scale=u_scale,
                compute_dtype=a.dtype, out_dtype=out_dtype)
        g = _ref.matmul_ref(a, b_gate, activation="none", out_dtype=out_dtype)
        u = _ref.matmul_ref(a, b_up, activation="none", out_dtype=out_dtype)
        return (jax.nn.silu(g.astype(jnp.float32))
                * u.astype(jnp.float32)).astype(out_dtype)


def _prologue_fields(prologue):
    if prologue is None:
        return dict(norm="none", gamma=None, nbeta=None, eps=RMS_EPS)
    return dict(norm=prologue.kind, gamma=prologue.scale, nbeta=prologue.bias,
                eps=prologue.eps)


def fused_matmul(x, w, *, prologue=None, epilogue=None, compute_dtype=None,
                 dot_dtype=None, block_m=128, block_n=128, block_k=512):
    """y = epilogue(norm(x) @ w);  x: [..., K], w: [K, N] -> [..., N].

    The model-facing fused GEMM: `prologue` (kernels.epilogue.Prologue)
    normalizes x in-register before the K-loop; `epilogue`
    (kernels.epilogue.Epilogue) applies bias + activation + residual-add +
    output cast in the accumulator before the single store.  With both None
    this is a plain GEMM emitting `dot_dtype`.

    `compute_dtype`: operand dtype of the contraction (the policy compute
    dtype); `dot_dtype`: preferred_element_type the unfused `pdot` would
    emit (the reference path matches it exactly for bit-identical fallback).
    Quantized weight dicts ({"q": int8, "scale": fp32 [N]}) stream the int8
    tiles and fold the dequant scale into the fp32 accumulator epilogue.
    """
    w, w_scale = split_quantized(w)
    ep = epilogue or Epilogue()
    out_dtype = ep.out_dtype or dot_dtype or x.dtype
    use, interp = _use_pallas()
    if use:
        lead = x.shape[:-1]
        K = x.shape[-1]
        N = w.shape[-1]
        x2 = x.reshape(-1, K)
        cd = compute_dtype or x.dtype
        if prologue is None:
            x2 = x2.astype(cd)      # normalized operands stay fp32 in-kernel
        # int8 weights stream uncast — the kernel casts tiles in-register
        wk = w if w_scale is not None else w.astype(cd)
        res2 = (ep.residual.reshape(-1, N) if ep.residual is not None
                else None)
        pf = _prologue_fields(prologue)
        out = _mm.matmul(x2, wk, activation=ep.activation, b_scale=w_scale,
                         bias=ep.bias, residual=res2, out_dtype=out_dtype,
                         block_m=block_m, block_n=block_n, block_k=block_k,
                         interpret=interp, **pf)
        return out.reshape(*lead, N)
    pf = _prologue_fields(prologue)
    with jax.named_scope("vmemk_fused_mm"):
        return _ref.fused_matmul_ref(
            x, w, w_scale=w_scale, bias=ep.bias, residual=ep.residual,
            activation=ep.activation, compute_dtype=compute_dtype,
            dot_dtype=dot_dtype, out_dtype=out_dtype, **pf)


def fused_matmul_swiglu(x, wg, wu, *, prologue=None, residual=None,
                        compute_dtype=None, out_dtype=None,
                        block_m=128, block_n=128, block_k=512):
    """y = silu(norm(x) @ wg) * (norm(x) @ wu) [+ residual]."""
    wg, g_scale = split_quantized(wg)
    wu, u_scale = split_quantized(wu)
    use, interp = _use_pallas()
    if use:
        lead = x.shape[:-1]
        K = x.shape[-1]
        N = wg.shape[-1]
        x2 = x.reshape(-1, K)
        cd = compute_dtype or x.dtype
        if prologue is None:
            x2 = x2.astype(cd)
        wgk = wg if g_scale is not None else wg.astype(cd)
        wuk = wu if u_scale is not None else wu.astype(cd)
        res2 = residual.reshape(-1, N) if residual is not None else None
        pf = _prologue_fields(prologue)
        out = _mm.matmul_swiglu(x2, wgk, wuk, bg_scale=g_scale,
                                bu_scale=u_scale, residual=res2,
                                out_dtype=out_dtype, block_m=block_m,
                                block_n=block_n, block_k=block_k,
                                interpret=interp, **pf)
        return out.reshape(*lead, N)
    pf = _prologue_fields(prologue)
    with jax.named_scope("vmemk_fused_mlp"):
        return _ref.fused_matmul_swiglu_ref(
            x, wg, wu, wg_scale=g_scale, wu_scale=u_scale,
            residual=residual, compute_dtype=compute_dtype,
            out_dtype=out_dtype, **pf)


def expert_swiglu(xe, wg, wu, *, compute_dtype=None, out_dtype=None):
    """Batched per-expert gated FFN: xe [NE, C, E] @ wg/wu [NE, E, F] ->
    silu(g) * u [NE, C, F].  The silu-mul epilogue never leaves VMEM; the
    Pallas path vmaps the fused swiglu kernel over the expert dim."""
    out_dtype = out_dtype or xe.dtype
    use, interp = _use_pallas()
    if use:
        cd = compute_dtype or xe.dtype
        import functools
        f = functools.partial(_mm.matmul_swiglu, out_dtype=out_dtype,
                              interpret=interp)
        return jax.vmap(f)(xe.astype(cd), wg.astype(cd), wu.astype(cd))
    cd = compute_dtype or xe.dtype
    with jax.named_scope("vmemk_moe"):
        g = jax.lax.dot_general(xe.astype(cd), wg.astype(cd),
                                (((2,), (1,)), ((0,), (0,))),
                                preferred_element_type=out_dtype)
        u = jax.lax.dot_general(xe.astype(cd), wu.astype(cd),
                                (((2,), (1,)), ((0,), (0,))),
                                preferred_element_type=out_dtype)
        return (jax.nn.silu(g.astype(jnp.float32))
                * u.astype(jnp.float32)).astype(out_dtype)


def residual_norm(x, y, params, kind: str):
    """Fused residual-add + pre-norm: r = x + y; h = norm(r) in one pass —
    the sub-layer boundary a GEMM epilogue can't absorb (the sum is both
    the next residual and the norm input).  -> (h, r)."""
    use, interp = _use_pallas()
    if use:
        if kind == "rmsnorm":
            return _norm.residual_rmsnorm(x, y, params["scale"],
                                          interpret=interp)
        return _norm.residual_layernorm(x, y, params["scale"],
                                        params["bias"], interpret=interp)
    r = x + y
    with jax.named_scope("vmemk_fused_norm"):
        if kind == "rmsnorm":
            h = _ref.rmsnorm_ref(r, params["scale"])
        else:
            h = _ref.layernorm_ref(r, params["scale"], params["bias"])
    return h, r


# --------------------------------------------------------------------------
# normalization
# --------------------------------------------------------------------------

def rmsnorm(x, gamma, *, eps=RMS_EPS):
    use, interp = _use_pallas()
    if use:
        return _norm.rmsnorm(x, gamma, eps=eps, interpret=interp)
    return _ref.rmsnorm_ref(x, gamma, eps=eps)


def layernorm(x, gamma, beta, *, eps=LN_EPS):
    use, interp = _use_pallas()
    if use:
        return _norm.layernorm(x, gamma, beta, eps=eps, interpret=interp)
    return _ref.layernorm_ref(x, gamma, beta, eps=eps)


def norm(x, params, kind: str):
    """Dispatch on the config's norm kind; params: {"scale": ...[, "bias"]}"""
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"])
    return layernorm(x, params["scale"], params["bias"])


# --------------------------------------------------------------------------
# Mamba2 SSD
# --------------------------------------------------------------------------

def ssd(x, dt, A, B, C, D, *, chunk=128):
    """x: [Bt, S, H, P] -> (y, h_final).  Chunked state-space-duality scan.

    TPU dispatch prefers the v2 multi-head kernel (grid (B, chunks), all
    heads per cell: B/C stream once per chunk — §Perf P2 kernel design);
    falls back to the per-head grid when the [H,P,N] state would overflow
    VMEM."""
    use, interp = _use_pallas()
    if use and x.shape[1] % min(chunk, x.shape[1]) == 0:
        H, P = x.shape[2], x.shape[3]
        N = B.shape[-1]
        c = min(chunk, x.shape[1])
        vmem = 4 * (H * P * N + c * c * H + 2 * c * H * P)
        if vmem < 12 * 2**20:
            return _ssd.ssd_multihead(x, dt, A, B, C, D, chunk=c,
                                      interpret=interp)
        return _ssd.ssd(x, dt, A, B, C, D, chunk=c, interpret=interp)
    with jax.named_scope("vmemk_ssd"):
        return _ref.ssd_chunked_ref(x, dt, A, B, C, D,
                                    chunk=_best_chunk(x.shape[1], chunk))


def _best_chunk(S: int, chunk: int) -> int:
    c = min(chunk, S)
    while S % c:
        c -= 1
    return max(c, 1)


def ssd_decode(x, dt, A, B, C, D, h):
    """Single-step SSD state update (no kernel needed — pure VPU math)."""
    return _ref.ssd_decode_ref(x, dt, A, B, C, D, h)
