"""Mamba2 SSD (state-space duality) chunk kernel.

The SSD form computes, per chunk of L timesteps, an attention-like
quadratic intra-chunk term (two MXU matmuls through a decay-masked L x L
matrix) plus a rank-N inter-chunk state recurrence.  This is the natural
TPU adaptation of the paper's FlashAttention dataflow for the attention-free
assigned arch (mamba2-2.7b): chunk tiles live in VMEM, the running state
h [P, N] is carried across the innermost grid dim in fp32 scratch exactly
like FA-2's (m, l, acc).

Grid: (B, H, n_chunks) — chunks innermost, heads are "parallel" (the
head dim is a pure batch dim of the recurrence; it shards freely across
chips, DESIGN.md §4)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, hout_ref,
                h_ref, *, chunk: int):
    """Blocks per (b, h, c): x [1,L,1,P], dt [1,L,1], A [1], B/C [1,L,N],
    D [1]; y [1,L,1,P]; hout [1,1,P,N]; scratch h [P,N] fp32."""
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    L = chunk
    x = x_ref[0, :, 0, :].astype(jnp.float32)            # [L, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)             # [L]
    A = a_ref[0].astype(jnp.float32)                     # scalar
    Bm = b_ref[0].astype(jnp.float32)                    # [L, N]
    Cm = c_ref[0].astype(jnp.float32)                    # [L, N]
    D = d_ref[0].astype(jnp.float32)

    da = dt * A                                          # [L]
    cum = jnp.cumsum(da)                                 # inclusive
    # intra-chunk: y[t] = sum_{s<=t} exp(cum_t - cum_s) * (C_t.B_s) * dt_s * x[s]
    seg = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.where(tri, jnp.exp(seg), 0.0)            # [L, L]
    g = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [L, L]
    m = g * decay
    y = jax.lax.dot_general(m, x * dt[:, None], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the incoming state
    in_decay = jnp.exp(cum)                              # [L]
    ch = jax.lax.dot_general(Cm, h_ref[...], (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [L, P]
    y = y + ch * in_decay[:, None]

    # state update: h' = exp(sum da) h + sum_s exp(cum_L - cum_s) dt_s x_s B_s^T
    b_decay = jnp.exp(cum[-1] - cum)                     # [L]
    xw = x * (dt * b_decay)[:, None]                     # [L, P]
    h_new = h_ref[...] * jnp.exp(cum[-1]) + jax.lax.dot_general(
        xw, Bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    h_ref[...] = h_new

    y_ref[0, :, 0, :] = (y + D * x).astype(y_ref.dtype)

    @pl.when(ci == pl.num_programs(2) - 1)
    def _finish():
        hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


def _ssd_mh_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref,
                   hout_ref, h_ref, *, chunk: int):
    """Multi-head SSD chunk kernel (v2, §Perf P2 kernel design).

    One grid cell = (batch, chunk) with ALL heads vectorized inside: B/C
    stream from HBM ONCE per chunk instead of once per (head, chunk) —
    H x less B/C traffic than the v1 head-parallel grid.  VMEM at the
    production shapes (L=128, H<=80, P=64, N=128): decay [L,L,H] 5.2 MB +
    state [H,P,N] 2.6 MB + blocks — fits the ~16 MB budget.

    Blocks: x [1,L,H,P], dt [1,L,H], A [H], B/C [1,L,N], D [H];
    y [1,L,H,P]; hout [1,H,P,N]; scratch h [H,P,N] fp32."""
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    L = chunk
    x = x_ref[0].astype(jnp.float32)                     # [L, H, P]
    dt = dt_ref[0].astype(jnp.float32)                   # [L, H]
    A = a_ref[...].astype(jnp.float32)                   # [H]
    Bm = b_ref[0].astype(jnp.float32)                    # [L, N]
    Cm = c_ref[0].astype(jnp.float32)                    # [L, N]
    D = d_ref[...].astype(jnp.float32)                   # [H]

    da = dt * A[None, :]                                 # [L, H]
    cum = jnp.cumsum(da, axis=0)
    seg = cum[:, None, :] - cum[None, :, :]              # [L, L, H]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (L, L), 1))
    decay = jnp.where(tri[..., None], jnp.exp(seg), 0.0)  # [L, L, H]
    g = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [L, L]
    w = g[..., None] * decay * dt[None, :, :]            # [L, L, H]

    # intra-chunk: y[l,h,p] = sum_m w[l,m,h] x[m,h,p]  (batched over H)
    wT = w.transpose(2, 0, 1)                            # [H, L, L]
    xT = x.transpose(1, 0, 2)                            # [H, L, P]
    y = jax.lax.dot_general(wT, xT, (((2,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)  # [H, L, P]

    # inter-chunk: y += exp(cum)[l,h] * (C @ h[h]^T)
    h = h_ref[...]                                       # [H, P, N]
    ch = jax.lax.dot_general(Cm, h, (((1,), (2,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [L, H, P]
    y = y + (jnp.exp(cum)[:, :, None] * ch).transpose(1, 0, 2)

    # state update
    b_decay = jnp.exp(cum[-1][None, :] - cum)            # [L, H]
    xw = x * (dt * b_decay)[..., None]                   # [L, H, P]
    dh = jax.lax.dot_general(xw.transpose(1, 2, 0), Bm,
                             (((2,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [H, P, N]
    h_new = h * jnp.exp(cum[-1])[:, None, None] + dh
    h_ref[...] = h_new

    y_ref[0] = (y.transpose(1, 0, 2)
                + D[None, :, None] * x).astype(y_ref.dtype)

    @pl.when(ci == pl.num_programs(1) - 1)
    def _finish():
        hout_ref[0] = h_new.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_multihead(x, dt, A, B, C, D, *, chunk=128, interpret=False):
    """v2 kernel: x [Bt, S, H, P] -> (y, h_final); grid (Bt, S/chunk)."""
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    out, hout = pl.pallas_call(
        functools.partial(_ssd_mh_kernel, chunk=chunk),
        grid=(Bt, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, H, P, N), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bt, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, D)
    return out, hout


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, B, C, D, *, chunk=128, interpret=False):
    """x: [Bt, S, H, P], dt: [Bt, S, H], A/D: [H], B/C: [Bt, S, N].
    Returns (y [Bt, S, H, P], h_final [Bt, H, P, N])."""
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    out, hout = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(Bt, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bt, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, D)
    return out, hout
