"""Pure-jnp oracles for every Pallas kernel.

Each `*_ref` is the numerical ground truth the kernels are validated against
(tests sweep shapes/dtypes with assert_allclose).  They are written for
clarity, not speed, and always follow the paper's precision rules:
fp32 softmax/statistics, fp32 GEMM accumulation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.epilogue import LN_EPS, RMS_EPS

NEG_INF = -1e30


def _dot(a, b, accum=jnp.float32):
    return jax.lax.dot_general(
        a, b, (((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=accum)


def matmul_ref(a, b, *, activation: str = "none", gate=None,
               accum_dtype=jnp.float32, out_dtype=None):
    """C = act(A @ B); the dot emits `out_dtype` directly (MXU accumulates
    fp32 internally; a narrow output keeps the backward dots narrow too) and
    the activation epilogue runs in fp32 (paper T6)."""
    out_dtype = out_dtype or a.dtype
    if activation == "none":
        return _dot(a, b, out_dtype)
    c = _dot(a, b, out_dtype).astype(jnp.float32)
    if activation == "gelu":
        c = jax.nn.gelu(c, approximate=True)
    elif activation == "silu":
        c = jax.nn.silu(c)
    elif activation == "swiglu":
        assert gate is not None
        c = jax.nn.silu(c) * gate.astype(jnp.float32)
    else:
        raise ValueError(activation)
    return c.astype(out_dtype)


def _attn_mask(q_len, kv_len, *, causal, window, q_offset=0):
    """Boolean mask [q_len, kv_len]: True = attend.

    `q_offset`: absolute position of q row 0 (for chunked/seq-sharded Q —
    the key positions are 0..kv_len-1)."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    mask = jnp.ones((q_len, kv_len), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window and window > 0:
        mask &= k_pos > q_pos - window
    return mask


def attention_ref(q, k, v, *, causal=True, window=0, q_offset=0,
                  softmax_dtype=jnp.float32, out_dtype=None, scale=None):
    """Naive full-materialization attention (the paper's baseline).

    q: [B, Sq, H, D]; k, v: [B, Skv, KV, D] with GQA (H % KV == 0).
    Softmax in fp32 regardless of input dtype (paper invariant).
    """
    out_dtype = out_dtype or q.dtype
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = scale if scale is not None else 1.0 / jnp.sqrt(D).astype(jnp.float32)
    qf = q.astype(softmax_dtype).reshape(B, Sq, KV, G, D)
    kf = k.astype(softmax_dtype)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf) * scale
    mask = _attn_mask(Sq, Skv, causal=causal, window=window, q_offset=q_offset)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(softmax_dtype))
    return out.reshape(B, Sq, H, D).astype(out_dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=0, q_offset=0,
                        block_kv=128, out_dtype=None):
    """Online-softmax (FlashAttention-2 dataflow) oracle: iterates KV blocks
    with running (m, l, o) statistics in fp32.  The Q.K^T and P.V GEMMs run
    in the *operand* dtype with fp32 accumulation (paper T6: low-precision
    GEMMs, fp32 softmax) — this is also what makes the dry-run's lowered
    FLOPs land on the bf16 MXU peak instead of the fp32 one."""
    out_dtype = out_dtype or q.dtype
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    qr = q.reshape(B, Sq, KV, G, D)

    n_blocks = (Skv + block_kv - 1) // block_kv
    pad = n_blocks * block_kv - Skv
    kf = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kpos = jnp.arange(n_blocks * block_kv)
    valid = kpos < Skv

    qpos = jnp.arange(Sq) + q_offset

    def body(carry, blk):
        m, l, o = carry
        kb, vb, pos_b, val_b = blk
        # scores emitted in the operand dtype (the paper converts at the
        # Q.K^T GEMM *output*), upcast to fp32 for the softmax statistics —
        # this also keeps the dq/dk backward dots in the narrow dtype
        s = jnp.einsum("bqkgd,bskd->bkgqs", qr, kb,
                       preferred_element_type=q.dtype
                       ).astype(jnp.float32) * scale
        msk = val_b[None, :]
        if causal:
            msk = msk & (pos_b[None, :] <= qpos[:, None])
        if window and window > 0:
            msk = msk & (pos_b[None, :] > qpos[:, None] - window)
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        o_new = o * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(q.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, o_new), None

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    o0 = jnp.zeros((B, KV, G, Sq, D), jnp.float32)
    kb = kf.reshape(B, n_blocks, block_kv, KV, D).transpose(1, 0, 2, 3, 4)
    vb = vf.reshape(B, n_blocks, block_kv, KV, D).transpose(1, 0, 2, 3, 4)
    pos_b = kpos.reshape(n_blocks, block_kv)
    val_b = valid.reshape(n_blocks, block_kv)
    (m, l, o), _ = jax.lax.scan(body, (m0, l0, o0), (kb, vb, pos_b, val_b))
    out = o / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)
    return out.astype(out_dtype)


def decode_attention_ref(q, k_cache, v_cache, length, *, window=0,
                         out_dtype=None):
    """Single-token decode oracle.  q: [B, H, D]; caches: [B, S, KV, D];
    `length`: number of valid cache entries (scalar or [B]).  Entries at
    positions >= length are masked.  `window`: only the last `window`
    positions attend (SWA)."""
    out_dtype = out_dtype or q.dtype
    B, S, KV, D = k_cache.shape
    H = q.shape[1]
    G = H // KV
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    qf = (q.astype(jnp.float32) * scale).reshape(B, KV, G, D)
    kf = k_cache.astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, kf)
    pos = jnp.arange(S)[None, :]
    length = jnp.asarray(length)
    ln = length[:, None] if length.ndim else length[None, None]
    msk = pos < ln
    if window and window > 0:
        msk = msk & (pos >= ln - window)
    s = jnp.where(msk[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, D).astype(out_dtype)


def _paged_gather(k_pool, v_pool, block_tables, lengths,
                  k_scale=None, v_scale=None):
    """Dereference block tables into a dense [B, MB*BS, KV, D] view plus a
    [B, MB*BS] validity mask (token t of entry e = absolute position
    e*BS + t; entries < 0 are absent).

    `k_scale`/`v_scale` ([NB, KV] fp32): per-block-per-head dequant scales
    for int8 pools — the single dequant hook for the quantized paged KV
    path (the Pallas kernel applies the same scalar per grid step)."""
    _, BS, KV, D = k_pool.shape
    B, MB = block_tables.shape
    present = block_tables >= 0                                  # [B, MB]
    tab = jnp.where(present, block_tables, 0)
    k = k_pool.astype(jnp.float32)[tab]                  # [B, MB, BS, KV, D]
    v = v_pool.astype(jnp.float32)[tab]
    if k_scale is not None:
        k = k * k_scale[tab][:, :, None, :, None]
    if v_scale is not None:
        v = v * v_scale[tab][:, :, None, :, None]
    k = k.reshape(B, MB * BS, KV, D)
    v = v.reshape(B, MB * BS, KV, D)
    pos = jnp.arange(MB * BS)[None, :]                           # absolute
    msk = pos < jnp.asarray(lengths, jnp.int32)[:, None]
    msk &= jnp.repeat(present, BS, axis=1)
    return k, v, msk


def _paged_scores(q, k, msk):
    """Masked fp32 scores [B, KV, G, S] from q [B, H, D]."""
    B, H, D = q.shape
    KV = k.shape[2]
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    qf = (q.astype(jnp.float32) * scale).reshape(B, KV, H // KV, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k)
    return jnp.where(msk[:, None, None], s, NEG_INF)


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, lengths, *,
                               k_scale=None, v_scale=None, out_dtype=None):
    """Paged single-token decode oracle (block-paged KV cache).

    q: [B, H, D]; k/v_pool: [NB, BS, KV, D] — a global pool of fixed-size
    KV blocks; block_tables: [B, MB] int32 block ids per slot in sequence
    order (entries < 0 are absent: unallocated, or owned by another cache
    shard); lengths: [B] valid tokens per slot.  Gathers the table into a
    dense cache and defers to the dense softmax — ground truth, not fast."""
    out_dtype = out_dtype or q.dtype
    B, H, D = q.shape
    k, v, msk = _paged_gather(k_pool, v_pool, block_tables, lengths,
                              k_scale, v_scale)
    s = _paged_scores(q, k, msk)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v)
    return out.reshape(B, H, D).astype(out_dtype)


def paged_decode_partials_ref(q, k_pool, v_pool, block_tables, lengths, *,
                              k_scale=None, v_scale=None):
    """Paged decode oracle emitting unnormalized online-softmax partials
    -> (o [B, H, D] fp32, m [B, H], l [B, H]) for the cross-shard T4 merge
    (each shard passes its local pool; absent entries are masked)."""
    B, H, D = q.shape
    k, v, msk = _paged_gather(k_pool, v_pool, block_tables, lengths,
                              k_scale, v_scale)
    s = _paged_scores(q, k, msk)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v)
    return o.reshape(B, H, D), m.reshape(B, H), l.reshape(B, H)


def paged_chunk_partials_ref(q, k_pool, v_pool, block_tables, q_pos,
                             lengths, *, k_scale=None, v_scale=None,
                             tree_mask=None):
    """Chunked-prefill partials: C query tokens per row against the paged
    pool (which already holds this chunk's own KV rows), causal-masked per
    query position.

    q: [B, C, H, D]; k/v_pool: [NB, BS, KV, D]; block_tables: [B, MB]
    (entries < 0 absent); q_pos: [B, C] absolute position of each query
    (pad queries may point past `lengths` — their outputs are garbage the
    caller discards); lengths: [B] valid tokens incl. this chunk.
    -> (o [B, C, H, D] fp32 unnormalized, m [B, C, H], l [B, C, H]) for the
    cross-shard T4 merge, same contract as `paged_decode_partials_ref`.

    tree_mask: optional [B, C, C] bool ancestor matrix for tree-speculative
    verify.  The chunk's C entries then form a token tree scattered at
    positions q_pos (= pos0 + node index): query node i attends the
    committed prefix (< pos0) plus in-chunk node j iff tree_mask[b, i, j].
    A lower-triangular tree_mask reproduces the causal `pos <= q_pos` mask
    exactly (the degenerate single-branch chain)."""
    B, C, H, D = q.shape
    k, v, msk = _paged_gather(k_pool, v_pool, block_tables, lengths,
                              k_scale, v_scale)
    KV = k.shape[2]
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    qf = (q.astype(jnp.float32) * scale).reshape(B, C, KV, H // KV, D)
    s = jnp.einsum("bckgd,bskd->bckgs", qf, k)                # [B,C,KV,G,S]
    pos = jnp.arange(k.shape[1])[None, None, :]
    if tree_mask is not None:
        pos0 = q_pos[:, :1]                                   # [B, 1]
        s_pos = pos[0]                                        # [1, S]
        prefix = s_pos < pos0                                 # [B, S]
        in_chunk = (s_pos >= pos0) & (s_pos < pos0 + C)
        rel = jnp.clip(s_pos - pos0, 0, C - 1)                # [B, S]
        anc = jnp.take_along_axis(
            tree_mask, jnp.broadcast_to(rel[:, None, :],
                                        (B, C, s_pos.shape[1])), axis=2)
        keep = msk[:, None, :] & (prefix[:, None, :]
                                  | (in_chunk[:, None, :] & anc))
    else:
        keep = msk[:, None, :] & (pos <= q_pos[:, :, None])   # [B, C, S]
    s = jnp.where(keep[:, :, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    o = jnp.einsum("bckgs,bskd->bckgd", p, v)
    return (o.reshape(B, C, H, D), m.reshape(B, C, H),
            l.reshape(B, C, H))


def rmsnorm_ref(x, gamma, *, eps=RMS_EPS, out_dtype=None):
    out_dtype = out_dtype or x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)
    return y.astype(out_dtype)


def layernorm_ref(x, gamma, beta, *, eps=LN_EPS, out_dtype=None):
    out_dtype = out_dtype or x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
    return y.astype(out_dtype)


def softmax_ref(x, *, axis=-1, out_dtype=None):
    out_dtype = out_dtype or x.dtype
    return jax.nn.softmax(x.astype(jnp.float32), axis=axis).astype(out_dtype)


def ssd_ref(x, dt, A, B, C, D, *, out_dtype=None):
    """Mamba2 SSD oracle — sequential recurrence over time (ground truth).

    x:  [Bt, S, H, P]   (P = head dim)
    dt: [Bt, S, H]      (positive step sizes; pre-softplus'd)
    A:  [H]             (negative decay rates)
    B:  [Bt, S, N]      (input gate,  N = state dim)
    C:  [Bt, S, N]      (output gate)
    D:  [H]             (skip)
    state h: [Bt, H, P, N];  h_t = exp(dt*A) h_{t-1} + dt * x_t B_t^T
                            y_t = h_t C_t + D * x_t
    """
    out_dtype = out_dtype or x.dtype
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af, Bf, Cf, Df = (t.astype(jnp.float32) for t in (A, B, C, D))

    def step(h, inp):
        xt, dtt, bt, ct = inp            # [Bt,H,P], [Bt,H], [Bt,N], [Bt,N]
        decay = jnp.exp(dtt * Af[None])  # [Bt, H]
        h = h * decay[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", xt * dtt[..., None], bt)
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((Bt, H, P, N), jnp.float32)
    xs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
          Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2))
    hT, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2, 3) + Df[None, None, :, None] * xf
    return y.astype(out_dtype), hT.astype(jnp.float32)


def ssd_chunked_ref(x, dt, A, B, C, D, *, chunk=64, h0=None, out_dtype=None):
    """Chunk-parallel SSD (the state-space-duality form the kernel uses):
    intra-chunk attention-like matmuls + inter-chunk state recurrence.
    Matches ssd_ref up to fp reordering."""
    out_dtype = out_dtype or x.dtype
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    xf = x.astype(jnp.float32).reshape(Bt, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bt, nc, chunk, H)
    Bf = B.astype(jnp.float32).reshape(Bt, nc, chunk, N)
    Cf = C.astype(jnp.float32).reshape(Bt, nc, chunk, N)
    Af = A.astype(jnp.float32)

    # cumulative log-decay within each chunk: a[t] = sum_{u<=t} dt_u * A
    da = dtf * Af[None, None, None, :]            # [Bt,nc,L,H]
    cum = jnp.cumsum(da, axis=2)                  # inclusive
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [Bt,nc,L,L,H] t>=s
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay_mat = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)

    # intra-chunk (the "attention-like" quadratic term)
    g = jnp.einsum("bcln,bcmn->bclm", Cf, Bf)     # [Bt,nc,L,L]
    m = g[..., None] * decay_mat                  # [Bt,nc,L,L,H]
    y_intra = jnp.einsum("bclmh,bcmh,bcmhp->bclhp", m, dtf, xf)

    # chunk-boundary states
    chunk_decay = jnp.exp(cum[:, :, -1])          # [Bt,nc,H]
    b_decay = jnp.exp(cum[:, :, -1:, :] - cum)    # decay from t to chunk end
    states = jnp.einsum("bclh,bclh,bclhp,bcln->bchpn",
                        b_decay, dtf, xf, Bf)     # [Bt,nc,H,P,N]

    def scan_fn(h, inp):
        st, dec = inp
        h_new = h * dec[..., None, None] + st
        return h_new, h
    h_init = (jnp.zeros((Bt, H, P, N), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    hT, h_prev = jax.lax.scan(
        scan_fn, h_init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)      # state entering each chunk

    # inter-chunk contribution
    in_decay = jnp.exp(cum)                       # decay from chunk start to t
    y_inter = jnp.einsum("bcln,bclh,bchpn->bclhp", Cf, in_decay, h_prev)

    y = (y_intra + y_inter).reshape(Bt, S, H, P)
    y = y + D.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(out_dtype), hT


def ssd_decode_ref(x, dt, A, B, C, D, h, *, out_dtype=None):
    """Single-step SSD state update (AR decode).
    x: [Bt,H,P], dt: [Bt,H], B,C: [Bt,N], h: [Bt,H,P,N]."""
    out_dtype = out_dtype or x.dtype
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A.astype(jnp.float32)[None])
    h = h * decay[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", xf * dtf[..., None], B.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", h, C.astype(jnp.float32))
    y = y + D.astype(jnp.float32)[None, :, None] * xf
    return y.astype(out_dtype), h


# --------------------------------------------------------------------------
# fused prologue/epilogue oracles
# --------------------------------------------------------------------------
#
# These compose the standalone oracles in EXACTLY the order (and with
# exactly the casts) the unfused op chain uses, so on the reference path a
# fused pipeline is bit-identical to the discrete chain it replaces —
# greedy decode stays token-identical when `fuse_epilogues` toggles.  The
# Pallas kernels compute the same math with streamed statistics and are
# tolerance-validated against these.

def norm_prologue_ref(x, *, norm, gamma, nbeta=None, eps):
    """Normalize the GEMM `a` operand (output in x.dtype, like ops.norm)."""
    if norm == "rmsnorm":
        return rmsnorm_ref(x, gamma, eps=eps)
    if norm == "layernorm":
        return layernorm_ref(x, gamma, nbeta, eps=eps)
    assert norm == "none", norm
    return x


def fused_matmul_ref(x, w, *, norm="none", gamma=None, nbeta=None,
                     w_scale=None, bias=None, residual=None,
                     activation="none", eps=RMS_EPS, compute_dtype=None,
                     dot_dtype=None, out_dtype=None):
    """act(norm(x) @ w + bias) cast to out_dtype, + residual.

    `compute_dtype`: operand cast before the dot (the policy compute
    dtype); `dot_dtype`: preferred_element_type of the dot (what `pdot`
    would emit); `out_dtype`: dtype of the result before the residual add.
    `w_scale` ([N] fp32): per-output-channel dequant scale for int8 `w` —
    applied to the dot output in fp32 before the (unquantized) bias, the
    same point the Pallas kernel folds it into the accumulator.
    """
    h = norm_prologue_ref(x, norm=norm, gamma=gamma, nbeta=nbeta, eps=eps)
    cd = compute_dtype or h.dtype
    od = dot_dtype or out_dtype or h.dtype
    y = jax.lax.dot_general(
        h.astype(cd), w.astype(cd),
        (((h.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=od)
    if w_scale is not None:
        y = (y.astype(jnp.float32)
             * w_scale.astype(jnp.float32)).astype(y.dtype)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    if activation != "none":
        from repro.core.activations import get_activation
        y = get_activation(activation)(y)
    if out_dtype is not None:
        y = y.astype(out_dtype)
    if residual is not None:
        y = residual + y
    return y


def fused_matmul_swiglu_ref(x, w_gate, w_up, *, norm="none", gamma=None,
                            nbeta=None, wg_scale=None, wu_scale=None,
                            residual=None, eps=RMS_EPS,
                            compute_dtype=None, out_dtype=None):
    """silu(norm(x) @ wg) * (norm(x) @ wu) [+ residual] — the exact op
    chain of ops.matmul_swiglu's reference path with the pre-norm folded
    in front and the residual add behind.  `wg_scale`/`wu_scale`: int8
    per-output-channel dequant, applied in fp32 before the silu gate."""
    h = norm_prologue_ref(x, norm=norm, gamma=gamma, nbeta=nbeta, eps=eps)
    cd = compute_dtype or h.dtype
    od = out_dtype or h.dtype
    a = h.astype(cd)
    g = matmul_ref(a, w_gate.astype(cd), activation="none", out_dtype=od)
    u = matmul_ref(a, w_up.astype(cd), activation="none", out_dtype=od)
    gf = g.astype(jnp.float32)
    uf = u.astype(jnp.float32)
    if wg_scale is not None:
        gf = gf * wg_scale.astype(jnp.float32)
        uf = uf * wu_scale.astype(jnp.float32)
    y = (jax.nn.silu(gf) * uf).astype(od)
    if residual is not None:
        y = residual + y
    return y


def residual_norm_ref(x, y, *, norm, gamma, nbeta=None, eps=RMS_EPS):
    """r = x + y; h = norm(r) — same two ops as the unfused chain.
    -> (h, r)."""
    r = x + y
    return norm_prologue_ref(r, norm=norm, gamma=gamma, nbeta=nbeta,
                             eps=eps), r
