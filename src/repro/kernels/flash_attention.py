"""FlashAttention-2 forward Pallas TPU kernel (paper T2, adapted).

Paper mapping (Snitch -> TPU):
  * head-per-cluster            -> grid dims (batch, kv_head): each TensorCore
                                   grid cell owns one (batch, kv-head) slice,
                                   GQA query groups folded into the Q-block rows.
  * SPM temporal tiling         -> BlockSpec VMEM tiles (block_q x d, block_kv x d),
                                   KV iterated as the innermost ("arbitrary")
                                   grid dimension with (m, l, acc) carried in
                                   VMEM scratch — the exact FA-2 dataflow.
  * DMA double buffering        -> Pallas pipelines the HBM->VMEM block copies
                                   across grid steps automatically.
  * fp32 softmax invariant      -> Q.K^T accumulates in fp32; m/l/acc scratch
                                   is fp32 regardless of input dtype.

Supports: causal masking, sliding-window (SWA), GQA, a query-position offset
(for sequence-parallel Q shards), bf16/fp32/fp8 inputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               causal: bool, window: int, q_offset: int, block_q: int,
               block_kv: int, sm_scale: float, kv_len: int):
    """Grid: (B, KV, num_q_blocks, num_kv_blocks); kv innermost.

    q_ref:   [1, 1, G, block_q, D]   (G = query group size)
    k_ref:   [1, 1, block_kv, D]
    v_ref:   [1, 1, block_kv, D]
    o_ref:   [1, 1, G, block_q, D]
    scratch: m/l [G*block_q], acc [G*block_q, D]  — fp32.
    """
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = q_ref.shape[2]
    d = q_ref.shape[-1]
    # GEMMs in the operand dtype (MXU-native), statistics in fp32 (paper T6)
    q = q_ref[0, 0].reshape(g * block_q, d)
    k = k_ref[0, 0]
    v = v_ref[0, 0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale

    # row r of the folded block is query (r % block_q) of this q block
    row = jax.lax.broadcasted_iota(jnp.int32, (g * block_q, block_kv), 0)
    q_pos = (row % block_q) + qi * block_q + q_offset
    col = jax.lax.broadcasted_iota(jnp.int32, (g * block_q, block_kv), 1)
    k_pos = col + ki * block_kv
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == n_kv - 1)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = out.reshape(g, block_q, d).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_kv",
                     "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    block_q=128, block_kv=128, interpret=False):
    """q: [B, Sq, H, D]; k, v: [B, Skv, KV, D] -> [B, Sq, H, D].

    Block sizes are clamped to the actual sequence lengths and padded shapes
    are handled by in-kernel masking (kv_len) + index clamping on Q.
    """
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    assert H % KV == 0, (H, KV)
    G = H // KV
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    sm_scale = float(1.0 / (D ** 0.5))

    nq = pl.cdiv(Sq, block_q)
    nk = pl.cdiv(Skv, block_kv)
    pad_q = nq * block_q - Sq
    pad_k = nk * block_kv - Skv
    # [B, KV, G, Sq, D] layout so a q block is one (b, kv) slice
    qr = q.reshape(B, Sq, KV, G, D).transpose(0, 2, 3, 1, 4)
    if pad_q:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, 0), (0, pad_q), (0, 0)))
    kr = k.transpose(0, 2, 1, 3)
    vr = v.transpose(0, 2, 1, 3)
    if pad_k:
        kr = jnp.pad(kr, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vr = jnp.pad(vr, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    kernel = functools.partial(
        _fa_kernel, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_kv=block_kv, sm_scale=sm_scale, kv_len=Skv)

    out = pl.pallas_call(
        kernel,
        grid=(B, KV, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, block_q, D),
                         lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, block_q, D),
                               lambda b, h, i, j: (b, h, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, nq * block_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * block_q,), jnp.float32),
            pltpu.VMEM((G * block_q,), jnp.float32),
            pltpu.VMEM((G * block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    out = out[:, :, :, :Sq]                    # drop q padding
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)
