"""Fused RMSNorm / LayerNorm Pallas kernels.

Paper mapping: LayerNorm is spatially tiled on rows across clusters with
row statistics accumulated via streamed SSR loops (V-A3).  Here: rows are
grid cells, each block reduces its rows in fp32 in VMEM and writes the
normalized output once (no separate mean/var pass over HBM).

`residual_rmsnorm` / `residual_layernorm` fuse the residual-stream add with
the following pre-norm — the one spot in a pre-norm block a GEMM epilogue
can't absorb (the sum is needed both as the next residual and as the norm
input).  One pass reads (x, y) and writes (r = x + y, norm(r)): the
separate read-back of r that the unfused chain pays is eliminated.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.epilogue import LN_EPS, RMS_EPS
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, g_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * g_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...].astype(jnp.float32)
                  + b_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, gamma, *, eps=RMS_EPS, block_rows=256, interpret=False):
    """x: [..., D] -> same shape; statistics in fp32."""
    shape = x.shape
    D = shape[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    block_rows = min(block_rows, R)
    pad = -R % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rms_kernel, eps=eps),
        grid=(xf.shape[0] // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, gamma)
    return out[:R].reshape(shape)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def layernorm(x, gamma, beta, *, eps=LN_EPS, block_rows=256, interpret=False):
    shape = x.shape
    D = shape[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    block_rows = min(block_rows, R)
    pad = -R % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        grid=(xf.shape[0] // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, gamma, beta)
    return out[:R].reshape(shape)


def _res_rms_kernel(x_ref, y_ref, g_ref, h_ref, r_ref, *, eps):
    r = x_ref[...].astype(jnp.float32) + y_ref[...].astype(jnp.float32)
    r_ref[...] = r.astype(r_ref.dtype)
    rq = r_ref[...].astype(jnp.float32)     # norm what was stored
    var = jnp.mean(rq * rq, axis=-1, keepdims=True)
    h_ref[...] = (rq * jax.lax.rsqrt(var + eps)
                  * g_ref[...].astype(jnp.float32)).astype(h_ref.dtype)


def _res_ln_kernel(x_ref, y_ref, g_ref, b_ref, h_ref, r_ref, *, eps):
    r = x_ref[...].astype(jnp.float32) + y_ref[...].astype(jnp.float32)
    r_ref[...] = r.astype(r_ref.dtype)
    rq = r_ref[...].astype(jnp.float32)
    mu = jnp.mean(rq, axis=-1, keepdims=True)
    var = jnp.mean((rq - mu) ** 2, axis=-1, keepdims=True)
    h = (rq - mu) * jax.lax.rsqrt(var + eps)
    h_ref[...] = (h * g_ref[...].astype(jnp.float32)
                  + b_ref[...].astype(jnp.float32)).astype(h_ref.dtype)


def _residual_norm_call(kernel, inputs, vec_params, shape, dtype,
                        block_rows, interpret):
    """Shared launch for the fused add+norm kernels -> (h, r)."""
    D = shape[-1]
    flats = [x.reshape(-1, D) for x in inputs]
    R = flats[0].shape[0]
    block_rows = min(block_rows, R)
    pad = -R % block_rows
    if pad:
        flats = [jnp.pad(x, ((0, pad), (0, 0))) for x in flats]
    rows = flats[0].shape[0]
    in_specs = ([pl.BlockSpec((block_rows, D), lambda i: (i, 0))
                 for _ in flats]
                + [pl.BlockSpec((D,), lambda i: (0,)) for _ in vec_params])
    h, r = pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=in_specs,
        out_specs=(pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
                   pl.BlockSpec((block_rows, D), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((rows, D), dtype),
                   jax.ShapeDtypeStruct((rows, D), dtype)),
        interpret=interpret,
    )(*flats, *vec_params)
    return h[:R].reshape(shape), r[:R].reshape(shape)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def residual_rmsnorm(x, y, gamma, *, eps=RMS_EPS, block_rows=256,
                     interpret=False):
    """r = x + y; h = rmsnorm(r) in one pass.  -> (h, r), both x.dtype."""
    return _residual_norm_call(
        functools.partial(_res_rms_kernel, eps=eps), [x, y], [gamma],
        x.shape, x.dtype, block_rows, interpret)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def residual_layernorm(x, y, gamma, beta, *, eps=LN_EPS, block_rows=256,
                       interpret=False):
    """r = x + y; h = layernorm(r) in one pass.  -> (h, r), both x.dtype."""
    return _residual_norm_call(
        functools.partial(_res_ln_kernel, eps=eps), [x, y], [gamma, beta],
        x.shape, x.dtype, block_rows, interpret)
