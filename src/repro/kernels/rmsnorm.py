"""Fused RMSNorm / LayerNorm Pallas kernels.

Paper mapping: LayerNorm is spatially tiled on rows across clusters with
row statistics accumulated via streamed SSR loops (V-A3).  Here: rows are
grid cells, each block reduces its rows in fp32 in VMEM and writes the
normalized output once (no separate mean/var pass over HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rms_kernel(x_ref, g_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * g_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...].astype(jnp.float32)
                  + b_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, gamma, *, eps=1e-6, block_rows=256, interpret=False):
    """x: [..., D] -> same shape; statistics in fp32."""
    shape = x.shape
    D = shape[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    block_rows = min(block_rows, R)
    pad = -R % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rms_kernel, eps=eps),
        grid=(xf.shape[0] // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, gamma)
    return out[:R].reshape(shape)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def layernorm(x, gamma, beta, *, eps=1e-5, block_rows=256, interpret=False):
    shape = x.shape
    D = shape[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    block_rows = min(block_rows, R)
    pad = -R % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        grid=(xf.shape[0] // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, gamma, beta)
    return out[:R].reshape(shape)
