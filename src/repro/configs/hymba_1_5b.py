"""hymba-1.5b  [hybrid]  32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attn+mamba heads  [arXiv:2411.13676; hf]

Every layer runs attention and SSM heads in parallel on the same input and
averages the normalized outputs.  Layers {0, 15, 31} use global attention,
the rest sliding-window (hymba paper).  25 heads don't divide 16 -> seq_sp.
Meta-tokens are an accuracy feature and are omitted (systems-neutral)."""
from repro.configs.base import ModelConfig

SCHEDULE = (
    ("hybrid_attn", 1), ("hybrid_local", 14),
    ("hybrid_attn", 1), ("hybrid_local", 15),
    ("hybrid_attn", 1),
)

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32_001,
    schedule=SCHEDULE,
    sliding_window=1024,
    ssm_state=16,
    ssm_head_dim=64,
    d_inner=3200,
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    attention_sharding="seq_sp",
)
