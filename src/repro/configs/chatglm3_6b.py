"""chatglm3-6b  [dense]  28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — RoPE 2d, GQA  [arXiv:2406.12793; hf]

2d-RoPE: only half the head dims are rotated (rope_fraction=0.5).
32 heads divide 16 -> head_tp with kv replication (kv=2)."""
from repro.configs.base import ModelConfig, uniform_schedule

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13_696,
    vocab=65_024,
    schedule=uniform_schedule("attn", 28),
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    rope_fraction=0.5,
    attention_sharding="head_tp",
)
