"""Config registry: ``get_config(arch_id)`` for every assigned architecture
(+ the paper's own five models)."""
from repro.configs.base import (ModelConfig, ShapeConfig, SHAPES, TRAIN_4K,
                                PREFILL_32K, DECODE_32K, LONG_500K,
                                supports_shape)
from repro.configs.phi4_mini_3_8b import CONFIG as PHI4_MINI
from repro.configs.chatglm3_6b import CONFIG as CHATGLM3
from repro.configs.deepseek_67b import CONFIG as DEEPSEEK67B
from repro.configs.gemma3_27b import CONFIG as GEMMA3_27B
from repro.configs.mixtral_8x22b import CONFIG as MIXTRAL_8X22B
from repro.configs.mixtral_8x7b import CONFIG as MIXTRAL_8X7B
from repro.configs.internvl2_76b import CONFIG as INTERNVL2_76B
from repro.configs.hymba_1_5b import CONFIG as HYMBA_1_5B
from repro.configs.mamba2_2_7b import CONFIG as MAMBA2_2_7B
from repro.configs.whisper_base import CONFIG as WHISPER_BASE
from repro.configs.paper_models import PAPER_MODELS
from repro.configs.drafts import DRAFTS, make_draft

ASSIGNED = {
    c.name: c for c in (
        PHI4_MINI, CHATGLM3, DEEPSEEK67B, GEMMA3_27B, MIXTRAL_8X22B,
        MIXTRAL_8X7B, INTERNVL2_76B, HYMBA_1_5B, MAMBA2_2_7B, WHISPER_BASE,
    )
}

REGISTRY = dict(ASSIGNED)
REGISTRY.update(PAPER_MODELS)
REGISTRY.update(DRAFTS)

# CLI-friendly aliases (--arch <id>)
ALIASES = {
    "phi4-mini-3.8b": "phi4-mini-3.8b",
    "phi4_mini_3_8b": "phi4-mini-3.8b",
    "chatglm3-6b": "chatglm3-6b",
    "chatglm3_6b": "chatglm3-6b",
    "deepseek-67b": "deepseek-67b",
    "deepseek_67b": "deepseek-67b",
    "gemma3-27b": "gemma3-27b",
    "gemma3_27b": "gemma3-27b",
    "mixtral-8x22b": "mixtral-8x22b",
    "mixtral_8x22b": "mixtral-8x22b",
    "mixtral-8x7b": "mixtral-8x7b",
    "mixtral_8x7b": "mixtral-8x7b",
    "internvl2-76b": "internvl2-76b",
    "internvl2_76b": "internvl2-76b",
    "hymba-1.5b": "hymba-1.5b",
    "hymba_1_5b": "hymba-1.5b",
    "mamba2-2.7b": "mamba2-2.7b",
    "mamba2_2_7b": "mamba2-2.7b",
    "whisper-base": "whisper-base",
    "whisper_base": "whisper-base",
}


def get_config(arch: str) -> ModelConfig:
    key = ALIASES.get(arch, arch)
    if key not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[key]


__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "TRAIN_4K", "PREFILL_32K",
    "DECODE_32K", "LONG_500K", "supports_shape", "get_config", "REGISTRY",
    "ASSIGNED", "PAPER_MODELS", "DRAFTS", "make_draft",
]
