"""gemma3-27b  [dense]  62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local:global, 128k  [hf:google/gemma-3-1b-pt; unverified]

Schedule: (5 sliding-window + 1 global) x 10 + 2 trailing local layers = 62.
Local window 1024 (gemma3 default).  long_500k is RUN: local layers keep a
window-bounded ring cache; the 10 global layers hold the full-length cache
(decode cost per step is linear in S — noted in DESIGN.md)."""
from repro.configs.base import ModelConfig

SCHEDULE = (("local", 5), ("attn", 1)) * 10 + (("local", 2),)

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21_504,
    vocab=262_144,
    schedule=SCHEDULE,
    sliding_window=1024,
    mlp_act="gelu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    attention_sharding="head_tp",
)
