"""The paper's own five benchmark models (Table II) — used for the faithful
reproduction of the paper's figures/tables.

ViT-{B,L,H}: encoder-only classifiers (S=197 = 196 patches + cls token).
GPT3-XL / GPT-J: decoder-only LLMs, NAR (prefill) + AR (decode) modes.
The paper uses classic MHA (n_kv_heads == n_heads), LayerNorm and GELU.
"""
from repro.configs.base import ModelConfig, uniform_schedule


def _vit(name, blocks, E, P, FF, H):
    return ModelConfig(
        name=name, family="vit",
        n_layers=blocks, d_model=E, n_heads=H, n_kv_heads=H, head_dim=P,
        d_ff=FF, vocab=0,
        schedule=uniform_schedule("vit", blocks),
        mlp_act="gelu", norm="layernorm", causal=False,
        rope_theta=0.0,
        n_classes=1000, image_seq=197,
        attention_sharding="seq_sp",
        max_seq=256,
    )


VIT_B = _vit("vit-b", 12, 768, 64, 3072, 12)
VIT_L = _vit("vit-l", 24, 1024, 64, 4096, 16)
VIT_H = _vit("vit-h", 32, 1280, 80, 5120, 16)


def _gpt(name, blocks, E, P, FF, H, vocab):
    return ModelConfig(
        name=name, family="dense",
        n_layers=blocks, d_model=E, n_heads=H, n_kv_heads=H, head_dim=P,
        d_ff=FF, vocab=vocab,
        schedule=uniform_schedule("attn", blocks),
        mlp_act="gelu", norm="layernorm",
        rope_theta=10_000.0,
        attention_sharding="head_tp",
        max_seq=2048,
    )


GPT3_XL = _gpt("gpt3-xl", 40, 2048, 128, 8192, 16, 50_257)
GPT_J = _gpt("gpt-j", 28, 4096, 256, 16_384, 16, 50_400)

PAPER_MODELS = {m.name: m for m in (VIT_B, VIT_L, VIT_H, GPT3_XL, GPT_J)}
