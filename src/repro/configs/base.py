"""Model & shape configuration system.

Every assigned architecture is a `ModelConfig`; every benchmark shape is a
`ShapeConfig`.  Layer heterogeneity (gemma3's 5:1 local:global pattern,
hymba's 3 global layers, whisper's encoder/decoder split) is expressed as a
*segment schedule*: an ordered tuple of ``(kind, count)`` segments.  Each
segment is executed as one `lax.scan` over `count` stacked layers, keeping the
HLO compact for deep models (deepseek-67b: 95 layers -> one while loop).

Layer kinds
-----------
``attn``          global attention + dense MLP
``local``         sliding-window attention + dense MLP
``moe``           global attention + mixture-of-experts FFN
``moe_local``     sliding-window attention + MoE FFN
``ssm``           Mamba2 SSD block (attention-free)
``hybrid_attn``   parallel attention + SSM heads (global attn), dense MLP
``hybrid_local``  parallel attention + SSM heads (sliding window), dense MLP
``enc``           bidirectional attention + MLP (encoder)
``dec``           causal self-attention + cross-attention + MLP (decoder)
``vit``           bidirectional attention + MLP (encoder-only classifier)
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

Schedule = Tuple[Tuple[str, int], ...]

ATTN_KINDS = ("attn", "local", "moe", "moe_local", "hybrid_attn",
              "hybrid_local", "enc", "dec", "vit")
LOCAL_KINDS = ("local", "moe_local", "hybrid_local")
SSM_KINDS = ("ssm", "hybrid_attn", "hybrid_local")
MOE_KINDS = ("moe", "moe_local")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | hybrid | ssm | encdec | vit
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    schedule: Schedule
    # -- attention ----------------------------------------------------------
    sliding_window: int = 0          # 0 = no SWA anywhere
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0       # chatglm3 2d-RoPE rotates half the dims
    causal: bool = True
    # -- mlp / norm ---------------------------------------------------------
    mlp_act: str = "swiglu"          # swiglu | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    # -- moe ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # -- ssm (mamba2 SSD) ---------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    d_inner: int = 0                 # 0 -> 2*d_model when SSM present
    conv_width: int = 4
    # -- encoder/decoder ----------------------------------------------------
    n_enc_layers: int = 0
    enc_schedule: Schedule = ()
    enc_seq: int = 0                 # whisper: 1500 precomputed frames
    # -- vlm ----------------------------------------------------------------
    n_patches: int = 0               # prefix patch embeddings in the sequence
    # -- vit classifier -----------------------------------------------------
    n_classes: int = 0
    image_seq: int = 0               # ViT: number of patches (+1 cls token)
    # -- systems knobs ------------------------------------------------------
    attention_sharding: str = "head_tp"   # head_tp | seq_sp
    tie_embeddings: bool = False
    max_seq: int = 32_768            # default cache/rope horizon

    # -- derived ------------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return max(1, self.n_heads // max(1, self.n_kv_heads))

    @property
    def ssm_heads(self) -> int:
        di = self.d_inner or 2 * self.d_model
        return di // self.ssm_head_dim if self.ssm_state else 0

    @property
    def padded_vocab(self) -> int:
        """Vocabulary padded to a multiple of 256 so the unembedding shards
        16-way over `model` (and 16-way over `data` for FSDP).  Megatron-style;
        padded logit columns are masked to -inf in CE and sampling."""
        return -(-self.vocab // 256) * 256 if self.vocab else 0

    def padded_ssm_heads(self, tp: int = 16) -> int:
        """SSM heads padded up so they divide the tp axis (hymba: 50 -> 64).
        Pad heads have zero out-projection rows => output-exact."""
        if not self.ssm_state:
            return 0
        h = self.ssm_heads
        return -(-h // tp) * tp if h % tp else h

    def padded_d_inner(self, tp: int = 16) -> int:
        return self.padded_ssm_heads(tp) * self.ssm_head_dim

    @property
    def enc_seq_padded(self) -> int:
        """Encoder frames padded so the sequence shards 16-way; pad frames
        are zero embeddings attended like real ones (systems-equivalent,
        DESIGN.md §5)."""
        return -(-self.enc_seq // 16) * 16 if self.enc_seq else 0

    @property
    def has_attention(self) -> bool:
        return any(k in ATTN_KINDS for k, _ in self.schedule)

    @property
    def has_ssm(self) -> bool:
        return any(k in SSM_KINDS for k, _ in self.schedule)

    @property
    def subquadratic(self) -> bool:
        """True when no layer does full-length quadratic *global* attention —
        or when global layers are rare enough that decode stays tractable
        (SWA-dominant archs keep window-bounded caches on local layers)."""
        kinds = [k for k, _ in self.schedule]
        return all(k in LOCAL_KINDS + ("ssm",) for k in kinds)

    @property
    def long_context_capable(self) -> bool:
        """Eligible for the long_500k cell: SSM / hybrid / SWA-dominant."""
        if self.family in ("ssm", "hybrid"):
            return True
        n_local = sum(c for k, c in self.schedule if k in LOCAL_KINDS)
        n_total = sum(c for _, c in self.schedule)
        return n_local >= n_total // 2 and n_local > 0   # SWA-dominant

    def n_params(self) -> int:
        """Parameter count (embedding + blocks + head), exact per family."""
        E, F, V = self.d_model, self.d_ff, self.vocab
        hd, H, KV = self.head_dim, self.n_heads, self.n_kv_heads
        total = V * E                           # embedding
        if not self.tie_embeddings:
            total += E * V                      # unembedding
        if self.n_classes:
            total = self.image_seq * E + E * self.n_classes  # vit: pos + head
        per_kind = {}
        for kind, count in self.schedule + self.enc_schedule:
            if kind in per_kind:
                total += per_kind[kind] * count
                continue
            p = 2 * E                           # two norms
            if kind in ATTN_KINDS:
                p += E * (H * hd) + 2 * E * (KV * hd) + (H * hd) * E
                if kind == "dec":               # cross attention + its norm
                    p += E * (H * hd) + 2 * E * (KV * hd) + (H * hd) * E + E
            if kind in SSM_KINDS or kind == "ssm":
                di = self.d_inner or 2 * E
                nh = di // self.ssm_head_dim
                p += E * (2 * di + 2 * self.ssm_state + nh)  # in_proj
                p += di * self.conv_width + nh + nh          # conv, A, D
                p += di * E                                  # out_proj
            if kind in MOE_KINDS:
                gated = 3 if self.mlp_act == "swiglu" else 2
                p += E * self.n_experts + self.n_experts * gated * E * F
            elif kind not in ("ssm",):
                gated = 3 if self.mlp_act == "swiglu" else 2
                p += gated * E * F
            per_kind[kind] = p
            total += p * count
        return total

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only top_k experts count)."""
        if not self.n_experts:
            return self.n_params()
        E, F = self.d_model, self.d_ff
        gated = 3 if self.mlp_act == "swiglu" else 2
        n_moe_layers = sum(c for k, c in self.schedule if k in MOE_KINDS)
        inactive = (self.n_experts - self.top_k) * gated * E * F * n_moe_layers
        return self.n_params() - inactive

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests (preserves schedule
        structure, shrinks widths/depths/vocab)."""
        def shrink(sched: Schedule, cap: int = 2) -> Schedule:
            return tuple((k, min(c, cap)) for k, c in sched[:3])
        hd = 16
        H = min(self.n_heads, 4) if self.n_heads else 0
        KV = max(1, min(self.n_kv_heads, 2)) if self.n_heads else 0
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=sum(c for _, c in shrink(self.schedule)),
            d_model=64,
            n_heads=H,
            n_kv_heads=KV,
            head_dim=hd,
            d_ff=128,
            vocab=256,
            schedule=shrink(self.schedule),
            enc_schedule=shrink(self.enc_schedule) if self.enc_schedule else (),
            n_enc_layers=sum(c for _, c in shrink(self.enc_schedule)) if self.enc_schedule else 0,
            sliding_window=min(self.sliding_window, 8) if self.sliding_window else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else self.ssm_head_dim,
            d_inner=128 if self.ssm_state else 0,
            enc_seq=min(self.enc_seq, 12) if self.enc_seq else 0,
            n_patches=min(self.n_patches, 4) if self.n_patches else 0,
            n_classes=min(self.n_classes, 16) if self.n_classes else 0,
            image_seq=min(self.image_seq, 17) if self.image_seq else 0,
            max_seq=128,
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeConfig("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Assignment rules: long_500k only for sub-quadratic-capable archs;
    encoder-only archs have no decode step."""
    if cfg.family == "vit":
        return shape.kind == "train" or shape.kind == "prefill"
    if shape.name == "long_500k":
        return cfg.long_context_capable
    return True


def uniform_schedule(kind: str, n: int) -> Schedule:
    return ((kind, n),)
