"""mixtral-8x7b  [moe]  32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8e top-2, SWA  [arXiv:2401.04088; hf]"""
from repro.configs.base import ModelConfig, uniform_schedule

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab=32_000,
    schedule=uniform_schedule("moe_local", 32),
    sliding_window=4096,
    n_experts=8,
    top_k=2,
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    attention_sharding="head_tp",
)
