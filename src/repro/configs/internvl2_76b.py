"""internvl2-76b  [vlm]  80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — InternViT + InternLM2  [arXiv:2404.16821; unverified]

Backbone only, per assignment: the InternViT frontend is a STUB —
input_specs() supplies precomputed patch embeddings (n_patches x d_model)
that are concatenated in front of the token embeddings."""
from repro.configs.base import ModelConfig, uniform_schedule

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28_672,
    vocab=128_256,
    schedule=uniform_schedule("attn", 80),
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    n_patches=256,
    attention_sharding="head_tp",
)
