"""whisper-base  [audio]  6L d_model=512 8H d_ff=2048 vocab=51865 —
enc-dec, conv frontend (stub)  [arXiv:2212.04356; unverified]

Backbone only: the conv/mel frontend is a STUB — input_specs() supplies
precomputed frame embeddings (1500 x d_model).  6 encoder + 6 decoder layers.
decode_32k is lowered mechanically per the assignment (real whisper caps the
target length at 448).  8 heads don't divide 16 -> seq_sp."""
from repro.configs.base import ModelConfig, uniform_schedule

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab=51_865,
    schedule=uniform_schedule("dec", 6),
    enc_schedule=uniform_schedule("enc", 6),
    n_enc_layers=6,
    enc_seq=1500,
    mlp_act="gelu",
    norm="layernorm",
    rope_theta=0.0,          # whisper uses learned/sinusoidal positions
    attention_sharding="seq_sp",
)
