"""deepseek-67b  [dense]  95L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=102400 — llama-arch  [arXiv:2401.02954; hf]"""
from repro.configs.base import ModelConfig, uniform_schedule

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22_016,
    vocab=102_400,
    schedule=uniform_schedule("attn", 95),
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    attention_sharding="head_tp",
)
