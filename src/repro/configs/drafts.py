"""Draft-model configs for speculative decoding (serving/spec.py).

A draft is a *small* causal LM that shares the target's tokenizer — same
`vocab` (and therefore the same padded/sharded vocabulary geometry) — so
its proposed token ids are directly comparable to the target's.  The
serving stack never needs the draft to be *good*: acceptance is verified
against the target exactly (serving/spec.py), so a weak draft only costs
acceptance rate, never correctness.

`make_draft(cfg)` derives a 2-layer GPT-J-shaped draft from any decoder
config: plain global-attention layers (no MoE / SSM / sliding window /
encoder — those change the cache layout, and the draft keeps a trivially
dense per-slot cache), same widths so every sharding divisibility the
target satisfies carries over, and `reduced()` targets derive reduced
drafts automatically.  Named drafts (`<target>-draft`) for the paper
families are registered in `repro.configs.REGISTRY` via `DRAFTS`.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig

DRAFT_LAYERS = 2


def make_draft(cfg: ModelConfig, n_layers: int = DRAFT_LAYERS) -> ModelConfig:
    """A tiny draft LM sharing `cfg`'s vocabulary: `n_layers` plain
    causal-attention layers, everything cache-layout-exotic stripped."""
    if not cfg.vocab:
        raise ValueError(
            f"{cfg.name} has no token vocabulary — a draft LM needs the "
            f"target's tokenizer (decoder LMs only)")
    n = max(1, min(n_layers, cfg.n_layers))
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-draft",
        family="dense",
        n_layers=n,
        schedule=(("attn", n),),
        sliding_window=0,
        n_experts=0,
        top_k=0,
        ssm_state=0,
        d_inner=0,
        n_enc_layers=0,
        enc_schedule=(),
        enc_seq=0,
        n_patches=0,
        n_classes=0,
        image_seq=0,
    )


def _paper_drafts() -> dict:
    # one registered draft per decoder family (paper LMs + the assigned
    # plain-decoder archs); the rest derive on demand via make_draft
    from repro.configs.chatglm3_6b import CONFIG as CHATGLM3
    from repro.configs.deepseek_67b import CONFIG as DEEPSEEK67B
    from repro.configs.mixtral_8x7b import CONFIG as MIXTRAL_8X7B
    from repro.configs.paper_models import GPT3_XL, GPT_J
    from repro.configs.phi4_mini_3_8b import CONFIG as PHI4_MINI
    targets = (GPT_J, GPT3_XL, PHI4_MINI, CHATGLM3, DEEPSEEK67B,
               MIXTRAL_8X7B)
    return {d.name: d for d in (make_draft(t) for t in targets)}


DRAFTS = _paper_drafts()
