"""phi4-mini-3.8b  [dense]  32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064 — RoPE SwiGLU GQA  [arXiv:2412.08905; hf]

24 heads do not divide the 16-way model axis -> seq_sp attention sharding
(context parallelism + distributed-softmax decode)."""
from repro.configs.base import ModelConfig, uniform_schedule

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=200_064,
    schedule=uniform_schedule("attn", 32),
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    attention_sharding="seq_sp",
)
