"""mamba2-2.7b  [ssm]  64L d_model=2560 (attn-free) d_ff=0 vocab=50280,
ssm_state=128 — SSD (state-space duality)  [arXiv:2405.21060; unverified]

Attention-free: the paper's FlashAttention technique (T2) is inapplicable —
the SSD chunk kernel takes its place; the fused output-projection reduction
(T3) still applies to the SSD head outputs.  long_500k RUN: O(1) state."""
from repro.configs.base import ModelConfig, uniform_schedule

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab=50_280,
    schedule=uniform_schedule("ssm", 64),
    ssm_state=128,
    ssm_head_dim=64,
    d_inner=5120,
    conv_width=4,
    norm="rmsnorm",
    causal=True,
    attention_sharding="seq_sp",
)
