"""mixtral-8x22b  [moe]  56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8e top-2, SWA  [arXiv:2401.04088; hf]

Window 4096 per the Mixtral SWA design.  long_500k RUN (window-bounded ring
caches everywhere)."""
from repro.configs.base import ModelConfig, uniform_schedule

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16_384,
    vocab=32_768,
    schedule=uniform_schedule("moe_local", 56),
    sliding_window=4096,
    n_experts=8,
    top_k=2,
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    attention_sharding="head_tp",
)
