"""Sharded, atomic, async checkpointing with elastic restore.

Layout: <dir>/step_<n>/   arr_<i>.npy (one per leaf) + manifest.json
Commit is atomic (write to step_<n>.tmp, fsync, rename) so a preemption
mid-save never corrupts the latest checkpoint.  `save_async` snapshots
device arrays to host synchronously (cheap) and writes in a background
thread — the train loop overlaps the next step with the disk write.

Elastic restore: checkpoints store plain host arrays + the tree structure,
NOT device layouts; `restore` re-shards onto whatever mesh/sharding the
relaunch provides via `jax.make_array_from_callback` (tested across device
counts in tests/test_checkpoint.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

# numpy can't serialize ml_dtypes (bf16/fp8); store raw uint views and
# recover the true dtype from the manifest.
_RAW_VIEW = {2: np.uint16, 1: np.uint8}
_NATIVE = {np.dtype(t) for t in
           (np.float64, np.float32, np.float16, np.int64, np.int32,
            np.int16, np.int8, np.uint64, np.uint32, np.uint16, np.uint8,
            np.bool_)}


def _to_saveable(x: np.ndarray) -> np.ndarray:
    if x.dtype in _NATIVE:
        return x
    return x.view(_RAW_VIEW[x.dtype.itemsize])


def _from_saveable(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    want = np.dtype(jnp.dtype(dtype_str).name) if dtype_str in (
        "bfloat16", "float8_e4m3fn", "float8_e5m2") else np.dtype(dtype_str)
    if want in _NATIVE:
        return arr.astype(want) if arr.dtype != want else arr
    return arr.view(jnp.dtype(dtype_str))


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def _write(self, host_leaves, treedef_repr: str, step: int,
               meta: Optional[dict]):
        final = os.path.join(self.directory, f"step_{step:08d}")
        if os.path.exists(final):      # this step is already committed
            return
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "n_leaves": len(host_leaves),
                    "treedef": treedef_repr, "time": time.time(),
                    "meta": meta or {},
                    "dtypes": [str(x.dtype) for x in host_leaves],
                    "shapes": [list(x.shape) for x in host_leaves]}
        for i, x in enumerate(host_leaves):
            np.save(os.path.join(tmp, f"arr_{i}.npy"), _to_saveable(x))
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, final)                       # atomic commit
        self._gc()

    def save(self, state: Any, step: int, *, meta: Optional[dict] = None,
             block: bool = True):
        self.wait()
        leaves, treedef = jax.tree.flatten(state)
        host = [np.asarray(x) for x in leaves]       # device -> host snapshot
        if block:
            self._write(host, str(treedef), step, meta)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(host, str(treedef), step, meta),
                daemon=True)
            self._thread.start()

    def save_async(self, state: Any, step: int, *, meta: Optional[dict] = None):
        self.save(state, step, meta=meta, block=False)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def all_steps(self):
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_like: Any, *, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """state_like: a pytree with the target structure (arrays or
        ShapeDtypeStructs).  `shardings`: matching tree of NamedShardings
        (or None leaves) — restore re-shards onto them (elastic)."""
        step = step if step is not None else self.latest_step()
        assert step is not None, f"no checkpoints in {self.directory}"
        path = os.path.join(self.directory, f"step_{step:08d}")
        manifest = json.load(open(os.path.join(path, "manifest.json")))
        leaves, treedef = jax.tree.flatten(state_like)
        assert manifest["n_leaves"] == len(leaves), (
            manifest["n_leaves"], len(leaves))
        sh_leaves = (jax.tree.leaves(
            shardings, is_leaf=lambda x: x is None or hasattr(x, "mesh"))
            if shardings is not None else [None] * len(leaves))

        out = []
        for i, (ref, sh) in enumerate(zip(leaves, sh_leaves)):
            arr = _from_saveable(np.load(os.path.join(path, f"arr_{i}.npy")),
                                 manifest["dtypes"][i])
            assert tuple(arr.shape) == tuple(ref.shape), (
                i, arr.shape, ref.shape)
            if sh is None:
                out.append(jax.numpy.asarray(arr, dtype=ref.dtype))
            else:
                arr = arr.astype(ref.dtype)
                out.append(jax.make_array_from_callback(
                    arr.shape, sh, lambda idx, a=arr: a[idx]))
        return jax.tree.unflatten(treedef, out)

    def manifest(self, step: Optional[int] = None) -> dict:
        step = step if step is not None else self.latest_step()
        path = os.path.join(self.directory, f"step_{step:08d}",
                            "manifest.json")
        return json.load(open(path))
