"""Speculative decoding: draft-model proposer + multi-token verification.

The paper's AR mode is memory-bound — every decode step streams the full
weight set from HBM to produce ONE token per slot.  Speculative decoding
amortizes that weight read: a small *draft* LM proposes `k` tokens per
round at negligible cost, and the *target* model verifies all `k` (+ the
mandatory next token) in a single multi-token forward over the paged KV
cache — the chunked-prefill machinery (lm.forward_chunk /
attn_chunk_paged) pointed at decode-time positions.  Accepted tokens cost
one target weight read for up to k+1 tokens; rejected tokens cost nothing
but their (already-written, position-masked) KV entries, which are rolled
back by rewinding the slot's block-table fill count.

Acceptance is *exact*, not approximate.  The engine's sampler is
deterministic: `core.embedding.sample_token` maps (residual, seed,
position) to one token — greedy rows are an argmax, sampled rows a
(seed, position)-keyed Gumbel-max draw.  Verification therefore computes,
at every proposed position, the token the target WOULD have chosen
step-by-step, and accepts the longest prefix where the draft guessed it.
The committed sequence is token-identical to non-speculative decoding for
greedy AND sampled requests (the same lossless guarantee exact rejection
sampling provides, obtained by determinism instead of accept/reject
coin-flips) — speculation changes how many target steps a sequence costs,
never which tokens it contains.

Round lifecycle (ModelRunner.spec_decode):

  propose   k lockstep draft-decode steps over the decode batch (the draft
            keeps a dense per-slot cache + per-slot `DraftState`), fed the
            same per-slot sampling lane as the target
  verify    one `launch/steps.make_verify_step` call: the target forwards
            [pending token, d_1..d_k] straight into the slot's paged
            blocks and returns its own choice at every position
  commit    host-side longest-prefix acceptance (+ EOS / max_new_tokens /
            max_seq trimming so retirement semantics match non-spec
            decode), then rollback: pos rewinds to the committed length,
            trailing blocks allocated solely for rejected tokens are
            freed, and the draft cache rewinds with it
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

from repro.configs.base import ModelConfig

ACCEPTANCE_MODES = ("lossless", "greedy")


@dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding knobs for an InferenceEngine.

    draft       which model proposes: "self" (the target itself — 100%
                greedy acceptance, useful as the zero-risk upper bound and
                for overhead measurement), "auto" (derive a 2-layer draft
                from the target via configs.drafts.make_draft), or a
                registered config name (e.g. "gpt-j-draft") sharing the
                target's vocabulary.
    k           speculation length: draft tokens proposed per round; each
                verify step commits between 1 and k+1 tokens.
    acceptance  "lossless" (default): per-request greedy/sampled acceptance
                against the target's deterministic sampler — outputs are
                token-identical to non-speculative decoding for every
                request.  "greedy": the engine additionally REJECTS sampled
                submissions at submit time (a pure-greedy deployment that
                wants the constraint enforced, not silently absorbed).
    draft_seed  RNG seed used to initialize draft parameters when the
                caller does not supply `draft_params` (matching the
                engine's init-at-construction convention).
    branches    token-tree width: candidates the draft proposes per depth.
                1 (default) is the classic single-chain round.  b > 1
                builds a Medusa-style "caterpillar" tree per slot — the
                sampled draft chain t_1..t_k plus (b - 1) top-k sibling
                leaves hanging off each chain node — verified in ONE
                tree-masked target pass; a round then accepts the deepest
                root path whose nodes all match the target's own choices,
                which strictly contains the single-chain acceptance
                (the chain IS one of the root paths).  Still lossless.
    """
    draft: str = "auto"
    k: int = 4
    acceptance: str = "lossless"
    draft_seed: int = 0
    branches: int = 1

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"speculation length k must be >= 1: {self.k}")
        if self.branches < 1:
            raise ValueError(
                f"tree branch count must be >= 1: {self.branches}")
        if self.acceptance not in ACCEPTANCE_MODES:
            raise ValueError(
                f"acceptance must be one of {ACCEPTANCE_MODES}: "
                f"{self.acceptance!r}")
        if not self.draft:
            raise ValueError("draft must name a config, 'auto', or 'self'")


@dataclass
class DraftState:
    """Per-slot draft bookkeeping (owned by ModelRunner, one per seated
    GenerateTask when speculation is on).

    pos   valid draft-cache length for this slot: positions [0, pos) of
          the draft's dense cache row hold KV for the committed token
          sequence.  Lags the target's pos by at most one after an
          all-accept round (the bonus token's predecessor was never fed
          through the draft); the next round's proposal phase replays the
          gap from the known committed tokens.
    """
    pos: int


def spec_support_reason(cfg: ModelConfig) -> Optional[str]:
    """Why `cfg` cannot decode speculatively (None = statically eligible).

    Verification rides the chunked-prefill machinery, so the gate is the
    same cache-layout one: every segment's KV must live in the paged pool
    (multi-token verify writes straight into the slot's blocks and the
    rollback is a fill-count rewind).  Recurrent / ring / cross-attention
    state cannot rewind that way.  The engine additionally requires its
    runtime layout to be paged with dp == 1 (ModelRunner.supports_chunked).
    """
    if not cfg.vocab:
        return "no token vocabulary (encoder-only topology)"
    if cfg.has_ssm:
        return "recurrent SSM state cannot roll back rejected tokens"
    if cfg.sliding_window > 0:
        return ("sliding-window ring caches stay dense per-slot — no "
                "block-table fill count to rewind")
    if cfg.enc_schedule:
        return "encoder-decoder cross-attention memory is not paged"
    if cfg.n_patches:
        return "VLM patch prefixes are not supported in verify chunks"
    if cfg.rope_theta == 0:
        return "absolute-position (sinusoidal) models lack the chunk path"
    return None


def resolve_draft(spec: SpecConfig, cfg: ModelConfig) -> ModelConfig:
    """The draft ModelConfig for (spec, target): "self" / "auto" / a
    registered name, reduced alongside a reduced target, vocabulary
    checked against the target's (shared tokenizer is the contract that
    makes proposed ids comparable)."""
    from repro.configs import get_config, make_draft
    if spec.draft == "self":
        return cfg
    if spec.draft == "auto":
        return make_draft(cfg)
    draft = get_config(spec.draft)
    if cfg.name.endswith("-reduced") and not draft.name.endswith("-reduced"):
        draft = draft.reduced()
    if draft.vocab != cfg.vocab:
        raise ValueError(
            f"draft {draft.name} does not share the target's tokenizer: "
            f"vocab {draft.vocab} != {cfg.vocab} ({cfg.name})")
    return draft


def accept_length(proposed: Sequence[int], target: Sequence[int]) -> int:
    """Longest accepted prefix: the number of leading positions where the
    draft's proposal equals the target's own (deterministic) choice for
    that position.  `target[j]` is the target's token for the position
    `proposed[j]` claims; acceptance stops at the first disagreement."""
    n = 0
    for d, c in zip(proposed, target):
        if int(d) != int(c):
            break
        n += 1
    return n


@dataclass
class TokenTree:
    """One slot's flattened proposal tree (build_tree output).

    Depth-major flatten order, chain node first within each depth:
    node 0 is the pending (already-committed) token, then per depth d the
    draft chain's token followed by its sibling candidates.  Any prefix of
    this order is ancestor-closed, so per-slot trees of different sizes
    batch into one fixed-width verify chunk by truncation + masking.

    tokens  [n] node token ids (tokens[0] = pending token)
    depth   [n] tree depth per node (depth[0] = 0; rope / sampling
            position of node i is pos0 + depth[i])
    parent  [n] parent node index (parent[0] = -1)
    anc     [n, n] bool ancestor-or-self matrix: anc[i, j] = node j lies
            on the root path of node i.  This IS the intra-chunk
            attention mask forward_verify_tree applies.
    chain   [n] bool: node sits on the draft's sampled chain (only chain
            nodes have children, so an accepted path leaves the chain at
            most once — at its final node).
    """
    tokens: "np.ndarray"
    depth: "np.ndarray"
    parent: "np.ndarray"
    anc: "np.ndarray"
    chain: "np.ndarray"

    @property
    def n_nodes(self) -> int:
        return int(self.tokens.shape[0])


def build_tree(pending: int, levels: Sequence[Sequence[int]]) -> TokenTree:
    """Flatten one slot's proposal levels into a TokenTree.

    `levels[d]` holds the candidate tokens for depth d + 1, with
    `levels[d][0]` the draft's sampled chain token (the token the draft
    actually fed forward) and the rest its same-step top-k siblings.
    Every depth's candidates attach to the previous depth's CHAIN node —
    the draft cache only ever advanced along the chain, so siblings are
    leaves.  A width-1 levels list reproduces the single-chain layout
    exactly: depth[i] == i and `anc` lower-triangular."""
    import numpy as np
    n = 1 + sum(len(lv) for lv in levels)
    tokens = np.zeros((n,), np.int32)
    depth = np.zeros((n,), np.int32)
    parent = np.full((n,), -1, np.int32)
    anc = np.zeros((n, n), bool)
    chain = np.zeros((n,), bool)
    tokens[0] = pending
    anc[0, 0] = True
    chain[0] = True
    i = 1
    par = 0
    for d, lv in enumerate(levels, start=1):
        nxt = i                          # this depth's chain node
        for j, t in enumerate(lv):
            tokens[i] = int(t)
            depth[i] = d
            parent[i] = par
            anc[i] = anc[par]
            anc[i, i] = True
            chain[i] = j == 0
            i += 1
        par = nxt
    return TokenTree(tokens=tokens, depth=depth, parent=parent, anc=anc,
                     chain=chain)


def accept_tree_path(tokens: Sequence[int], parent: Sequence[int],
                     choices: Sequence[int], n_nodes: int) -> List[int]:
    """Deepest accepted root path through a verified token tree.

    `choices[i]` is the target's own deterministic choice for the
    position AFTER node i's root path — acceptance of a child node j
    requires tokens[j] == choices[parent[j]], the same equality
    `accept_length` tests per chain position.  Walk from the root,
    descending into the (unique, by distinct-sibling construction) child
    matching the parent's choice, until no child matches.  Returns the
    accepted node indices in depth order, root excluded — so the round
    emits [choices[i] for i in [0] + path], mirroring the chain round's
    cand[:j + 1].  On a width-1 chain tree (parent[i] == i - 1) this
    reduces to exactly `accept_length` semantics."""
    path: List[int] = []
    cur = 0
    while True:
        want = int(choices[cur])
        nxt = -1
        for j in range(cur + 1, n_nodes):
            if int(parent[j]) == cur and int(tokens[j]) == want:
                nxt = j
                break
        if nxt < 0:
            return path
        path.append(nxt)
        cur = nxt


def trim_emitted(emitted: List[int], *, room: int,
                 eos_id: Optional[int]) -> List[int]:
    """Clamp one round's committed tokens to non-speculative retirement
    semantics: at most `room` tokens (max_new_tokens / max_seq budget,
    pre-clamped by the caller), cut at the first EOS inclusive — exactly
    where step-by-step decoding would have stopped."""
    out = emitted[:max(room, 1)]
    if eos_id is not None and eos_id in out:
        out = out[:out.index(eos_id) + 1]
    return out


def round_annotation(*, proposed: int, accepted: int, emitted: int,
                     tree_nodes: int = 0,
                     path_depths: Optional[Sequence[int]] = None,
                     branch_hits: int = 0) -> dict:
    """Trace-span args summarizing one propose->verify->commit round
    (serving/trace.py): proposal volume, acceptance, and — under token
    trees — node count, accepted root-path depths, and how many slots'
    accepted paths left the draft's sampled chain.  Pure observer; the
    commit loop computes these numbers either way."""
    ann = {"proposed": int(proposed), "accepted": int(accepted),
           "emitted": int(emitted),
           "accept_rate": (accepted / proposed if proposed else 0.0)}
    if tree_nodes:
        ann["tree_nodes"] = int(tree_nodes)
        ann["branch_hits"] = int(branch_hits)
        if path_depths:
            ann["accept_depths"] = [int(d) for d in path_depths]
    return ann


__all__ = ["SpecConfig", "DraftState", "TokenTree", "spec_support_reason",
           "resolve_draft", "accept_length", "accept_tree_path",
           "build_tree", "trim_emitted", "round_annotation"]
