"""Speculative decoding: draft-model proposer + multi-token verification.

The paper's AR mode is memory-bound — every decode step streams the full
weight set from HBM to produce ONE token per slot.  Speculative decoding
amortizes that weight read: a small *draft* LM proposes `k` tokens per
round at negligible cost, and the *target* model verifies all `k` (+ the
mandatory next token) in a single multi-token forward over the paged KV
cache — the chunked-prefill machinery (lm.forward_chunk /
attn_chunk_paged) pointed at decode-time positions.  Accepted tokens cost
one target weight read for up to k+1 tokens; rejected tokens cost nothing
but their (already-written, position-masked) KV entries, which are rolled
back by rewinding the slot's block-table fill count.

Acceptance is *exact*, not approximate.  The engine's sampler is
deterministic: `core.embedding.sample_token` maps (residual, seed,
position) to one token — greedy rows are an argmax, sampled rows a
(seed, position)-keyed Gumbel-max draw.  Verification therefore computes,
at every proposed position, the token the target WOULD have chosen
step-by-step, and accepts the longest prefix where the draft guessed it.
The committed sequence is token-identical to non-speculative decoding for
greedy AND sampled requests (the same lossless guarantee exact rejection
sampling provides, obtained by determinism instead of accept/reject
coin-flips) — speculation changes how many target steps a sequence costs,
never which tokens it contains.

Round lifecycle (ModelRunner.spec_decode):

  propose   k lockstep draft-decode steps over the decode batch (the draft
            keeps a dense per-slot cache + per-slot `DraftState`), fed the
            same per-slot sampling lane as the target
  verify    one `launch/steps.make_verify_step` call: the target forwards
            [pending token, d_1..d_k] straight into the slot's paged
            blocks and returns its own choice at every position
  commit    host-side longest-prefix acceptance (+ EOS / max_new_tokens /
            max_seq trimming so retirement semantics match non-spec
            decode), then rollback: pos rewinds to the committed length,
            trailing blocks allocated solely for rejected tokens are
            freed, and the draft cache rewinds with it
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

from repro.configs.base import ModelConfig

ACCEPTANCE_MODES = ("lossless", "greedy")


@dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding knobs for an InferenceEngine.

    draft       which model proposes: "self" (the target itself — 100%
                greedy acceptance, useful as the zero-risk upper bound and
                for overhead measurement), "auto" (derive a 2-layer draft
                from the target via configs.drafts.make_draft), or a
                registered config name (e.g. "gpt-j-draft") sharing the
                target's vocabulary.
    k           speculation length: draft tokens proposed per round; each
                verify step commits between 1 and k+1 tokens.
    acceptance  "lossless" (default): per-request greedy/sampled acceptance
                against the target's deterministic sampler — outputs are
                token-identical to non-speculative decoding for every
                request.  "greedy": the engine additionally REJECTS sampled
                submissions at submit time (a pure-greedy deployment that
                wants the constraint enforced, not silently absorbed).
    draft_seed  RNG seed used to initialize draft parameters when the
                caller does not supply `draft_params` (matching the
                engine's init-at-construction convention).
    """
    draft: str = "auto"
    k: int = 4
    acceptance: str = "lossless"
    draft_seed: int = 0

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"speculation length k must be >= 1: {self.k}")
        if self.acceptance not in ACCEPTANCE_MODES:
            raise ValueError(
                f"acceptance must be one of {ACCEPTANCE_MODES}: "
                f"{self.acceptance!r}")
        if not self.draft:
            raise ValueError("draft must name a config, 'auto', or 'self'")


@dataclass
class DraftState:
    """Per-slot draft bookkeeping (owned by ModelRunner, one per seated
    GenerateTask when speculation is on).

    pos   valid draft-cache length for this slot: positions [0, pos) of
          the draft's dense cache row hold KV for the committed token
          sequence.  Lags the target's pos by at most one after an
          all-accept round (the bonus token's predecessor was never fed
          through the draft); the next round's proposal phase replays the
          gap from the known committed tokens.
    """
    pos: int


def spec_support_reason(cfg: ModelConfig) -> Optional[str]:
    """Why `cfg` cannot decode speculatively (None = statically eligible).

    Verification rides the chunked-prefill machinery, so the gate is the
    same cache-layout one: every segment's KV must live in the paged pool
    (multi-token verify writes straight into the slot's blocks and the
    rollback is a fill-count rewind).  Recurrent / ring / cross-attention
    state cannot rewind that way.  The engine additionally requires its
    runtime layout to be paged with dp == 1 (ModelRunner.supports_chunked).
    """
    if not cfg.vocab:
        return "no token vocabulary (encoder-only topology)"
    if cfg.has_ssm:
        return "recurrent SSM state cannot roll back rejected tokens"
    if cfg.sliding_window > 0:
        return ("sliding-window ring caches stay dense per-slot — no "
                "block-table fill count to rewind")
    if cfg.enc_schedule:
        return "encoder-decoder cross-attention memory is not paged"
    if cfg.n_patches:
        return "VLM patch prefixes are not supported in verify chunks"
    if cfg.rope_theta == 0:
        return "absolute-position (sinusoidal) models lack the chunk path"
    return None


def resolve_draft(spec: SpecConfig, cfg: ModelConfig) -> ModelConfig:
    """The draft ModelConfig for (spec, target): "self" / "auto" / a
    registered name, reduced alongside a reduced target, vocabulary
    checked against the target's (shared tokenizer is the contract that
    makes proposed ids comparable)."""
    from repro.configs import get_config, make_draft
    if spec.draft == "self":
        return cfg
    if spec.draft == "auto":
        return make_draft(cfg)
    draft = get_config(spec.draft)
    if cfg.name.endswith("-reduced") and not draft.name.endswith("-reduced"):
        draft = draft.reduced()
    if draft.vocab != cfg.vocab:
        raise ValueError(
            f"draft {draft.name} does not share the target's tokenizer: "
            f"vocab {draft.vocab} != {cfg.vocab} ({cfg.name})")
    return draft


def accept_length(proposed: Sequence[int], target: Sequence[int]) -> int:
    """Longest accepted prefix: the number of leading positions where the
    draft's proposal equals the target's own (deterministic) choice for
    that position.  `target[j]` is the target's token for the position
    `proposed[j]` claims; acceptance stops at the first disagreement."""
    n = 0
    for d, c in zip(proposed, target):
        if int(d) != int(c):
            break
        n += 1
    return n


def trim_emitted(emitted: List[int], *, room: int,
                 eos_id: Optional[int]) -> List[int]:
    """Clamp one round's committed tokens to non-speculative retirement
    semantics: at most `room` tokens (max_new_tokens / max_seq budget,
    pre-clamped by the caller), cut at the first EOS inclusive — exactly
    where step-by-step decoding would have stopped."""
    out = emitted[:max(room, 1)]
    if eos_id is not None and eos_id in out:
        out = out[:out.index(eos_id) + 1]
    return out


__all__ = ["SpecConfig", "DraftState", "spec_support_reason",
           "resolve_draft", "accept_length", "trim_emitted"]
