"""Serving telemetry: the paper's two regimes as first-class metrics.

`EngineStats` is the engine's live accumulator — NAR (prompt-encoding) and
AR (decode) token counts and wall time are tracked separately, mirroring the
paper's Sec. VI-A split, plus the serving-level signals every scheduler
decision needs: TTFT / queue-wait / decode-stall percentiles, decode-slot
occupancy, prefill length-bucket hit/compile counts, chunked-prefill
counters, and the per-task-class throughput split (generate vs encode —
the paper's decoder and encoder topologies sharing one engine).
`launch/serve.py` and `benchmarks/serving_bench.py` consume it instead of
print-scraping.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List


def percentile(values: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); 0.0 on empty input."""
    if not values:
        return 0.0
    s = sorted(values)
    rank = max(0, min(len(s) - 1, int(round(q / 100.0 * (len(s) - 1)))))
    return s[rank]


def percentiles(values: List[float],
                qs=(50, 95, 99)) -> Dict[str, float]:
    """{"p50": ..., "p95": ..., "p99": ...} in one sorted pass — THE
    percentile helper for stats properties, benchmarks and the load
    harness (replaces the per-call-site hand-rolled p50/p95 math)."""
    if not values:
        return {f"p{q:g}": 0.0 for q in qs}
    s = sorted(values)
    hi = len(s) - 1
    return {f"p{q:g}": s[max(0, min(hi, int(round(q / 100.0 * hi))))]
            for q in qs}


# Latency samples kept per metric, bounding a long-lived engine's memory.
MAX_SAMPLES = 4096


class Reservoir(List[float]):
    """Uniform reservoir sample (Algorithm R) that IS a list — callers
    that index, iterate, or len() a sample field keep working unchanged.

    The previous bound kept a sliding window of the most recent
    MAX_SAMPLES values, so long-run percentiles silently reflected only a
    slice of history.  Algorithm R keeps every seen value with equal
    probability capacity/seen: a late-arriving outlier is exactly as
    likely to appear in p99 as an early one.  Seeded => deterministic
    (two engines fed the same sample sequence hold identical
    reservoirs)."""

    def __init__(self, capacity: int = MAX_SAMPLES, seed: int = 0):
        super().__init__()
        assert capacity >= 1, capacity
        self.capacity = capacity
        self.seen = 0
        self._rng = random.Random(seed)

    def add(self, v: float) -> None:
        self.seen += 1
        if len(self) < self.capacity:
            self.append(v)
            return
        j = self._rng.randrange(self.seen)
        if j < self.capacity:
            self[j] = v


def _bounded_append(values: List[float], v: float) -> None:
    if isinstance(values, Reservoir):
        values.add(v)
        return
    # plain-list fallback (externally constructed stats): sliding window
    values.append(v)
    if len(values) > MAX_SAMPLES:
        del values[:len(values) - MAX_SAMPLES]


@dataclass
class EngineStats:
    batch_size: int = 0
    requests_submitted: int = 0
    requests_completed: int = 0
    # -- NAR (prompt encoding / prefill) ------------------------------------
    nar_tokens: int = 0            # true prompt tokens encoded
    padded_nar_tokens: int = 0     # incl. length-bucket padding computed
    nar_time_s: float = 0.0
    prefill_batches: int = 0       # whole-prompt prefill passes run
    # -- AR (decode) --------------------------------------------------------
    ar_tokens: int = 0             # tokens produced by decode steps
    ar_time_s: float = 0.0
    decode_steps: int = 0
    occupied_slot_steps: int = 0   # occupied decode-slot-steps (occupancy)
    decode_step_ms: List[float] = field(default_factory=Reservoir)
    # -- encoder-only (EncodeTask) ------------------------------------------
    encode_tokens: int = 0         # true tokens through pooled passes
    padded_encode_tokens: int = 0  # incl. length-bucket padding computed
    encode_time_s: float = 0.0
    encode_batches: int = 0        # batched pooled passes run
    encode_compiles: int = 0       # distinct (bucket, group, pooling) steps
    encode_latency_ms: List[float] = field(default_factory=Reservoir)
    # -- chunked prefill ----------------------------------------------------
    prefill_chunks: int = 0        # chunk steps run
    chunked_prefill_tokens: int = 0  # true prompt tokens through chunks
    # -- speculative decoding -----------------------------------------------
    spec_rounds: int = 0           # propose->verify->commit rounds run
    spec_slot_steps: int = 0       # decoding slots summed over rounds
    spec_proposed_tokens: int = 0  # draft tokens proposed
    spec_accepted_tokens: int = 0  # of those, accepted by the target
    spec_emitted_tokens: int = 0   # tokens committed by verify steps
    verify_positions: int = 0      # target positions executed by verify
    #                                passes (chain: sum of chunk lens;
    #                                tree: nodes incl. root per slot-round)
    spec_draft_time_s: float = 0.0  # wall time in draft propose phases
    draft_time_ms: List[float] = field(default_factory=Reservoir)
    # token-tree speculation (spec.branches > 1; zero under chain rounds)
    spec_tree_nodes: int = 0       # tree nodes verified (incl. root)
    spec_branch_hits: int = 0      # slot-rounds whose accepted path left
    #                                the draft's sampled chain
    spec_path_depth: List[float] = field(default_factory=Reservoir)  # accepted
    #                                root-path depth per slot-round
    # -- serving-level ------------------------------------------------------
    ttft_ms: List[float] = field(default_factory=Reservoir)
    queue_wait_ms: List[float] = field(default_factory=Reservoir)
    # gap between consecutive decode steps while slots were decoding: the
    # time running AR requests sat stalled behind admission work
    decode_stall_ms: List[float] = field(default_factory=Reservoir)
    bucket_hits: Dict[int, int] = field(default_factory=dict)
    prefill_compiles: int = 0      # distinct (bucket, group-size) compiled
    # -- paged KV pool ------------------------------------------------------
    kv_pool_blocks: int = 0        # pool capacity (0 = dense layout)
    kv_block_size: int = 0
    peak_blocks_used: int = 0
    preemptions: int = 0           # requests evicted to the queue (pool full)
    recompute_tokens: int = 0      # tokens re-prefilled after preemption
    recompute_time_s: float = 0.0  # prefill wall time spent on recomputes
    block_slot_steps: int = 0      # sum over decode steps of blocks in use
    token_slot_steps: int = 0      # sum over decode steps of live tokens
    # -- prefix cache (serving/prefix_cache.py) -----------------------------
    prefix_lookups: int = 0        # admission-time cache lookups
    prefix_hits: int = 0           # of those, lookups matching >= 1 token
    cached_prefix_tokens: int = 0  # prompt tokens served from cached blocks
    cached_blocks: int = 0         # blocks the index holds now (gauge)
    evicted_blocks: int = 0        # index blocks LRU-reclaimed by the pool
    cow_copies: int = 0            # shared blocks duplicated before a write
    # -- low-precision serving (models/quantize, PR 7) -----------------------
    weight_dtype: str = "bfloat16"  # GEMM weight storage ("int8" = quantized)
    kv_dtype: str = "bfloat16"      # paged-pool storage ("int8" = quantized)
    weight_bytes_per_device: int = 0  # resident param bytes (one device)
    kv_pool_bytes: int = 0            # resident cache bytes (one device)
    # -- goodput / SLO (serving/loadgen.py, DeadlinePolicy) ------------------
    slo_requests: int = 0          # finished requests that carried an SLO
    slo_met: int = 0               # of those, TTFT and TPOT budgets both met
    requests_shed: int = 0         # dropped unserved (SLO provably missed)
    requests_degraded: int = 0     # served with speculation disabled /
    #                                chunk budget shrunk (tokens unchanged)
    # TTFT / deadline per SLO'd request (< 1.0 = met); attainment
    # percentiles come from this window
    ttft_slo_ratio: List[float] = field(default_factory=Reservoir)
    tpot_ms_samples: List[float] = field(default_factory=Reservoir)
    # -- utilization attribution (serving/trace.py, analysis/roofline.py) ----
    # per-token constants the engine stamps at construction so phase_util()
    # can turn phase (time, token) sums into achieved MFU / MBU
    model_flops_per_token: float = 0.0  # analytic fwd FLOPs per position
    kv_bytes_per_token: float = 0.0     # KV bytes read per attended position
    # -- async overlapped host loop (engine overlap=True) --------------------
    overlapped_steps: int = 0      # decode steps whose token fetch was
    #                                deferred past host scheduling work
    overlap_host_s: float = 0.0    # host wall spent between dispatching a
    #                                step and fetching its tokens — work
    #                                hidden under device time

    # -- recorders (bounded: percentiles cover the recent MAX_SAMPLES) ------
    def add_ttft_ms(self, v: float) -> None:
        _bounded_append(self.ttft_ms, v)

    def add_decode_step_ms(self, v: float) -> None:
        _bounded_append(self.decode_step_ms, v)

    def add_queue_wait_ms(self, v: float) -> None:
        _bounded_append(self.queue_wait_ms, v)

    def add_decode_stall_ms(self, v: float) -> None:
        _bounded_append(self.decode_stall_ms, v)

    def add_encode_latency_ms(self, v: float) -> None:
        _bounded_append(self.encode_latency_ms, v)

    def add_draft_time_ms(self, v: float) -> None:
        _bounded_append(self.draft_time_ms, v)

    def add_spec_path_depth(self, v: float) -> None:
        _bounded_append(self.spec_path_depth, v)

    def add_tpot_ms(self, v: float) -> None:
        _bounded_append(self.tpot_ms_samples, v)

    def record_slo(self, task) -> None:
        """Score a finished task against its SLOs at retirement: TTFT vs
        `deadline_ms` (EncodeTasks score their end-to-end latency — their
        only response IS the first response) and mean TPOT vs
        `slo_tpot_ms`.  No-op for tasks that carry no SLO."""
        dl = getattr(task, "deadline_ms", None)
        tpot_budget = getattr(task, "slo_tpot_ms", None)
        if dl is None and tpot_budget is None:
            return
        self.slo_requests += 1
        met = True
        if dl is not None:
            ttft = getattr(task, "ttft_ms", 0.0) or task.latency_ms
            _bounded_append(self.ttft_slo_ratio, ttft / dl)
            met = met and ttft <= dl
        if tpot_budget is not None and len(getattr(task, "output", ())) > 1:
            met = met and task.tpot_ms <= tpot_budget
        if met:
            self.slo_met += 1

    def record_shed(self, task) -> None:
        """Account a shed request: counted against SLO attainment (an SLO
        the engine refused to attempt is an SLO missed)."""
        self.requests_shed += 1
        if (getattr(task, "deadline_ms", None) is not None
                or getattr(task, "slo_tpot_ms", None) is not None):
            self.slo_requests += 1

    # -- derived ------------------------------------------------------------
    @property
    def nar_tok_s(self) -> float:
        """NAR prompt-encoding throughput (true prompt tokens / s)."""
        return self.nar_tokens / self.nar_time_s if self.nar_time_s else 0.0

    @property
    def ar_tok_s(self) -> float:
        """AR decode throughput (generated tokens / s)."""
        return self.ar_tokens / self.ar_time_s if self.ar_time_s else 0.0

    @property
    def encode_tok_s(self) -> float:
        """Encoder-only throughput (true tokens through pooled passes / s) —
        the per-task-class split's encode side (generate side: nar/ar)."""
        return (self.encode_tokens / self.encode_time_s
                if self.encode_time_s else 0.0)

    @property
    def encode_completed(self) -> int:
        """EncodeTasks finished (== latency samples; bounded window)."""
        return len(self.encode_latency_ms)

    @property
    def spec_acceptance_rate(self) -> float:
        """Fraction of draft-proposed tokens the target accepted."""
        if not self.spec_proposed_tokens:
            return 0.0
        return self.spec_accepted_tokens / self.spec_proposed_tokens

    @property
    def spec_tokens_per_step(self) -> float:
        """Mean tokens committed per target decode step per decoding slot
        under speculation (non-speculative decoding sits at exactly 1.0;
        the k+1 upper bound is the all-accept round)."""
        if not self.spec_slot_steps:
            return 0.0
        return self.spec_emitted_tokens / self.spec_slot_steps

    @property
    def draft_time_ms_p50(self) -> float:
        return percentile(self.draft_time_ms, 50)

    @property
    def draft_time_ms_p95(self) -> float:
        return percentile(self.draft_time_ms, 95)

    @property
    def draft_time_ms_p99(self) -> float:
        return percentile(self.draft_time_ms, 99)

    @property
    def spec_path_depth_p50(self) -> float:
        return percentile(self.spec_path_depth, 50)

    @property
    def spec_path_depth_p95(self) -> float:
        return percentile(self.spec_path_depth, 95)

    @property
    def spec_path_depth_p99(self) -> float:
        return percentile(self.spec_path_depth, 99)

    @property
    def spec_branch_utilization(self) -> float:
        """Fraction of tree slot-rounds whose accepted path used a
        sibling branch (left the draft's sampled chain) — the share of
        rounds where the tree beat what the chain alone would have
        accepted.  0.0 under single-branch rounds."""
        if not self.spec_slot_steps:
            return 0.0
        return self.spec_branch_hits / self.spec_slot_steps

    @property
    def slot_occupancy(self) -> float:
        """Mean fraction of decode slots occupied per AR step."""
        total = self.decode_steps * self.batch_size
        return self.occupied_slot_steps / total if total else 0.0

    @property
    def padding_overhead(self) -> float:
        """Fraction of prefill compute spent on bucket padding."""
        if not self.padded_nar_tokens:
            return 0.0
        return 1.0 - self.nar_tokens / self.padded_nar_tokens

    @property
    def ttft_p50_ms(self) -> float:
        return percentile(self.ttft_ms, 50)

    @property
    def ttft_p95_ms(self) -> float:
        return percentile(self.ttft_ms, 95)

    @property
    def ttft_p99_ms(self) -> float:
        return percentile(self.ttft_ms, 99)

    @property
    def slo_attainment(self) -> float:
        """Fraction of SLO-carrying requests that met every budget
        (shed requests count as missed)."""
        if not self.slo_requests:
            return 0.0
        return self.slo_met / self.slo_requests

    @property
    def host_overlap_ratio(self) -> float:
        """Fraction of AR decode wall during which the host was doing
        scheduling/admission work concurrently with an in-flight device
        step (0.0 under the synchronous loop)."""
        if not self.ar_time_s:
            return 0.0
        return min(1.0, self.overlap_host_s / self.ar_time_s)

    @property
    def queue_wait_p50_ms(self) -> float:
        return percentile(self.queue_wait_ms, 50)

    @property
    def queue_wait_p95_ms(self) -> float:
        return percentile(self.queue_wait_ms, 95)

    @property
    def queue_wait_p99_ms(self) -> float:
        return percentile(self.queue_wait_ms, 99)

    @property
    def decode_step_p50_ms(self) -> float:
        return percentile(self.decode_step_ms, 50)

    @property
    def decode_step_p95_ms(self) -> float:
        return percentile(self.decode_step_ms, 95)

    @property
    def decode_step_p99_ms(self) -> float:
        return percentile(self.decode_step_ms, 99)

    @property
    def decode_stall_p50_ms(self) -> float:
        return percentile(self.decode_stall_ms, 50)

    @property
    def decode_stall_p95_ms(self) -> float:
        return percentile(self.decode_stall_ms, 95)

    @property
    def decode_stall_p99_ms(self) -> float:
        return percentile(self.decode_stall_ms, 99)

    @property
    def encode_latency_p50_ms(self) -> float:
        return percentile(self.encode_latency_ms, 50)

    @property
    def encode_latency_p95_ms(self) -> float:
        return percentile(self.encode_latency_ms, 95)

    @property
    def encode_latency_p99_ms(self) -> float:
        return percentile(self.encode_latency_ms, 99)

    @property
    def prefix_cache_hit_rate(self) -> float:
        """Fraction of admission lookups that matched a cached prefix."""
        if not self.prefix_lookups:
            return 0.0
        return self.prefix_hits / self.prefix_lookups

    @property
    def pool_utilization(self) -> float:
        """Peak fraction of the KV block pool in use (0.0 = dense layout)."""
        if not self.kv_pool_blocks:
            return 0.0
        return self.peak_blocks_used / self.kv_pool_blocks

    @property
    def blocks_per_token(self) -> float:
        """Mean pool *positions* held per live token across decode steps
        (>= 1.0; the excess is partial-tail-block fragmentation).  A dense
        [B, max_seq] layout would sit at B * max_seq / live tokens."""
        if not self.token_slot_steps:
            return 0.0
        return (self.block_slot_steps * self.kv_block_size
                / self.token_slot_steps)

    def phase_util(self) -> Dict[str, Dict[str, float]]:
        """Per-phase achieved MFU / MBU from the engine's own counters.

        Joins each phase's (busy time, token positions, weight passes, KV
        positions attended) with the per-token FLOP / byte constants the
        engine stamps at construction (analysis/roofline.py): useful
        FLOPs = flops_per_token * positions; HBM traffic = weight bytes *
        passes + KV bytes per token * attended positions.  Phases mirror
        the paper's NAR / AR split: "prefill" (whole-prompt + chunked +
        recompute passes), then "verify" under speculation or "decode"
        otherwise (an engine runs one AR mode per session), plus "encode"
        for encoder-only traffic.  {} when the FLOP constant is unknown
        (encoder-only config or externally built stats)."""
        if not self.model_flops_per_token:
            return {}
        from repro.analysis.roofline import utilization
        wbytes = float(self.weight_bytes_per_device)

        def row(time_s, tokens, passes, kv_positions):
            flops = self.model_flops_per_token * tokens
            hbm = wbytes * passes + self.kv_bytes_per_token * kv_positions
            mfu, mbu = utilization(flops, hbm, time_s)
            return {"time_s": time_s, "tokens": float(tokens),
                    "passes": float(passes),
                    "kv_positions": float(kv_positions),
                    "flops": flops, "hbm_bytes": hbm,
                    "mfu": mfu, "mbu": mbu}

        out: Dict[str, Dict[str, float]] = {}
        pre_t = self.nar_time_s + self.recompute_time_s
        if pre_t > 0:
            out["prefill"] = row(
                pre_t, self.padded_nar_tokens + self.recompute_tokens,
                self.prefill_batches + self.prefill_chunks,
                self.nar_tokens + self.recompute_tokens)
        if self.ar_time_s > 0:
            if self.spec_rounds:
                out["verify"] = row(self.ar_time_s, self.verify_positions,
                                    self.spec_rounds, self.token_slot_steps)
            else:
                out["decode"] = row(self.ar_time_s, self.ar_tokens,
                                    self.decode_steps, self.token_slot_steps)
        if self.encode_time_s > 0:
            out["encode"] = row(self.encode_time_s,
                                self.padded_encode_tokens,
                                self.encode_batches, 0)
        return out

    def to_dict(self) -> dict:
        """JSON-ready snapshot (benchmarks/serving_bench.py)."""
        return {
            "batch_size": self.batch_size,
            "requests_submitted": self.requests_submitted,
            "requests_completed": self.requests_completed,
            "nar_tokens": self.nar_tokens,
            "padded_nar_tokens": self.padded_nar_tokens,
            "nar_time_s": self.nar_time_s,
            "nar_tok_s": self.nar_tok_s,
            "ar_tokens": self.ar_tokens,
            "ar_time_s": self.ar_time_s,
            "ar_tok_s": self.ar_tok_s,
            "decode_steps": self.decode_steps,
            "slot_occupancy": self.slot_occupancy,
            "padding_overhead": self.padding_overhead,
            "encode_tokens": self.encode_tokens,
            "padded_encode_tokens": self.padded_encode_tokens,
            "encode_time_s": self.encode_time_s,
            "encode_tok_s": self.encode_tok_s,
            "encode_batches": self.encode_batches,
            "encode_compiles": self.encode_compiles,
            "encode_completed": self.encode_completed,
            **{f"encode_latency_{k}_ms": v
               for k, v in percentiles(self.encode_latency_ms).items()},
            "prefill_batches": self.prefill_batches,
            "prefill_chunks": self.prefill_chunks,
            "chunked_prefill_tokens": self.chunked_prefill_tokens,
            "spec_rounds": self.spec_rounds,
            "spec_proposed_tokens": self.spec_proposed_tokens,
            "spec_accepted_tokens": self.spec_accepted_tokens,
            "spec_emitted_tokens": self.spec_emitted_tokens,
            "spec_acceptance_rate": self.spec_acceptance_rate,
            "spec_tokens_per_step": self.spec_tokens_per_step,
            "spec_draft_time_s": self.spec_draft_time_s,
            "verify_positions": self.verify_positions,
            **{f"draft_time_ms_{k}": v
               for k, v in percentiles(self.draft_time_ms).items()},
            "spec_tree_nodes": self.spec_tree_nodes,
            "spec_branch_hits": self.spec_branch_hits,
            "spec_branch_utilization": self.spec_branch_utilization,
            **{f"spec_path_depth_{k}": v
               for k, v in percentiles(self.spec_path_depth).items()},
            **{f"ttft_{k}_ms": v
               for k, v in percentiles(self.ttft_ms).items()},
            "slo_requests": self.slo_requests,
            "slo_met": self.slo_met,
            "slo_attainment": self.slo_attainment,
            "requests_shed": self.requests_shed,
            "requests_degraded": self.requests_degraded,
            **{f"ttft_slo_ratio_{k}": v
               for k, v in percentiles(self.ttft_slo_ratio).items()},
            **{f"tpot_{k}_ms": v
               for k, v in percentiles(self.tpot_ms_samples).items()},
            "overlapped_steps": self.overlapped_steps,
            "overlap_host_s": self.overlap_host_s,
            "host_overlap_ratio": self.host_overlap_ratio,
            **{f"queue_wait_{k}_ms": v
               for k, v in percentiles(self.queue_wait_ms).items()},
            **{f"decode_step_{k}_ms": v
               for k, v in percentiles(self.decode_step_ms).items()},
            **{f"decode_stall_{k}_ms": v
               for k, v in percentiles(self.decode_stall_ms).items()},
            "bucket_hits": {str(k): v
                            for k, v in sorted(self.bucket_hits.items())},
            "prefill_compiles": self.prefill_compiles,
            "kv_pool_blocks": self.kv_pool_blocks,
            "kv_block_size": self.kv_block_size,
            "peak_blocks_used": self.peak_blocks_used,
            "pool_utilization": self.pool_utilization,
            "blocks_per_token": self.blocks_per_token,
            "preemptions": self.preemptions,
            "recompute_tokens": self.recompute_tokens,
            "recompute_time_s": self.recompute_time_s,
            "prefix_lookups": self.prefix_lookups,
            "prefix_hits": self.prefix_hits,
            "prefix_cache_hit_rate": self.prefix_cache_hit_rate,
            "cached_prefix_tokens": self.cached_prefix_tokens,
            "cached_blocks": self.cached_blocks,
            "evicted_blocks": self.evicted_blocks,
            "cow_copies": self.cow_copies,
            "weight_dtype": self.weight_dtype,
            "kv_dtype": self.kv_dtype,
            "weight_bytes_per_device": self.weight_bytes_per_device,
            "kv_pool_bytes": self.kv_pool_bytes,
            "model_flops_per_token": self.model_flops_per_token,
            "kv_bytes_per_token": self.kv_bytes_per_token,
            "phase_util": self.phase_util(),
        }

    def summary(self) -> str:
        pool = ""
        if self.kv_pool_blocks:
            pool = (f" | KV pool peak {self.pool_utilization:.0%} "
                    f"({self.peak_blocks_used}/{self.kv_pool_blocks} x "
                    f"{self.kv_block_size}-token blocks, "
                    f"{self.preemptions} preempt)")
        enc = ""
        if self.encode_batches:
            enc = (f" | ENC {self.encode_tok_s:8.1f} tok/s "
                   f"({self.encode_completed} reqs, p95 "
                   f"{self.encode_latency_p95_ms:.0f}ms)")
        chunk = ""
        if self.prefill_chunks:
            chunk = (f" | chunked {self.chunked_prefill_tokens} tok in "
                     f"{self.prefill_chunks} chunks, decode-stall p95 "
                     f"{self.decode_stall_p95_ms:.0f}ms")
        spec = ""
        if self.spec_rounds:
            tree = ""
            if self.spec_tree_nodes:
                tree = (f", tree {self.spec_tree_nodes} nodes, path p50 "
                        f"{self.spec_path_depth_p50:.1f} p95 "
                        f"{self.spec_path_depth_p95:.1f}, branch "
                        f"{self.spec_branch_utilization:.0%}")
            spec = (f" | SPEC {self.spec_acceptance_rate:.0%} accept, "
                    f"{self.spec_tokens_per_step:.2f} tok/step, draft p95 "
                    f"{self.draft_time_ms_p95:.1f}ms" + tree)
        quant = ""
        if self.weight_dtype != "bfloat16" or self.kv_dtype != "bfloat16":
            quant = (f" | QUANT w={self.weight_dtype} kv={self.kv_dtype}, "
                     f"params {self.weight_bytes_per_device / 2**20:.1f}MiB, "
                     f"pool {self.kv_pool_bytes / 2**20:.1f}MiB")
        slo = ""
        if self.slo_requests or self.requests_shed:
            r = percentiles(self.ttft_slo_ratio)
            slo = (f" | SLO {self.slo_attainment:.0%} met "
                   f"({self.slo_met}/{self.slo_requests}, "
                   f"ttft/deadline p50 {r['p50']:.2f} p99 {r['p99']:.2f}), "
                   f"{self.requests_shed} shed, "
                   f"{self.requests_degraded} degraded")
        ovl = ""
        if self.overlapped_steps:
            ovl = (f" | OVERLAP {self.overlapped_steps} steps, "
                   f"{self.host_overlap_ratio:.0%} host hidden")
        prefix = ""
        if self.prefix_lookups:
            prefix = (f" | PREFIX {self.prefix_cache_hit_rate:.0%} hit, "
                      f"{self.cached_prefix_tokens} tok reused, "
                      f"{self.cow_copies} COW, "
                      f"{self.evicted_blocks} evicted")
        return (f"NAR {self.nar_tok_s:8.1f} tok/s ({self.nar_tokens} prompt "
                f"tokens, {self.padding_overhead:.0%} pad) | "
                f"AR {self.ar_tok_s:8.1f} tok/s ({self.ar_tokens} tokens, "
                f"occupancy {self.slot_occupancy:.0%}) | "
                f"TTFT p50 {self.ttft_p50_ms:.0f}ms p95 "
                f"{self.ttft_p95_ms:.0f}ms"
                + enc + chunk + spec + quant + slo + ovl + prefix + pool)
