"""Task hierarchy for the serving stack.

A `Task` is *what a client wants*; the scheduler (serving/scheduler.py)
decides *when* it runs and the `ModelRunner` (serving/runner.py) decides
*how*.  Two concrete task classes cover the paper's two topologies:

  GenerateTask   decoder-LM request: NAR prefill + AR decode loop, streaming
                 tokens (subsumes the pre-split `Request` — that name stays
                 importable as an alias and every old field keeps working).
  EncodeTask     encoder-only request: one NAR full-sequence forward pass
                 (the paper's 12.8x-speedup topology), returning a pooled
                 embedding — no KV cache, no decode slot, no AR steps.

Both carry `priority` (higher = more urgent; only PriorityPolicy looks at
it) and `deadline_ms` (advisory latency budget from submission; exposed to
policies for deadline-aware ordering, never enforced by the engine).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.serving.sampling import SamplingParams


def _require_keyword_prompt(task: "Task") -> None:
    """The Task base fields (priority, deadline_ms, ...) sit between `uid`
    and the subclass' `prompt`, so the pre-split `Request(0, tokens)`
    positional form would silently land the prompt in `priority` — fail
    loudly instead of misbehaving later."""
    if task.prompt is None:
        extra = ""
        if isinstance(task.priority, np.ndarray):
            extra = (" (an array landed in `priority`: positional "
                     "construction is no longer supported)")
        raise TypeError(
            f"{type(task).__name__} requires `prompt`; pass fields by "
            f"keyword, e.g. {type(task).__name__}(uid=0, prompt=tokens)"
            + extra)


@dataclass
class Task:
    """Common serving-request state.  `uid` must be unique per engine."""
    uid: int
    priority: int = 0                   # higher = scheduled sooner (policy)
    deadline_ms: Optional[float] = None  # advisory latency budget (policy)
    # filled by the engine:
    prompt_len: int = 0                 # true token length (set at submit)
    bucket: int = 0                     # padded batch length (set at admit)
    queue_wait_ms: float = 0.0          # submit -> first admission
    done: bool = False
    _t_submit: float = field(default=0.0, repr=False)
    _seq: int = field(default=0, repr=False)   # admission order (preemption)

    def age_s(self, now: Optional[float] = None) -> float:
        """Seconds this task has been waiting since submission."""
        return max(0.0, (now if now is not None else time.perf_counter())
                   - self._t_submit)


@dataclass
class GenerateTask(Task):
    """Decoder-LM request: prefill the prompt, then decode up to
    `max_new_tokens` AR steps (stopping early on `eos_id`)."""
    prompt: np.ndarray = None           # [S_prompt] int32, any length
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    sampling: SamplingParams = field(default_factory=SamplingParams)
    # filled by the engine:
    output: List[int] = field(default_factory=list)
    prefill_ms: float = 0.0             # amortized share of group prefills
    decode_ms: float = 0.0
    ttft_ms: float = 0.0                # submit -> first token
    # chunked-prefill progress: prompt tokens whose KV is already in the
    # cache (0 = not admitted / whole-prompt prefill; == full length once
    # the final chunk lands and the first token is sampled)
    prefilled: int = 0
    # prompt tokens served from the prefix cache at the most recent
    # admission (0 = cold); the suffix actually encoded was
    # prompt_len - cached_prefix
    cached_prefix: int = 0

    def __post_init__(self):
        _require_keyword_prompt(self)

    def remaining_prefill(self) -> int:
        return self.prompt_len + len(self.output) - self.prefilled


@dataclass
class EncodeTask(Task):
    """Encoder-only request: one full-sequence forward, pooled output.

    pooling   "last" — residual of the final true position (causal-LM
                        sentence embedding; equals the hidden state a
                        prefill would sample from)
              "mean" — masked mean over the true positions (BERT-style)
    """
    prompt: np.ndarray = None           # [S_prompt] int32, any length
    pooling: str = "last"
    # filled by the engine:
    embedding: Optional[np.ndarray] = None   # [d_model] float32 result
    encode_ms: float = 0.0              # amortized share of the batched pass
    latency_ms: float = 0.0             # submit -> result

    def __post_init__(self):
        _require_keyword_prompt(self)
        if self.pooling not in ("last", "mean"):
            raise ValueError(f"pooling must be 'last' or 'mean': "
                             f"{self.pooling!r}")


# The pre-split engine exposed a single `Request` class; it was exactly
# today's GenerateTask.  Old call sites (serve.py traces, tests, benches)
# construct it with the same keyword fields and keep working unmodified.
Request = GenerateTask


@dataclass(frozen=True)
class TokenEvent:
    """One streamed token: emitted by `InferenceEngine.generate()` the
    moment the engine step that produced it completes."""
    uid: int
    token: int
    is_last: bool
