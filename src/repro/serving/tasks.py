"""Task hierarchy for the serving stack.

A `Task` is *what a client wants*; the scheduler (serving/scheduler.py)
decides *when* it runs and the `ModelRunner` (serving/runner.py) decides
*how*.  Two concrete task classes cover the paper's two topologies:

  GenerateTask   decoder-LM request: NAR prefill + AR decode loop, streaming
                 tokens (subsumes the pre-split `Request` — that name stays
                 importable as an alias and every old field keeps working).
  EncodeTask     encoder-only request: one NAR full-sequence forward pass
                 (the paper's 12.8x-speedup topology), returning a pooled
                 embedding — no KV cache, no decode slot, no AR steps.

Both carry `priority` (higher = more urgent; only PriorityPolicy looks at
it) and `deadline_ms` (TTFT latency budget from submission: DeadlinePolicy
orders admission by deadline slack and sheds requests that provably cannot
meet it — see serving/scheduler.py; other policies treat it as advisory).
GenerateTasks additionally carry `slo_tpot_ms`, a per-output-token budget
checked at retirement for SLO-attainment accounting (never scheduled on).
Unservable values fail loudly: `validate_task` runs at construction AND at
`Engine.submit`, mirroring sampling.validate_sampling.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.serving.sampling import SamplingParams


def validate_task(task: "Task") -> None:
    """Reject unservable `priority` / `deadline_ms` / `slo_tpot_ms` values
    with a clear ValueError instead of silently accepting them (a NaN
    priority poisons every policy sort; a zero/negative deadline would shed
    instantly).  Called from Task.__post_init__ AND Engine.submit — the
    latter covers tasks mutated or `dataclasses.replace`d after
    construction."""
    try:
        p = float(task.priority)
    except (TypeError, ValueError):
        raise ValueError(
            f"priority must be a real number (higher = more urgent): "
            f"{task.priority!r}")
    if math.isnan(p) or math.isinf(p):
        raise ValueError(
            f"priority must be finite (NaN/inf break policy ordering): "
            f"{task.priority!r}")
    for name in ("deadline_ms", "slo_tpot_ms"):
        v = getattr(task, name, None)
        if v is None:
            continue
        try:
            f = float(v)
        except (TypeError, ValueError):
            raise ValueError(
                f"{name} must be a positive finite millisecond budget "
                f"or None (no SLO): {v!r}")
        if math.isnan(f) or math.isinf(f) or f <= 0:
            raise ValueError(
                f"{name} must be > 0 and finite; got {v!r} "
                f"(use None for no SLO — 0 would mean 'already missed')")


@dataclass(frozen=True)
class Rejection:
    """Typed reason a request was shed instead of served.  Attached to
    `task.rejection` (with `done=True`, empty output) when the scheduler
    proves the SLO unattainable and the engine drops the request rather
    than burn prefill/decode capacity on a guaranteed miss."""
    kind: str       # machine-readable, e.g. "slo_unattainable"
    detail: str     # human-readable explanation


def _require_keyword_prompt(task: "Task") -> None:
    """The Task base fields (priority, deadline_ms, ...) sit between `uid`
    and the subclass' `prompt`, so the pre-split `Request(0, tokens)`
    positional form would silently land the prompt in `priority` — fail
    loudly instead of misbehaving later."""
    if task.prompt is None:
        extra = ""
        if isinstance(task.priority, np.ndarray):
            extra = (" (an array landed in `priority`: positional "
                     "construction is no longer supported)")
        raise TypeError(
            f"{type(task).__name__} requires `prompt`; pass fields by "
            f"keyword, e.g. {type(task).__name__}(uid=0, prompt=tokens)"
            + extra)


@dataclass
class Task:
    """Common serving-request state.  `uid` must be unique per engine."""
    uid: int
    priority: int = 0                   # higher = scheduled sooner (policy)
    deadline_ms: Optional[float] = None  # advisory latency budget (policy)
    # filled by the engine:
    prompt_len: int = 0                 # true token length (set at submit)
    bucket: int = 0                     # padded batch length (set at admit)
    queue_wait_ms: float = 0.0          # submit -> first admission
    done: bool = False
    # set (with done=True) when the scheduler sheds this request instead of
    # serving it; None for every served request
    rejection: Optional[Rejection] = None
    _t_submit: float = field(default=0.0, repr=False)
    _seq: int = field(default=0, repr=False)   # admission order (preemption)

    def age_s(self, now: Optional[float] = None) -> float:
        """Seconds this task has been waiting since submission."""
        return max(0.0, (now if now is not None else time.perf_counter())
                   - self._t_submit)

    def slack_ms(self, now: Optional[float] = None) -> float:
        """Milliseconds of deadline budget left (negative = already
        missed); +inf when the task has no deadline."""
        if self.deadline_ms is None:
            return math.inf
        return self.deadline_ms - self.age_s(now) * 1e3


@dataclass
class GenerateTask(Task):
    """Decoder-LM request: prefill the prompt, then decode up to
    `max_new_tokens` AR steps (stopping early on `eos_id`)."""
    prompt: np.ndarray = None           # [S_prompt] int32, any length
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    sampling: SamplingParams = field(default_factory=SamplingParams)
    # per-output-token latency budget (TPOT SLO, ms/token beyond the
    # first); checked at retirement for goodput accounting, never scheduled
    slo_tpot_ms: Optional[float] = None
    # filled by the engine:
    output: List[int] = field(default_factory=list)
    prefill_ms: float = 0.0             # amortized share of group prefills
    decode_ms: float = 0.0
    ttft_ms: float = 0.0                # submit -> first token
    latency_ms: float = 0.0             # submit -> retirement (e2e)
    tpot_ms: float = 0.0                # (latency - ttft) / (tokens - 1)
    # True once the engine served this request in degraded mode (admitted
    # under pressure: speculation off for this request, chunk budget
    # shrunk engine-wide) — degrade never changes tokens, only latency
    degraded: bool = False
    # chunked-prefill progress: prompt tokens whose KV is already in the
    # cache (0 = not admitted / whole-prompt prefill; == full length once
    # the final chunk lands and the first token is sampled)
    prefilled: int = 0
    # prompt tokens served from the prefix cache at the most recent
    # admission (0 = cold); the suffix actually encoded was
    # prompt_len - cached_prefix
    cached_prefix: int = 0

    def __post_init__(self):
        _require_keyword_prompt(self)
        validate_task(self)

    def remaining_prefill(self) -> int:
        return self.prompt_len + len(self.output) - self.prefilled


@dataclass
class EncodeTask(Task):
    """Encoder-only request: one full-sequence forward, pooled output.

    pooling   "last" — residual of the final true position (causal-LM
                        sentence embedding; equals the hidden state a
                        prefill would sample from)
              "mean" — masked mean over the true positions (BERT-style)
    """
    prompt: np.ndarray = None           # [S_prompt] int32, any length
    pooling: str = "last"
    # filled by the engine:
    embedding: Optional[np.ndarray] = None   # [d_model] float32 result
    encode_ms: float = 0.0              # amortized share of the batched pass
    latency_ms: float = 0.0             # submit -> result

    def __post_init__(self):
        _require_keyword_prompt(self)
        validate_task(self)
        if self.pooling not in ("last", "mean"):
            raise ValueError(f"pooling must be 'last' or 'mean': "
                             f"{self.pooling!r}")


# The pre-split engine exposed a single `Request` class; it was exactly
# today's GenerateTask.  Old call sites (serve.py traces, tests, benches)
# construct it with the same keyword fields and keep working unmodified.
Request = GenerateTask


@dataclass(frozen=True)
class TokenEvent:
    """One streamed token: emitted by `InferenceEngine.generate()` the
    moment the engine step that produced it completes."""
    uid: int
    token: int
    is_last: bool
