from repro.serving.engine import (InferenceEngine, Request, ServingEngine,
                                  TokenEvent)
from repro.serving.sampling import GREEDY, SamplingParams
from repro.serving.stats import EngineStats
