from repro.serving.engine import InferenceEngine, ServingEngine
from repro.serving.kv_cache import BlockAllocator
from repro.serving.prefix_cache import PrefixCache
from repro.serving.runner import ModelRunner
from repro.serving.sampling import GREEDY, SamplingParams, validate_sampling
from repro.serving.scheduler import (ChunkedPrefillPolicy, FCFSPolicy,
                                     PriorityPolicy, SchedulerPolicy,
                                     make_policy)
from repro.serving.spec import (DraftState, SpecConfig, resolve_draft,
                                spec_support_reason)
from repro.serving.stats import EngineStats
from repro.serving.tasks import (EncodeTask, GenerateTask, Request, Task,
                                 TokenEvent)
