from repro.serving.engine import ServingEngine, Request
