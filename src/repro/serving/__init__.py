from repro.serving.engine import InferenceEngine, ServingEngine
from repro.serving.kv_cache import BlockAllocator
from repro.serving.loadgen import (ArrivalSpec, LoadSpec, PromptSpec,
                                   SLOSpec, TimedTask, arrival_times,
                                   make_trace, replay)
from repro.serving.prefix_cache import PrefixCache
from repro.serving.runner import DecodeHandle, ModelRunner
from repro.serving.sampling import GREEDY, SamplingParams, validate_sampling
from repro.serving.scheduler import (ChunkedPrefillPolicy, DeadlinePolicy,
                                     FCFSPolicy, PriorityPolicy,
                                     SchedulerPolicy, make_policy)
from repro.serving.spec import (DraftState, SpecConfig, resolve_draft,
                                spec_support_reason)
from repro.serving.stats import (EngineStats, Reservoir, percentile,
                                 percentiles)
from repro.serving.tasks import (EncodeTask, GenerateTask, Rejection,
                                 Request, Task, TokenEvent, validate_task)
from repro.serving.trace import (Tracer, derive_phase_metrics,
                                 prometheus_text, validate_chrome_trace)
