"""KV-cache utilities for the serving engine.

The cache *layout* (ring vs linear, sequence sharding) is owned by
launch/steps.cache_layout; this module materializes zero-initialized caches
and provides the row-scatter used by continuous batching (inserting one
freshly-prefilled request into an existing decode batch).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def zero_caches(cache_struct, shardings=None):
    """Materialize zeroed caches matching the struct tree (optionally with
    shardings — the decode step's cache specs)."""
    def mk(st, sh):
        if sh is None:
            return jnp.zeros(st.shape, st.dtype)
        return jax.jit(lambda: jnp.zeros(st.shape, st.dtype),
                       out_shardings=sh)()
    if shardings is None:
        return jax.tree.map(lambda st: jnp.zeros(st.shape, st.dtype),
                            cache_struct)
    return jax.tree.map(mk, cache_struct, shardings)


@jax.jit
def insert_row(batch_caches, single_caches, row: int):
    """Scatter a single-request cache (batch dim 1) into row `row` of the
    batch caches.  Cache leaves are [count, B, ...]: dim 1 is the batch."""
    def ins(b, s):
        return jax.lax.dynamic_update_slice_in_dim(b, s.astype(b.dtype),
                                                   row, axis=1)
    return jax.tree.map(ins, batch_caches, single_caches)
