"""KV-cache utilities for the serving engine.

The cache *layout* (paged pools vs dense rows, ring vs linear, sequence
sharding) is owned by launch/steps.cache_layout; this module materializes
zero-initialized caches and provides the host-side paging machinery:

  BlockAllocator       free-list over a global pool of fixed-size KV blocks;
                       the engine owns one per decode batch and keeps a
                       per-slot block table of the blocks each request holds.
  make_prefill_scatter jitted admission scatter: a freshly prefilled group's
                       compact KV goes straight into its assigned pool
                       blocks (per-block scatter), while dense leaves (SSM
                       state, ring caches, cross-attn memory) row-scatter
                       into the group's slots — no B x max_seq
                       dynamic_update_slice ever runs.
  insert_row           legacy single-row scatter (dense layouts).
"""
from __future__ import annotations

import functools
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.core.attention import SCALE_EPS

# One compiled zeros-builder per (shape, dtype, sharding) leaf, shared by
# every engine construction in the process — re-jitting a fresh lambda per
# leaf per call would recompile each time (the keys here are hashable, the
# struct pytrees are not, so the cache lives at leaf granularity).
_ZEROS_CACHE: dict = {}


def _zeros(shape, dtype, sharding=None):
    key = (tuple(shape), jnp.dtype(dtype).str, sharding)
    fn = _ZEROS_CACHE.get(key)
    if fn is None:
        build = functools.partial(jnp.zeros, tuple(shape), dtype)
        fn = (jax.jit(build) if sharding is None
              else jax.jit(build, out_shardings=sharding))
        _ZEROS_CACHE[key] = fn
    return fn()


def zero_caches(cache_struct, shardings=None):
    """Materialize zeroed caches matching the struct tree (optionally with
    shardings — the decode step's cache specs)."""
    if shardings is None:
        return jax.tree.map(lambda st: _zeros(st.shape, st.dtype),
                            cache_struct)
    return jax.tree.map(lambda st, sh: _zeros(st.shape, st.dtype, sh),
                        cache_struct, shardings)


@jax.jit
def insert_row(batch_caches, single_caches, row: int):
    """Scatter a single-request cache (batch dim 1) into row `row` of the
    batch caches.  Cache leaves are [count, B, ...]: dim 1 is the batch."""
    def ins(b, s):
        return jax.lax.dynamic_update_slice_in_dim(b, s.astype(b.dtype),
                                                   row, axis=1)
    return jax.tree.map(ins, batch_caches, single_caches)


# --------------------------------------------------------------------------
# block-paged pool
# --------------------------------------------------------------------------

class BlockAllocator:
    """Host-side refcounted free-list over a global pool of `num_blocks` KV
    blocks of `block_size` tokens each.  The engine allocates
    ceil(tokens / bs) blocks at admission, one more whenever a slot's decode
    position crosses a block boundary, and releases a request's blocks the
    moment it retires (or is preempted back to the queue) — pool occupancy
    tracks *live tokens*, not slots x max_seq.

    Refcounts (serving/prefix_cache.py): a block may be shared by several
    holders — decode slots reusing a cached prompt prefix, plus the prefix
    cache's radix index itself.  `alloc` hands out blocks at refcount 1,
    `retain` adds a holder, and `free` drops one — the block returns to the
    free list only when the last holder lets go.  When the free list cannot
    satisfy an `alloc`, the optional `reclaim` hook (the prefix cache's LRU
    evictor) is asked to release index-only blocks first, so cached prefixes
    survive exactly as long as the pool has room for them (lazy eviction
    replaces the pre-cache eager free).

    Invariant guards raise `RuntimeError` (not `assert`) so double frees and
    stale retains stay fatal under `python -O`; the free-set mirror makes the
    membership check O(1)."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1 or block_size < 1:
            raise ValueError(f"pool needs >= 1 block of >= 1 token: "
                             f"({num_blocks}, {block_size})")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # LIFO free list: freshly freed blocks are reused first (their pool
        # slots are the warmest in cache); the set mirrors it for O(1)
        # membership checks
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._free_set = set(self._free)
        self._ref: List[int] = [0] * num_blocks
        self.peak_used = 0
        # lazy-reclaim hook: called with the shortfall when alloc() would
        # otherwise fail; returns how many blocks it pushed back to the
        # free list (serving/prefix_cache.py registers its LRU evictor)
        self.reclaim = None

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.num_blocks - len(self._free)

    def refcount(self, block: int) -> int:
        """Live holder count for `block` (0 = on the free list)."""
        return self._ref[block]

    def blocks_for(self, tokens: int) -> int:
        """Blocks needed to hold `tokens` cache positions."""
        return -(-tokens // self.block_size)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop `n` blocks at refcount 1, or None (allocation is
        all-or-nothing) when the pool cannot satisfy the request even after
        asking `reclaim` to evict cached blocks."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free) and self.reclaim is not None:
            self.reclaim(n - len(self._free))
        if n > len(self._free):
            return None
        out = []
        for _ in range(n):
            b = self._free.pop()
            self._free_set.discard(b)
            self._ref[b] = 1
            out.append(b)
        self.peak_used = max(self.peak_used, self.num_used)
        return out

    def retain(self, blocks: List[int]) -> None:
        """Add one holder to each allocated block (prefix-cache sharing)."""
        for b in blocks:
            if self._ref[b] <= 0:
                raise RuntimeError(f"retain of unallocated block {b}")
            self._ref[b] += 1

    def free(self, blocks: List[int]) -> None:
        """Drop one holder from each block; a block whose last holder lets
        go returns to the free list."""
        if len(set(blocks)) != len(blocks):
            raise RuntimeError(f"double free within batch: {blocks}")
        for b in blocks:
            if b in self._free_set or self._ref[b] <= 0:
                raise RuntimeError(f"double free of block {b}")
        for b in blocks:
            self._ref[b] -= 1
            if self._ref[b] == 0:
                self._free.append(b)
                self._free_set.add(b)


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("paged_segments", "block_size"))
def _prefill_scatter(caches, group_caches, slots, tables, *,
                     paged_segments, block_size: int):
    out = []
    for seg, new, paged in zip(caches, group_caches, paged_segments):
        d = {}
        quantized = paged and "ks" in seg
        for key, leaf in seg.items():
            if key in ("ks", "vs"):
                continue                 # written alongside their pools
            val = new[key]
            if paged and key in ("k", "v"):
                nb_pool = leaf.shape[1]
                n, S = val.shape[1], val.shape[2]
                ne = -(-S // block_size)            # entries this group fills
                pad = ne * block_size - S
                if pad:
                    val = jnp.pad(
                        val, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                val = val.reshape(val.shape[0], n, ne, block_size,
                                  *val.shape[3:])
                ids = tables[:, :ne]
                # -1 wraps in .at[]; route out of range so "drop" applies
                ids = jnp.where(ids >= 0, ids, nb_pool)
                if quantized:
                    # admission covers every written block from offset 0, so
                    # each block's scale is simply the per-head amax of the
                    # tokens landing in it (pad positions are zero — inert).
                    # The prefill step itself ran bf16: quantization happens
                    # once, here, on admission into the pool.
                    xf = val.astype(jnp.float32)
                    amax = jnp.max(jnp.abs(xf), axis=(3, 5))
                    s = jnp.maximum(amax, SCALE_EPS) / 127.0
                    q = jnp.clip(jnp.round(xf / s[:, :, :, None, :, None]),
                                 -127, 127).astype(jnp.int8)
                    d[key] = leaf.at[:, ids].set(q, mode="drop")
                    sk = key + "s"
                    d[sk] = seg[sk].at[:, ids].set(s, mode="drop")
                else:
                    d[key] = leaf.at[:, ids].set(val.astype(leaf.dtype),
                                                 mode="drop")
            else:
                d[key] = leaf.at[:, slots].set(val.astype(leaf.dtype))
        out.append(d)
    return tuple(out)


def make_prefill_scatter(paged_segments, block_size: int):
    """The jitted admission scatter for one engine layout.

    scatter(caches, group_caches, slots, tables) -> caches

      caches        the live decode cache pytree (paged segments: k/v pools
                    [count, NB, BS, KV, hd]; donated — updated in place)
      group_caches  a prefilled admission group's compact caches (paged
                    leaves [count, nB, S, KV, hd] at prompt length; dense
                    leaves [count, nB, ...])
      slots         [nB] int32 decode-slot index per group row
      tables        [nB, MB] int32 assigned pool blocks in sequence order
                    (-1 beyond the allocation)

    Paged k/v leaves scatter per assigned block; every other leaf scatters
    per slot row.  The jit lives at module level with the layout as static
    args, so compiles (one per (nB, prompt-length) shape) are shared across
    engine constructions."""
    return functools.partial(_prefill_scatter,
                             paged_segments=tuple(bool(p)
                                                  for p in paged_segments),
                             block_size=block_size)


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("paged_segments",))
def _block_copy(caches, src, dst, *, paged_segments):
    out = []
    for seg, paged in zip(caches, paged_segments):
        d = dict(seg)
        if paged:
            for key in ("k", "v", "ks", "vs"):
                if key not in d:
                    continue
                leaf = d[key]       # pools [count, NB, BS, KV, hd];
                #                     scales [count, NB, KV] — dim 1 is NB
                #                     for both, so one copy path serves them
                row = jax.lax.dynamic_index_in_dim(leaf, src, axis=1,
                                                   keepdims=True)
                d[key] = jax.lax.dynamic_update_slice_in_dim(leaf, row, dst,
                                                             axis=1)
        out.append(d)
    return tuple(out)


def make_block_copy(paged_segments):
    """The jitted copy-on-write block duplicator for one engine layout.

    copy(caches, src, dst) -> caches

    Copies pool block `src` into pool block `dst` across every paged k/v
    leaf (dense leaves pass through untouched).  The prefix cache calls this
    before a slot writes into a *shared* block — a partially filled tail
    whose content other holders (the radix index, or another slot) still
    depend on — so the writer mutates its private duplicate instead.
    `src`/`dst` are traced scalars: one compile serves every block pair."""
    segs = tuple(bool(p) for p in paged_segments)

    def copy(caches, src, dst):
        return _block_copy(caches, jnp.asarray(src, jnp.int32),
                           jnp.asarray(dst, jnp.int32), paged_segments=segs)

    return copy


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("paged_segments",))
def _row_copy(caches, src_blk, src_off, dst_blk, dst_off, *, paged_segments):
    out = []
    for seg, paged in zip(caches, paged_segments):
        d = dict(seg)
        if paged:
            for key in ("k", "v"):      # NOT ks/vs: per-block int8 scales
                #                         cannot move one row at a time
                if key not in d:
                    continue
                leaf = d[key]           # [count, NB, BS, KV, hd]
                count, _, _, kv, hd = leaf.shape
                row = jax.lax.dynamic_slice(
                    leaf, (0, src_blk, src_off, 0, 0),
                    (count, 1, 1, kv, hd))
                d[key] = jax.lax.dynamic_update_slice(
                    leaf, row, (0, dst_blk, dst_off, 0, 0))
        out.append(d)
    return tuple(out)


def make_row_copy(paged_segments):
    """The jitted single-position KV mover for one engine layout.

    copy(caches, src_blk, src_off, dst_blk, dst_off) -> caches

    Copies ONE cache position — (block, in-block offset) — across every
    paged k/v pool leaf.  Tree-speculative commit uses this to compact the
    accepted root path's KV into the slot's canonical positions: tree
    nodes scatter their KV at pos0 + node_index (unique per node), but the
    committed sequence needs depth-d's KV at pos0 + d.  Rope was applied
    at the node's LOGICAL position (pos0 + depth) during verify, so the
    move is a pure byte relocation — no re-rotation.  Sources sit strictly
    above their destinations in flatten order, and copies run in
    increasing depth, so moves never clobber a pending source.  int8 pools
    are excluded at the runner level (per-block scales pin entries to
    their block), not here.  All four indices are traced scalars: one
    compile serves every move."""
    segs = tuple(bool(p) for p in paged_segments)

    def copy(caches, src_blk, src_off, dst_blk, dst_off):
        return _row_copy(caches, jnp.asarray(src_blk, jnp.int32),
                         jnp.asarray(src_off, jnp.int32),
                         jnp.asarray(dst_blk, jnp.int32),
                         jnp.asarray(dst_off, jnp.int32),
                         paged_segments=segs)

    return copy
