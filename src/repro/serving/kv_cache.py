"""KV-cache utilities for the serving engine.

The cache *layout* (ring vs linear, sequence sharding) is owned by
launch/steps.cache_layout; this module materializes zero-initialized caches
and provides the row-scatter used by continuous batching (inserting one
freshly-prefilled request into an existing decode batch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# One compiled zeros-builder per (shape, dtype, sharding) leaf, shared by
# every engine construction in the process — re-jitting a fresh lambda per
# leaf per call would recompile each time (the keys here are hashable, the
# struct pytrees are not, so the cache lives at leaf granularity).
_ZEROS_CACHE: dict = {}


def _zeros(shape, dtype, sharding=None):
    key = (tuple(shape), jnp.dtype(dtype).str, sharding)
    fn = _ZEROS_CACHE.get(key)
    if fn is None:
        build = functools.partial(jnp.zeros, tuple(shape), dtype)
        fn = (jax.jit(build) if sharding is None
              else jax.jit(build, out_shardings=sharding))
        _ZEROS_CACHE[key] = fn
    return fn()


def zero_caches(cache_struct, shardings=None):
    """Materialize zeroed caches matching the struct tree (optionally with
    shardings — the decode step's cache specs)."""
    if shardings is None:
        return jax.tree.map(lambda st: _zeros(st.shape, st.dtype),
                            cache_struct)
    return jax.tree.map(lambda st, sh: _zeros(st.shape, st.dtype, sh),
                        cache_struct, shardings)


@jax.jit
def insert_row(batch_caches, single_caches, row: int):
    """Scatter a single-request cache (batch dim 1) into row `row` of the
    batch caches.  Cache leaves are [count, B, ...]: dim 1 is the batch."""
    def ins(b, s):
        return jax.lax.dynamic_update_slice_in_dim(b, s.astype(b.dtype),
                                                   row, axis=1)
    return jax.tree.map(ins, batch_caches, single_caches)
