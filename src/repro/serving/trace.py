"""Request-level tracing + MFU/MBU attribution for the serving engine.

The paper's headline results are *attribution* results — per-phase cycle
breakdowns and FPU-utilization numbers that explain where every cycle
goes.  `EngineStats` aggregates; this module answers the per-request and
per-interval questions aggregates cannot: why was request #1743 slow
(queued? shed? preempted? degraded?), and what was decode MFU during the
bursty window?

`Tracer` is a low-overhead structured tracer:

  request rows (pid 2, tid = uid)   lifecycle spans and instants —
      submit -> queue -> (warm_hit | degrade | preempt | shed) ->
      first_token -> retire ("request" span).  Policy decisions annotate
      the spans: EDF slack at admission, shed reason, degrade rung, COW
      copies, cached-prefix length, tree accept depth.
  engine row (pid 1, tid 0)         per-step spans — prefill / chunk /
      decode (dispatch vs commit split, overlap lag, batch composition) /
      draft / verify / encode, plus one "engine_step" wrapper per engine
      iteration.

Events land in a bounded ring buffer (deque, drop-oldest) and export as
Chrome trace-event JSON (`chrome_trace` / `write`) viewable at
https://ui.perfetto.dev, or as a flat Prometheus-style text snapshot of
an `EngineStats.to_dict()` (`prometheus_text`).

Tracing is OPT-IN with a no-op fast path: every hook site in
engine.py/runner.py guards on a single `if tracer:` branch (`__bool__` is
the enabled flag), so a disabled or absent tracer costs one falsy check
per hook and records nothing — token outputs are identical either way
(hooks are pure observers).

`derive_phase_metrics` joins the step spans with the analysis/roofline.py
FLOP/byte model to report achieved MFU/MBU per serving phase (prefill vs
decode vs verify); `EngineStats.phase_util()` computes the same
attribution from counters alone (no tracer needed), and the two agree on
traced runs (tests/test_trace.py).

CLI validator (the CI artifact gate):

    PYTHONPATH=src python -m repro.serving.trace TRACE.json
"""
from __future__ import annotations

import json
import time
from collections import deque
from typing import Dict, Iterable, List, Optional

# Chrome trace-event process rows: one for engine steps, one per-request.
PID_ENGINE = 1
PID_REQUEST = 2


class Tracer:
    """Bounded ring-buffer tracer for one engine instance.

    `capacity` bounds the buffer (drop-oldest beyond it; `dropped` counts
    the evictions so a truncated artifact is never mistaken for a complete
    one).  Timestamps are `time.perf_counter()` values converted to
    microseconds relative to the tracer's construction epoch — the same
    clock every EngineStats latency uses, so trace-derived TTFT/TPOT
    reconstruct the stats to within float rounding.
    """

    def __init__(self, capacity: int = 65536, enabled: bool = True):
        assert capacity >= 1, capacity
        self.capacity = capacity
        self.enabled = enabled
        self.epoch = time.perf_counter()
        self._ring: deque = deque(maxlen=capacity)
        self.dropped = 0

    def __bool__(self) -> bool:
        # THE no-op fast path: every hook site is `if tracer: ...`, so a
        # disabled tracer (or None) costs one falsy check and nothing else
        return self.enabled

    @property
    def events(self) -> List[dict]:
        return list(self._ring)

    def __len__(self) -> int:
        return len(self._ring)

    def clear(self):
        self._ring.clear()
        self.dropped = 0

    # -- recording ------------------------------------------------------
    def _ts(self, t: float) -> float:
        return (t - self.epoch) * 1e6            # µs, Chrome's unit

    def _push(self, ev: dict):
        if len(self._ring) == self.capacity:
            self.dropped += 1
        self._ring.append(ev)

    def span(self, name: str, t0: float, t1: float, *, pid: int, tid: int,
             cat: str, **args):
        """One complete ('X') event: [t0, t1] perf_counter seconds."""
        self._push({"name": name, "ph": "X", "cat": cat,
                    "ts": self._ts(t0), "dur": max(0.0, (t1 - t0) * 1e6),
                    "pid": pid, "tid": tid, "args": args})

    def step_span(self, name: str, t0: float, t1: float, **args):
        """Engine-row span (pid 1, tid 0): prefill / decode / verify /
        encode passes and the per-iteration engine_step wrapper."""
        self.span(name, t0, t1, pid=PID_ENGINE, tid=0, cat="step", **args)

    def request_span(self, uid: int, name: str, t0: float, t1: float,
                     **args):
        """Request-row span (pid 2, tid = uid): queue / shed / request."""
        self.span(name, t0, t1, pid=PID_REQUEST, tid=int(uid),
                  cat="request", **args)

    def instant(self, name: str, t: float, *, tid: int,
                pid: int = PID_REQUEST, **args):
        """One instant ('i') event — submit, first_token, warm_hit,
        cow_copy, degrade, preempt markers."""
        self._push({"name": name, "ph": "i", "cat": "mark", "s": "t",
                    "ts": self._ts(t), "pid": pid, "tid": int(tid),
                    "args": args})

    # -- export ---------------------------------------------------------
    def chrome_trace(self) -> dict:
        """Chrome trace-event JSON object (Perfetto-loadable).  Events are
        sorted by timestamp; metadata events name the process/thread rows."""
        evs = sorted(self._ring, key=lambda e: e["ts"])
        meta = [
            {"name": "process_name", "ph": "M", "pid": PID_ENGINE, "tid": 0,
             "args": {"name": "engine"}},
            {"name": "thread_name", "ph": "M", "pid": PID_ENGINE, "tid": 0,
             "args": {"name": "steps"}},
            {"name": "process_name", "ph": "M", "pid": PID_REQUEST,
             "tid": 0, "args": {"name": "requests"}},
        ]
        for tid in sorted({e["tid"] for e in evs
                           if e["pid"] == PID_REQUEST}):
            meta.append({"name": "thread_name", "ph": "M",
                         "pid": PID_REQUEST, "tid": tid,
                         "args": {"name": f"req {tid}"}})
        return {"traceEvents": meta + evs, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped,
                              "capacity": self.capacity}}

    def write(self, path: str) -> int:
        """Write the Chrome trace JSON; returns the event count."""
        doc = self.chrome_trace()
        with open(path, "w") as f:
            json.dump(doc, f, default=_jsonable)
        return len(self._ring)


def _jsonable(x):
    """json.dump fallback for numpy scalars riding in span args."""
    if hasattr(x, "item"):
        return x.item()
    return str(x)


# -- validation (the CI artifact gate) ----------------------------------
_REQUIRED = ("name", "ph", "ts", "pid", "tid")


def validate_chrome_trace(doc: dict) -> List[str]:
    """Schema-check a Chrome trace-event document: non-empty, required
    fields present and numeric where they must be, 'X' events carry a
    non-negative dur, and non-metadata timestamps are monotonic (the
    export sorts; an unsorted artifact means a broken writer).  Returns
    a list of problems (empty = clean)."""
    problems = []
    evs = doc.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        return ["traceEvents missing or empty"]
    body = [e for e in evs if e.get("ph") != "M"]
    if not body:
        problems.append("no non-metadata events")
    last_ts = None
    for i, e in enumerate(body):
        for k in _REQUIRED:
            if k not in e:
                problems.append(f"event {i} ({e.get('name')!r}): "
                                f"missing field {k!r}")
        ts = e.get("ts")
        if not isinstance(ts, (int, float)):
            problems.append(f"event {i} ({e.get('name')!r}): "
                            f"non-numeric ts {ts!r}")
            continue
        if e.get("ph") == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i} ({e.get('name')!r}): "
                                f"'X' event needs dur >= 0, got {dur!r}")
        if last_ts is not None and ts < last_ts:
            problems.append(f"event {i} ({e.get('name')!r}): timestamp "
                            f"{ts} < predecessor {last_ts} "
                            f"(not monotonic)")
        last_ts = ts
        if len(problems) >= 20:
            problems.append("... (truncated)")
            break
    return problems


# -- interval metrics: join step spans with the roofline FLOP/byte model -
def derive_phase_metrics(events: Iterable[dict], *, flops_per_token: float,
                         weight_bytes: float, kv_bytes_per_token: float,
                         peak_flops: Optional[float] = None,
                         hbm_bw: Optional[float] = None) -> Dict[str, dict]:
    """Per-phase achieved MFU/MBU from recorded step spans.

    Every compute span (prefill / prefill_chunk / decode_step /
    spec_verify / encode) carries `phase`, `tokens` (positions the pass
    executed, padding included), `kv_positions` (live KV positions the
    pass read/wrote), `passes`, and `busy_ms` (device-busy wall, floored
    against pipelined neighbors so overlapped steps never double-count).

      MFU = flops_per_token * tokens / (busy_s * peak)      [achieved/peak]
      MBU = (weight_bytes * passes + kv_bytes_per_token * kv_positions)
            / (busy_s * bw)

    `flops_per_token` is the analytic decoder forward cost
    (analysis/roofline.decoder_flops_per_token); peak/bw default to the
    TPU v5e roofline constants.  The "draft" phase reports time only
    (its FLOPs belong to a different, smaller model)."""
    from repro.analysis import roofline
    peak = peak_flops if peak_flops is not None else roofline.PEAK_BF16
    bw = hbm_bw if hbm_bw is not None else roofline.HBM_BW
    acc: Dict[str, dict] = {}
    for e in events:
        args = e.get("args") or {}
        phase = args.get("phase")
        if e.get("cat") != "step" or phase is None:
            continue
        a = acc.setdefault(phase, {"time_s": 0.0, "tokens": 0,
                                   "kv_positions": 0, "passes": 0})
        busy = args.get("busy_ms")
        a["time_s"] += (busy / 1e3 if busy is not None
                        else e.get("dur", 0.0) / 1e6)
        a["tokens"] += int(args.get("tokens", 0))
        a["kv_positions"] += int(args.get("kv_positions", 0))
        a["passes"] += int(args.get("passes", 0))
    out: Dict[str, dict] = {}
    for phase, a in acc.items():
        t = a["time_s"]
        flops = flops_per_token * a["tokens"]
        mem = weight_bytes * a["passes"] + kv_bytes_per_token * a[
            "kv_positions"]
        out[phase] = {
            **a,
            "flops": flops,
            "hbm_bytes": mem,
            "mfu": flops / (t * peak) if t > 0 else 0.0,
            "mbu": mem / (t * bw) if t > 0 else 0.0,
        }
    return out


# -- flat Prometheus-style text snapshot --------------------------------
def prometheus_text(snapshot: dict, prefix: str = "serving") -> str:
    """Flatten an `EngineStats.to_dict()` into Prometheus text exposition
    format: scalars become `<prefix>_<key> <value>`, one-level dicts
    become labeled series (`bucket_hits` -> {bucket="8"}, `phase_util`
    -> per-phase {phase="decode"} series), and string fields collapse
    into one `<prefix>_info{...} 1` metric."""
    lines: List[str] = []
    info: Dict[str, str] = {}
    for key, val in snapshot.items():
        if isinstance(val, bool):
            lines.append(f"{prefix}_{key} {int(val)}")
        elif isinstance(val, (int, float)):
            lines.append(f"{prefix}_{key} {val:g}")
        elif isinstance(val, str):
            info[key] = val
        elif isinstance(val, dict):
            if key == "phase_util":
                for phase, m in val.items():
                    for mk, mv in m.items():
                        if isinstance(mv, (int, float)):
                            lines.append(
                                f'{prefix}_phase_{mk}{{phase="{phase}"}} '
                                f"{mv:g}")
            else:
                label = key.rstrip("s") or key
                for k, v in val.items():
                    if isinstance(v, (int, float)):
                        lines.append(
                            f'{prefix}_{key}{{{label}="{k}"}} {v:g}')
    if info:
        labels = ",".join(f'{k}="{v}"' for k, v in sorted(info.items()))
        lines.append(f"{prefix}_info{{{labels}}} 1")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    """Validate a trace artifact: non-empty, schema-clean, monotonic
    timestamps.  Exit 1 (with the problems on stderr) otherwise."""
    import argparse
    import sys
    ap = argparse.ArgumentParser(
        description="validate a serving trace artifact (Chrome trace JSON)")
    ap.add_argument("trace", help="path to a Tracer.write() artifact")
    args = ap.parse_args(argv)
    with open(args.trace) as f:
        doc = json.load(f)
    problems = validate_chrome_trace(doc)
    evs = [e for e in doc.get("traceEvents", []) if e.get("ph") != "M"]
    reqs = {e["tid"] for e in evs if e.get("pid") == PID_REQUEST}
    span = (max((e["ts"] + e.get("dur", 0) for e in evs), default=0)
            - min((e["ts"] for e in evs), default=0))
    print(f"{args.trace}: {len(evs)} events, {len(reqs)} request rows, "
          f"{span / 1e3:.1f}ms span, "
          f"{doc.get('otherData', {}).get('dropped_events', 0)} dropped")
    if problems:
        for p in problems:
            print(f"  INVALID: {p}", file=sys.stderr)
        return 1
    print("  schema clean, timestamps monotonic")
    return 0


__all__ = ["Tracer", "PID_ENGINE", "PID_REQUEST", "validate_chrome_trace",
           "derive_phase_metrics", "prometheus_text"]

if __name__ == "__main__":
    import sys
    sys.exit(main())
