"""Session-based continuous-batching inference engine (NAR prefill + AR
decode, paper T8 / Sec. VI-A) over a block-paged KV cache.

A fixed decode batch of B slots runs lockstep AR steps (the paper's AR
mode); finished rows are immediately replaced by prefilling queued requests
(NAR pass, paper's prompt-encoding mode) — decode never drains to admit
work.

KV memory is *paged*: a `BlockAllocator` owns a global pool of fixed-size
KV blocks and each slot holds an ordered block table of the blocks its
request occupies.  Admission allocates ceil(tokens / block_size) blocks,
decode allocates one more each time a slot crosses a block boundary, and
retirement frees them — live pool occupancy tracks active tokens, never
B x max_seq.  When the pool is exhausted the youngest running request is
preempted back to the queue (its blocks freed) and later re-admitted by
re-prefilling its prompt + generated prefix — recompute preemption, the
same (seed, position)-keyed sampling draws making the continuation exact.
Sliding-window (ring), SSM and cross-attention caches stay dense per-slot
(they are already bounded); archs with no full-context attention simply
have no paged leaves.

Admission is *batched*: queued requests sharing a prefill length bucket are
prefilled together in one compiled call and their compact KV is scattered
straight into their assigned blocks (serving/kv_cache.make_prefill_scatter)
— a per-block scatter, not a whole-batch-cache `dynamic_update_slice`.

The session API decouples *what a request wants* from *how the engine
batches it*:

  variable-length prompts   prefill steps are compiled lazily per
      (length bucket, group size); prompts are right-padded to the bucket.
      Buckets step by 1.5x/2x rungs (8, 12, 16, 24, 32, ...) — batched
      admission amortizes the extra compiles that finer rungs cost, and
      halves worst-case padding waste vs pure powers of two.  Padding is
      output-exact for linear attention caches; archs with recurrent or
      ring-buffer state (SSM hybrids, sliding-window attention) compile at
      exact prompt length instead — their state would absorb pad positions.
  per-request sampling      `SamplingParams` (greedy / temperature / top-k,
      per-request seed) scattered into per-slot lane arrays; the draw
      happens *inside* the jitted step (core/embedding.sample_token), so one
      compiled decode step serves any mix of greedy and sampled requests.
  streaming                 `generate()` yields `TokenEvent(uid, token,
      is_last)` as steps complete; `run()` drains it for batch use.
  telemetry                 `stats()` -> EngineStats: NAR / AR throughput
      tracked separately (the paper's two metrics), TTFT, slot occupancy,
      decode-step latency percentiles, pool utilization, preemptions.

All model math goes through the launch/steps bundles, so the engine runs
identically on 1 CPU device (tests) and on the production mesh.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import steps as steps_mod
from repro.serving.kv_cache import (BlockAllocator, make_prefill_scatter,
                                    zero_caches)
from repro.serving.sampling import (SamplingParams, set_lane,
                                    stack_prefill_lanes, zero_lane)
from repro.serving.stats import EngineStats


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # [S_prompt] int32, any length
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    sampling: SamplingParams = field(default_factory=SamplingParams)
    # filled by the engine:
    output: List[int] = field(default_factory=list)
    prompt_len: int = 0                 # true length (set at submit)
    bucket: int = 0                     # padded prefill length (set at admit)
    prefill_ms: float = 0.0             # amortized share of group prefills
    decode_ms: float = 0.0
    ttft_ms: float = 0.0                # submit -> first token
    done: bool = False
    _t_submit: float = field(default=0.0, repr=False)
    _seq: int = field(default=0, repr=False)   # admission order (preemption)


@dataclass(frozen=True)
class TokenEvent:
    """One streamed token: emitted by `InferenceEngine.generate()` the
    moment the engine step that produced it completes."""
    uid: int
    token: int
    is_last: bool


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_size: int = 4,
                 max_seq: int = 256, mesh=None, policy=None,
                 min_bucket: int = 8, paged: bool = True,
                 block_size: int = 16, kv_pool_blocks: Optional[int] = None):
        assert min_bucket >= 1, f"min_bucket must be >= 1: {min_bucket}"
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.max_seq = max_seq
        self.min_bucket = min_bucket
        self.mesh = mesh
        self.policy = policy
        # pad-to-bucket is exact only for linear attention caches (see module
        # docstring); recurrent / ring-buffer archs prefill at exact length
        self._pad_buckets = not (cfg.has_ssm or cfg.sliding_window > 0)
        # VLM patch prefix rides along in every prefill: it consumes cache
        # positions, shrinking the token budget a prompt may use
        self._n_prefix = cfg.n_patches or 0
        dshape = ShapeConfig("engine_decode", "decode", max_seq, batch_size)
        # the pool is shared across slots: a batch-sharded decode would give
        # each data shard a divergent pool copy -> fall back to dense rows
        if paged and steps_mod.serve_dp(cfg, dshape, mesh) > 1:
            paged = False
        self.paged = paged
        if paged:
            default_blocks = batch_size * (-(-max_seq // block_size))
            paged_arg: Optional[Tuple[int, int]] = (
                kv_pool_blocks or default_blocks, block_size)
        else:
            paged_arg = None
        self.decode_step = steps_mod.make_decode_step(
            cfg, dshape, mesh, policy=policy, max_seq=max_seq,
            with_sampling=True, paged=paged_arg)
        self.layout = self.decode_step.aux["paged"]
        self._prefill_steps: Dict[tuple, steps_mod.StepBundle] = {}
        self.caches = zero_caches(self.decode_step.aux["cache_struct"],
                                  steps_mod.to_shardings(
                                      self.decode_step.aux["cache_specs"],
                                      mesh))
        if self.paged:
            self.allocator = BlockAllocator(self.layout.num_blocks,
                                            self.layout.block_size)
            self.block_tables = np.full(
                (batch_size, self.layout.max_blocks), -1, np.int32)
            self._scatter = make_prefill_scatter(self.layout.segments,
                                                 self.layout.block_size)
        else:
            self.allocator = None
            self.block_tables = None
            self._scatter = make_prefill_scatter(
                (False,) * len(cfg.schedule), 1)
        self._slot_blocks: List[List[int]] = [[] for _ in range(batch_size)]
        self._tables_dev = None            # device copy, rebuilt when dirty
        self._admit_seq = 0
        self.tokens = jnp.zeros((batch_size,), jnp.int32)
        self.pos = jnp.zeros((batch_size,), jnp.int32)
        self.lane = zero_lane(batch_size)
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        self.steps_run = 0
        self._stats = self._fresh_stats()

    def _fresh_stats(self) -> EngineStats:
        st = EngineStats(batch_size=self.B)
        if self.paged:
            st.kv_pool_blocks = self.layout.num_blocks
            st.kv_block_size = self.layout.block_size
        return st

    # -- prefill compilation cache -------------------------------------
    def bucket_for(self, prompt_len: int) -> int:
        """Prefill length bucket for a prompt: smallest rung of
        {m, 1.5m} x 2^k >= max(min_bucket, len), capped at the token budget
        (max_seq minus any patch prefix); exact length for archs whose
        caches cannot absorb padding."""
        if not self._pad_buckets:
            return prompt_len
        cap = self.max_seq - self._n_prefix
        base = self.min_bucket
        while True:
            for cand in (base, base + base // 2):
                if cand >= prompt_len or cand >= cap:
                    return min(cand, cap)
            base *= 2

    def _prefill_for(self, bucket: int, group: int) -> steps_mod.StepBundle:
        step = self._prefill_steps.get((bucket, group))
        if step is None:
            pshape = ShapeConfig(f"engine_prefill_{bucket}x{group}",
                                 "prefill", bucket, group)
            step = steps_mod.make_prefill_step(
                self.cfg, pshape, self.mesh, policy=self.policy,
                max_seq=self.max_seq, with_sampling=True,
                compact_kv=self.paged)
            self._prefill_steps[(bucket, group)] = step
            self._stats.prefill_compiles += 1
        return step

    # -- admission -----------------------------------------------------
    def submit(self, req: Request):
        n = len(req.prompt)
        cap = self.max_seq - 1 - self._n_prefix
        assert 0 < n <= cap, (
            f"prompt length {n} not in [1, {cap}] "
            f"(max_seq={self.max_seq}, patch prefix={self._n_prefix})")
        assert req.max_new_tokens >= 1, (
            f"max_new_tokens must be >= 1 (the prefill emits the first "
            f"token): {req.max_new_tokens}")
        req.prompt_len = n
        req._t_submit = time.perf_counter()
        self.queue.append(req)
        self._stats.requests_submitted += 1

    def _full_prompt(self, req: Request) -> np.ndarray:
        """The token sequence a (re-)prefill must encode: the prompt plus
        any tokens already generated before a preemption."""
        if not req.output:
            return np.asarray(req.prompt, np.int32)
        return np.concatenate([np.asarray(req.prompt, np.int32),
                               np.asarray(req.output, np.int32)])

    def _full_len(self, req: Request) -> int:
        """len(_full_prompt(req)) without materializing it (admission scans
        the whole queue; only admitted requests build the array)."""
        return req.prompt_len + len(req.output)

    def _next_group(self, max_n: int) -> List[Tuple[Request, List[int]]]:
        """Pop the next admission group off the queue: up to `max_n`
        requests sharing the head-of-line's length bucket, each with its
        pool blocks allocated (all-or-nothing per request).  Empty when the
        head cannot get blocks — the caller waits for running requests to
        free some."""
        head_bucket = self.bucket_for(self._full_len(self.queue[0]))
        idxs = [i for i, r in enumerate(self.queue)
                if self.bucket_for(self._full_len(r)) == head_bucket]
        idxs = idxs[:max_n]
        group: List[Tuple[Request, List[int]]] = []
        taken: List[int] = []
        for i in idxs:
            req = self.queue[i]
            blocks: List[int] = []
            if self.paged:
                need = self.allocator.blocks_for(
                    self._n_prefix + self._full_len(req))
                got = self.allocator.alloc(need)
                if got is None:
                    break
                blocks = got
            group.append((req, blocks))
            taken.append(i)
        if not group:
            if all(s is None for s in self.slots):
                need = self.allocator.blocks_for(
                    self._n_prefix + self._full_len(self.queue[0]))
                raise RuntimeError(
                    f"KV pool too small: request {self.queue[0].uid} needs "
                    f"{need} blocks, pool has {self.allocator.num_blocks} "
                    f"({self.allocator.num_free} free) and no running "
                    f"request can be preempted to free more")
            return []
        for i in reversed(taken):
            self.queue.pop(i)
        return group

    def _admit(self, fresh: List) -> int:
        admitted = 0
        while True:
            free = [b for b in range(self.B) if self.slots[b] is None]
            if not free or not self.queue:
                return admitted
            group = self._next_group(len(free))
            if not group:
                return admitted
            self._prefill_group(group, free, fresh)
            admitted += len(group)

    def _prefill_group(self, group, free_slots: List[int], fresh: List):
        """One batched NAR pass for an admission group, scattering its KV
        into the assigned blocks (paged) / slot rows (dense)."""
        reqs = [req for req, _ in group]
        fulls = [self._full_prompt(req) for req in reqs]
        bucket = self.bucket_for(len(fulls[0]))
        n = len(reqs)
        step = self._prefill_for(bucket, n)
        t0 = time.perf_counter()
        padded = np.zeros((n, bucket), np.int32)
        for j, seq in enumerate(fulls):
            padded[j, :len(seq)] = seq
        batch = {"tokens": jnp.asarray(padded)}
        if self.cfg.n_patches:
            batch["patches"] = jnp.zeros(
                (n, self.cfg.n_patches, self.cfg.d_model), jnp.bfloat16)
        if self.cfg.enc_schedule:
            batch["frames"] = jnp.zeros(
                (n, self.cfg.enc_seq_padded, self.cfg.d_model), jnp.bfloat16)
        tok, caches_g, pos_g = step.fn(
            self.params, batch,
            stack_prefill_lanes([r.sampling for r in reqs],
                                [len(f) for f in fulls]))

        slots = free_slots[:n]
        if self.paged:
            tables = np.full((n, self.layout.max_blocks), -1, np.int32)
            for j, (_, blocks) in enumerate(group):
                tables[j, :len(blocks)] = blocks
        else:
            tables = np.zeros((n, 1), np.int32)      # unused by the scatter
        self.caches = self._scatter(self.caches, caches_g,
                                    jnp.asarray(slots, jnp.int32),
                                    jnp.asarray(tables))
        slots_arr = jnp.asarray(slots, jnp.int32)
        self.tokens = self.tokens.at[slots_arr].set(tok)
        self.pos = self.pos.at[slots_arr].set(pos_g)
        tok_np = np.asarray(tok)
        now = time.perf_counter()
        dt_ms = (now - t0) * 1e3

        st = self._stats
        n_first = 0
        for j, (req, blocks) in enumerate(group):
            b = slots[j]
            first_admit = not req.output
            req.bucket = bucket
            req.prefill_ms += dt_ms / n    # amortized share of the group call
            req.output.append(int(tok_np[j]))
            req._seq = self._admit_seq
            self._admit_seq += 1
            self.lane = set_lane(self.lane, b, req.sampling)
            self.slots[b] = req
            self._slot_blocks[b] = list(blocks)
            if self.paged:
                self.block_tables[b] = tables[j]
                self._tables_dev = None
            fresh.append((req, len(req.output) - 1))
            st.bucket_hits[bucket] = st.bucket_hits.get(bucket, 0) + 1
            if first_admit:
                n_first += 1
                req.ttft_ms = (now - req._t_submit) * 1e3
                st.nar_tokens += req.prompt_len
                st.padded_nar_tokens += bucket
                st.add_ttft_ms(req.ttft_ms)
            else:
                st.recompute_tokens += len(fulls[j])
        # preemption recomputes are overhead, not prompt-encoding goodput:
        # split the group's wall time so nar_tok_s stays comparable between
        # preempting and non-preempting runs
        st.nar_time_s += (now - t0) * n_first / n
        st.recompute_time_s += (now - t0) * (n - n_first) / n

    # -- paged bookkeeping ---------------------------------------------
    def _preempt_youngest(self) -> Optional[int]:
        """Evict the most recently admitted running request back to the
        queue head, freeing its blocks (recompute preemption)."""
        cand = [b for b in range(self.B) if self.slots[b] is not None]
        if not cand:
            return None
        b = max(cand, key=lambda b: self.slots[b]._seq)
        req = self.slots[b]
        self._release_slot(b)
        self.queue.insert(0, req)
        self._stats.preemptions += 1
        return b

    def _release_slot(self, b: int):
        if self.paged and self._slot_blocks[b]:
            self.allocator.free(self._slot_blocks[b])
        self._slot_blocks[b] = []
        if self.paged:
            self.block_tables[b, :] = -1
            self._tables_dev = None
        self.slots[b] = None

    def _grow_tables(self):
        """Before a decode step: every occupied slot must own the block its
        next token lands in (pos // block_size).  Allocation failure
        preempts the youngest running request until it succeeds."""
        if not self.paged:
            return
        bs = self.layout.block_size
        pos = np.asarray(self.pos)
        for b in range(self.B):
            if self.slots[b] is None:
                continue
            need = int(pos[b]) // bs + 1
            if need > self.allocator.num_blocks:
                # impossible to ever satisfy — fail before preempting (and
                # discarding) every other in-flight request's progress
                raise RuntimeError(
                    f"KV pool too small: request {self.slots[b].uid} needs "
                    f"{need} blocks, pool capacity is "
                    f"{self.allocator.num_blocks} (raise kv_pool_blocks, "
                    f"raise block_size, or cap max_new_tokens)")
            while self.slots[b] is not None and len(self._slot_blocks[b]) < need:
                got = self.allocator.alloc(1)
                if got is not None:
                    self.block_tables[b, len(self._slot_blocks[b])] = got[0]
                    self._slot_blocks[b].extend(got)
                    self._tables_dev = None
                    continue
                if self._preempt_youngest() is None:
                    raise RuntimeError(
                        "KV pool exhausted with no running request to "
                        "preempt")

    def _tables(self):
        if self._tables_dev is None:
            self._tables_dev = jnp.asarray(self.block_tables)
        return self._tables_dev

    # -- retirement ------------------------------------------------------
    def _retire(self):
        pos = np.asarray(self.pos)
        for b, req in enumerate(self.slots):
            if req is None:
                continue
            tok = req.output[-1]
            if (len(req.output) >= req.max_new_tokens
                    or (req.eos_id is not None and tok == req.eos_id)
                    or int(pos[b]) >= self.max_seq - 1):
                req.done = True
                self.completed.append(req)
                self._stats.requests_completed += 1
                self._release_slot(b)

    # -- engine loop ------------------------------------------------------
    def step(self) -> List[TokenEvent]:
        """One engine iteration: admit -> retire -> AR step -> retire.
        Returns the TokenEvents produced (prefill first-tokens + decoded
        tokens), with `is_last` resolved against retirement."""
        fresh: List = []                  # (request, output index) pairs
        # admit/retire until slots are full or the queue drains: a request
        # finished by its prefill token alone (max_new_tokens=1, prompt-eos,
        # pos cap) frees its slot (and blocks) for another admission before
        # the AR step.  A free slot the pool cannot serve yet is not
        # progress — stop and let the decode/retire cycle free blocks.
        while True:
            n_done = len(self.completed)
            admitted = self._admit(fresh)
            self._retire()
            if not self.queue or all(s is not None for s in self.slots):
                break
            if not admitted and len(self.completed) == n_done:
                break
        if any(s is not None for s in self.slots):
            self._grow_tables()           # may preempt back to the queue
        if any(s is not None for s in self.slots):
            t0 = time.perf_counter()
            if self.paged:
                self.tokens, self.pos, self.caches = self.decode_step.fn(
                    self.params, self.tokens, self.pos, self.caches,
                    self._tables(), self.lane)
            else:
                self.tokens, self.pos, self.caches = self.decode_step.fn(
                    self.params, self.tokens, self.pos, self.caches,
                    self.lane)
            toks = np.asarray(self.tokens)          # blocks: honest timing
            dt = time.perf_counter() - t0
            self.steps_run += 1
            occupied = live_tokens = 0
            pos_np = np.asarray(self.pos)
            for b, req in enumerate(self.slots):
                if req is None:
                    continue
                occupied += 1
                live_tokens += int(pos_np[b])
                req.output.append(int(toks[b]))
                req.decode_ms += dt * 1e3
                fresh.append((req, len(req.output) - 1))
            st = self._stats
            st.decode_steps += 1
            st.ar_tokens += occupied
            st.ar_time_s += dt
            st.add_decode_step_ms(dt * 1e3)
            st.occupied_slot_steps += occupied
            if self.paged:
                st.block_slot_steps += self.allocator.num_used
                st.token_slot_steps += live_tokens
            self._retire()
        return [TokenEvent(req.uid, req.output[i],
                           req.done and i == len(req.output) - 1)
                for req, i in fresh]

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def generate(self, max_steps: int = 10_000) -> Iterator[TokenEvent]:
        """Streaming interface: run engine steps until queue + slots drain,
        yielding each token the moment its step completes."""
        for _ in range(max_steps):
            if not self.has_work():
                return
            yield from self.step()

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Batch interface: drain `generate()`; returns the requests that
        completed during THIS call (`self.completed` keeps the full session
        history)."""
        start = len(self.completed)
        for _ in self.generate(max_steps):
            pass
        return self.completed[start:]

    # -- telemetry --------------------------------------------------------
    def stats(self) -> EngineStats:
        """Live serving telemetry (accumulated since construction or the
        last `reset_stats()`)."""
        if self.paged:
            # the allocator tracks the true high-water mark on every alloc,
            # including admissions that never reach a decode step
            self._stats.peak_blocks_used = self.allocator.peak_used
        return self._stats

    def reset_stats(self):
        """Drop accumulated telemetry, keeping compiled steps (benchmarks:
        warm buckets up, reset, then measure)."""
        if self.paged:
            self.allocator.peak_used = self.allocator.num_used
        self._stats = self._fresh_stats()


# The original fixed-prompt-length engine grew into the session API above.
# The old name stays importable, but the constructor deliberately dropped
# `prompt_len` — variable-length prompts made it meaningless.
ServingEngine = InferenceEngine
