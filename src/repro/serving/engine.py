"""Continuous-batching serving engine (NAR prefill + AR decode, paper T8).

A fixed decode batch of B slots runs lockstep AR steps (the paper's AR
mode); finished rows are immediately replaced by prefilling queued requests
(batch-1 NAR pass, paper's prompt-encoding mode) and scattering their cache
into the free slot — decode never drains to admit work.

All model math goes through the launch/steps bundles, so the engine runs
identically on 1 CPU device (tests) and on the production mesh.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import steps as steps_mod
from repro.serving.kv_cache import insert_row, zero_caches


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # [S_prompt] int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine:
    output: List[int] = field(default_factory=list)
    prefill_ms: float = 0.0
    decode_ms: float = 0.0
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_size: int = 4,
                 max_seq: int = 256, prompt_len: int = 32, mesh=None,
                 policy=None):
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.max_seq = max_seq
        self.prompt_len = prompt_len
        dshape = ShapeConfig("engine_decode", "decode", max_seq, batch_size)
        pshape = ShapeConfig("engine_prefill", "prefill", prompt_len, 1)
        self.decode_step = steps_mod.make_decode_step(
            cfg, dshape, mesh, policy=policy, max_seq=max_seq)
        self.prefill_step = steps_mod.make_prefill_step(
            cfg, pshape, mesh, policy=policy, max_seq=max_seq)
        self.caches = zero_caches(self.decode_step.aux["cache_struct"],
                                  steps_mod.to_shardings(
                                      self.decode_step.aux["cache_specs"],
                                      mesh))
        self.tokens = jnp.zeros((batch_size,), jnp.int32)
        self.pos = jnp.zeros((batch_size,), jnp.int32)
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        self.steps_run = 0

    # -- admission -----------------------------------------------------
    def submit(self, req: Request):
        assert len(req.prompt) == self.prompt_len, (
            f"engine is configured for prompt_len={self.prompt_len}")
        self.queue.append(req)

    def _admit(self):
        for b in range(self.B):
            if self.slots[b] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            t0 = time.perf_counter()
            batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
            if self.cfg.n_patches:
                batch["patches"] = jnp.zeros(
                    (1, self.cfg.n_patches, self.cfg.d_model), jnp.bfloat16)
            if self.cfg.enc_schedule:
                batch["frames"] = jnp.zeros(
                    (1, self.cfg.enc_seq_padded, self.cfg.d_model),
                    jnp.bfloat16)
            tok, caches1, pos1 = self.prefill_step.fn(self.params, batch)
            req.prefill_ms = (time.perf_counter() - t0) * 1e3
            req.output.append(int(tok[0]))
            self.caches = insert_row(self.caches, caches1, b)
            self.tokens = self.tokens.at[b].set(tok[0])
            self.pos = self.pos.at[b].set(pos1[0])
            self.slots[b] = req

    # -- decode ----------------------------------------------------------
    def _retire(self):
        for b, req in enumerate(self.slots):
            if req is None:
                continue
            tok = req.output[-1]
            if (len(req.output) >= req.max_new_tokens
                    or (req.eos_id is not None and tok == req.eos_id)
                    or int(self.pos[b]) >= self.max_seq - 1):
                req.done = True
                self.completed.append(req)
                self.slots[b] = None

    def step(self):
        """One engine iteration: admit -> AR step -> collect."""
        self._admit()
        if all(s is None for s in self.slots):
            return False
        t0 = time.perf_counter()
        self.tokens, self.pos, self.caches = self.decode_step.fn(
            self.params, self.tokens, self.pos, self.caches)
        dt = (time.perf_counter() - t0) * 1e3
        self.steps_run += 1
        toks = np.asarray(self.tokens)
        for b, req in enumerate(self.slots):
            if req is not None:
                req.output.append(int(toks[b]))
                req.decode_ms += dt
        self._retire()
        return True

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Run until queue + slots drain; returns completed requests."""
        for _ in range(max_steps):
            if not self.step() and not self.queue:
                break
        return self.completed
