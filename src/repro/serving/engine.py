"""Session-based continuous-batching inference engine (NAR prefill + AR
decode, paper T8 / Sec. VI-A).

A fixed decode batch of B slots runs lockstep AR steps (the paper's AR
mode); finished rows are immediately replaced by prefilling queued requests
(batch-1 NAR pass, paper's prompt-encoding mode) and scattering their cache
into the free slot — decode never drains to admit work.

The session API decouples *what a request wants* from *how the engine
batches it*:

  variable-length prompts   prefill steps are compiled lazily per
      power-of-two length bucket; prompts are right-padded to the bucket.
      Padding is output-exact for linear attention caches (causality masks
      pads during the prefill, `pos` masks them at decode, and decode
      overwrites each pad slot exactly when it first becomes attendable).
      Archs with recurrent or ring-buffer state (SSM hybrids, sliding-window
      attention) compile at exact prompt length instead — their state would
      absorb pad positions.
  per-request sampling      `SamplingParams` (greedy / temperature / top-k,
      per-request seed) scattered into per-slot lane arrays; the draw
      happens *inside* the jitted step (core/embedding.sample_token), so one
      compiled decode step serves any mix of greedy and sampled requests.
  streaming                 `generate()` yields `TokenEvent(uid, token,
      is_last)` as steps complete; `run()` drains it for batch use.
  telemetry                 `stats()` -> EngineStats: NAR / AR throughput
      tracked separately (the paper's two metrics), TTFT, slot occupancy,
      bucket hit counts.

All model math goes through the launch/steps bundles, so the engine runs
identically on 1 CPU device (tests) and on the production mesh.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import steps as steps_mod
from repro.serving.kv_cache import insert_row, zero_caches
from repro.serving.sampling import (SamplingParams, prefill_lane, set_lane,
                                    zero_lane)
from repro.serving.stats import EngineStats


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # [S_prompt] int32, any length
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    sampling: SamplingParams = field(default_factory=SamplingParams)
    # filled by the engine:
    output: List[int] = field(default_factory=list)
    prompt_len: int = 0                 # true length (set at submit)
    bucket: int = 0                     # padded prefill length (set at admit)
    prefill_ms: float = 0.0
    decode_ms: float = 0.0
    ttft_ms: float = 0.0                # submit -> first token
    done: bool = False
    _t_submit: float = field(default=0.0, repr=False)


@dataclass(frozen=True)
class TokenEvent:
    """One streamed token: emitted by `InferenceEngine.generate()` the
    moment the engine step that produced it completes."""
    uid: int
    token: int
    is_last: bool


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_size: int = 4,
                 max_seq: int = 256, mesh=None, policy=None,
                 min_bucket: int = 8):
        assert min_bucket >= 1, f"min_bucket must be >= 1: {min_bucket}"
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.max_seq = max_seq
        self.min_bucket = min_bucket
        self.mesh = mesh
        self.policy = policy
        # pad-to-bucket is exact only for linear attention caches (see module
        # docstring); recurrent / ring-buffer archs prefill at exact length
        self._pad_buckets = not (cfg.has_ssm or cfg.sliding_window > 0)
        # VLM patch prefix rides along in every prefill: it consumes cache
        # positions, shrinking the token budget a prompt may use
        self._n_prefix = cfg.n_patches or 0
        dshape = ShapeConfig("engine_decode", "decode", max_seq, batch_size)
        self.decode_step = steps_mod.make_decode_step(
            cfg, dshape, mesh, policy=policy, max_seq=max_seq,
            with_sampling=True)
        self._prefill_steps: Dict[int, steps_mod.StepBundle] = {}
        self.caches = zero_caches(self.decode_step.aux["cache_struct"],
                                  steps_mod.to_shardings(
                                      self.decode_step.aux["cache_specs"],
                                      mesh))
        self.tokens = jnp.zeros((batch_size,), jnp.int32)
        self.pos = jnp.zeros((batch_size,), jnp.int32)
        self.lane = zero_lane(batch_size)
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        self.steps_run = 0
        self._stats = EngineStats(batch_size=batch_size)

    # -- prefill compilation cache -------------------------------------
    def bucket_for(self, prompt_len: int) -> int:
        """Prefill length bucket for a prompt: next power of two >=
        max(min_bucket, len), capped at the token budget (max_seq minus any
        patch prefix); exact length for archs whose caches cannot absorb
        padding."""
        if not self._pad_buckets:
            return prompt_len
        b = self.min_bucket
        while b < prompt_len:
            b *= 2
        return min(b, self.max_seq - self._n_prefix)

    def _prefill_for(self, bucket: int) -> steps_mod.StepBundle:
        step = self._prefill_steps.get(bucket)
        if step is None:
            pshape = ShapeConfig(f"engine_prefill_{bucket}", "prefill",
                                 bucket, 1)
            step = steps_mod.make_prefill_step(
                self.cfg, pshape, self.mesh, policy=self.policy,
                max_seq=self.max_seq, with_sampling=True)
            self._prefill_steps[bucket] = step
            self._stats.prefill_compiles += 1
        return step

    # -- admission -----------------------------------------------------
    def submit(self, req: Request):
        n = len(req.prompt)
        cap = self.max_seq - 1 - self._n_prefix
        assert 0 < n <= cap, (
            f"prompt length {n} not in [1, {cap}] "
            f"(max_seq={self.max_seq}, patch prefix={self._n_prefix})")
        assert req.max_new_tokens >= 1, (
            f"max_new_tokens must be >= 1 (the prefill emits the first "
            f"token): {req.max_new_tokens}")
        req.prompt_len = n
        req._t_submit = time.perf_counter()
        self.queue.append(req)
        self._stats.requests_submitted += 1

    def _admit(self, fresh: List):
        for b in range(self.B):
            if self.slots[b] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            bucket = self.bucket_for(req.prompt_len)
            req.bucket = bucket
            step = self._prefill_for(bucket)
            t0 = time.perf_counter()
            padded = np.zeros((bucket,), np.int32)
            padded[:req.prompt_len] = np.asarray(req.prompt, np.int32)
            batch = {"tokens": jnp.asarray(padded)[None]}
            if self.cfg.n_patches:
                batch["patches"] = jnp.zeros(
                    (1, self.cfg.n_patches, self.cfg.d_model), jnp.bfloat16)
            if self.cfg.enc_schedule:
                batch["frames"] = jnp.zeros(
                    (1, self.cfg.enc_seq_padded, self.cfg.d_model),
                    jnp.bfloat16)
            tok, caches1, pos1 = step.fn(
                self.params, batch, prefill_lane(req.sampling,
                                                 req.prompt_len))
            tok0 = int(tok[0])
            now = time.perf_counter()
            req.prefill_ms = (now - t0) * 1e3
            req.ttft_ms = (now - req._t_submit) * 1e3
            req.output.append(tok0)
            self.caches = insert_row(self.caches, caches1, b)
            self.tokens = self.tokens.at[b].set(tok[0])
            self.pos = self.pos.at[b].set(pos1[0])
            self.lane = set_lane(self.lane, b, req.sampling)
            self.slots[b] = req
            fresh.append((req, 0))
            st = self._stats
            st.bucket_hits[bucket] = st.bucket_hits.get(bucket, 0) + 1
            st.nar_tokens += req.prompt_len
            st.padded_nar_tokens += bucket
            st.nar_time_s += now - t0
            st.ttft_ms.append(req.ttft_ms)

    # -- retirement ------------------------------------------------------
    def _retire(self):
        pos = np.asarray(self.pos)
        for b, req in enumerate(self.slots):
            if req is None:
                continue
            tok = req.output[-1]
            if (len(req.output) >= req.max_new_tokens
                    or (req.eos_id is not None and tok == req.eos_id)
                    or int(pos[b]) >= self.max_seq - 1):
                req.done = True
                self.completed.append(req)
                self._stats.requests_completed += 1
                self.slots[b] = None

    # -- engine loop ------------------------------------------------------
    def step(self) -> List[TokenEvent]:
        """One engine iteration: admit -> retire -> AR step -> retire.
        Returns the TokenEvents produced (prefill first-tokens + decoded
        tokens), with `is_last` resolved against retirement."""
        fresh: List = []                  # (request, output index) pairs
        # admit/retire until slots are full or the queue drains: a request
        # finished by its prefill token alone (max_new_tokens=1, prompt-eos,
        # pos cap) frees its slot for another admission before the AR step
        while True:
            self._admit(fresh)
            self._retire()
            if not self.queue or all(s is not None for s in self.slots):
                break
        if any(s is not None for s in self.slots):
            t0 = time.perf_counter()
            self.tokens, self.pos, self.caches = self.decode_step.fn(
                self.params, self.tokens, self.pos, self.caches, self.lane)
            toks = np.asarray(self.tokens)          # blocks: honest timing
            dt = time.perf_counter() - t0
            self.steps_run += 1
            occupied = 0
            for b, req in enumerate(self.slots):
                if req is None:
                    continue
                occupied += 1
                req.output.append(int(toks[b]))
                req.decode_ms += dt * 1e3
                fresh.append((req, len(req.output) - 1))
            st = self._stats
            st.decode_steps += 1
            st.ar_tokens += occupied
            st.ar_time_s += dt
            st.occupied_slot_steps += occupied
            self._retire()
        return [TokenEvent(req.uid, req.output[i],
                           req.done and i == len(req.output) - 1)
                for req, i in fresh]

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def generate(self, max_steps: int = 10_000) -> Iterator[TokenEvent]:
        """Streaming interface: run engine steps until queue + slots drain,
        yielding each token the moment its step completes."""
        for _ in range(max_steps):
            if not self.has_work():
                return
            yield from self.step()

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Batch interface: drain `generate()`; returns the requests that
        completed during THIS call (`self.completed` keeps the full session
        history)."""
        start = len(self.completed)
        for _ in self.generate(max_steps):
            pass
        return self.completed[start:]

    # -- telemetry --------------------------------------------------------
    def stats(self) -> EngineStats:
        """Live serving telemetry (accumulated since construction or the
        last `reset_stats()`)."""
        return self._stats

    def reset_stats(self):
        """Drop accumulated telemetry, keeping compiled steps (benchmarks:
        warm buckets up, reset, then measure)."""
        self._stats = EngineStats(batch_size=self.B)


# The original fixed-prompt-length engine grew into the session API above.
# The old name stays importable, but the constructor deliberately dropped
# `prompt_len` — variable-length prompts made it meaningless.
ServingEngine = InferenceEngine
