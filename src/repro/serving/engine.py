"""Session-based continuous-batching inference engine — thin façade wiring
queue -> SchedulerPolicy -> ModelRunner.

The pre-split engine fused admission policy, step execution and cache
bookkeeping into one class; it is now three layers with explicit seams:

  serving/tasks.py      what a client wants: `GenerateTask` (NAR prefill +
                        AR decode, the paper's decoder topology) and
                        `EncodeTask` (one pooled NAR pass, the paper's
                        encoder topology), each with priority/deadline.
  serving/scheduler.py  what runs next: `SchedulerPolicy` (FCFS / priority
                        + aging / chunked prefill) — pure host-side
                        ordering + preemption-victim selection.
  serving/runner.py     how it runs: `ModelRunner` owns the compiled
                        steps, caches, block pool and sampling lanes, and
                        exposes prefill / chunk_step / decode / encode with
                        no policy logic.

Engine mechanics preserved from the pre-split engine (see runner.py for
the paging details): a fixed decode batch of B slots runs lockstep AR
steps; finished rows are immediately replaced by prefilling queued
requests; KV memory is block-paged with recompute preemption when the pool
exhausts; admission is batched per prefill-length bucket; per-request
sampling happens inside the jitted steps; `generate()` streams
`TokenEvent`s and `stats()` returns `EngineStats`.

New in the split:

  scheduler=            any SchedulerPolicy; FCFSPolicy (default) is
                        token-for-token identical to the pre-split engine.
  EncodeTask serving    encoder-only requests batch into pooled
                        full-sequence passes (no slots, no KV) interleaved
                        with generate traffic — mixed workloads share one
                        engine.
  chunked prefill       ChunkedPrefillPolicy(chunk_tokens=N): prompts
                        longer than N prefill in N-token pieces between
                        decode steps, so a long admission never stalls
                        running AR slots for its whole prefill (outputs
                        stay token-identical to FCFS; the decode-stall p95
                        drop is measured by benchmarks/serving_bench.py).

Back-compat: `InferenceEngine(cfg, params, batch_size=..., max_seq=...,
policy=<precision>)`, `submit/generate/run/stats/reset_stats/has_work`,
`Request` (= GenerateTask), `ServingEngine` (= InferenceEngine), and the
paged internals tests/benches touch (`allocator`, `layout`,
`block_tables`, `steps_run`, `bucket_for`) all keep working unmodified.
"""
from __future__ import annotations

import time
from typing import Iterator, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.serving.runner import DecodeHandle, ModelRunner
from repro.serving.sampling import validate_sampling
from repro.serving.scheduler import FCFSPolicy, SchedulerPolicy
from repro.serving.spec import SpecConfig
from repro.serving.stats import EngineStats
from repro.serving.tasks import (EncodeTask, GenerateTask, Rejection,
                                 Request, Task, TokenEvent, validate_task)


class InferenceEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_size: int = 4,
                 max_seq: int = 256, mesh=None, policy=None,
                 min_bucket: int = 8, paged: bool = True,
                 block_size: int = 16, kv_pool_blocks: Optional[int] = None,
                 scheduler: Optional[SchedulerPolicy] = None,
                 encode_batch: Optional[int] = None,
                 fuse_epilogues: bool = True,
                 spec: Optional[SpecConfig] = None, draft_params=None,
                 draft_checkpoint: Optional[str] = None,
                 prefix_cache: bool = False,
                 cache_blocks: Optional[int] = None,
                 weight_dtype: str = "bfloat16",
                 kv_dtype: Optional[str] = None,
                 overlap: bool = False,
                 tracer=None):
        # `policy` is the PRECISION policy (pre-split name, kept for
        # back-compat); the scheduling policy is `scheduler`.  `spec`
        # turns on speculative decoding (serving/spec.py): the runner
        # owns a draft LM (params from `draft_params`, the target itself
        # for draft="self", or a seeded init) and replaces per-token
        # decode steps with propose->verify->commit rounds.
        # `draft_checkpoint` loads the draft params from a
        # checkpoint/checkpointer.py directory instead (shape-checked
        # against the spec's resolved draft config; mutually exclusive
        # with draft_params).
        # `prefix_cache` turns on refcounted KV prefix sharing
        # (serving/prefix_cache.py): retired requests' blocks stay indexed
        # by token content and warm admissions prefill only their uncached
        # suffix; `cache_blocks` caps how many pool blocks the index may
        # hold (None = bounded by pool pressure alone).
        # `weight_dtype="int8"` quantizes the dense GEMM weights per output
        # channel (models/quantize); `kv_dtype="int8"` stores the paged KV
        # pools int8 with per-block-per-head scales.  Both default to
        # lossless bf16.
        # `tracer` (serving/trace.py Tracer, or None) turns on opt-in
        # structured tracing: request lifecycle spans and engine-step
        # spans land in its ring buffer, exportable as a Chrome trace.
        # Pure observer — with tracer=None every hook is one falsy
        # branch and tokens are identical under all traffic.
        # `overlap=True` switches to the async overlapped host loop: the
        # engine dispatches a decode step and runs host-side scheduling /
        # admission (and, in steady state, even the NEXT dispatch) before
        # fetching the step's tokens, hiding host work under device time.
        # Token-identical to the synchronous loop for greedy and sampled
        # traffic (tests/test_goodput.py).
        if draft_checkpoint is not None:
            if spec is None:
                raise ValueError(
                    "draft_checkpoint requires a SpecConfig (`spec=`)")
            if draft_params is not None:
                raise ValueError(
                    "pass draft_params OR draft_checkpoint, not both")
            draft_params = self._restore_draft(cfg, params, spec,
                                               draft_checkpoint)
        self.runner = ModelRunner(cfg, params, batch_size=batch_size,
                                  max_seq=max_seq, mesh=mesh, policy=policy,
                                  min_bucket=min_bucket, paged=paged,
                                  block_size=block_size,
                                  kv_pool_blocks=kv_pool_blocks,
                                  fuse_epilogues=fuse_epilogues,
                                  spec=spec, draft_params=draft_params,
                                  prefix_cache=prefix_cache,
                                  cache_blocks=cache_blocks,
                                  weight_dtype=weight_dtype,
                                  kv_dtype=kv_dtype,
                                  tracer=tracer)
        self.tracer = tracer
        self.scheduler = scheduler or FCFSPolicy()
        self.encode_batch = encode_batch or batch_size
        self.queue: List[Task] = []
        self.completed: List[Task] = []
        # requests dropped unserved by the scheduler's shed decision, each
        # carrying a typed `rejection` (they also appear in `completed` so
        # run()/generate() callers see every submitted uid resolve)
        self.shed: List[Task] = []
        self.overlap = overlap
        self._pending: Optional[DecodeHandle] = None
        self._degrade = 0                  # current scheduler degrade level
        self._stats = self._fresh_stats()
        self._prefix_base = self._prefix_snapshot()
        self._t_last_decode: Optional[float] = None

    @staticmethod
    def _restore_draft(cfg: ModelConfig, params, spec: SpecConfig,
                       directory: str):
        """Load draft params from a Checkpointer directory: resolve the
        spec's draft config, eval_shape the init to get the reference
        tree (leaf count / shapes / dtypes checked by restore — a
        mismatched checkpoint fails loudly, not with silent garbage), and
        restore into it.  The draft inherits the target params' dtype,
        matching the in-memory seeded-init convention bit for bit."""
        import functools
        import jax
        from repro.checkpoint.checkpointer import Checkpointer
        from repro.models import lm as lm_mod
        from repro.serving.spec import resolve_draft
        dcfg = resolve_draft(spec, cfg)
        pdtype = jax.tree.leaves(params)[0].dtype
        like = jax.eval_shape(
            functools.partial(lm_mod.init_lm, cfg=dcfg, dtype=pdtype),
            jax.random.key(0))
        return Checkpointer(directory).restore(like)

    # -- delegated runner state (back-compat surface) -------------------
    @property
    def cfg(self):
        return self.runner.cfg

    @property
    def params(self):
        return self.runner.params

    @property
    def B(self) -> int:
        return self.runner.B

    @property
    def max_seq(self) -> int:
        return self.runner.max_seq

    @property
    def paged(self) -> bool:
        return self.runner.paged

    @property
    def layout(self):
        return self.runner.layout

    @property
    def allocator(self):
        return self.runner.allocator

    @property
    def block_tables(self):
        return self.runner.block_tables

    @property
    def prefix_cache(self):
        return self.runner.prefix_cache

    @property
    def slots(self):
        return self.runner.slots

    @property
    def steps_run(self) -> int:
        return self.runner.steps_run

    def bucket_for(self, prompt_len: int) -> int:
        return self.runner.bucket_for(prompt_len)

    def _fresh_stats(self) -> EngineStats:
        st = EngineStats(batch_size=self.runner.B)
        if self.runner.paged:
            st.kv_pool_blocks = self.runner.layout.num_blocks
            st.kv_block_size = self.runner.layout.block_size
        st.weight_dtype = self.runner.weight_dtype
        st.kv_dtype = self.runner.kv_dtype
        st.weight_bytes_per_device = self.runner.weight_bytes_per_device()
        st.kv_pool_bytes = self.runner.kv_pool_bytes()
        # per-token FLOP / byte constants for phase_util()'s MFU / MBU
        # attribution (analysis/roofline.py); encoder-only topologies and
        # configs without an active-param count leave them 0 (phase_util
        # then reports {})
        from repro.analysis.roofline import decoder_flops_per_token
        try:
            st.model_flops_per_token = decoder_flops_per_token(
                self.runner.cfg)
        except Exception:
            st.model_flops_per_token = 0.0
        if self.runner.paged:
            denom = (self.runner.layout.num_blocks
                     * self.runner.layout.block_size)
        else:
            denom = self.runner.B * self.runner.max_seq
        if denom > 0:
            st.kv_bytes_per_token = st.kv_pool_bytes / denom
        return st

    # -- admission -----------------------------------------------------
    def submit(self, task: Task):
        """Queue a GenerateTask (alias: Request) or EncodeTask."""
        # re-validate priority/deadline at submit: construction validated
        # too, but tasks can be mutated or dataclasses.replace'd since
        validate_task(task)
        n = len(task.prompt)
        if isinstance(task, EncodeTask):
            cap = self.runner.max_seq - self.runner._n_prefix
        else:
            cap = self.runner.prompt_cap
        assert 0 < n <= cap, (
            f"prompt length {n} not in [1, {cap}] "
            f"(max_seq={self.runner.max_seq}, "
            f"patch prefix={self.runner._n_prefix})")
        if isinstance(task, GenerateTask):
            assert task.max_new_tokens >= 1, (
                f"max_new_tokens must be >= 1 (the prefill emits the first "
                f"token): {task.max_new_tokens}")
            # submit-time sampling validation: a clear ValueError here
            # instead of a silent clamp (top_k) or misbehavior deep in the
            # jitted step (covers params built around __post_init__ too)
            validate_sampling(task.sampling)
            spec = self.runner.spec
            if (spec is not None and spec.acceptance == "greedy"
                    and not task.sampling.is_greedy):
                raise ValueError(
                    f"request {task.uid} samples (temperature="
                    f"{task.sampling.temperature}) but SpecConfig "
                    f"acceptance='greedy' serves greedy traffic only; "
                    f"use acceptance='lossless' for exact sampled "
                    f"speculation")
        task.prompt_len = n
        task._t_submit = time.perf_counter()
        self.queue.append(task)
        self._stats.requests_submitted += 1
        if self.tracer:
            ann = {"prompt_len": n}
            if getattr(task, "deadline_ms", None) is not None:
                ann["deadline_ms"] = task.deadline_ms
            self.tracer.instant("submit", task._t_submit, tid=task.uid,
                                **ann)

    def _first_admission(self, task: Task):
        # fresh clock, not the step-start timestamp: blocking encode/prefill
        # calls (possibly compiles) may have run earlier in this same step,
        # and they are part of this task's wait
        now = time.perf_counter()
        task.queue_wait_ms = (now - task._t_submit) * 1e3
        self._stats.add_queue_wait_ms(task.queue_wait_ms)
        if self.tracer:
            self.tracer.request_span(
                task.uid, "queue", task._t_submit, now,
                queue_wait_ms=task.queue_wait_ms,
                **self.scheduler.admission_annotation(task, now))

    def _chunk_budget(self) -> Optional[int]:
        """The per-step chunked-prefill token budget at the current
        degrade level (DeadlinePolicy halves it under pressure; chunk
        width only moves prefill FLOPs in time, never changes tokens)."""
        return self.scheduler.effective_chunk_tokens(self._degrade)

    def _chunkable(self, task: GenerateTask) -> bool:
        ct = self._chunk_budget()
        return (ct is not None and self.runner.supports_chunked
                and self.runner.full_len(task) > ct)

    def _note_admitted(self, task: Task):
        """Degrade ladder, per-request half: a generate task admitted
        while the scheduler reports pressure is served without speculation
        (spec_lookahead proposes 0 for it — exact, just no lookahead).
        The flag is sticky: 'admitted under pressure' stays true for the
        request's lifetime.

        Tree runners (spec.branches > 1) have a gentler first rung —
        level 1 only shrinks their trees to single-branch chains
        (step() flips runner._tree_chain_only), so per-request
        speculation-off waits for level 2.  Single-branch runners keep
        degrading at level 1, exactly as before trees existed."""
        thresh = 2 if self.runner.tree_branches > 1 else 1
        if (self._degrade >= thresh and isinstance(task, GenerateTask)
                and self.runner.spec is not None and not task.degraded):
            task.degraded = True
            self._stats.requests_degraded += 1
            if self.tracer:
                self.tracer.instant("degrade", time.perf_counter(),
                                    tid=task.uid, rung=self._degrade)

    def _shed_expired(self):
        """Drop queued requests whose SLO the policy proves unattainable:
        each gets a typed Rejection, done=True, and lands in both `shed`
        and `completed` unserved — capacity goes to requests that can
        still meet their deadline."""
        cands = self.scheduler.shed_candidates(self.queue,
                                               time.perf_counter())
        for task in cands:
            self.queue.remove(task)
            task.rejection = Rejection(
                "slo_unattainable",
                f"deadline_ms={task.deadline_ms:.1f} already exceeded "
                f"after {task.age_s() * 1e3:.1f}ms in queue "
                f"(policy={self.scheduler.name})")
            task.done = True
            self.shed.append(task)
            self.completed.append(task)
            self._stats.record_shed(task)
            if self.tracer:
                self.tracer.request_span(
                    task.uid, "shed", task._t_submit, time.perf_counter(),
                    reason=task.rejection.kind,
                    detail=task.rejection.detail)

    def _next_group(self, order: List[GenerateTask], max_n: int):
        """The next whole-prompt admission group: up to `max_n` tasks
        sharing the policy head's length bucket, each with its pool blocks
        allocated (all-or-nothing per task).  Empty when the head cannot
        get blocks — the caller waits for running requests to free some."""
        runner = self.runner
        head_bucket = runner.bucket_for(runner.full_len(order[0]))
        cands = [t for t in order
                 if runner.bucket_for(runner.full_len(t)) == head_bucket]
        cands = cands[:max_n]
        group = []
        for task in cands:
            blk = runner.alloc_for(task)
            if blk is None:
                break
            group.append((task, blk))
        if not group:
            self._pool_too_small_check(order[0])
        return group

    def _pool_too_small_check(self, head: GenerateTask):
        """Admission got nothing: fatal only when nothing is running (no
        retirement can ever free blocks for the head)."""
        runner = self.runner
        if runner.has_running():
            return
        need = runner.blocks_needed(head)
        raise RuntimeError(
            f"KV pool too small: request {head.uid} needs "
            f"{need} blocks, pool has {runner.allocator.num_blocks} "
            f"({runner.allocator.num_free} free) and no running "
            f"request can be preempted to free more")

    def _gen_queue(self) -> List[GenerateTask]:
        return [t for t in self.queue if isinstance(t, GenerateTask)]

    def _admit(self, fresh: List) -> int:
        """Admit generate tasks into free slots per the scheduling policy:
        cached-prefix hits seat with shared blocks and prefill only their
        suffix; whole-prompt groups prefill immediately; prompts over the
        chunk budget park in their slot and advance chunk-by-chunk."""
        runner = self.runner
        admitted = 0
        while True:
            free = runner.free_slots()
            gen = self._gen_queue()
            if not free or not gen:
                return admitted
            # fresh clock per iteration: earlier groups in this same step
            # ran blocking prefills, which age the remaining queue
            order = self.scheduler.admission_order(gen,
                                                   time.perf_counter())
            if runner.prefix_cache is not None:
                order = self.scheduler.cached_order(
                    order, runner.cached_tokens_for)
            head = order[0]
            if runner.prefix_cache is not None:
                res = runner.admit_cached(head, free[0])
                if res is False:
                    if runner.has_running():
                        return admitted    # retirement will free blocks
                    # nothing running and the warm layout still does not
                    # fit (e.g. the COW duplicate when the pool exactly
                    # matches the request): flush the cache and admit cold
                    runner.prefix_cache.clear()
                    res = None
                if res:
                    self.queue.remove(head)
                    if not head.output:
                        self._first_admission(head)
                    self._note_admitted(head)
                    ct = self._chunk_budget()
                    suffix = runner.full_len(head) - head.prefilled
                    if ct is not None and suffix > ct:
                        # over the chunk budget: stays parked, the budget
                        # loop in step() advances it
                        admitted += 1
                        continue
                    # run the whole suffix now (one bucketed chunk pass)
                    width = runner.bucket_for(suffix)
                    while runner.prefilling[free[0]]:
                        ev = runner.chunk_step(head, width, self._stats)
                        if ev is not None:
                            fresh.append(ev)
                    admitted += 1
                    continue
            if self._chunkable(head):
                blk = runner.alloc_for(head)
                if blk is None:
                    self._pool_too_small_check(head)
                    return admitted
                self.queue.remove(head)
                if not head.output:
                    self._first_admission(head)
                self._note_admitted(head)
                runner.begin_chunked(head, blk, free[0])
                admitted += 1
                continue
            group = self._next_group(order, len(free))
            if not group:
                return admitted
            for task, _ in group:
                self.queue.remove(task)
                if not task.output:
                    self._first_admission(task)
                self._note_admitted(task)
            fresh.extend(runner.prefill(group, free, self._stats))
            admitted += len(group)

    def _run_encode(self) -> int:
        """Run ONE same-bucket encode batch (policy-ordered) — encode uses
        no slots or cache, so it interleaves with generate admission; one
        batch per engine step keeps long encode backlogs from starving
        decode."""
        enc = [t for t in self.queue if isinstance(t, EncodeTask)]
        if not enc:
            return 0
        runner = self.runner
        order = self.scheduler.admission_order(enc, time.perf_counter())
        head = order[0]
        bucket = runner.encode_bucket_for(head.prompt_len)
        group = [t for t in order
                 if runner.encode_bucket_for(t.prompt_len) == bucket
                 and t.pooling == head.pooling][:self.encode_batch]
        for task in group:
            self.queue.remove(task)
            self._first_admission(task)
        runner.encode(group, self._stats)
        now = time.perf_counter()
        for task in group:
            self._stats.record_slo(task)
            self.completed.append(task)
            self._stats.requests_completed += 1
            if self.tracer:
                self.tracer.request_span(
                    task.uid, "request", task._t_submit, now,
                    latency_ms=task.latency_ms,
                    prompt_len=task.prompt_len, encode=True)
        return len(group)

    # -- retirement ------------------------------------------------------
    def _retire(self, skip=()):
        """Release finished decode slots.  `skip` holds slot indices with
        an UNCOMMITTED in-flight decode step (overlapped loop): their
        output/pos lag by one token, so the finished-check would both
        misjudge and drop the flying token — they retire after commit."""
        runner = self.runner
        pos = np.asarray(runner.pos)
        for b, task in enumerate(runner.slots):
            if task is None or runner.prefilling[b] or b in skip:
                continue
            tok = task.output[-1]
            if (len(task.output) >= task.max_new_tokens
                    or (task.eos_id is not None and tok == task.eos_id)
                    or int(pos[b]) >= self.runner.max_seq - 1):
                task.done = True
                now = time.perf_counter()
                task.latency_ms = (now - task._t_submit) * 1e3
                n = len(task.output)
                task.tpot_ms = ((task.latency_ms - task.ttft_ms) / (n - 1)
                                if n > 1 else 0.0)
                if n > 1:
                    self._stats.add_tpot_ms(task.tpot_ms)
                self._stats.record_slo(task)
                self.completed.append(task)
                self._stats.requests_completed += 1
                if self.tracer:
                    self.tracer.request_span(
                        task.uid, "request", task._t_submit, now,
                        ttft_ms=task.ttft_ms, tpot_ms=task.tpot_ms,
                        latency_ms=task.latency_ms, tokens=n,
                        prompt_len=task.prompt_len,
                        degraded=task.degraded,
                        cached_prefix=task.cached_prefix)
                runner.release_slot(b)

    # -- engine loop ------------------------------------------------------
    def step(self) -> List[TokenEvent]:
        """One engine iteration: encode batch -> shed -> admit -> chunk
        advance -> AR step -> retire.  Returns the TokenEvents produced
        (prefill first-tokens + decoded tokens), with `is_last` resolved
        against retirement.  With overlap=True the AR step's token fetch
        is deferred into the NEXT iteration so host scheduling work runs
        while the device computes (token-identical either way)."""
        if not self.tracer:
            return self._step_inner()
        t0 = time.perf_counter()
        events = self._step_inner()
        self.tracer.step_span(
            "engine_step", t0, time.perf_counter(),
            queued=len(self.queue), degrade=self._degrade,
            running=sum(s is not None for s in self.runner.slots),
            events=len(events))
        return events

    def _step_inner(self) -> List[TokenEvent]:
        self._shed_expired()
        self._degrade = self.scheduler.degrade_level(
            len(self._gen_queue()), self.runner.B)
        if self.runner.spec is not None and self.runner.tree_branches > 1:
            # degrade ladder, rung 1 (lossless): backlogged tree rounds
            # shrink to single-branch chains for as long as the pressure
            # lasts — a round-scoped flag, not sticky like `degraded`
            self.runner._tree_chain_only = self._degrade >= 1
        if self.overlap:
            return self._step_overlapped()
        return self._step_sync()

    def _advance_chunks(self, fresh: List):
        """Chunked-prefill advancement under a per-STEP token budget: the
        point of chunking is bounding the prefill work between two decode
        steps, so the budget is shared across prefilling slots (oldest
        admitted first), not per-slot — several long admissions in flight
        still cost at most ~chunk_tokens before the next AR step."""
        runner = self.runner
        ct = self._chunk_budget()
        budget = ct or 0
        for task in sorted((runner.slots[b] for b in range(runner.B)
                            if runner.slots[b] is not None
                            and runner.prefilling[b]),
                           key=lambda t: t._seq):
            if budget <= 0:
                break
            ev = runner.chunk_step(task, ct, self._stats)
            # every call costs one full compiled chunk_tokens-wide pass
            # (short final chunks are padded), so the budget is spent per
            # CALL, not per true token — with budget == chunk_tokens that
            # is exactly one chunk pass between AR steps
            budget -= ct
            if ev is not None:
                fresh.append(ev)

    def _token_events(self, fresh: List) -> List[TokenEvent]:
        return [TokenEvent(task.uid, task.output[i],
                           task.done and i == len(task.output) - 1)
                for task, i in fresh]

    def _step_sync(self) -> List[TokenEvent]:
        runner = self.runner
        fresh: List = []                  # (task, output index) pairs
        self._run_encode()
        # admit/retire until slots are full or the queue drains: a request
        # finished by its prefill token alone (max_new_tokens=1, prompt-eos,
        # pos cap) frees its slot (and blocks) for another admission before
        # the AR step.  A free slot the pool cannot serve yet is not
        # progress — stop and let the decode/retire cycle free blocks.
        while True:
            n_done = len(self.completed)
            admitted = self._admit(fresh)
            self._retire()
            if not self._gen_queue() or not runner.free_slots():
                break
            if not admitted and len(self.completed) == n_done:
                break
        self._advance_chunks(fresh)
        # retire before decode: the final chunk's token may end its request
        self._retire()
        if runner.decoding_slots():
            victim = lambda running: self.scheduler.select_victim(
                running, time.perf_counter())
            # speculation needs the verify chunk's blocks up front: the
            # lookahead extends the per-slot need to pos + k_eff
            la = runner.spec_lookahead() if runner.spec else None
            # each eviction goes to the queue head (most recently evicted
            # first), matching the pre-split engine's re-queue order
            for task in runner.ensure_decode_blocks(victim, self._stats,
                                                    lookahead=la):
                self.queue.insert(0, task)
            if runner.decoding_slots():
                t0 = time.perf_counter()
                if self._t_last_decode is not None:
                    # time decode slots sat idle since the last AR step —
                    # admission prefill work between decode steps shows up
                    # here (chunked prefill exists to bound it)
                    self._stats.add_decode_stall_ms(
                        (t0 - self._t_last_decode) * 1e3)
                fresh.extend(runner.spec_decode(self._stats) if runner.spec
                             else runner.decode(self._stats))
                self._t_last_decode = time.perf_counter()
                self._retire()
        if not runner.decoding_slots():
            self._t_last_decode = None    # idle gaps are not decode stalls
        return self._token_events(fresh)

    # -- async overlapped loop (overlap=True) ----------------------------
    def _pending_slots(self):
        return (frozenset(b for b, _ in self._pending.decoding)
                if self._pending is not None else frozenset())

    def _commit_pending(self, fresh: List):
        """Fetch the in-flight step's tokens.  Everything the engine did
        since its dispatch — encode, admission, chunk advancement, even the
        next dispatch — ran while the device computed it; that hidden host
        wall is the overlap win (`host_overlap_ratio`)."""
        handle = self._pending
        self._pending = None
        self._stats.overlap_host_s += max(
            0.0, time.perf_counter() - handle.t0)
        self._stats.overlapped_steps += 1
        fresh.extend(self.runner.decode_commit(handle, self._stats))
        self._t_last_decode = time.perf_counter()

    def _fast_dispatch_ok(self) -> bool:
        """True when the NEXT decode step may be dispatched BEFORE the
        pending step's tokens are fetched — the double-buffered steady
        state.  Requires proving, from host mirrors alone, that the
        pending commit cannot change any scheduling state: no retirement
        is possible (no eos watch, output budget and sequence horizon not
        at their last token) and every decoding slot already owns its next
        write block exclusively (no allocation, no preemption, no COW).
        Sampled traffic needs no special-casing: lanes sample inside the
        step keyed by (seed, position), independent of neighbors."""
        runner = self.runner
        if (runner.spec is not None          # acceptance is data-dependent
                or runner._tok_dev is None   # host token write intervened
                or self.queue                # admission may reseat slots
                or any(runner.prefilling)):  # chunk landing writes tokens
            return False
        pend = self._pending.decoding
        if not pend:
            return False
        for b, task in pend:
            if task.eos_id is not None:
                return False     # the flying token may be EOS
            if len(task.output) + 1 >= task.max_new_tokens:
                return False     # commit reaches the output budget
            if int(runner.pos[b]) >= runner.max_seq - 1:
                return False     # commit reaches the sequence horizon
            if not runner.next_token_block_ready(b):
                return False     # needs allocator/COW work first
        return True

    def _step_overlapped(self) -> List[TokenEvent]:
        runner = self.runner
        fresh: List = []
        if self._pending is not None and self._fast_dispatch_ok():
            # steady-state fast path: dispatch step N+1 chained on step
            # N's device-side token future, THEN fetch N's tokens — the
            # device never waits for the host round-trip.  No retirement
            # or admission is possible by _fast_dispatch_ok construction.
            nxt = runner.decode_dispatch()
            self._commit_pending(fresh)
            self._pending = nxt
            return self._token_events(fresh)
        # regular path: run every piece of host + non-decode device work
        # that cannot disturb the in-flight step BEFORE fetching its
        # tokens (encode batches, admission prefills and chunk advancement
        # chain device-side behind it; pending slots are skipped by
        # retirement until the commit lands their token)
        self._run_encode()
        pend = self._pending_slots()
        self._admit(fresh)
        self._retire(skip=pend)
        self._advance_chunks(fresh)
        if self._pending is not None:
            self._commit_pending(fresh)
        self._retire()
        # settle: slots freed by retirement admit more work this step,
        # exactly like the synchronous loop's admit/retire cycle
        while True:
            n_done = len(self.completed)
            admitted = self._admit(fresh)
            self._retire()
            if not self._gen_queue() or not runner.free_slots():
                break
            if not admitted and len(self.completed) == n_done:
                break
        if runner.decoding_slots():
            victim = lambda running: self.scheduler.select_victim(
                running, time.perf_counter())
            la = runner.spec_lookahead() if runner.spec else None
            for task in runner.ensure_decode_blocks(victim, self._stats,
                                                    lookahead=la):
                self.queue.insert(0, task)
            if runner.decoding_slots():
                t0 = time.perf_counter()
                if self._t_last_decode is not None:
                    self._stats.add_decode_stall_ms(
                        (t0 - self._t_last_decode) * 1e3)
                if runner.spec:
                    # speculation never pipelines: the round's commit /
                    # rollback depends on how many proposals verify
                    fresh.extend(runner.spec_decode(self._stats))
                    self._t_last_decode = time.perf_counter()
                    self._retire()
                else:
                    self._pending = runner.decode_dispatch()
        if not runner.decoding_slots() and self._pending is None:
            self._t_last_decode = None
        return self._token_events(fresh)

    def has_work(self) -> bool:
        return (bool(self.queue) or self.runner.has_running()
                or self._pending is not None)

    def generate(self, max_steps: int = 10_000) -> Iterator[TokenEvent]:
        """Streaming interface: run engine steps until queue + slots drain,
        yielding each token the moment its step completes."""
        for _ in range(max_steps):
            if not self.has_work():
                return
            yield from self.step()

    def run(self, max_steps: int = 10_000) -> List[Task]:
        """Batch interface: drain `generate()`; returns the tasks that
        completed during THIS call (`self.completed` keeps the full session
        history).  EncodeTasks carry their result in `.embedding`."""
        start = len(self.completed)
        for _ in self.generate(max_steps):
            pass
        return self.completed[start:]

    # -- telemetry --------------------------------------------------------
    def _prefix_snapshot(self):
        """Prefix-cache counters are cumulative on the cache/runner; the
        engine diffs them against the last reset so `stats()` windows
        compose like every other counter."""
        pc = self.runner.prefix_cache
        if pc is None:
            return None
        return (pc.lookups, pc.hits, pc.hit_tokens, pc.evicted_blocks,
                self.runner.cow_copies)

    def stats(self) -> EngineStats:
        """Live serving telemetry (accumulated since construction or the
        last `reset_stats()`)."""
        if self.runner.paged:
            # the allocator tracks the true high-water mark on every alloc,
            # including admissions that never reach a decode step
            self._stats.peak_blocks_used = self.runner.allocator.peak_used
        pc = self.runner.prefix_cache
        if pc is not None:
            base = self._prefix_base
            self._stats.prefix_lookups = pc.lookups - base[0]
            self._stats.prefix_hits = pc.hits - base[1]
            self._stats.cached_prefix_tokens = pc.hit_tokens - base[2]
            self._stats.evicted_blocks = pc.evicted_blocks - base[3]
            self._stats.cow_copies = self.runner.cow_copies - base[4]
            self._stats.cached_blocks = pc.cached_blocks
        return self._stats

    def reset_stats(self):
        """Drop accumulated telemetry, keeping compiled steps AND the
        prefix-cache contents (benchmarks: warm buckets + cache up, reset,
        then measure)."""
        if self.runner.paged:
            self.runner.allocator.peak_used = self.runner.allocator.num_used
        self._stats = self._fresh_stats()
        self._prefix_base = self._prefix_snapshot()
        # a stall sample must never span a reset (warm-up-then-measure)
        self._t_last_decode = None


# The original fixed-prompt-length engine grew into the session API above;
# the scheduler/runner split kept the façade.  The old names stay
# importable: `Request` is GenerateTask, `ServingEngine` is this class.
ServingEngine = InferenceEngine

__all__ = ["InferenceEngine", "ServingEngine", "Request", "GenerateTask",
           "EncodeTask", "TokenEvent"]
