"""Pluggable scheduling policies for the serving engine.

The engine's step loop is policy-free mechanics (queue -> policy -> runner);
everything *discretionary* — admission order, preemption victim choice, and
whether long prompts prefill whole or in token-budget chunks — lives behind
`SchedulerPolicy`:

  FCFSPolicy            arrival order, youngest-admitted preemption victim:
                        byte-for-byte the pre-split engine's behavior.
  PriorityPolicy        effective priority = priority + age / aging_s, so a
                        starving low-priority task eventually outranks fresh
                        high-priority arrivals (priority inversion is bounded
                        by aging_s * delta_priority seconds).  Preemption
                        evicts the lowest-effective-priority running task.
  ChunkedPrefillPolicy  FCFS ordering + `chunk_tokens`: prompts longer than
                        the budget prefill in fixed-size chunks interleaved
                        with decode steps (serving/runner.py carries chunk
                        state in the paged block tables), so admitting a
                        long prompt never stalls running AR slots for the
                        whole prefill.
  DeadlinePolicy        earliest-deadline-first over `deadline_ms` slack,
                        plus the two goodput levers the engine exposes:
                        SHED queued requests whose TTFT deadline is already
                        provably unattainable (typed Rejection instead of a
                        guaranteed-miss serve), and DEGRADE under queue
                        pressure (speculation off for newly admitted
                        requests, chunk budget halved) before shedding.
                        Degrade never changes tokens — speculation is
                        lossless and chunk width only moves prefill FLOPs
                        in time.

Policies are pure ordering/selection logic over host-side `Task` objects —
they never touch device state, steps, or caches, which is what makes them
pluggable: a new policy is a subclass, not an engine fork.
"""
from __future__ import annotations

from abc import ABC, abstractmethod
from typing import List, Optional, Sequence

from repro.serving.tasks import Task


class SchedulerPolicy(ABC):
    """Admission ordering + preemption victim selection.

    `chunk_tokens`: None => whole-prompt prefill; an int => the engine asks
    the runner to prefill at most this many prompt tokens per engine step
    for prompts that exceed it (falls back to whole-prompt prefill on archs
    whose cache layout cannot carry chunk state — see
    ModelRunner.supports_chunked).
    """

    name: str = "policy"
    chunk_tokens: Optional[int] = None
    # cache-aware admission (prefix cache only): stable-resort the
    # admission order by descending cached-prefix length — a mostly-cached
    # request admits almost for free, so serving it first costs the rest of
    # the queue the least prefill wall-time.  Off by default: plain FCFS
    # order stays byte-for-byte the pre-split behavior.
    cache_aware: bool = False

    @abstractmethod
    def admission_order(self, queue: Sequence[Task],
                        now: float) -> List[Task]:
        """The queue in the order admission should consider it (a new list;
        the engine's queue itself is arrival-ordered and never reordered —
        completed/admitted entries are removed by identity)."""

    def cached_order(self, order: List[Task], cached_tokens) -> List[Task]:
        """Apply cache-aware admission to an `admission_order` result.
        `cached_tokens(task) -> int` is the engine's peek into the prefix
        cache (no LRU touch, no hit-rate skew).  The sort is stable: ties —
        including the all-cold case — preserve the policy's own order."""
        if not self.cache_aware:
            return order
        return sorted(order, key=lambda t: -cached_tokens(t))

    def select_victim(self, running: Sequence[Task], now: float) -> Task:
        """The running task to preempt when the KV pool is exhausted.
        Default: the most recently admitted (youngest) — it has the least
        decode progress to recompute."""
        return max(running, key=lambda t: t._seq)

    # -- goodput hooks (no-ops outside DeadlinePolicy) ------------------
    def shed_candidates(self, queue: Sequence[Task],
                        now: float) -> List[Task]:
        """Queued tasks to drop with a typed Rejection because their SLO
        is provably unattainable.  Default: never shed."""
        return []

    def degrade_level(self, n_queued: int, n_slots: int) -> int:
        """0 = full service; >= 1 = the engine should degrade (disable
        speculation for newly admitted requests, shrink the chunk budget)
        before any shedding.  Default: never degrade."""
        return 0

    def effective_chunk_tokens(self, level: int) -> Optional[int]:
        """The chunk budget at a given degrade level (None = whole-prompt
        prefill regardless of level)."""
        return self.chunk_tokens

    def admission_annotation(self, task: Task, now: float) -> dict:
        """Trace-span args explaining WHY this task admits now (pure
        observer for serving/trace.py — never consulted by admission
        itself).  Policies add their ordering key: EDF its slack,
        priority its effective priority."""
        return {"policy": self.name}


class FCFSPolicy(SchedulerPolicy):
    """First-come-first-served: today's (pre-split) engine behavior."""

    name = "fcfs"

    def admission_order(self, queue: Sequence[Task],
                        now: float) -> List[Task]:
        return list(queue)


class PriorityPolicy(SchedulerPolicy):
    """Priority + age ordering with bounded inversion.

    effective(t) = t.priority + t.age_s(now) / aging_s

    A task of priority p waiting longer than `aging_s * (q - p)` seconds
    outranks a fresh task of priority q, so no task starves.  Tasks with a
    `deadline_ms` get a second boost as the deadline approaches (urgency
    grows linearly to +`deadline_boost` at the deadline)."""

    name = "priority"

    def __init__(self, aging_s: float = 10.0, deadline_boost: float = 1.0):
        assert aging_s > 0, aging_s
        self.aging_s = aging_s
        self.deadline_boost = deadline_boost

    def effective_priority(self, task: Task, now: float) -> float:
        p = task.priority + task.age_s(now) / self.aging_s
        if task.deadline_ms is not None and task.deadline_ms > 0:
            urgency = min(1.0, task.age_s(now) * 1e3 / task.deadline_ms)
            p += self.deadline_boost * urgency
        return p

    def admission_order(self, queue: Sequence[Task],
                        now: float) -> List[Task]:
        # stable sort: equal effective priority keeps arrival order
        return sorted(queue, key=lambda t: -self.effective_priority(t, now))

    def select_victim(self, running: Sequence[Task], now: float) -> Task:
        # evict the least important; among equals, the youngest (least
        # decode progress lost to recompute)
        return min(running, key=lambda t: (self.effective_priority(t, now),
                                           -t._seq))

    def admission_annotation(self, task: Task, now: float) -> dict:
        return {"policy": self.name,
                "effective_priority": self.effective_priority(task, now)}


class ChunkedPrefillPolicy(FCFSPolicy):
    """FCFS admission, but long prompts prefill in `chunk_tokens`-sized
    pieces interleaved with decode steps (continuous batching's chunked
    prefill).  Token outputs are identical to FCFSPolicy — chunking changes
    *when* prefill FLOPs run, never what they compute."""

    name = "chunked"

    def __init__(self, chunk_tokens: int = 32):
        assert chunk_tokens >= 1, chunk_tokens
        self.chunk_tokens = chunk_tokens


class DeadlinePolicy(SchedulerPolicy):
    """Earliest-deadline-first scheduling with load shedding and degrade —
    the goodput policy (requests/s meeting their SLO, not raw throughput).

    Admission runs in ascending `slack_ms` = deadline_ms - age (tightest
    deadline first; no-deadline tasks have infinite slack and keep arrival
    order after every deadlined task).  Preemption evicts the MOST slack
    (an undeadlined or far-from-deadline task loses the least goodput to a
    recompute).  Both are stable, so equal-deadline traffic degenerates to
    exact FCFS.

    shed: a queued request that has not produced its first token and whose
    `age + ttft_floor_ms` already exceeds its deadline can no longer meet
    its TTFT SLO under ANY schedule — serving it burns prefill + decode
    capacity on a guaranteed miss, starving requests that could still win.
    With the default floor of 0 this is pure expiry (provable with zero
    assumptions about service time); a measured floor sheds earlier.

    degrade: a two-rung lossless ladder keyed to the generate backlog.
    Level 1 (backlog > `degrade_depth` requests per decode slot) shrinks
    token-tree speculation to single-branch chains (the tree's sibling
    lookahead is the widest per-step variance source) and halves the
    chunk budget (engine-wide).  Level 2 (backlog > 2x the same
    threshold) additionally serves newly admitted requests with
    speculation off entirely (per request, sticky).  Engines running
    single-branch speculation have no rung-1 tree to shrink, so the
    engine applies the per-request half at level >= 1 for them —
    identical to the pre-tree ladder.  Tokens never change on any rung:
    speculation is exact at every width and depth (serving/spec.py) and
    chunk width only moves prefill FLOPs in time."""

    name = "deadline"

    def __init__(self, chunk_tokens: Optional[int] = None,
                 shed: bool = True, ttft_floor_ms: float = 0.0,
                 degrade_depth: float = 2.0):
        assert ttft_floor_ms >= 0, ttft_floor_ms
        assert degrade_depth >= 0, degrade_depth
        self.chunk_tokens = chunk_tokens
        self.shed = shed
        self.ttft_floor_ms = ttft_floor_ms
        self.degrade_depth = degrade_depth

    def admission_order(self, queue: Sequence[Task],
                        now: float) -> List[Task]:
        # stable: equal slack (and the all-inf no-deadline tail) keeps
        # arrival order
        return sorted(queue, key=lambda t: t.slack_ms(now))

    def select_victim(self, running: Sequence[Task], now: float) -> Task:
        # evict the most slack; among equals the youngest (least decode
        # progress lost to the recompute)
        return max(running, key=lambda t: (t.slack_ms(now), t._seq))

    def shed_candidates(self, queue: Sequence[Task],
                        now: float) -> List[Task]:
        if not self.shed:
            return []
        # `output` non-empty means the first token was already produced
        # (a preemption re-queue): its TTFT is decided, shedding it now
        # throws away real progress for no SLO gain
        return [t for t in queue
                if t.deadline_ms is not None
                and not getattr(t, "output", None)
                and t.age_s(now) * 1e3 + self.ttft_floor_ms > t.deadline_ms]

    def degrade_level(self, n_queued: int, n_slots: int) -> int:
        thresh = self.degrade_depth * max(1, n_slots)
        if n_queued > 2 * thresh:
            return 2
        return 1 if n_queued > thresh else 0

    def effective_chunk_tokens(self, level: int) -> Optional[int]:
        if self.chunk_tokens is None or level <= 0:
            return self.chunk_tokens
        # halved, floored: a tiny chunk step is all padding overhead
        # (levels 1 and 2 share the one halving — the ladder's second
        # rung is about speculation, not chunk width)
        return max(8, self.chunk_tokens // 2)

    def admission_annotation(self, task: Task, now: float) -> dict:
        ann = {"policy": self.name}
        slack = task.slack_ms(now)
        if slack != float("inf"):
            ann["edf_slack_ms"] = slack
        return ann


POLICIES = {
    "fcfs": FCFSPolicy,
    "priority": PriorityPolicy,
    "chunked": ChunkedPrefillPolicy,
    "deadline": DeadlinePolicy,
}


def make_policy(name: str, *, chunk_tokens: Optional[int] = None,
                aging_s: float = 10.0,
                cache_aware: bool = False) -> SchedulerPolicy:
    """CLI-friendly factory (launch/serve.py --policy)."""
    if name == "fcfs":
        p = FCFSPolicy()
    elif name == "priority":
        p = PriorityPolicy(aging_s=aging_s)
    elif name == "chunked":
        p = ChunkedPrefillPolicy(chunk_tokens or 32)
    elif name == "deadline":
        p = DeadlinePolicy(chunk_tokens=chunk_tokens)
    else:
        raise KeyError(f"unknown policy {name!r}; known: {sorted(POLICIES)}")
    p.cache_aware = cache_aware
    return p
