"""ModelRunner: policy-free model execution for the serving engine.

Owns everything device-side — the compiled `StepBundle`s (decode, per-bucket
prefill, chunked prefill, per-bucket encode), the live caches, the
`BlockAllocator` and block tables, the sampling lanes, and the per-slot
token/pos state — and exposes exactly four execution verbs:

  prefill(group, stats)       one batched NAR pass admitting a group of
                              GenerateTasks into free decode slots
  chunk_step(task, stats)     advance one chunked-prefill piece for a task
                              parked in a slot (see begin_chunked)
  decode(stats)               one AR step over every *decoding* slot
                              (= decode_dispatch() + decode_commit(): the
                              overlapped engine loop splits them, running
                              host scheduling work — or the next dispatch —
                              between launch and token fetch)
  spec_decode(stats)          one speculative round (draft proposals ->
                              multi-token verify -> commit/rollback) over
                              every decoding slot, replacing decode() when
                              a SpecConfig is set (serving/spec.py)
  encode(group, stats)        one pooled full-sequence pass for a batch of
                              EncodeTasks (no slots, no cache)

No scheduling decisions happen here: which tasks to admit, in what order,
in what chunk budget, and who to preempt is the SchedulerPolicy's job
(serving/scheduler.py); the engine (serving/engine.py) wires queue ->
policy -> runner.  The runner's only "choice" is mechanical bookkeeping:
block alloc/free, table maintenance, lane scatter, retirement plumbing.

Chunked-prefill state: a task mid-chunk occupies a slot but its block-table
row is NOT installed in the decode tables until the final chunk lands — so
interleaved decode steps write nothing into its blocks (absent table rows
scatter-drop) and its garbage token/pos rows are ignored.  The chunk state
that persists between engine steps is exactly (block tables, prefilled
count): what PR 2's paged layout already carries.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import blocks
from repro.launch import steps as steps_mod
from repro.serving.kv_cache import (BlockAllocator, make_block_copy,
                                    make_prefill_scatter, make_row_copy,
                                    zero_caches)
from repro.models.quantize import quantize_params
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampling import (device_lane, set_lane, stack_lanes,
                                    stack_prefill_lanes, zero_lane)
from repro.serving.spec import (DraftState, SpecConfig, accept_length,
                                accept_tree_path, build_tree, resolve_draft,
                                round_annotation, spec_support_reason,
                                trim_emitted)
from repro.serving.stats import EngineStats
from repro.serving.tasks import EncodeTask, GenerateTask, Task


@dataclass
class DecodeHandle:
    """One in-flight AR step: the device token future plus the host
    snapshot needed to commit it later.  `decode_dispatch` returns one;
    `decode_commit` consumes it.  The overlapped engine loop
    (serving/engine.py, overlap=True) holds at most one pending handle and
    runs host scheduling work — or even dispatches the NEXT step, chained
    on `tok_d` device-side — before fetching this one's tokens."""
    tok_d: object                               # [B] int32 device future
    t0: float                                   # dispatch wall-clock
    decoding: List[Tuple[int, GenerateTask]]    # slots this step decoded
    live_tokens: int                            # post-step pos over decoding
    blocks_used: int                            # allocator.num_used at dispatch
    t_disp: float = 0.0                         # dispatch-return wall-clock
    #                                             (set only when tracing: the
    #                                             commit-side overlap lag is
    #                                             t_fetch - t_disp)


def _device_nbytes(x) -> int:
    """Bytes one device holds for array `x` (first addressable shard;
    replicated arrays charge their full size, as every device keeps a
    copy)."""
    shards = getattr(x, "addressable_shards", None)
    if shards:
        return shards[0].data.nbytes
    return x.nbytes


class ModelRunner:
    """Compiled steps + caches + pool for one serving engine instance."""

    def __init__(self, cfg: ModelConfig, params, *, batch_size: int = 4,
                 max_seq: int = 256, mesh=None, policy=None,
                 min_bucket: int = 8, paged: bool = True,
                 block_size: int = 16, kv_pool_blocks: Optional[int] = None,
                 fuse_epilogues: bool = True,
                 spec: Optional[SpecConfig] = None, draft_params=None,
                 prefix_cache: bool = False,
                 cache_blocks: Optional[int] = None,
                 weight_dtype: str = "bfloat16",
                 kv_dtype: Optional[str] = None,
                 tracer=None):
        assert min_bucket >= 1, f"min_bucket must be >= 1: {min_bucket}"
        assert weight_dtype in ("bfloat16", "int8"), weight_dtype
        assert kv_dtype in (None, "bfloat16", "int8"), kv_dtype
        self.cfg = cfg
        # opt-in structured tracer (serving/trace.py).  Every hook below is
        # behind one `if self.tracer:` branch — disabled tracing costs a
        # single falsy check and observes nothing (token identity by
        # construction: hooks never feed back into scheduling or sampling).
        self.tracer = tracer
        # weight-only int8 (models/quantize): the dense GEMM weights are
        # quantized ONCE here, per output channel; every compiled step then
        # streams int8 tiles and dequantizes inside the fp32 epilogue.
        # `params` arrives as the usual full-precision tree.
        self.weight_dtype = weight_dtype
        if weight_dtype == "int8":
            params = quantize_params(params)
        self.params = params
        self.B = batch_size
        self.max_seq = max_seq
        self.min_bucket = min_bucket
        self.mesh = mesh
        self.policy = policy                 # precision policy (not sched)
        # fused prologue/epilogue GEMM pipeline (sharding/plan.py); the
        # unfused chain is kept for A/B parity (token-identical on the
        # reference dispatch path)
        self.fuse_epilogues = fuse_epilogues
        # pad-to-bucket is exact only for linear attention caches; recurrent
        # / ring-buffer archs (SSM hybrids, sliding window) prefill at exact
        # prompt length — their state would absorb pad positions
        self._pad_buckets = not (cfg.has_ssm or cfg.sliding_window > 0)
        # encode has no cache: padding is exact whenever every kind is
        # causal (pads sit after the true positions and are never pooled);
        # bidirectional kinds (enc/vit, or causal=False) attend their pads
        self._encode_pad = all(blocks.kind_causal(k, cfg)
                               for k, _ in cfg.schedule)
        # VLM patch prefix rides along in every prefill: it consumes cache
        # positions, shrinking the token budget a prompt may use
        self._n_prefix = cfg.n_patches or 0
        dshape = ShapeConfig("engine_decode", "decode", max_seq, batch_size)
        # the pool is shared across slots: a batch-sharded decode would give
        # each data shard a divergent pool copy -> fall back to dense rows
        if paged and steps_mod.serve_dp(cfg, dshape, mesh) > 1:
            paged = False
        self.paged = paged
        # int8 KV needs the block-paged pools (per-block scale granularity);
        # a dense fallback layout silently stays lossless bf16
        self.kv_dtype = ("int8" if kv_dtype == "int8" and paged
                         else "bfloat16")
        if paged:
            default_blocks = batch_size * (-(-max_seq // block_size))
            paged_arg: Optional[Tuple[int, int]] = (
                kv_pool_blocks or default_blocks, block_size)
        else:
            paged_arg = None
        self.decode_step = steps_mod.make_decode_step(
            cfg, dshape, mesh, policy=policy, max_seq=max_seq,
            with_sampling=True, paged=paged_arg,
            kv_cache_dtype=self.kv_dtype, weight_dtype=weight_dtype,
            fuse_epilogues=fuse_epilogues)
        self.layout = self.decode_step.aux["paged"]
        self._prefill_steps: Dict[tuple, steps_mod.StepBundle] = {}
        self._encode_steps: Dict[tuple, steps_mod.StepBundle] = {}
        self._chunk_steps: Dict[int, steps_mod.StepBundle] = {}
        self.caches = zero_caches(self.decode_step.aux["cache_struct"],
                                  steps_mod.to_shardings(
                                      self.decode_step.aux["cache_specs"],
                                      mesh))
        if self.paged:
            self.allocator = BlockAllocator(self.layout.num_blocks,
                                            self.layout.block_size)
            self.block_tables = np.full(
                (batch_size, self.layout.max_blocks), -1, np.int32)
            self._scatter = make_prefill_scatter(self.layout.segments,
                                                 self.layout.block_size)
        else:
            self.allocator = None
            self.block_tables = None
            self._scatter = make_prefill_scatter(
                (False,) * len(cfg.schedule), 1)
        # chunked prefill needs every segment's KV in the pool (the tables
        # ARE the chunk state) and a token-only causal stack
        self.chunk_unsupported_reason = steps_mod.chunk_support_reason(
            cfg, self.layout if self.paged else None)
        self.supports_chunked = self.chunk_unsupported_reason is None
        # -- prefix cache (serving/prefix_cache.py): warm admissions reuse
        # cached prompt-prefix blocks and chunk-prefill only the suffix;
        # requires the chunk stack (suffix prefill IS a chunk at pos0 > 0)
        self.prefix_cache: Optional[PrefixCache] = None
        self.prefix_cache_reason: Optional[str] = None
        self.cow_copies = 0
        if prefix_cache:
            if self.supports_chunked:
                self.prefix_cache = PrefixCache(
                    self.allocator, self.layout.block_size,
                    max_blocks=cache_blocks)
                self._block_copy = make_block_copy(self.layout.segments)
            else:
                self.prefix_cache_reason = self.chunk_unsupported_reason
        self._slot_blocks: List[List[int]] = [[] for _ in range(batch_size)]
        self._tables_dev = None            # device copy, rebuilt when dirty
        self._admit_seq = 0
        # -- speculative decoding (serving/spec.py) --------------------
        # the draft LM is a second, much smaller model sharing the
        # target's vocabulary: its own dense per-slot cache, its own
        # decode/prefill bundles, proposals verified by ONE multi-token
        # target pass (make_verify_step) per round instead of one target
        # pass per token
        self.spec = spec
        if spec is not None:
            reason = spec_support_reason(cfg)
            if reason is None and not self.supports_chunked:
                reason = ("engine layout cannot carry multi-token verify "
                          "state (paged KV cache with every segment paged "
                          "and dp == 1 required)")
            if reason is not None:
                raise ValueError(f"speculative decoding unsupported for "
                                 f"{cfg.name}: {reason}")
            self.draft_cfg = resolve_draft(spec, cfg)
            # the draft LM stays bf16 — it is tiny (its weight traffic is
            # noise next to the target's) — unless it IS the target
            # ("self"), whose params are already quantized above
            self._draft_wdt = (weight_dtype if spec.draft == "self"
                               else "bfloat16")
            if spec.draft == "self":
                self.draft_params = params
            elif draft_params is not None:
                self.draft_params = draft_params
            else:
                from repro.models import lm as lm_mod
                pdtype = jax.tree.leaves(params)[0].dtype
                self.draft_params = lm_mod.init_lm(
                    jax.random.key(spec.draft_seed), self.draft_cfg, pdtype)
            # token-tree speculation (spec.branches > 1): the draft's
            # top-b candidates per depth become sibling leaves verified in
            # one tree-masked pass.  int8 KV pools force the single-chain
            # round: accepted-path compaction moves KV rows ACROSS blocks,
            # which per-block quantization scales cannot follow.
            self.tree_branches = (1 if self.kv_dtype == "int8"
                                  else spec.branches)
            self._tree_chain_only = False  # engine degrade rung 1 sets it
            self._round_depth: Optional[np.ndarray] = None
            self._round_width: Optional[np.ndarray] = None
            dshape_draft = ShapeConfig("draft_decode", "decode",
                                       max_seq, batch_size)
            if self.tree_branches > 1:
                self.draft_topk_step = steps_mod.make_draft_topk_step(
                    self.draft_cfg, dshape_draft, mesh,
                    branches=self.tree_branches, policy=policy,
                    max_seq=max_seq, weight_dtype=self._draft_wdt,
                    fuse_epilogues=fuse_epilogues)
                self.draft_decode_step = None
                self.tree_verify_step = steps_mod.make_tree_verify_step(
                    cfg, dshape, mesh, layout=self.layout,
                    num_tokens=1 + spec.k * self.tree_branches,
                    policy=policy, max_seq=max_seq,
                    kv_cache_dtype=self.kv_dtype, weight_dtype=weight_dtype,
                    fuse_epilogues=fuse_epilogues)
                self.verify_step = None
                self._row_copy = make_row_copy(self.layout.segments)
                dstep = self.draft_topk_step
            else:
                self.draft_decode_step = steps_mod.make_decode_step(
                    self.draft_cfg, dshape_draft,
                    mesh, policy=policy, max_seq=max_seq, with_sampling=True,
                    paged=None, weight_dtype=self._draft_wdt,
                    fuse_epilogues=fuse_epilogues)
                self.draft_topk_step = None
                self.verify_step = steps_mod.make_verify_step(
                    cfg, dshape, mesh, layout=self.layout,
                    num_tokens=spec.k + 1, policy=policy, max_seq=max_seq,
                    kv_cache_dtype=self.kv_dtype, weight_dtype=weight_dtype,
                    fuse_epilogues=fuse_epilogues)
                self.tree_verify_step = None
                dstep = self.draft_decode_step
            self.draft_caches = zero_caches(
                dstep.aux["cache_struct"],
                steps_mod.to_shardings(dstep.aux["cache_specs"], mesh))
            self._draft_prefill_steps: Dict[tuple,
                                            steps_mod.StepBundle] = {}
            self._draft_scatter = make_prefill_scatter(
                (False,) * len(self.draft_cfg.schedule), 1)
            self.draft_states: List[Optional[DraftState]] = (
                [None] * batch_size)
        else:
            self.draft_cfg = None
            self.tree_branches = 1
            self.draft_states = [None] * batch_size
        # token/pos live HOST-side: per-slot updates (prefill landing, chunk
        # completion) index by a python int, and a device `.at[b].set()`
        # would jit-compile once per distinct slot index — a 20-50ms spike
        # in the middle of serving.  [B] int32 transfers per step are noise.
        self.tokens = np.zeros((batch_size,), np.int32)
        self.pos = np.zeros((batch_size,), np.int32)
        # device-side copy of `tokens` chained from the last decode step's
        # output: a dispatch may feed it straight back into the next step
        # without a host round-trip.  Any HOST write to a token row
        # (prefill landing, chunk completion, spec commit) invalidates it.
        self._tok_dev = None
        self._t_last_commit: Optional[float] = None
        self.lane = zero_lane(batch_size)
        self.slots: List[Optional[GenerateTask]] = [None] * batch_size
        # slots holding a task whose prompt is still chunk-prefilling: their
        # table rows stay out of the decode tables and their token/pos rows
        # are garbage until the final chunk lands
        self.prefilling: List[bool] = [False] * batch_size
        self.steps_run = 0

    # -- resident-memory telemetry -------------------------------------
    def weight_bytes_per_device(self) -> int:
        """Per-device resident bytes of the target params (int8 `q` leaves
        count 1 byte/elem; their fp32 scales ride along)."""
        return sum(_device_nbytes(x) for x in jax.tree.leaves(self.params))

    def kv_pool_bytes(self) -> int:
        """Per-device resident bytes of the live decode caches — the paged
        pools plus their scale leaves and any dense (ring / cross-attn /
        SSM) state."""
        return sum(_device_nbytes(x) for x in jax.tree.leaves(self.caches))

    # -- capacity / bucket geometry ------------------------------------
    @property
    def prompt_cap(self) -> int:
        """Longest admissible prompt (one decode position + patch prefix
        reserved)."""
        return self.max_seq - 1 - self._n_prefix

    def bucket_for(self, prompt_len: int) -> int:
        """Prefill length bucket for a prompt: smallest rung of
        {m, 1.5m} x 2^k >= max(min_bucket, len), capped at the token budget
        (max_seq minus any patch prefix); exact length for archs whose
        caches cannot absorb padding."""
        if not self._pad_buckets:
            return prompt_len
        return self._bucket(prompt_len)

    def encode_bucket_for(self, prompt_len: int) -> int:
        """Length bucket for an EncodeTask batch (no cache: exactness is
        about attention masks, not cache state — see _encode_pad)."""
        if not self._encode_pad:
            return prompt_len
        return self._bucket(prompt_len)

    def _bucket(self, n: int) -> int:
        cap = self.max_seq - self._n_prefix
        base = self.min_bucket
        while True:
            for cand in (base, base + base // 2):
                if cand >= n or cand >= cap:
                    return min(cand, cap)
            base *= 2

    # -- step compilation caches ---------------------------------------
    def _prefill_for(self, bucket: int, group: int,
                     stats: EngineStats) -> steps_mod.StepBundle:
        step = self._prefill_steps.get((bucket, group))
        if step is None:
            pshape = ShapeConfig(f"engine_prefill_{bucket}x{group}",
                                 "prefill", bucket, group)
            # NOTE: no kv_cache_dtype here even when the pool is int8 — the
            # compact prefill caches stay bf16 and the admission scatter
            # (kv_cache._prefill_scatter) quantizes on entry to the pool
            step = steps_mod.make_prefill_step(
                self.cfg, pshape, self.mesh, policy=self.policy,
                max_seq=self.max_seq, with_sampling=True,
                compact_kv=self.paged, weight_dtype=self.weight_dtype,
                fuse_epilogues=self.fuse_epilogues)
            self._prefill_steps[(bucket, group)] = step
            stats.prefill_compiles += 1
        return step

    def _encode_for(self, bucket: int, group: int, pooling: str,
                    stats: EngineStats) -> steps_mod.StepBundle:
        step = self._encode_steps.get((bucket, group, pooling))
        if step is None:
            eshape = ShapeConfig(f"engine_encode_{bucket}x{group}",
                                 "prefill", bucket + self._n_prefix, group)
            step = steps_mod.make_encode_step(
                self.cfg, eshape, self.mesh, policy=self.policy,
                pooling=pooling, weight_dtype=self.weight_dtype,
                fuse_epilogues=self.fuse_epilogues)
            self._encode_steps[(bucket, group, pooling)] = step
            stats.encode_compiles += 1
        return step

    def _chunk_for(self, chunk_tokens: int) -> steps_mod.StepBundle:
        step = self._chunk_steps.get(chunk_tokens)
        if step is None:
            cshape = ShapeConfig(f"engine_chunk_{chunk_tokens}", "decode",
                                 self.max_seq, 1)
            step = steps_mod.make_chunk_prefill_step(
                self.cfg, cshape, self.mesh, layout=self.layout,
                chunk_tokens=chunk_tokens, policy=self.policy,
                max_seq=self.max_seq, with_sampling=True,
                kv_cache_dtype=self.kv_dtype,
                weight_dtype=self.weight_dtype,
                fuse_epilogues=self.fuse_epilogues)
            self._chunk_steps[chunk_tokens] = step
        return step

    # -- slot / pool bookkeeping ---------------------------------------
    def free_slots(self) -> List[int]:
        return [b for b in range(self.B) if self.slots[b] is None]

    def running(self) -> List[GenerateTask]:
        return [t for t in self.slots if t is not None]

    def has_running(self) -> bool:
        return any(s is not None for s in self.slots)

    def full_prompt(self, task: GenerateTask) -> np.ndarray:
        """The token sequence a (re-)prefill must encode: the prompt plus
        any tokens already generated before a preemption."""
        if not task.output:
            return np.asarray(task.prompt, np.int32)
        return np.concatenate([np.asarray(task.prompt, np.int32),
                               np.asarray(task.output, np.int32)])

    def full_len(self, task: GenerateTask) -> int:
        """len(full_prompt(task)) without materializing it."""
        return task.prompt_len + len(task.output)

    def blocks_needed(self, task: GenerateTask) -> int:
        return self.allocator.blocks_for(self._n_prefix + self.full_len(task))

    def alloc_for(self, task: GenerateTask) -> Optional[List[int]]:
        """All-or-nothing block allocation for (re-)admitting `task`."""
        if not self.paged:
            return []
        return self.allocator.alloc(self.blocks_needed(task))

    def release_slot(self, b: int):
        if self.paged and self._slot_blocks[b]:
            if self.prefix_cache is not None:
                self._index_slot(b)
            self.allocator.free(self._slot_blocks[b])
        self._slot_blocks[b] = []
        if self.paged:
            self.block_tables[b, :] = -1
            self._tables_dev = None
        self.slots[b] = None
        self.prefilling[b] = False
        self.draft_states[b] = None

    def evict(self, b: int) -> GenerateTask:
        """Pull the task out of slot `b`, releasing its blocks (recompute
        preemption: the engine re-queues it; a mid-chunk prefill restarts
        from scratch on re-admission — with the prefix cache on, the
        released blocks stay indexed, so the recompute is itself a warm
        admission as long as the pool doesn't reclaim them first)."""
        task = self.slots[b]
        if self.tracer:
            self.tracer.instant("preempt", time.perf_counter(), tid=task.uid,
                                recompute_tokens=self.full_len(task))
        self.release_slot(b)      # indexes [0, prefilled/pos) before reset
        task.prefilled = 0
        return task

    # -- prefix cache (serving/prefix_cache.py) ------------------------
    def cached_tokens_for(self, task: GenerateTask) -> int:
        """Peek at the cached-prefix length for `task` (no LRU touch, no
        hit-rate accounting) — the scheduler's cache-aware admission probe."""
        if self.prefix_cache is None:
            return 0
        return self.prefix_cache.lookup(
            self.full_prompt(task), limit=self.full_len(task) - 1,
            touch=False, record=False)[1]

    def admit_cached(self, task: GenerateTask, b: int) -> Optional[bool]:
        """Warm admission: look up the longest cached prefix of the (re-)
        prefill sequence, share those blocks into slot `b`'s table, and
        park the task prefilling with `prefilled = hit` so only the suffix
        gets encoded (chunk_step at pos0 = hit).

        The hit is capped at full_len - 1: the final position must be
        prefilled live to produce the sampling logits.  A hit ending
        mid-block copy-on-writes the shared tail before the suffix
        overwrites its trailing positions.

        Returns None on a cache miss (caller falls back to whole-prompt
        admission), False when the pool cannot supply the uncached blocks
        (caller stops admitting this step), True when seated."""
        pc = self.prefix_cache
        full = self.full_prompt(task)
        hit_blocks, hit = pc.lookup(full, limit=len(full) - 1)
        if hit <= 0:
            return None
        # pin the shared blocks before anything that could evict them
        self.allocator.retain(hit_blocks)
        bs = self.layout.block_size
        n_hit = len(hit_blocks)
        partial = hit % bs != 0
        need = self.blocks_needed(task) - n_hit + (1 if partial else 0)
        new = self.allocator.alloc(need)
        if new is None:
            self.allocator.free(hit_blocks)     # drop the pins
            return False
        table = list(hit_blocks)
        if partial:
            # COW: the suffix writes positions [hit, ...) of the tail block
            # other holders still depend on — duplicate it first and swap
            # the private copy into this slot's table
            src, dst = table[n_hit - 1], new[0]
            self.caches = self._block_copy(self.caches, src, dst)
            self.allocator.free([src])          # un-pin the shared original
            table[n_hit - 1] = dst
            new = new[1:]
            self.cow_copies += 1
        table.extend(new)
        # parked prefilling like begin_chunked: the decode-table row stays
        # -1 (interleaved decode writes drop) until the final suffix chunk
        # lands in chunk_step
        self._seat(task, b, table)
        self.prefilling[b] = True
        task.prefilled = hit
        task.cached_prefix = hit
        if self.tracer:
            self.tracer.instant("warm_hit", time.perf_counter(),
                                tid=task.uid, cached_prefix=hit, cow=partial)
        return True

    def _index_slot(self, b: int):
        """Index slot `b`'s committed tokens before its blocks are
        released: the full (re-)prefill sequence for a slot that reached
        decode (KV covers [0, pos)), or the prefix landed so far for a slot
        still chunk-prefilling.  Newly indexed blocks gain an allocator
        reference and survive the slot's release until LRU reclaim."""
        task = self.slots[b]
        if task is None:
            return
        n_kv = task.prefilled if self.prefilling[b] else int(self.pos[b])
        if n_kv <= 0:
            return
        nb = self.allocator.blocks_for(n_kv)
        self.prefix_cache.insert(self.full_prompt(task)[:n_kv],
                                 self._slot_blocks[b][:nb])

    def _index_prompt_blocks(self, task: GenerateTask, blk: List[int]):
        """Index the *full* blocks of a freshly prefilled prompt the moment
        its KV lands — later admissions in the same batch already hit.  The
        partial tail keeps changing as the slot decodes, so it only joins
        the index at release time (_index_slot).

        Called after landing appends its sampled token to `task.output`, so
        the landed KV extent is full_len - 1: that last token's KV only
        materializes on the next decode step, and indexing a block that
        straddles it would publish a position the pool hasn't written."""
        if self.prefix_cache is None:
            return
        bs = self.layout.block_size
        n_full = ((self.full_len(task) - 1) // bs) * bs
        if n_full > 0:
            self.prefix_cache.insert(self.full_prompt(task)[:n_full],
                                     blk[:n_full // bs])

    def _tables(self):
        if self._tables_dev is None:
            self._tables_dev = jnp.asarray(self.block_tables)
        return self._tables_dev

    def ensure_decode_blocks(
            self, select_victim: Callable[[Sequence[Task]], Task],
            stats: EngineStats,
            lookahead: Optional[np.ndarray] = None) -> List[GenerateTask]:
        """Before a decode step: every decoding slot must own the block its
        next token lands in (pos // block_size) — plus, under speculation,
        the blocks the verify chunk's `lookahead[b]` extra positions write
        into (spec_lookahead() caps each row so the need always fits the
        pool).  Allocation failure evicts `select_victim(running)` until it
        succeeds; returns the evicted tasks (the engine re-queues them)."""
        if not self.paged:
            return []
        evicted: List[GenerateTask] = []
        bs = self.layout.block_size
        pos = np.asarray(self.pos)
        for b in range(self.B):
            if self.slots[b] is None or self.prefilling[b]:
                continue
            la = int(lookahead[b]) if lookahead is not None else 0
            need = (int(pos[b]) + la) // bs + 1
            if need > self.allocator.num_blocks:
                # impossible to ever satisfy — fail before preempting (and
                # discarding) every other in-flight request's progress
                raise RuntimeError(
                    f"KV pool too small: request {self.slots[b].uid} needs "
                    f"{need} blocks, pool capacity is "
                    f"{self.allocator.num_blocks} (raise kv_pool_blocks, "
                    f"raise block_size, or cap max_new_tokens)")
            while (self.slots[b] is not None
                   and len(self._slot_blocks[b]) < need):
                got = self.allocator.alloc(1)
                if got is not None:
                    self.block_tables[b, len(self._slot_blocks[b])] = got[0]
                    self._slot_blocks[b].extend(got)
                    self._tables_dev = None
                    continue
                cand = self.running()
                if not cand:
                    raise RuntimeError(
                        "KV pool exhausted with no running request to "
                        "preempt")
                victim = select_victim(cand)
                vb = self.slots.index(victim)
                evicted.append(self.evict(vb))
                stats.preemptions += 1
            # write discipline under prefix sharing: the block the next
            # token lands in must be private to this slot.  Warm admission
            # already COWs the shared tail, so this guard is belt-and-
            # braces — but it makes decode safe against any future sharing
            # path by construction.
            while (self.prefix_cache is not None
                   and self.slots[b] is not None):
                e = int(pos[b]) // bs
                blk = self._slot_blocks[b][e]
                if self.allocator.refcount(blk) <= 1:
                    break
                got = self.allocator.alloc(1)
                if got is not None:
                    self.caches = self._block_copy(self.caches, blk, got[0])
                    self._slot_blocks[b][e] = got[0]
                    self.block_tables[b, e] = got[0]
                    self._tables_dev = None
                    self.allocator.free([blk])
                    self.cow_copies += 1
                    if self.tracer:
                        self.tracer.instant(
                            "cow_copy", time.perf_counter(),
                            tid=self.slots[b].uid, block=blk)
                    break
                cand = self.running()
                if not cand:
                    raise RuntimeError(
                        "KV pool exhausted with no running request to "
                        "preempt")
                victim = select_victim(cand)
                vb = self.slots.index(victim)
                evicted.append(self.evict(vb))
                stats.preemptions += 1
        return evicted

    # -- execution: batched whole-prompt prefill -----------------------
    def prefill(self, group: List[Tuple[GenerateTask, List[int]]],
                free_slots: List[int], stats: EngineStats,
                ) -> List[Tuple[GenerateTask, int]]:
        """One batched NAR pass for an admission group, scattering its KV
        into the assigned blocks (paged) / slot rows (dense).  Returns
        (task, output index) pairs for the freshly sampled first tokens."""
        tasks = [t for t, _ in group]
        fulls = [self.full_prompt(t) for t in tasks]
        bucket = self.bucket_for(len(fulls[0]))
        n = len(tasks)
        step = self._prefill_for(bucket, n, stats)
        t0 = time.perf_counter()
        padded = np.zeros((n, bucket), np.int32)
        for j, seq in enumerate(fulls):
            padded[j, :len(seq)] = seq
        batch = {"tokens": jnp.asarray(padded)}
        if self.cfg.n_patches:
            batch["patches"] = jnp.zeros(
                (n, self.cfg.n_patches, self.cfg.d_model), jnp.bfloat16)
        if self.cfg.enc_schedule:
            batch["frames"] = jnp.zeros(
                (n, self.cfg.enc_seq_padded, self.cfg.d_model), jnp.bfloat16)
        tok, caches_g, pos_g = step.fn(
            self.params, batch,
            stack_prefill_lanes([t.sampling for t in tasks],
                                [len(f) for f in fulls]))

        slots = free_slots[:n]
        if self.paged:
            tables = np.full((n, self.layout.max_blocks), -1, np.int32)
            for j, (_, blk) in enumerate(group):
                tables[j, :len(blk)] = blk
        else:
            tables = np.zeros((n, 1), np.int32)      # unused by the scatter
        self.caches = self._scatter(self.caches, caches_g,
                                    jnp.asarray(slots, jnp.int32),
                                    jnp.asarray(tables))
        tok_np = np.asarray(tok)
        self.tokens[slots] = tok_np
        self._tok_dev = None        # host token write: the chained device
        #                             copy no longer matches the mirror
        self.pos[slots] = np.asarray(pos_g)
        now = time.perf_counter()
        dt_ms = (now - t0) * 1e3

        fresh: List[Tuple[GenerateTask, int]] = []
        n_first = 0
        for j, (task, blk) in enumerate(group):
            b = slots[j]
            first_admit = not task.output
            task.bucket = bucket
            task.prefill_ms += dt_ms / n   # amortized share of the group
            task.prefilled = len(fulls[j])
            task.output.append(int(tok_np[j]))
            self._seat(task, b, blk)
            if self.paged:
                self.block_tables[b] = tables[j]
                self._tables_dev = None
            self._index_prompt_blocks(task, blk)
            fresh.append((task, len(task.output) - 1))
            stats.bucket_hits[bucket] = stats.bucket_hits.get(bucket, 0) + 1
            if first_admit:
                n_first += 1
                task.ttft_ms = (now - task._t_submit) * 1e3
                stats.nar_tokens += task.prompt_len
                stats.padded_nar_tokens += bucket
                stats.add_ttft_ms(task.ttft_ms)
                if self.tracer:
                    self.tracer.instant("first_token", now, tid=task.uid,
                                        ttft_ms=task.ttft_ms)
            else:
                stats.recompute_tokens += len(fulls[j])
        # preemption recomputes are overhead, not prompt-encoding goodput:
        # split the group's wall time so nar_tok_s stays comparable between
        # preempting and non-preempting runs
        stats.nar_time_s += (now - t0) * n_first / n
        stats.recompute_time_s += (now - t0) * (n - n_first) / n
        stats.prefill_batches += 1
        if self.tracer:
            self.tracer.step_span(
                "prefill", t0, now, phase="prefill", bucket=bucket, group=n,
                tokens=bucket * n, kv_positions=sum(len(f) for f in fulls),
                passes=1, busy_ms=(now - t0) * 1e3,
                uids=[t.uid for t in tasks])
        if self.spec is not None:
            self._draft_prefill(fulls, slots, stats)
        return fresh

    def _seat(self, task: GenerateTask, b: int, blk: List[int]):
        task._seq = self._admit_seq
        self._admit_seq += 1
        self.lane = set_lane(self.lane, b, task.sampling)
        self.slots[b] = task
        self.prefilling[b] = False
        self._slot_blocks[b] = list(blk)

    # -- execution: draft prefill (speculative decoding) ----------------
    def _draft_prefill(self, fulls: List[np.ndarray], slots: List[int],
                       stats: EngineStats):
        """Build the draft LM's dense cache rows for freshly (re-)admitted
        tasks: one batched draft prefill over the same padded token batch
        the target encoded, row-scattered into the draft cache at the
        assigned slots.  Pad positions beyond each row's true length hold
        junk KV the draft never attends (decode masks by pos).  The
        sampled token is discarded — the draft is only ever fed COMMITTED
        tokens, so its first proposal conditions on the target's first
        token, not its own guess."""
        n = len(fulls)
        bucket = self.bucket_for(max(len(f) for f in fulls))
        step = self._draft_prefill_steps.get((bucket, n))
        if step is None:
            pshape = ShapeConfig(f"draft_prefill_{bucket}x{n}", "prefill",
                                 bucket, n)
            step = steps_mod.make_prefill_step(
                self.draft_cfg, pshape, self.mesh, policy=self.policy,
                max_seq=self.max_seq, with_sampling=False, compact_kv=False,
                weight_dtype=self._draft_wdt,
                fuse_epilogues=self.fuse_epilogues)
            self._draft_prefill_steps[(bucket, n)] = step
        t0 = time.perf_counter()
        padded = np.zeros((n, bucket), np.int32)
        for j, seq in enumerate(fulls):
            padded[j, :len(seq)] = seq
        _, dcaches, _ = step.fn(self.draft_params,
                                {"tokens": jnp.asarray(padded)})
        self.draft_caches = self._draft_scatter(
            self.draft_caches, dcaches, jnp.asarray(slots, jnp.int32),
            jnp.zeros((n, 1), jnp.int32))
        jax.block_until_ready(self.draft_caches)   # honest overhead timing
        for j, b in enumerate(slots):
            self.draft_states[b] = DraftState(pos=len(fulls[j]))
        stats.spec_draft_time_s += time.perf_counter() - t0

    # -- execution: chunked prefill ------------------------------------
    def begin_chunked(self, task: GenerateTask, blk: List[int], b: int):
        """Park `task` in slot `b` with its full block allocation, in the
        prefilling state: its table row stays OUT of the decode tables (so
        interleaved decode steps drop every write to it) until the final
        chunk lands in `chunk_step`."""
        assert self.supports_chunked
        self._seat(task, b, blk)
        self.prefilling[b] = True
        task.prefilled = 0

    def chunk_step(self, task: GenerateTask, chunk_tokens: int,
                   stats: EngineStats) -> Optional[Tuple[GenerateTask, int]]:
        """Advance one <= `chunk_tokens`-sized prefill piece for `task`.
        Returns the (task, output index) first-token event when this chunk
        completes the prompt (the slot then joins decode), else None."""
        b = self.slots.index(task)
        assert self.prefilling[b], task.uid
        full = self.full_prompt(task)
        start = task.prefilled
        step = self._chunk_for(chunk_tokens)
        t0 = time.perf_counter()
        C = chunk_tokens
        take = min(C, len(full) - start)
        chunk = np.zeros((1, C), np.int32)
        chunk[0, :take] = full[start:start + take]
        row_table = np.full((1, self.layout.max_blocks), -1, np.int32)
        row_table[0, :len(self._slot_blocks[b])] = self._slot_blocks[b]
        lane = stack_lanes([task.sampling])
        tok, self.caches, pos_end = step.fn(
            self.params, jnp.asarray(chunk),
            jnp.asarray([start], jnp.int32),
            jnp.asarray([take], jnp.int32),
            self.caches, jnp.asarray(row_table), lane)
        tok_np = int(np.asarray(tok)[0])          # blocks: honest timing
        pos_np = int(np.asarray(pos_end)[0])
        now = time.perf_counter()
        task.prefilled = start + take
        task.prefill_ms += (now - t0) * 1e3
        first_admit = not task.output
        if first_admit:
            stats.nar_tokens += take
            stats.padded_nar_tokens += C
            stats.nar_time_s += now - t0
        else:
            stats.recompute_tokens += take
            stats.recompute_time_s += now - t0
        stats.prefill_chunks += 1
        stats.chunked_prefill_tokens += take
        if self.tracer:
            self.tracer.step_span(
                "prefill_chunk", t0, now, phase="prefill", uid=task.uid,
                tokens=C, true_tokens=take, kv_positions=take, passes=1,
                busy_ms=(now - t0) * 1e3, pos0=start,
                recompute=not first_admit)
        if task.prefilled < len(full):
            return None
        # final chunk: the sampled token is the prompt's first output and
        # the slot joins the decode batch
        task.bucket = -(-len(full) // chunk_tokens) * chunk_tokens
        task.output.append(tok_np)
        self.tokens[b] = tok_np
        self._tok_dev = None        # host token write invalidates the chain
        self.pos[b] = pos_np
        self.prefilling[b] = False
        if self.paged:
            self.block_tables[b] = row_table[0]
            self._tables_dev = None
        self._index_prompt_blocks(task, self._slot_blocks[b])
        if first_admit:
            task.ttft_ms = (now - task._t_submit) * 1e3
            stats.add_ttft_ms(task.ttft_ms)
            if self.tracer:
                self.tracer.instant("first_token", now, tid=task.uid,
                                    ttft_ms=task.ttft_ms)
        if self.spec is not None:
            # the draft (being small) prefills whole even when the target
            # chunked — one cheap pass once the final chunk lands
            self._draft_prefill([full], [b], stats)
        return (task, len(task.output) - 1)

    # -- execution: AR decode ------------------------------------------
    def decode_dispatch(self) -> DecodeHandle:
        """Launch one lockstep AR step over every decoding slot WITHOUT
        waiting for its tokens — JAX async dispatch returns device futures
        immediately.  The host token/pos mirrors advance eagerly: the
        compiled step returns `pos + 1` for every row (launch/steps.py), so
        `self.pos += 1` is exact, and the returned token future is kept as
        `_tok_dev` so a back-to-back dispatch chains on it device-side
        instead of re-uploading the host mirror."""
        t0 = time.perf_counter()
        tok_in = (self._tok_dev if self._tok_dev is not None
                  else jnp.asarray(self.tokens))
        pos_d = jnp.asarray(self.pos)
        lane_d = device_lane(self.lane)
        if self.paged:
            tok_d, _, self.caches = self.decode_step.fn(
                self.params, tok_in, pos_d, self.caches,
                self._tables(), lane_d)
        else:
            tok_d, _, self.caches = self.decode_step.fn(
                self.params, tok_in, pos_d, self.caches, lane_d)
        self._tok_dev = tok_d
        start_d2h = getattr(tok_d, "copy_to_host_async", None)
        if start_d2h is not None:
            start_d2h()     # non-blocking device_get: the commit's fetch
            #                 finds the bytes already on their way
        self.pos += 1
        self.steps_run += 1
        decoding = [(b, self.slots[b]) for b in self.decoding_slots()]
        live = sum(int(self.pos[b]) for b, _ in decoding)
        handle = DecodeHandle(
            tok_d, t0, decoding, live,
            self.allocator.num_used if self.paged else 0)
        if self.tracer:
            handle.t_disp = time.perf_counter()
            self.tracer.step_span(
                "decode_dispatch", t0, handle.t_disp, slots=len(decoding),
                uids=[t.uid for _, t in decoding])
        return handle

    def decode_commit(self, handle: DecodeHandle, stats: EngineStats,
                      ) -> List[Tuple[GenerateTask, int]]:
        """Fetch a dispatched step's tokens (blocking) and commit them to
        the host mirrors, task outputs and stats.  Under the overlapped
        loop the elapsed-time sample is floored at the previous commit so
        back-to-back pipelined steps don't double-count wall time."""
        tr = self.tracer
        t_fetch = time.perf_counter() if tr else 0.0
        toks = np.asarray(handle.tok_d)           # blocks: honest timing
        now = time.perf_counter()
        floor = self._t_last_commit
        dt = now - (max(handle.t0, floor) if floor is not None
                    else handle.t0)
        self._t_last_commit = now
        fresh: List[Tuple[GenerateTask, int]] = []
        for b, task in handle.decoding:
            tok = int(toks[b])
            if self.slots[b] is task:
                self.tokens[b] = tok    # mirror update, not a host write:
                #                         _tok_dev stays valid
            task.output.append(tok)
            task.decode_ms += dt * 1e3
            fresh.append((task, len(task.output) - 1))
        stats.decode_steps += 1
        stats.ar_tokens += len(handle.decoding)
        stats.ar_time_s += dt
        stats.add_decode_step_ms(dt * 1e3)
        stats.occupied_slot_steps += len(handle.decoding)
        if self.paged:
            stats.block_slot_steps += handle.blocks_used
            stats.token_slot_steps += handle.live_tokens
        if tr:
            ann = {}
            if handle.t_disp:
                # host wall between dispatch returning and the commit-side
                # fetch starting: scheduling work the device step hid
                ann["overlap_lag_ms"] = max(
                    0.0, (t_fetch - handle.t_disp) * 1e3)
            tr.step_span(
                "decode_step", handle.t0, now, phase="decode",
                slots=len(handle.decoding), tokens=len(handle.decoding),
                kv_positions=handle.live_tokens, passes=1, busy_ms=dt * 1e3,
                uids=[t.uid for _, t in handle.decoding], **ann)
        return fresh

    def decode(self, stats: EngineStats) -> List[Tuple[GenerateTask, int]]:
        """One lockstep AR step over every decoding slot (synchronous:
        dispatch + immediate commit).  Returns the (task, output index)
        token events."""
        return self.decode_commit(self.decode_dispatch(), stats)

    def next_token_block_ready(self, b: int) -> bool:
        """Whether decoding slot `b` can take one MORE decode step with no
        allocator/COW work: it already owns the block position `pos[b]`
        writes into, and (under prefix sharing) owns it exclusively.  The
        overlapped loop's dispatch-ahead fast path requires this — it runs
        before `ensure_decode_blocks` would."""
        if not self.paged:
            return True
        bs = self.layout.block_size
        need = int(self.pos[b]) // bs + 1
        if len(self._slot_blocks[b]) < need:
            return False
        if self.prefix_cache is not None:
            blk = self._slot_blocks[b][need - 1]
            if self.allocator.refcount(blk) > 1:
                return False
        return True

    def decoding_slots(self) -> List[int]:
        return [b for b in range(self.B)
                if self.slots[b] is not None and not self.prefilling[b]]

    # -- execution: speculative decode (propose -> verify -> commit) ----
    def spec_lookahead(self) -> np.ndarray:
        """Per-slot speculation depth for the next round: `spec.k` capped
        so the verify chunk's writes stay inside the sequence horizon
        (committing past max_seq - 1 would emit tokens a step-by-step
        decode never reaches), inside the pool's total block capacity (so
        ensure_decode_blocks can always satisfy the lookahead, by
        preemption if necessary), and inside the request's remaining
        max_new_tokens budget (a round commits at most room = budget
        tokens, so proposing past room - 1 would reserve blocks — and
        possibly preempt a neighbor for them — that trim_emitted then
        discards; capping cannot change outputs, each position's verify
        choice being independent of how many proposals follow it).
        Requests admitted degraded (DeadlinePolicy under pressure) get 0
        lookahead — their rounds propose nothing and commit exactly the
        pending token, i.e. plain decode at verify-step cost, still
        token-identical (speculation is lossless at every k).

        Token trees (tree_branches > 1) reserve NODE capacity, not chain
        depth: a depth-d, width-w tree scatters d*w node positions past
        pos.  Each row uses the deepest tree whose node count fits the
        same horizon / pool-capacity caps (at least depth 1), else falls
        back to the plain chain for the round; and when the trees'
        collective extra blocks exceed the pool's FREE blocks, every row
        shrinks to its chain — sibling scratch is pure lookahead and must
        never preempt a neighbor's committed state (the chain lookahead
        may, exactly as at width 1).  The per-row (depth, width) choice
        lands in _round_depth/_round_width for the round that follows."""
        la = np.zeros((self.B,), np.int64)
        chain_la = np.zeros((self.B,), np.int64)
        cap_tokens = self.allocator.num_blocks * self.layout.block_size
        w = self.tree_branches
        depths = np.zeros((self.B,), np.int64)
        widths = np.ones((self.B,), np.int64)
        for b in self.decoding_slots():
            p = int(self.pos[b])
            task = self.slots[b]
            if task.degraded:
                continue
            room = task.max_new_tokens - len(task.output)
            la_c = max(0, min(self.spec.k, self.max_seq - 1 - p,
                              cap_tokens - 1 - p, room - 1))
            chain_la[b] = la_c
            if w > 1 and not self._tree_chain_only:
                la_t = min(la_c, (self.max_seq - 1 - p) // w,
                           (cap_tokens - 1 - p) // w)
                if la_t >= 1:
                    depths[b], widths[b], la[b] = la_t, w, la_t * w
                    continue
            depths[b], la[b] = la_c, la_c
        if w > 1 and bool(np.any(widths > 1)):
            bs = self.layout.block_size
            need = sum(
                max(0, (int(self.pos[b]) + int(la[b])) // bs + 1
                    - len(self._slot_blocks[b]))
                for b in self.decoding_slots())
            if need > self.allocator.num_free:
                for b in self.decoding_slots():
                    if widths[b] > 1:
                        depths[b], widths[b] = chain_la[b], 1
                        la[b] = chain_la[b]
        self._round_depth = depths
        self._round_width = widths
        return la

    def _token_at(self, task: GenerateTask, p: int) -> int:
        """Committed token occupying absolute position `p` (prompt, then
        output history; patch prefixes are unsupported under spec)."""
        if p < task.prompt_len:
            return int(task.prompt[p])
        return int(task.output[p - task.prompt_len])

    def spec_decode(self, stats: EngineStats
                    ) -> List[Tuple[GenerateTask, int]]:
        """One speculative round over every decoding slot: k lockstep
        draft-decode proposal steps, ONE multi-token target verify pass
        (the whole round's target weight traffic), then host-side
        longest-prefix acceptance with rollback — pos rewinds to the
        committed length, blocks allocated solely for rejected tokens are
        freed, and the draft cache rewinds alongside.  Returns the
        committed (task, output index) token events: between 1 and k+1
        per slot, token-identical to `decode()` run step-by-step for
        greedy AND sampled requests (serving/spec.py).

        With `spec.branches > 1` the round goes through the token-tree
        variant instead (_spec_decode_tree); at branches == 1 this path
        runs unchanged."""
        if self.tree_branches > 1:
            return self._spec_decode_tree(stats)
        active = self.decoding_slots()
        if not active:
            return []
        k = self.spec.k
        C = k + 1
        la = self.spec_lookahead()
        pos0 = np.array(self.pos, np.int64)

        # -- propose.  The draft may lag the committed sequence by one
        # position after an all-accept round (the bonus token's
        # predecessor was never fed through it); `known` replays the gap
        # from committed history before the draft feeds its own guesses.
        starts = np.zeros((self.B,), np.int64)
        known: Dict[int, List[int]] = {}
        for b in active:
            ds = self.draft_states[b]
            starts[b] = ds.pos
            known[b] = [self._token_at(self.slots[b], p)
                        for p in range(ds.pos, int(pos0[b]) + 1)]
        n_steps = max(max(len(known[b]) - 1 + int(la[b]) for b in active), 1)
        t0 = time.perf_counter()
        lane_d = device_lane(self.lane)
        feed = np.zeros((self.B,), np.int32)
        proposals: Dict[int, List[int]] = {b: [] for b in active}
        last_out = np.zeros((self.B,), np.int32)
        for s in range(n_steps):
            for b in active:
                feed[b] = (known[b][s] if s < len(known[b])
                           else int(last_out[b]))
            out_d, _, self.draft_caches = self.draft_decode_step.fn(
                self.draft_params, jnp.asarray(feed),
                jnp.asarray(starts + s, jnp.int32), self.draft_caches,
                lane_d)
            last_out = np.asarray(out_d)
            for b in active:
                if (s >= len(known[b]) - 1
                        and len(proposals[b]) < int(la[b])):
                    proposals[b].append(int(last_out[b]))
        t_draft = time.perf_counter() - t0
        stats.spec_draft_time_s += t_draft
        stats.add_draft_time_ms(t_draft * 1e3)
        if self.tracer:
            self.tracer.step_span(
                "spec_draft", t0, t0 + t_draft, phase="draft",
                steps=n_steps, slots=len(active), busy_ms=t_draft * 1e3)

        # -- verify: target forwards [pending token, d_1..d_ke] into the
        # slot's paged blocks, returning its own choice at every position
        chunk = np.zeros((self.B, C), np.int32)
        chunk_len = np.zeros((self.B,), np.int32)
        for b in active:
            chunk[b, 0] = self.tokens[b]
            props = proposals[b]
            chunk[b, 1:1 + len(props)] = props
            chunk_len[b] = 1 + len(props)
        t1 = time.perf_counter()
        choices_d, self.caches, _ = self.verify_step.fn(
            self.params, jnp.asarray(chunk), jnp.asarray(pos0, jnp.int32),
            jnp.asarray(chunk_len), self.caches, self._tables(), lane_d)
        choices = np.asarray(choices_d)           # blocks: honest timing
        dt = time.perf_counter() - t1
        self.steps_run += 1

        # -- commit + rollback
        fresh: List[Tuple[GenerateTask, int]] = []
        occupied = live_tokens = emitted_total = 0
        round_proposed = round_accepted = 0
        for b in active:
            task = self.slots[b]
            occupied += 1
            ke = len(proposals[b])
            cand = [int(c) for c in choices[b, :ke + 1]]
            j = accept_length(proposals[b], cand)
            stats.spec_proposed_tokens += ke
            stats.spec_accepted_tokens += j
            round_proposed += ke
            round_accepted += j
            # commit c_0..c_j, clamped to step-by-step retirement
            # semantics (max_new / max_seq budget, cut at the first EOS)
            room = min(task.max_new_tokens - len(task.output),
                       self.max_seq - 1 - int(pos0[b]))
            emitted = trim_emitted(cand[:j + 1], room=room,
                                   eos_id=task.eos_id)
            for tok in emitted:
                task.output.append(tok)
                fresh.append((task, len(task.output) - 1))
            m = len(emitted)
            emitted_total += m
            pos_new = int(pos0[b]) + m
            self.tokens[b] = emitted[-1]
            self._tok_dev = None    # host token write invalidates the chain
            self.pos[b] = pos_new
            task.decode_ms += dt * 1e3
            live_tokens += pos_new
            # rollback: free blocks holding only rejected-token KV (the
            # garbage inside kept blocks sits beyond pos and is masked,
            # then overwritten as decoding advances)
            keep = self.allocator.blocks_for(pos_new)
            if len(self._slot_blocks[b]) > keep:
                extra = self._slot_blocks[b][keep:]
                del self._slot_blocks[b][keep:]
                self.allocator.free(extra)
                self.block_tables[b, keep:] = -1
                self._tables_dev = None
            # draft rewind: valid through the last draft-cache position
            # whose written token matches the committed sequence (and
            # never past the committed horizon)
            self.draft_states[b].pos = min(int(starts[b]) + n_steps,
                                           int(pos0[b]) + j + 1, pos_new)
        stats.decode_steps += 1
        stats.spec_rounds += 1
        stats.spec_slot_steps += occupied
        stats.spec_emitted_tokens += emitted_total
        stats.ar_tokens += emitted_total
        stats.ar_time_s += dt
        stats.add_decode_step_ms(dt * 1e3)
        stats.occupied_slot_steps += occupied
        stats.block_slot_steps += self.allocator.num_used
        stats.token_slot_steps += live_tokens
        executed = int(chunk_len.sum())
        stats.verify_positions += executed
        if self.tracer:
            self.tracer.step_span(
                "spec_verify", t1, t1 + dt, phase="verify", tokens=executed,
                kv_positions=live_tokens, passes=1, busy_ms=dt * 1e3,
                slots=occupied,
                **round_annotation(proposed=round_proposed,
                                   accepted=round_accepted,
                                   emitted=emitted_total))
        return fresh

    def _spec_decode_tree(self, stats: EngineStats
                          ) -> List[Tuple[GenerateTask, int]]:
        """One token-tree speculative round (spec.branches > 1).

        Propose: the same lockstep draft replay loop as the chain round,
        via `draft_topk_step` — each step also returns the row's top-b
        candidates, so while the draft's dense cache advances ONLY along
        its sampled chain, the (b - 1) siblings per depth come free.
        Verify: per-slot caterpillar trees (spec.build_tree) flatten into
        one fixed-width [B, 1 + k*b] chunk; `tree_verify_step` scatters
        node KV at pos0 + node_index, applies rope and the sampler's
        position key at pos0 + depth, masks intra-chunk attention to each
        node's ancestors, and returns the target's own choice after every
        node's root path.  Commit: the deepest root path whose node
        tokens all match their parent's choice (spec.accept_tree_path) is
        accepted — its KV rows are compacted into the slot's canonical
        positions pos0 + d (kv_cache.make_row_copy; rope already matches,
        the move is bytes only) — then the usual trim / rollback / draft
        rewind, the draft rewinding to the accepted path's leading CHAIN
        prefix (siblings never entered its cache).  Lossless: every
        acceptance test is the same (seed, position)-keyed equality the
        chain round uses, so committed outputs stay token-identical to
        plain decode."""
        active = self.decoding_slots()
        if not active:
            return []
        C = 1 + self.spec.k * self.tree_branches
        if self._round_depth is None:
            self.spec_lookahead()
        depth_la, width = self._round_depth, self._round_width
        pos0 = np.array(self.pos, np.int64)

        # -- propose (chain replay identical to spec_decode's loop)
        starts = np.zeros((self.B,), np.int64)
        known: Dict[int, List[int]] = {}
        for b in active:
            ds = self.draft_states[b]
            starts[b] = ds.pos
            known[b] = [self._token_at(self.slots[b], p)
                        for p in range(ds.pos, int(pos0[b]) + 1)]
        n_steps = max(max(len(known[b]) - 1 + int(depth_la[b])
                          for b in active), 1)
        t0 = time.perf_counter()
        lane_d = device_lane(self.lane)
        feed = np.zeros((self.B,), np.int32)
        levels: Dict[int, List[List[int]]] = {b: [] for b in active}
        last_out = np.zeros((self.B,), np.int32)
        for s in range(n_steps):
            for b in active:
                feed[b] = (known[b][s] if s < len(known[b])
                           else int(last_out[b]))
            out_d, alts_d, _, self.draft_caches = self.draft_topk_step.fn(
                self.draft_params, jnp.asarray(feed),
                jnp.asarray(starts + s, jnp.int32), self.draft_caches,
                lane_d)
            last_out = np.asarray(out_d)
            alts = np.asarray(alts_d)
            for b in active:
                if (s >= len(known[b]) - 1
                        and len(levels[b]) < int(depth_la[b])):
                    # alts[b, 0] == the chain token fed next step, by
                    # sample_topn construction
                    levels[b].append([int(t)
                                      for t in alts[b, :int(width[b])]])
        t_draft = time.perf_counter() - t0
        stats.spec_draft_time_s += t_draft
        stats.add_draft_time_ms(t_draft * 1e3)
        if self.tracer:
            self.tracer.step_span(
                "spec_draft", t0, t0 + t_draft, phase="draft",
                steps=n_steps, slots=len(active), busy_ms=t_draft * 1e3)

        # -- verify: one tree-masked target pass over every slot's tree
        chunk = np.zeros((self.B, C), np.int32)
        chunk_len = np.zeros((self.B,), np.int32)
        depth_op = np.zeros((self.B, C), np.int32)
        anc_op = np.zeros((self.B, C, C), bool)
        trees = {}
        for b in active:
            tree = build_tree(int(self.tokens[b]), levels[b])
            trees[b] = tree
            n = tree.n_nodes
            chunk[b, :n] = tree.tokens
            depth_op[b, :n] = tree.depth
            anc_op[b, :n, :n] = tree.anc
            chunk_len[b] = n
        t1 = time.perf_counter()
        choices_d, self.caches, _ = self.tree_verify_step.fn(
            self.params, jnp.asarray(chunk), jnp.asarray(pos0, jnp.int32),
            jnp.asarray(chunk_len), jnp.asarray(depth_op),
            jnp.asarray(anc_op), self.caches, self._tables(), lane_d)
        choices = np.asarray(choices_d)           # blocks: honest timing
        dt = time.perf_counter() - t1
        self.steps_run += 1

        # -- commit + compact + rollback
        fresh: List[Tuple[GenerateTask, int]] = []
        occupied = live_tokens = emitted_total = 0
        round_proposed = round_accepted = round_nodes = round_branch = 0
        path_depths: List[int] = []
        bs = self.layout.block_size
        for b in active:
            task = self.slots[b]
            occupied += 1
            tree = trees[b]
            n = tree.n_nodes
            path = accept_tree_path(tree.tokens, tree.parent, choices[b], n)
            stats.spec_proposed_tokens += n - 1
            stats.spec_accepted_tokens += len(path)
            stats.spec_tree_nodes += n
            stats.add_spec_path_depth(len(path))
            round_proposed += n - 1
            round_accepted += len(path)
            round_nodes += n
            path_depths.append(len(path))
            if any(not tree.chain[i] for i in path):
                stats.spec_branch_hits += 1
                round_branch += 1
            full = [0] + path
            cand = [int(choices[b, i]) for i in full]
            room = min(task.max_new_tokens - len(task.output),
                       self.max_seq - 1 - int(pos0[b]))
            emitted = trim_emitted(cand, room=room, eos_id=task.eos_id)
            m = len(emitted)
            # compact: committed position pos0 + d must hold the KV that
            # node full[d] wrote at pos0 + full[d] (already roped at its
            # logical position pos0 + d).  full[] is strictly increasing
            # with full[d] >= d, so ascending-d moves never clobber a
            # pending source.
            blks = self._slot_blocks[b]
            for d in range(1, m):
                if int(full[d]) == d:
                    continue
                src_p = int(pos0[b]) + int(full[d])
                dst_p = int(pos0[b]) + d
                self.caches = self._row_copy(
                    self.caches, blks[src_p // bs], src_p % bs,
                    blks[dst_p // bs], dst_p % bs)
            for tok in emitted:
                task.output.append(tok)
                fresh.append((task, len(task.output) - 1))
            emitted_total += m
            pos_new = int(pos0[b]) + m
            self.tokens[b] = emitted[-1]
            self._tok_dev = None    # host token write invalidates the chain
            self.pos[b] = pos_new
            task.decode_ms += dt * 1e3
            live_tokens += pos_new
            # rollback: free blocks holding only rejected-node KV
            keep = self.allocator.blocks_for(pos_new)
            if len(self._slot_blocks[b]) > keep:
                extra = self._slot_blocks[b][keep:]
                del self._slot_blocks[b][keep:]
                self.allocator.free(extra)
                self.block_tables[b, keep:] = -1
                self._tables_dev = None
            # draft rewind: the dense draft cache followed the CHAIN, so
            # it stays valid through the accepted path's leading chain
            # prefix only (a sibling acceptance diverges from what the
            # draft fed itself)
            j_chain = 0
            for i in path:
                if not tree.chain[i]:
                    break
                j_chain += 1
            self.draft_states[b].pos = min(int(starts[b]) + n_steps,
                                           int(pos0[b]) + j_chain + 1,
                                           pos_new)
        self._round_depth = self._round_width = None
        stats.decode_steps += 1
        stats.spec_rounds += 1
        stats.spec_slot_steps += occupied
        stats.spec_emitted_tokens += emitted_total
        stats.ar_tokens += emitted_total
        stats.ar_time_s += dt
        stats.add_decode_step_ms(dt * 1e3)
        stats.occupied_slot_steps += occupied
        stats.block_slot_steps += self.allocator.num_used
        stats.token_slot_steps += live_tokens
        executed = int(chunk_len.sum())
        stats.verify_positions += executed
        if self.tracer:
            self.tracer.step_span(
                "spec_verify", t1, t1 + dt, phase="verify", tokens=executed,
                kv_positions=live_tokens, passes=1, busy_ms=dt * 1e3,
                slots=occupied,
                **round_annotation(proposed=round_proposed,
                                   accepted=round_accepted,
                                   emitted=emitted_total,
                                   tree_nodes=round_nodes,
                                   path_depths=path_depths,
                                   branch_hits=round_branch))
        return fresh

    # -- execution: encoder-only NAR -----------------------------------
    def encode(self, group: List[EncodeTask], stats: EngineStats):
        """One pooled full-sequence pass for a same-bucket batch of
        EncodeTasks (and same pooling mode).  Fills task.embedding."""
        assert group and len({t.pooling for t in group}) == 1
        n = len(group)
        lens = [t.prompt_len for t in group]
        bucket = self.encode_bucket_for(max(lens))
        step = self._encode_for(bucket, n, group[0].pooling, stats)
        t0 = time.perf_counter()
        padded = np.zeros((n, bucket), np.int32)
        for j, task in enumerate(group):
            padded[j, :task.prompt_len] = np.asarray(task.prompt, np.int32)
        batch = {"tokens": jnp.asarray(padded)}
        if self.cfg.n_patches:
            batch["patches"] = jnp.zeros(
                (n, self.cfg.n_patches, self.cfg.d_model), jnp.bfloat16)
        if self.cfg.enc_schedule:
            batch["frames"] = jnp.zeros(
                (n, self.cfg.enc_seq_padded, self.cfg.d_model), jnp.bfloat16)
        pooled = step.fn(self.params, batch, jnp.asarray(lens, jnp.int32))
        pooled_np = np.asarray(pooled)            # blocks: honest timing
        now = time.perf_counter()
        dt = now - t0
        for j, task in enumerate(group):
            task.bucket = bucket
            task.embedding = pooled_np[j]
            task.encode_ms = dt * 1e3 / n
            task.latency_ms = (now - task._t_submit) * 1e3
            task.done = True
            stats.encode_tokens += task.prompt_len
            stats.padded_encode_tokens += bucket
            stats.add_encode_latency_ms(task.latency_ms)
            stats.bucket_hits[bucket] = stats.bucket_hits.get(bucket, 0) + 1
        stats.encode_time_s += dt
        stats.encode_batches += 1
        if self.tracer:
            self.tracer.step_span(
                "encode", t0, now, phase="encode", bucket=bucket, group=n,
                tokens=bucket * n, kv_positions=0, passes=1,
                busy_ms=dt * 1e3, uids=[t.uid for t in group])
