"""Per-request sampling parameters for the serving engine.

`SamplingParams` is the host-side request option; the jit-side math lives in
core/embedding.sample_token (Gumbel-max over the tp-sharded vocab) and is
threaded through launch/steps' prefill/decode bundles as a per-slot "lane":
a dict of [B] arrays (temperature / top_k / seed) the engine scatters into
whenever a request is admitted to a slot.  Sampling therefore happens inside
the jitted step — no logits ever leave the device, and one compiled decode
step serves any mix of greedy and sampled requests.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core.embedding import TOP_K_CAP


def validate_sampling(params: "SamplingParams") -> None:
    """Reject unservable sampling parameters with a clear ValueError at
    construction/submit time — the jitted step would otherwise silently
    clamp them (top_k beyond the exact distributed threshold-search depth)
    or misbehave (negative temperature), deep inside the engine loop."""
    if params.temperature < 0:
        raise ValueError(
            f"temperature must be >= 0 (0 = greedy): {params.temperature}")
    if params.top_k < 0:
        raise ValueError(f"top_k must be >= 0 (0 = full vocabulary): "
                         f"{params.top_k}")
    if params.top_k > TOP_K_CAP:
        raise ValueError(
            f"top_k {params.top_k} exceeds TOP_K_CAP={TOP_K_CAP}: the "
            f"distributed top-k threshold search is exact only up to the "
            f"cap (each tp shard contributes its local top-{TOP_K_CAP}); "
            f"pass top_k <= {TOP_K_CAP}, or 0 for full-vocabulary sampling")


@dataclass(frozen=True)
class SamplingParams:
    """What a request wants from the token sampler.

    temperature  0.0 => greedy (exact argmax path inside the step);
                 > 0 => softmax(z/temperature) via Gumbel-max
    top_k        truncate to the k highest-logit tokens before sampling;
                 0 => full vocabulary (ignored when temperature == 0;
                 must be <= core.embedding.TOP_K_CAP — the distributed
                 threshold search is exact only up to the cap, and
                 out-of-range values are rejected here rather than
                 silently clamped inside the jitted step)
    seed         the request's RNG lane — (seed, position) maps to one
                 reproducible draw regardless of batching or slot placement
    """
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    def __post_init__(self):
        validate_sampling(self)

    @property
    def is_greedy(self) -> bool:
        return self.temperature == 0.0


GREEDY = SamplingParams()


def zero_lane(batch_size: int) -> dict:
    """Fresh per-slot lane arrays (all slots greedy) for a decode batch.

    HOST-side numpy: slot updates index by a python int, and a device
    `.at[slot].set()` would jit-compile once per distinct slot index; the
    engine converts at the step-call boundary instead."""
    return {"temperature": np.zeros((batch_size,), np.float32),
            "top_k": np.zeros((batch_size,), np.int32),
            "seed": np.zeros((batch_size,), np.int32)}


def set_lane(lane: dict, slot: int, params: SamplingParams) -> dict:
    """Scatter one request's SamplingParams into slot `slot` (functional:
    the input lane is not mutated)."""
    out = {k: v.copy() for k, v in lane.items()}
    out["temperature"][slot] = params.temperature
    out["top_k"][slot] = params.top_k
    out["seed"][slot] = params.seed
    return out


def device_lane(lane: dict) -> dict:
    """Host lane -> device arrays for a jitted step call."""
    return {k: jnp.asarray(v) for k, v in lane.items()}


def stack_lanes(params_list) -> dict:
    """[n] lane arrays for a row batch of SamplingParams (the schema the
    jitted steps consume; chunked-prefill rows use this directly)."""
    return {"temperature": jnp.asarray([p.temperature for p in params_list],
                                       jnp.float32),
            "top_k": jnp.asarray([p.top_k for p in params_list], jnp.int32),
            "seed": jnp.asarray([p.seed for p in params_list], jnp.int32)}


def stack_prefill_lanes(params_list, prompt_lens) -> dict:
    """[nB] lane for a batched-admission prefill: one admission group's
    SamplingParams and true prompt lengths, row-aligned with the padded
    token batch."""
    return dict(stack_lanes(params_list),
                prompt_len=jnp.asarray(list(prompt_lens), jnp.int32))
