"""Prefix cache: refcounted copy-on-write KV block sharing across requests.

The paper's core lever is minimizing main-memory traffic per token; at
serving scale the largest *redundant* traffic is re-prefilling shared prompt
prefixes (system prompts, few-shot preambles) for every request.  The PR 2
block-paged pool is exactly the substrate for reuse: KV for token position p
depends only on tokens [0, p] (causal attention), so any two requests whose
prompts share a prefix can share the pool blocks that hold that prefix's KV.

    PrefixCache     radix/trie index over *token content*: each node owns one
                    pool block and covers a tuple of tokens.  Edges are keyed
                    by a rolling hash of that tuple (O(1)-sized dict keys
                    instead of block_size-tuple keys); every hash hit is
                    verified against the node's stored tokens before it is
                    accepted, so a collision degrades to a miss, never to a
                    wrong share.  Full-block nodes (block_size tokens) form
                    the trie spine; partially filled tails hang off their
                    parent as leaf nodes and match by longest common prefix.
    lookup()        longest cached prefix of a prompt -> (blocks, tokens).
                    A partial match *inside* a block is still a hit — the
                    suffix prefill copy-on-writes the block before
                    overwriting the positions past the match.
    insert()        index a slot's committed tokens: full prompt blocks at
                    admission, the partial tail (prompt + sampled output) at
                    retirement.  Each newly indexed block gains one allocator
                    reference; content already present is deduplicated
                    (first writer wins).
    reclaim()       lazy LRU eviction, registered as `BlockAllocator.reclaim`:
                    when `alloc()` would fail, index-only leaf blocks
                    (allocator refcount 1 — no live slot holds them) are
                    evicted oldest-first until the shortfall is covered.
                    This replaces the pre-cache eager free: a retired
                    request's blocks stay warm exactly as long as the pool
                    has room for them.

Sharing is sampling-independent by construction: the index key is token
content, and KV depends only on token content — temperature, seeds, and
penalties affect *which* tokens get committed, never the KV of committed
ones.  The engine enforces the write discipline that makes sharing safe:
a block is only ever written by a slot that holds it at refcount 1 (fresh
allocation or copy-on-write duplicate).
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

from repro.serving.kv_cache import BlockAllocator


class _Node:
    """One indexed pool block.  `key` is the tuple of tokens the block
    covers (len == block_size for spine nodes, < block_size for partial
    tails), kept for collision verification; `hash` is its rolling hash —
    the dict key under which the node is indexed; `stamp` is the LRU clock
    of the last lookup/insert that touched this node's path."""
    __slots__ = ("key", "hash", "block", "tokens", "parent", "children",
                 "partials", "stamp")

    def __init__(self, key: tuple, block: int, parent: Optional["_Node"]):
        self.key = key
        self.hash = _rhash(key)
        self.block = block
        self.tokens = len(key)
        self.parent = parent
        self.children: dict = {}    # rolling hash -> [_Node] (full blocks)
        self.partials: dict = {}    # rolling hash -> [_Node] (partial tails)
        self.stamp = 0


# Polynomial rolling hash over token ids, mod the Mersenne prime 2^61 - 1.
# Content-derived and incremental (h extends token-by-token), so the index
# key for a block is a single machine word regardless of block_size.  Hash
# equality is never trusted on its own — see _get().
_HASH_BASE = 1_000_003
_HASH_MOD = (1 << 61) - 1


def _rhash(toks, h: int = 0) -> int:
    for t in toks:
        h = (h * _HASH_BASE + int(t) + 1) % _HASH_MOD
    return h


def _get(group: dict, key: tuple) -> Optional[_Node]:
    """Collision-safe probe: a node is returned only if its stored token
    tuple matches `key` exactly.  A hash collision (same bucket, different
    tokens) therefore reads as a miss."""
    for cand in group.get(_rhash(key), ()):
        if cand.key == key:
            return cand
    return None


def _put(group: dict, node: _Node) -> None:
    group.setdefault(node.hash, []).append(node)


def _unlink(group: dict, node: _Node) -> None:
    bucket = group[node.hash]
    bucket.remove(node)
    if not bucket:
        del group[node.hash]


def _nodes(group: dict):
    for bucket in group.values():
        yield from bucket


def _common(a, b) -> int:
    n = min(len(a), len(b))
    for i in range(n):
        if a[i] != b[i]:
            return i
    return n


class PrefixCache:
    """Radix index + LRU pool over a `BlockAllocator`.

    max_blocks caps how many pool blocks the index may hold references to
    (None = bounded only by pool pressure via lazy reclaim)."""

    def __init__(self, allocator: BlockAllocator, block_size: int,
                 max_blocks: Optional[int] = None):
        if max_blocks is not None and max_blocks < 0:
            raise ValueError(f"max_blocks must be >= 0: {max_blocks}")
        self.allocator = allocator
        self.block_size = block_size
        self.max_blocks = max_blocks
        self._root = _Node((), -1, None)     # sentinel, owns no block
        self._clock = 0
        self._n_blocks = 0
        # counters — cumulative; engine.stats() diffs them against a
        # reset_stats() baseline
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.inserted_blocks = 0
        self.evicted_blocks = 0
        allocator.reclaim = self.reclaim

    @property
    def cached_blocks(self) -> int:
        """Pool blocks the index currently holds a reference to."""
        return self._n_blocks

    # -- lookup ------------------------------------------------------------

    def lookup(self, tokens, *, limit: Optional[int] = None,
               touch: bool = True, record: bool = True
               ) -> Tuple[List[int], int]:
        """Longest cached prefix of `tokens` (capped at `limit` tokens).

        Returns (blocks, matched): `blocks[i]` holds the KV for positions
        [i*bs, (i+1)*bs) of the match; the last block may be matched only
        partially (matched % bs != 0) — its positions past the match carry
        other content and must be copy-on-written before reuse.

        The returned blocks are NOT retained — the caller must
        `allocator.retain()` them before anything that could trigger
        eviction (an alloc, another insert).  `touch=False, record=False`
        is the scheduler's peek: no LRU update, no hit-rate skew."""
        toks = [int(t) for t in tokens]
        if limit is not None:
            toks = toks[:limit]
        bs = self.block_size
        node = self._root
        path = [node]
        blocks: List[int] = []
        matched = 0
        i = 0
        while i + bs <= len(toks):
            child = _get(node.children, tuple(toks[i:i + bs]))
            if child is None:
                break
            node = child
            path.append(node)
            blocks.append(node.block)
            matched += bs
            i += bs
        # best partial continuation: longest common prefix of the remaining
        # tokens with any child at this node (a full-block child matched
        # only partway is as good as a stored partial tail — causality makes
        # its leading positions valid for us)
        rest = toks[i:]
        best = best_cp = None
        for group in (node.partials, node.children):
            for cand in _nodes(group):
                cp = _common(cand.key, rest)
                if cp > 0 and (best is None or cp > best_cp):
                    best, best_cp = cand, cp
        if best is not None:
            path.append(best)
            blocks.append(best.block)
            matched += best_cp
        if record:
            self.lookups += 1
            if matched > 0:
                self.hits += 1
                self.hit_tokens += matched
        if touch and matched > 0:
            self._clock += 1
            for n in path:
                n.stamp = self._clock
        return blocks, matched

    # -- insert ------------------------------------------------------------

    def insert(self, tokens, blocks: List[int]) -> None:
        """Index committed content: `blocks[i]` holds the KV of positions
        [i*bs, (i+1)*bs) of `tokens`.  A final partial block (len(tokens)
        not a block multiple) is indexed as a partial-tail leaf.  Every
        block the index newly references is retained; content already
        indexed keeps its existing block (the caller's duplicate stays
        owned by the caller and dies with it)."""
        toks = [int(t) for t in tokens]
        bs = self.block_size
        if len(blocks) < -(-len(toks) // bs):
            raise ValueError(f"{len(blocks)} blocks cannot cover "
                             f"{len(toks)} tokens")
        self._clock += 1
        node = self._root
        node.stamp = self._clock
        i = bi = 0
        while i + bs <= len(toks):
            key = tuple(toks[i:i + bs])
            child = _get(node.children, key)
            if child is None:
                child = _Node(key, blocks[bi], node)
                _put(node.children, child)
                self.allocator.retain([child.block])
                self._n_blocks += 1
                self.inserted_blocks += 1
            child.stamp = self._clock
            node = child
            i += bs
            bi += 1
        rest = tuple(toks[i:])
        if rest and _get(node.partials, rest) is None:
            tail = _Node(rest, blocks[bi], node)
            _put(node.partials, tail)
            tail.stamp = self._clock
            self.allocator.retain([tail.block])
            self._n_blocks += 1
            self.inserted_blocks += 1
        if self.max_blocks is not None:
            while self._n_blocks > self.max_blocks and self._evict_lru():
                pass

    # -- eviction ----------------------------------------------------------

    def _evictable(self) -> List[_Node]:
        """Leaf nodes whose block only the index holds (allocator refcount
        1): safe to drop.  Interior nodes become evictable once their
        subtree is gone; pinned nodes (a live slot shares the block) keep
        their whole ancestor path alive."""
        out = []
        stack = [self._root]
        while stack:
            n = stack.pop()
            stack.extend(_nodes(n.children))
            stack.extend(_nodes(n.partials))
            if (n is not self._root and not n.children and not n.partials
                    and self.allocator.refcount(n.block) == 1):
                out.append(n)
        return out

    def _evict_lru(self) -> bool:
        victims = self._evictable()
        if not victims:
            return False
        node = min(victims, key=lambda n: n.stamp)
        parent = node.parent
        if node.tokens == self.block_size:
            _unlink(parent.children, node)
        else:
            _unlink(parent.partials, node)
        self.allocator.free([node.block])
        self._n_blocks -= 1
        self.evicted_blocks += 1
        return True

    def reclaim(self, shortfall: int) -> int:
        """`BlockAllocator.reclaim` hook: evict LRU index-only blocks back
        to the free list until `shortfall` blocks are recovered or nothing
        evictable remains.  Returns the number of blocks freed."""
        freed = 0
        while freed < shortfall and self._evict_lru():
            freed += 1
        return freed

    def clear(self) -> int:
        """Drop every unpinned index entry (testing / manual flush)."""
        freed = 0
        while self._evict_lru():
            freed += 1
        return freed

    # -- invariants --------------------------------------------------------

    def index_blocks(self) -> set:
        """The set of pool blocks the index currently references
        (telemetry / invariant tests)."""
        out = set()
        stack = list(_nodes(self._root.children)) \
            + list(_nodes(self._root.partials))
        while stack:
            n = stack.pop()
            out.add(n.block)
            stack.extend(_nodes(n.children))
            stack.extend(_nodes(n.partials))
        return out

    def check(self) -> None:
        """Structural invariants (tests call this after every operation):
        node count matches the block counter, partial tails are leaves,
        every node is filed under the rolling hash of its token content,
        every indexed block is live (refcount >= 1) and off the free list,
        and no block is indexed twice."""
        seen = set()
        count = 0
        stack = [(self._root, True)]
        while stack:
            n, is_root = stack.pop()
            for group in (n.children, n.partials):
                for h, bucket in group.items():
                    for c in bucket:
                        if c.hash != h or _rhash(c.key) != h:
                            raise AssertionError(
                                f"node filed under stale hash {h}")
                        stack.append((c, False))
            for p in _nodes(n.partials):
                if p.children or p.partials:
                    raise AssertionError("partial tail is not a leaf")
            if is_root:
                continue
            count += 1
            if n.block in seen:
                raise AssertionError(f"block {n.block} indexed twice")
            seen.add(n.block)
            if self.allocator.refcount(n.block) < 1:
                raise AssertionError(f"indexed block {n.block} is free")
        if count != self._n_blocks:
            raise AssertionError(f"node count {count} != "
                                 f"counter {self._n_blocks}")
