"""Deterministic open-loop load generation for goodput measurement.

Production serving is judged in goodput — requests/sec meeting their
TTFT/TPOT SLOs under sustained arrival pressure — which a closed
submit-all-then-drain trace cannot measure: arrival pressure must be
OPEN-LOOP (requests arrive on the trace's clock whether or not the engine
keeps up), or queueing collapse is invisible.  This module builds seeded,
reproducible traces and replays them against an `InferenceEngine`:

  ArrivalSpec   when requests arrive: "poisson" (exponential gaps at
                `rate_rps`) or "bursty" (Markov-modulated Poisson: the
                rate flips between a lo and a hi state with exponential
                dwell times — the flash-crowd shape real traffic has)
  PromptSpec    what arrives: prompt lengths uniform or long-tailed
                (Pareto), a shared-prefix fraction (prefix-cache traffic),
                an encoder-only fraction (EncodeTask blend), and a sampled
                fraction (vs greedy)
  SLOSpec       per-request budgets: `ttft_ms` -> task.deadline_ms,
                `tpot_ms` -> task.slo_tpot_ms
  make_trace()  LoadSpec -> [TimedTask], thousands if asked
  replay()      open-loop wall-clock submission harness shared by tests
                and benchmarks/serving_bench.py

Seed discipline (tested): arrival timing and prompt content draw from two
INDEPENDENT numpy Generators, and each request's sampling seed is its uid
— so changing the traffic seed (`arrival_seed`) reshuffles *when* requests
arrive but never what any request computes, and a given uid's sampled
tokens are identical across traces, policies and loops.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.serving.sampling import SamplingParams
from repro.serving.tasks import EncodeTask, GenerateTask, Task

# Domain-separation constants so arrival_seed == prompt_seed still yields
# independent streams (default_rng hashes the full key sequence).
_ARRIVAL_DOMAIN = 0x41525256        # "ARRV"
_PROMPT_DOMAIN = 0x50524D50         # "PRMP"


@dataclass(frozen=True)
class ArrivalSpec:
    """Arrival process.  kind="poisson": exponential inter-arrivals at
    `rate_rps`.  kind="bursty": Markov-modulated Poisson — the process
    dwells in a lo state (`rate_rps`) or a hi state (`burst_rate_rps`,
    default 4x) with exponential `dwell_s` mean holding times."""
    kind: str = "poisson"
    rate_rps: float = 8.0
    burst_rate_rps: float = 0.0     # 0 => 4 * rate_rps
    dwell_s: float = 1.0

    def __post_init__(self):
        if self.kind not in ("poisson", "bursty"):
            raise ValueError(f"arrival kind must be 'poisson' or "
                             f"'bursty': {self.kind!r}")
        if not self.rate_rps > 0:
            raise ValueError(f"rate_rps must be > 0: {self.rate_rps}")
        if self.kind == "bursty" and not self.dwell_s > 0:
            raise ValueError(f"dwell_s must be > 0: {self.dwell_s}")

    @property
    def hi_rate(self) -> float:
        return self.burst_rate_rps or 4.0 * self.rate_rps


@dataclass(frozen=True)
class PromptSpec:
    """Prompt mix.  Lengths are uniform in [min_len, max_len] unless
    `tail_alpha` > 0, which draws min_len + Pareto(tail_alpha) clipped to
    max_len — a long-tail mix where most prompts are short and a few hit
    the cap.  `shared_frac` of requests open with one common prefix of
    `prefix_len` tokens (prefix-cache traffic); `encode_frac` arrive as
    EncodeTasks; `sampled_frac` of generate requests sample (temperature
    0.8, top-k 40), the rest are greedy."""
    min_len: int = 4
    max_len: int = 48
    tail_alpha: float = 0.0
    shared_frac: float = 0.0
    prefix_len: int = 0
    encode_frac: float = 0.0
    sampled_frac: float = 0.5

    def __post_init__(self):
        if not 1 <= self.min_len <= self.max_len:
            raise ValueError(f"need 1 <= min_len <= max_len: "
                             f"{self.min_len}..{self.max_len}")
        for name in ("shared_frac", "encode_frac", "sampled_frac"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1]: {v}")
        if self.shared_frac > 0 and not 0 < self.prefix_len:
            raise ValueError("shared_frac > 0 needs prefix_len >= 1")
        if self.prefix_len > self.min_len:
            raise ValueError(f"prefix_len {self.prefix_len} exceeds "
                             f"min_len {self.min_len}")


@dataclass(frozen=True)
class SLOSpec:
    """Per-request budgets stamped onto every emitted task (None = no
    SLO): `ttft_ms` becomes `deadline_ms` (TTFT budget — DeadlinePolicy
    schedules and sheds on it), `tpot_ms` becomes `slo_tpot_ms` (checked
    at retirement for attainment accounting only)."""
    ttft_ms: Optional[float] = None
    tpot_ms: Optional[float] = None


@dataclass(frozen=True)
class LoadSpec:
    requests: int
    vocab: int
    arrival: ArrivalSpec = field(default_factory=ArrivalSpec)
    prompts: PromptSpec = field(default_factory=PromptSpec)
    slo: SLOSpec = field(default_factory=SLOSpec)
    max_new: int = 8
    eos_id: Optional[int] = None

    def __post_init__(self):
        if self.requests < 1:
            raise ValueError(f"requests must be >= 1: {self.requests}")
        if self.vocab < 2:
            raise ValueError(f"vocab must be >= 2: {self.vocab}")


@dataclass(frozen=True)
class TimedTask:
    """One trace entry: submit `task` when the trace clock passes `t_s`."""
    t_s: float
    task: Task


def arrival_times(spec: ArrivalSpec, n: int,
                  rng: np.random.Generator) -> np.ndarray:
    """[n] float64 seconds from trace start, nondecreasing."""
    if spec.kind == "poisson":
        return np.cumsum(rng.exponential(1.0 / spec.rate_rps, n))
    # bursty (MMPP): walk lo/hi states with exponential dwells, drawing
    # exponential gaps at the current state's rate; a gap that crosses a
    # state flip is re-drawn from the flip point (memorylessness makes
    # this exact, not an approximation)
    times = np.empty(n)
    t = 0.0
    hi = False
    flip = rng.exponential(spec.dwell_s)
    for i in range(n):
        while True:
            rate = spec.hi_rate if hi else spec.rate_rps
            gap = rng.exponential(1.0 / rate)
            if t + gap <= flip:
                t += gap
                break
            t = flip
            hi = not hi
            flip = t + rng.exponential(spec.dwell_s)
        times[i] = t
    return times


def make_trace(spec: LoadSpec, *, arrival_seed: int = 0,
               prompt_seed: int = 0, uid0: int = 0) -> List[TimedTask]:
    """Build a deterministic open-loop trace.  Same (spec, seeds, uid0)
    => identical trace, always.  `arrival_seed` drives ONLY the arrival
    clock; `prompt_seed` drives ONLY prompt content/class; request uid
    `u` always samples with seed `u` — three independent axes."""
    rng_arr = np.random.default_rng([_ARRIVAL_DOMAIN, arrival_seed])
    rng_pr = np.random.default_rng([_PROMPT_DOMAIN, prompt_seed])
    p = spec.prompts
    times = arrival_times(spec.arrival, spec.requests, rng_arr)
    # the shared prefix is one draw per trace: every shared request opens
    # with the same tokens (what a prefix cache can actually reuse)
    prefix = (rng_pr.integers(0, spec.vocab, p.prefix_len, dtype=np.int32)
              if p.shared_frac > 0 else None)
    out: List[TimedTask] = []
    for i in range(spec.requests):
        uid = uid0 + i
        # per-request class/content draws all come from rng_pr, in a fixed
        # order, so the stream is reproducible position-by-position
        if p.tail_alpha > 0:
            n = p.min_len + int(rng_pr.pareto(p.tail_alpha) * p.min_len)
            n = min(n, p.max_len)
        else:
            n = int(rng_pr.integers(p.min_len, p.max_len + 1))
        tokens = rng_pr.integers(0, spec.vocab, n, dtype=np.int32)
        is_enc = rng_pr.random() < p.encode_frac
        is_shared = prefix is not None and rng_pr.random() < p.shared_frac
        is_sampled = rng_pr.random() < p.sampled_frac
        if is_shared:
            tokens = np.concatenate([prefix, tokens[p.prefix_len:]])
        if is_enc:
            task: Task = EncodeTask(uid=uid, prompt=tokens,
                                    deadline_ms=spec.slo.ttft_ms)
        else:
            sampling = (SamplingParams(temperature=0.8, top_k=40, seed=uid)
                        if is_sampled else SamplingParams())
            task = GenerateTask(uid=uid, prompt=tokens,
                                max_new_tokens=spec.max_new,
                                eos_id=spec.eos_id, sampling=sampling,
                                deadline_ms=spec.slo.ttft_ms,
                                slo_tpot_ms=spec.slo.tpot_ms)
        out.append(TimedTask(float(times[i]), task))
    return out


def replay(engine, trace: List[TimedTask], *, time_scale: float = 1.0,
           max_steps: int = 200_000) -> Tuple[List[Task], float]:
    """Open-loop wall-clock replay: submit each task once the (scaled)
    clock passes its arrival time — arrivals never wait for the engine,
    which is exactly what makes over-capacity pressure measurable — and
    step the engine in between.  `time_scale=0` collapses the clock
    (every arrival is due immediately: a closed-loop batch, useful for
    warmup and capacity calibration).  Returns (tasks completed during
    this call — served AND shed, wall seconds)."""
    trace = sorted(trace, key=lambda tt: tt.t_s)
    start = len(engine.completed)
    i = 0
    t0 = time.perf_counter()
    steps = 0
    while (i < len(trace) or engine.has_work()) and steps < max_steps:
        now = (math.inf if time_scale <= 0
               else (time.perf_counter() - t0) / time_scale)
        while i < len(trace) and trace[i].t_s <= now:
            engine.submit(trace[i].task)
            i += 1
        if engine.has_work():
            engine.step()
            steps += 1
        elif i < len(trace):
            # idle until the next arrival: sleep in sub-ms slices so a
            # due arrival is picked up promptly
            wait = (trace[i].t_s - now) * max(time_scale, 1e-9)
            time.sleep(min(max(wait, 0.0), 0.0005))
    wall = time.perf_counter() - t0
    return engine.completed[start:], wall
