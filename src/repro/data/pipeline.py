"""Deterministic, step-indexed data pipeline.

Restart-exact: batch(step) is a pure function of (seed, step, host), so a
restore-at-step-k run is bitwise identical to the uninterrupted one (the
fault-tolerance contract in runtime/fault_tolerance.py).  Two sources:

  SyntheticStream  hash-seeded token batches (benchmarks, dry-runs, tests)
  FileStream       binary token file (uint16/uint32) via np.memmap, sharded
                   by host and strided by step

Both emit the `frontends.batch_struct` layout (tokens/labels + stub patch /
frame embeddings for VLM/audio archs).
"""
from __future__ import annotations

import os
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


class SyntheticStream:
    def __init__(self, cfg: ModelConfig, *, global_batch: int, seq_len: int,
                 seed: int = 0, kind: str = "train",
                 host_id: int = 0, n_hosts: int = 1):
        self.cfg = cfg
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.seed = seed
        self.kind = kind
        self.host_id = host_id
        self.n_hosts = n_hosts

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        s_text = self.seq_len - (cfg.n_patches or 0)
        b = self.global_batch // self.n_hosts
        toks = rng.integers(0, max(cfg.vocab, 2), (b, s_text + 1),
                            dtype=np.int32)
        out = {"tokens": jnp.asarray(toks[:, :-1])}
        if self.kind == "train":
            out["labels"] = jnp.asarray(toks[:, 1:])
        if cfg.n_patches:
            out["patches"] = jnp.asarray(rng.standard_normal(
                (b, cfg.n_patches, cfg.d_model), dtype=np.float32),
                jnp.bfloat16)
        if cfg.enc_schedule:
            fr = np.zeros((b, cfg.enc_seq_padded, cfg.d_model), np.float32)
            fr[:, :cfg.enc_seq] = rng.standard_normal(
                (b, cfg.enc_seq, cfg.d_model), dtype=np.float32)
            out["frames"] = jnp.asarray(fr, jnp.bfloat16)
        return out


class FileStream:
    """Binary token file -> LM batches.  The file is one flat token array;
    batch(step) reads a deterministic window: restart-exact and host-sharded.
    """
    def __init__(self, cfg: ModelConfig, path: str, *, global_batch: int,
                 seq_len: int, dtype=np.uint16, kind: str = "train",
                 host_id: int = 0, n_hosts: int = 1):
        self.cfg = cfg
        self.path = path
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.kind = kind
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        need = (global_batch // n_hosts) * (seq_len + 1)
        assert len(self.tokens) >= need, (
            f"{path}: {len(self.tokens)} tokens < one batch ({need})")

    def batch(self, step: int) -> dict:
        b = self.global_batch // self.n_hosts
        width = self.seq_len + 1
        n_windows = len(self.tokens) // width
        rows = []
        for i in range(b):
            w = (step * self.global_batch + self.host_id * b + i) % n_windows
            rows.append(np.asarray(self.tokens[w * width:(w + 1) * width],
                                   dtype=np.int32))
        toks = np.clip(np.stack(rows), 0, self.cfg.vocab - 1)
        out = {"tokens": jnp.asarray(toks[:, :-1])}
        if self.kind == "train":
            out["labels"] = jnp.asarray(toks[:, 1:])
        return out


def make_stream(cfg: ModelConfig, *, global_batch: int, seq_len: int,
                path: Optional[str] = None, seed: int = 0,
                kind: str = "train") -> object:
    if path and os.path.exists(path):
        return FileStream(cfg, path, global_batch=global_batch,
                          seq_len=seq_len, kind=kind)
    return SyntheticStream(cfg, global_batch=global_batch, seq_len=seq_len,
                           seed=seed, kind=kind)
