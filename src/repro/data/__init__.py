from repro.data.pipeline import SyntheticStream, FileStream, make_stream
