"""End-to-end training driver example: a ~100M-parameter llama-style model
for a few hundred steps with checkpointing, resume and straggler watch.

    PYTHONPATH=src python examples/train_100m.py --steps 300          # full
    PYTHONPATH=src python examples/train_100m.py --tiny --steps 50    # quick

The full 100M config takes a while on one CPU (it is sized for a TPU chip);
--tiny swaps in a 5M model with the identical code path.  Interrupt with
Ctrl-C: the run checkpoints and resumes from the same step (bitwise-exact
data pipeline).
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs.base import ModelConfig, uniform_schedule
from repro.launch import train as train_cli
from repro.configs import REGISTRY

M100 = ModelConfig(
    name="llama-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, head_dim=64, d_ff=2048, vocab=32_000,
    schedule=uniform_schedule("attn", 12), mlp_act="swiglu", norm="rmsnorm",
    rope_theta=10_000.0, attention_sharding="seq_sp", max_seq=1024)

TINY = ModelConfig(
    name="llama-5m", family="dense", n_layers=4, d_model=256,
    n_heads=4, n_kv_heads=4, head_dim=64, d_ff=512, vocab=8_192,
    schedule=uniform_schedule("attn", 4), mlp_act="swiglu", norm="rmsnorm",
    rope_theta=10_000.0, attention_sharding="seq_sp", max_seq=1024)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_100m_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = TINY if args.tiny else M100
    REGISTRY[cfg.name] = cfg                 # register for the CLI
    print(f"training {cfg.name}: {cfg.n_params():,} params")
    argv = ["--arch", cfg.name, "--steps", str(args.steps),
            "--global-batch", "8", "--seq", "256",
            "--checkpoint-dir", args.checkpoint_dir,
            "--checkpoint-every", "50", "--single-device",
            "--log-every", "10"]
    if args.resume:
        argv.append("--resume")
    return train_cli.main(argv)


if __name__ == "__main__":
    sys.exit(main())
