"""Quickstart: train a tiny assigned-architecture model and generate from it.

    PYTHONPATH=src python examples/quickstart.py [--arch phi4-mini-3.8b]

Runs in ~2 minutes on one CPU: 40 train steps on a reduced config (loss
drops), then generation through the session-based `InferenceEngine` — the
same code paths the production mesh uses (launch/steps.py), just unsharded.
The engine takes variable-length prompts (no fixed prompt_len; prefill is
bucketed per length) and per-request `SamplingParams`.
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data import SyntheticStream
from repro.launch import steps
from repro.serving import InferenceEngine, Request, SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"arch={cfg.name}  params={cfg.n_params():,}")

    # -- train ------------------------------------------------------------
    shape = ShapeConfig("quickstart", "train", 64, 4)
    bundle = steps.make_train_step(cfg, shape, None,
                                   lr_fn=lambda s: jnp.asarray(1e-3))
    state = bundle.aux["init_state"](0)
    stream = SyntheticStream(cfg, global_batch=4, seq_len=64, seed=0)
    batch = stream.batch(0)               # overfit one batch for the demo
    for step in range(args.steps):
        state, metrics = bundle.fn(state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:3d}  loss {float(metrics['loss']):.4f}")

    # -- serve ------------------------------------------------------------
    params = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, state["params"])
    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=96)
    rng = np.random.default_rng(0)
    for uid, n in enumerate((12, 16, 24)):    # variable-length prompts
        engine.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab, n, dtype=np.int32),
            max_new_tokens=8,
            sampling=SamplingParams(temperature=0.7, top_k=20, seed=uid)
            if uid == 2 else SamplingParams()))
    for req in engine.run():
        mode = "sampled" if req.sampling.temperature > 0 else "greedy"
        print(f"request {req.uid} ({req.prompt_len} prompt tokens, {mode}): "
              f"generated {req.output}")
    print(engine.stats().summary())


if __name__ == "__main__":
    main()
