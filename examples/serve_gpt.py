"""Serving example: the paper's AR/NAR modes through the session-based
`InferenceEngine` on a GPT-class model (reduced GPT-J).

    PYTHONPATH=src python examples/serve_gpt.py

Demonstrates the serving surface end to end:

  1. the session API — variable-length prompts (bucketed NAR prefill, the
     paper's prompt-encoding mode), per-request SamplingParams (greedy and
     temperature/top-k mixed in one batch), streaming TokenEvents, and
     `engine.stats()` telemetry (Sec. VI-A's two throughput regimes);
  2. the scheduler split — a `PriorityPolicy` engine serving mixed-urgency
     traffic (priority + aging, bounded inversion);
  3. encoder-only serving — a batch of `EncodeTask`s (pooled NAR forward,
     the paper's encoder topology) sharing the engine with generation.

All forwards run the fused prologue/epilogue kernel pipeline (the
default); pass `fuse_epilogues=False` to A/B the discrete op chain —
greedy outputs are token-identical either way.
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import PAPER_MODELS
from repro.models import lm
from repro.serving import (EncodeTask, InferenceEngine, PriorityPolicy,
                           Request, SamplingParams)


def streaming_session(cfg, params, rng):
    """1. Session API: mixed sampling, streaming, telemetry."""
    engine = InferenceEngine(cfg, params, batch_size=4, max_seq=128)
    for uid in range(8):
        n = int(rng.integers(8, 40))          # variable-length prompts
        sampling = (SamplingParams(temperature=0.8, top_k=20, seed=uid)
                    if uid % 2 else SamplingParams())       # mixed in-batch
        engine.submit(Request(
            uid=uid, prompt=rng.integers(0, cfg.vocab, n, dtype=np.int32),
            max_new_tokens=12, sampling=sampling))

    # streaming: tokens arrive the moment their engine step completes
    streamed = {}
    for ev in engine.generate():
        streamed.setdefault(ev.uid, []).append(ev.token)
        if ev.is_last:
            print(f"  req {ev.uid} done: {len(streamed[ev.uid])} tokens, "
                  f"first: {streamed[ev.uid][:6]}...")

    stats = engine.stats()
    print(f"{stats.requests_completed} requests served in "
          f"{engine.steps_run} AR steps")
    print(stats.summary())


def priority_session(cfg, params, rng):
    """2. PriorityPolicy: urgent traffic jumps the queue (with aging, so
    background work cannot starve)."""
    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=128,
                             scheduler=PriorityPolicy(aging_s=5.0))
    # a burst of background work, then two urgent requests behind it
    for uid in range(4):
        engine.submit(Request(
            uid=uid, prompt=rng.integers(0, cfg.vocab, 16, dtype=np.int32),
            max_new_tokens=8, priority=0))
    for uid in (100, 101):
        engine.submit(Request(
            uid=uid, prompt=rng.integers(0, cfg.vocab, 12, dtype=np.int32),
            max_new_tokens=8, priority=5, deadline_ms=500.0))

    order = [t.uid for t in engine.run()]
    urgent_rank = max(order.index(100), order.index(101))
    print(f"  completion order: {order} "
          f"(urgent uids 100/101 finished by rank {urgent_rank})")


def encode_session(cfg, params, rng):
    """3. EncodeTask batch: pooled sentence embeddings through the same
    engine — no KV cache, no decode slots, batched per length bucket."""
    engine = InferenceEngine(cfg, params, batch_size=4, max_seq=128)
    for uid in range(4):
        n = int(rng.integers(6, 30))
        engine.submit(EncodeTask(
            uid=uid, prompt=rng.integers(0, cfg.vocab, n, dtype=np.int32),
            pooling="mean" if uid % 2 else "last"))
    done = sorted(engine.run(), key=lambda t: t.uid)
    for t in done:
        e = t.embedding
        print(f"  encode {t.uid} ({t.pooling:4s}): [{cfg.d_model}] "
              f"embedding, norm {float(np.linalg.norm(e)):.2f}")
    st = engine.stats()
    print(f"  encode throughput: {st.encode_batches} batches, "
          f"{st.encode_tokens} tokens")


def main():
    cfg = PAPER_MODELS["gpt-j"].reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.bfloat16)
    rng = np.random.default_rng(1)
    print("== 1. streaming session (FCFS) ==")
    streaming_session(cfg, params, rng)
    print("== 2. priority scheduling ==")
    priority_session(cfg, params, rng)
    print("== 3. encoder-only serving (EncodeTask) ==")
    encode_session(cfg, params, rng)


if __name__ == "__main__":
    main()
