"""Serving example: the paper's AR/NAR modes through the continuous-batching
engine on a GPT-class model (reduced GPT-J).

    PYTHONPATH=src python examples/serve_gpt.py

Reports prefill (NAR, paper's prompt-encoding mode) and decode (AR) timing
per request — the paper's two benchmark regimes (Sec. VI-A).
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import PAPER_MODELS, REGISTRY
from repro.models import lm
from repro.serving import Request, ServingEngine


def main():
    cfg = PAPER_MODELS["gpt-j"].reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.bfloat16)
    engine = ServingEngine(cfg, params, batch_size=4, max_seq=128,
                           prompt_len=32)
    rng = np.random.default_rng(1)
    for uid in range(8):
        engine.submit(Request(
            uid=uid, prompt=rng.integers(0, cfg.vocab, 32, dtype=np.int32),
            max_new_tokens=12))
    done = engine.run()
    print(f"{len(done)} requests served in {engine.steps_run} AR steps "
          f"(continuous batching: {8 * 12} tokens total)")
    for r in done[:4]:
        print(f"  req {r.uid}: NAR prefill {r.prefill_ms:6.0f}ms | "
              f"AR {len(r.output)} tokens | {r.output[:6]}...")


if __name__ == "__main__":
    main()
