"""Serving example: the paper's AR/NAR modes through the session-based
`InferenceEngine` on a GPT-class model (reduced GPT-J).

    PYTHONPATH=src python examples/serve_gpt.py

Demonstrates the session API: variable-length prompts (bucketed NAR
prefill, the paper's prompt-encoding mode), per-request SamplingParams
(greedy and temperature/top-k mixed in one batch), streaming TokenEvents,
and `engine.stats()` serving telemetry (Sec. VI-A's two throughput
regimes).
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import PAPER_MODELS
from repro.models import lm
from repro.serving import InferenceEngine, Request, SamplingParams


def main():
    cfg = PAPER_MODELS["gpt-j"].reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.bfloat16)
    engine = InferenceEngine(cfg, params, batch_size=4, max_seq=128)
    rng = np.random.default_rng(1)
    for uid in range(8):
        n = int(rng.integers(8, 40))          # variable-length prompts
        sampling = (SamplingParams(temperature=0.8, top_k=20, seed=uid)
                    if uid % 2 else SamplingParams())       # mixed in-batch
        engine.submit(Request(
            uid=uid, prompt=rng.integers(0, cfg.vocab, n, dtype=np.int32),
            max_new_tokens=12, sampling=sampling))

    # streaming: tokens arrive the moment their engine step completes
    streamed = {}
    for ev in engine.generate():
        streamed.setdefault(ev.uid, []).append(ev.token)
        if ev.is_last:
            print(f"  req {ev.uid} done: {len(streamed[ev.uid])} tokens, "
                  f"first: {streamed[ev.uid][:6]}...")

    stats = engine.stats()
    print(f"{stats.requests_completed} requests served in "
          f"{engine.steps_run} AR steps")
    print(stats.summary())


if __name__ == "__main__":
    main()
