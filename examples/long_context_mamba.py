"""Long-context decode with an attention-free SSM (the long_500k story).

    PYTHONPATH=src python examples/long_context_mamba.py

Decodes with mamba2 (reduced) far past the context where a quadratic
attention cache would grow: the SSM state is O(1) per layer regardless of
how many tokens have been consumed — the property that qualifies SSM/hybrid
archs for the 512k-token cell (DESIGN.md §5).
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.precision import BF16
from repro.models import lm
from repro.sharding.plan import UNSHARDED


def main():
    cfg = get_config("mamba2-2.7b").reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
    rng = np.random.default_rng(0)
    prompt = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (1, 32), dtype=np.int32))}

    tok, caches, pos = lm.forward_prefill(params, prompt, plan=UNSHARDED,
                                          cfg=cfg, policy=BF16, max_seq=1 << 20)
    state_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(caches))
    print(f"SSM state total: {state_bytes/1024:.1f} KiB "
          f"(constant — no KV growth)")

    decode = jax.jit(lambda p, t, po, c: lm.forward_decode(
        p, t, po, c, plan=UNSHARDED, cfg=cfg, policy=BF16))
    t, p = tok, pos
    t0 = time.perf_counter()
    n = 64
    for i in range(n):
        t, caches = decode(params, t, p, caches)
        p = p + 1
    jax.block_until_ready(t)
    dt = time.perf_counter() - t0
    print(f"decoded {n} tokens to position {int(p[0])} "
          f"at {n/dt:.1f} tok/s; per-step cost is position-independent")


if __name__ == "__main__":
    main()
