"""Paper Table III: per-precision utilization & efficiency (GPT-J, S=1024).

CPU container => no power rail; we report the roofline analogs:
  FPU util  -> compute_fraction (compute term / binding term)
  GFLOPS/W  -> useful model FLOPs / step_time / (chips x 170 W v5e TDP)
Paper validation shape: NAR compute-heavy and rising with precision width;
AR utilization <10% at every precision (memory-roofline property).
"""
from __future__ import annotations

import os

from benchmarks.common import ART, V5E_TDP_W, cell, write_csv
from repro.configs import get_config


def main():
    print("== Table III: precision sweep, GPT-J S=1024 (1 chip) ==")
    rows = []
    cfg = get_config("gpt-j")
    n = cfg.n_active_params() - cfg.padded_vocab * cfg.d_model * 2
    for mode, shape, useful in (
            ("NAR", "prefill:1024:1", 2.0 * n * 1024),
            ("AR", "decode:1024:1", 2.0 * n)):
        for pol in ("fp32", "bf16", "fp8_serve"):
            rec = cell(arch="gpt-j", shape=shape, mesh="none", policy=pol,
                       tag=f"prec_{mode}_{pol}")
            if not rec.get("ok"):
                rows.append(["gpt-j", mode, pol, "FAIL", "", ""])
                continue
            r = rec["roofline"]
            st = r["step_time_s"]
            gflops_w = useful / st / V5E_TDP_W / 1e9
            rows.append(["gpt-j", mode, pol,
                         f"{r['compute_fraction']*100:.1f}%",
                         f"{gflops_w:.1f}", r["bound"]])
    header = ["arch", "mode", "policy", "mxu_util(analog)", "GFLOPS/W",
              "bound"]
    print("  " + " | ".join(f"{h:>16s}" for h in header))
    for r in rows:
        print("  " + " | ".join(f"{str(x):>16s}" for x in r))
    write_csv(os.path.join(ART, "tab3_precision.csv"), header, rows)
    return rows


if __name__ == "__main__":
    main()
