"""Low-precision serving gates: int8 weights + int8 paged KV ->
artifacts/bench/BENCH_quant.json  (the CI gate for the PR's two knobs).

Two evidence layers, matching the repo's split between roofline-projected
and host-measured numbers (EXPERIMENTS.md §Methodology):

  roofline (dry-run cells, full GPT-J arch)
    weight-only int8 must shrink the AR step's HBM-traffic proxy
    (`mem_bytes_per_device`) to <= 0.62x bf16 and make the roofline-
    projected decode step STRICTLY faster — decode is weight-read-bound,
    so streaming int8 tiles instead of bf16 halves the dominant term.

  engine (reduced GPT-J on this host)
    int8 KV at equal cache_blocks must (about) halve the pool bytes
    (<= 0.53x: the per-block-per-head fp32 scales cost a few bytes per
    block) — equivalently, resident-context capacity at a fixed pool-byte
    budget rises >= 1.9x.  Weight-only int8 greedy choices are compared
    TEACHER-FORCED: every param set conditions on the identical
    bf16-generated prefix (the verification stack pointed at the engine's
    rollout), so one flip counts once instead of cascading.  The gate
    requires the full-model max logit perturbation under 1% of the logit
    span AND one of: zero flips; flip rate < 1% (the real-checkpoint
    criterion, where semantic argmax margins dwarf quantization noise);
    or flips within 2x + 2 of a noise-floor control — the same model
    perturbed by independent unbiased noise of exactly quantization
    magnitude (+- scale/2 per weight).  Random-init reduced weights have
    razor-thin exchangeable-logit margins, so SOME flips are forced by
    ANY perturbation that size; matching the noise floor shows rounding
    adds no systematic decision bias beyond it.  The free-running engine
    divergence is recorded alongside for context.  Host-measured decode
    tok/s is recorded for audit but NOT gated: on this CPU host the
    reference GEMM path dequantizes before the dot, so the
    memory-bandwidth win the kernels exist for is only visible in the
    roofline numbers.

Exits nonzero when any check fails.  `--smoke` shrinks the dry-run shape
for CI (same gates, smaller compile).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import ART, cell, step_time, throughput

WEIGHT_MEM_RATIO_MAX = 0.62
KV_POOL_RATIO_MAX = 0.53
KV_CAPACITY_MIN = 1.9
GREEDY_DIVERGENCE_MAX = 0.01
LOGIT_ERR_SPAN_MAX = 0.01


def roofline_section(smoke: bool, cells: dict, checks: dict) -> None:
    shape = "decode:64:4" if smoke else "decode:256:8"
    bf = cell("gpt-j", shape, tag="quant_wbf16")
    w8 = cell("gpt-j", shape, tag="quant_wint8", weight_dtype="int8")
    kv8 = cell("gpt-j", shape, tag="quant_kvint8", kv_dtype="int8")
    cells["roofline"] = {"shape": shape, "wbf16": bf, "wint8": w8,
                         "kvint8": kv8}
    if not (bf.get("ok") and w8.get("ok")):
        return                       # incomplete: required checks stay absent
    mb = bf["roofline"]["mem_bytes_per_device"]
    m8 = w8["roofline"]["mem_bytes_per_device"]
    ratio = m8 / mb
    checks["weight_mem_ratio_le_0.62"] = bool(ratio <= WEIGHT_MEM_RATIO_MAX)
    checks["weight_decode_toks_strictly_better"] = bool(
        step_time(w8) < step_time(bf))
    cells["roofline"]["weight_mem_ratio"] = ratio
    cells["roofline"]["decode_tok_s_roofline"] = {
        "bf16": throughput(bf), "int8": throughput(w8)}
    print(f"  roofline {shape}: AR mem/device {mb / 2**30:.2f} -> "
          f"{m8 / 2**30:.2f} GiB ({ratio:.3f}x), decode "
          f"{throughput(bf):.0f} -> {throughput(w8):.0f} tok/s projected")


def _noise_params(params, seed: int):
    """The noise-floor control: the same bf16 tree with every would-be-
    quantized weight perturbed by INDEPENDENT uniform noise of exactly the
    quantization error magnitude (+- scale/2 per element, the worst-case
    round-to-nearest error).  Greedy flips under this perturbation are the
    flips any unbiased noise of quantization size causes on this model's
    argmax margins — int8 should not flip meaningfully more."""
    import jax
    import jax.numpy as jnp

    from repro.models.quantize import quantize_params

    qp = quantize_params(params)
    key = [jax.random.key(seed)]

    def walk(p, q):
        if isinstance(q, dict) and set(q) == {"q", "scale"}:
            key[0], k = jax.random.split(key[0])
            amp = 0.5 * q["scale"][..., None, :]     # scale drops axis -2
            noise = jax.random.uniform(k, p.shape, jnp.float32, -1.0, 1.0)
            return (p.astype(jnp.float32) + noise * amp).astype(p.dtype)
        if isinstance(p, dict):
            return {name: walk(p[name], q[name]) for name in p}
        if isinstance(p, tuple):
            return tuple(walk(a, b) for a, b in zip(p, q))
        return p

    return walk(params, qp)


def _teacher_forced_logits(cfg, params, reqs, base, max_new):
    """Full-vocab logits at every position of the bf16-generated sequences,
    conditioned on identical prefixes (the verification stack pointed at
    the engine's rollout), for three parameter sets: bf16 reference, int8
    weights, and the bf16 noise-floor control.
    -> dict of [B, C, V] fp32 arrays keyed "bf16" / "int8" / "noise"."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import ShapeConfig
    from repro.core.embedding import logits_local
    from repro.core.precision import FP32
    from repro.launch import steps as steps_mod
    from repro.models.quantize import quantize_params

    B, max_seq, bs = len(reqs), 64, 8
    nb = B * (max_seq // bs)
    dshape = ShapeConfig("quant_tf", "decode", max_seq, B)
    plens = np.array([len(r.prompt) for r in reqs], np.int32)
    cont = np.zeros((B, max_new), np.int32)
    for b, r in enumerate(reqs):
        cont[b] = base[r.uid][:max_new]

    def logits(weight_dtype, p):
        # FP32 compute policy throughout (the engine runs match): chunk /
        # verify numerics are then bit-identical to prefill / decode, so
        # the only perturbation between ref and int8 is quantization
        dstep = steps_mod.make_decode_step(
            cfg, dshape, None, max_seq=max_seq, with_sampling=True,
            paged=(nb, bs), weight_dtype=weight_dtype, policy=FP32)
        layout = dstep.aux["paged"]
        chunk = steps_mod.make_chunk_prefill_step(
            cfg, dshape, None, layout=layout, chunk_tokens=16,
            max_seq=max_seq, weight_dtype=weight_dtype, policy=FP32)
        caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                              dstep.aux["cache_struct"])
        if weight_dtype == "int8":
            p = quantize_params(p)
        per_row = max_seq // bs
        tables = np.full((B, layout.max_blocks), -1, np.int32)
        for b in range(B):
            tables[b, :per_row] = np.arange(b * per_row, (b + 1) * per_row)
        tables = jnp.asarray(tables)
        for start in range(0, int(plens.max()), 16):
            take = np.clip(plens - start, 0, 16).astype(np.int32)
            toks = np.zeros((B, 16), np.int32)
            for b, r in enumerate(reqs):
                got = r.prompt[start:start + take[b]]
                toks[b, :len(got)] = got
            _, caches, _ = chunk.fn(p, jnp.asarray(toks),
                                    jnp.full((B,), start, jnp.int32),
                                    jnp.asarray(take), caches, tables)
        # the verification stack, unrolled one level (single device, no
        # shard_map) so the per-position logits are observable — the
        # verify step itself folds them straight into its sampling head
        from repro.models import lm as lm_mod
        plan, policy = dstep.plan, dstep.policy
        x, _, head_norm = lm_mod._run_chunk_stack(
            p, jnp.asarray(cont), jnp.asarray(plens),
            jnp.full((B,), max_new, jnp.int32), caches, tables,
            plan=plan, cfg=cfg, policy=policy,
            paged_segments=layout.segments)
        E = x.shape[-1]
        z, _ = logits_local(x.reshape(B * max_new, E),
                            p["embedding"]["unemb"], plan=plan, cfg=cfg,
                            policy=policy, norm=head_norm)
        return np.asarray(z, np.float32).reshape(B, max_new, -1)

    z_ref = logits("bfloat16", params)
    # self-consistency: teacher-forcing the bf16 model over its own greedy
    # rollout must reproduce that rollout
    ref_choice = z_ref.argmax(-1)
    for b in range(B):
        want = list(base[reqs[b].uid][1:max_new])
        assert list(ref_choice[b][:max_new - 1]) == want, \
            f"teacher-forced stack disagrees with the engine (row {b})"
    return {"bf16": z_ref,
            "int8": logits("int8", params),
            "noise": logits("bfloat16", _noise_params(params, seed=17))}


def engine_section(checks: dict) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.core.precision import FP32
    from repro.models import lm
    from repro.serving import InferenceEngine, Request

    cfg = get_config("gpt-j").reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.bfloat16)
    rng = np.random.default_rng(3)
    max_new = 16

    def trace():
        return [Request(uid=i,
                        prompt=rng.integers(0, cfg.vocab, 12 + 3 * i,
                                            dtype=np.int32),
                        max_new_tokens=max_new) for i in range(8)]

    reqs = trace()

    def run(**kw):
        eng = InferenceEngine(cfg, params, batch_size=4, max_seq=64,
                              policy=FP32, **kw)
        for r in reqs:
            eng.submit(Request(uid=r.uid, prompt=r.prompt.copy(),
                               max_new_tokens=r.max_new_tokens))
        done = {t.uid: list(t.output) for t in eng.run()}
        st = eng.stats()
        return done, st

    base, bst = run()
    quant, qst = run(weight_dtype="int8", kv_dtype="int8")

    pool_ratio = qst.kv_pool_bytes / bst.kv_pool_bytes
    capacity = bst.kv_pool_bytes / qst.kv_pool_bytes
    checks["kv_pool_ratio_le_0.53"] = bool(pool_ratio <= KV_POOL_RATIO_MAX)
    checks["kv_capacity_ge_1.9"] = bool(capacity >= KV_CAPACITY_MIN)

    # free-running divergence (recorded, not gated): once one argmax flips,
    # the two engines decode different prefixes and every later position
    # counts as "diverged" — a cascade artifact, not 1 flip per position
    total = diverged = 0
    for uid in base:
        for a, b in zip(base[uid], quant[uid]):
            total += 1
            diverged += int(a != b)
    div_frac = diverged / max(total, 1)

    # the gated metric: teacher-forced greedy agreement.  Land each
    # request's bf16-generated sequence in the paged cache, then take the
    # full-vocab logits at EVERY position through the verification stack —
    # all param sets condition on the identical prefix at each step, so a
    # flip counts once, where the quantized logits actually crossed.
    z = _teacher_forced_logits(cfg, params, reqs, base, max_new)
    c_ref = z["bf16"].argmax(-1)
    flips = int((c_ref != z["int8"].argmax(-1)).sum())
    noise_flips = int((c_ref != z["noise"].argmax(-1)).sum())
    tf_total = c_ref.size
    flip_frac = flips / max(tf_total, 1)
    logit_span = float(z["bf16"].max() - z["bf16"].min())
    logit_err = float(np.abs(z["int8"] - z["bf16"]).max())
    noise_err = float(np.abs(z["noise"] - z["bf16"]).max())
    top2 = np.sort(z["bf16"], axis=-1)[..., -2:]
    margin_med = float(np.median(top2[..., 1] - top2[..., 0]))

    # two ways to pass, both with the full-model logit perturbation bounded
    # under 1% of the observed logit span:
    #   (a) flip rate < 1% of teacher-forced positions — the real-
    #       checkpoint criterion, where semantic argmax margins dwarf
    #       quantization noise;
    #   (b) flips within 2x + 2 of the noise-floor control — random-init
    #       reduced weights have razor-thin exchangeable-logit margins, so
    #       SOME flips are forced by ANY perturbation of quantization
    #       magnitude; int8 passes iff it flips no more than equally-sized
    #       unbiased noise, i.e. rounding adds no systematic decision bias.
    checks["greedy_match_or_bounded_divergence"] = bool(
        logit_err < LOGIT_ERR_SPAN_MAX * logit_span
        and (flips == 0 or flip_frac < GREEDY_DIVERGENCE_MAX
             or flips <= 2 * noise_flips + 2))

    print(f"  engine: pool {bst.kv_pool_bytes} -> {qst.kv_pool_bytes} B "
          f"({pool_ratio:.3f}x, capacity {capacity:.2f}x)")
    print(f"  teacher-forced: int8 flips {flips}/{tf_total} vs noise-floor "
          f"{noise_flips}/{tf_total} (free-running divergence "
          f"{diverged}/{total}); logit err {logit_err:.4f} (noise "
          f"{noise_err:.4f}) of span {logit_span:.2f}, median argmax "
          f"margin {margin_med:.4f}")
    print(f"  measured (CPU host, audit only): decode "
          f"{bst.ar_tok_s:.1f} tok/s bf16 vs {qst.ar_tok_s:.1f} tok/s int8")
    return {
        "arch": cfg.name,
        "weight_bytes_per_device": {"bf16": bst.weight_bytes_per_device,
                                    "int8": qst.weight_bytes_per_device},
        "kv_pool_bytes": {"bf16": bst.kv_pool_bytes,
                          "int8": qst.kv_pool_bytes},
        "kv_pool_ratio": pool_ratio,
        "kv_capacity_x": capacity,
        "teacher_forced_positions": tf_total,
        "teacher_forced_flips": flips,
        "teacher_forced_flip_frac": flip_frac,
        "noise_floor_flips": noise_flips,
        "noise_floor_logit_err_max": noise_err,
        "free_running_tokens_total": total,
        "free_running_tokens_diverged": diverged,
        "free_running_divergence_frac": div_frac,
        "logit_err_max": logit_err,
        "logit_span": logit_span,
        "median_argmax_margin": margin_med,
        "measured_ar_tok_s": {"bf16": bst.ar_tok_s, "int8": qst.ar_tok_s},
    }


REQUIRED = ("weight_mem_ratio_le_0.62", "weight_decode_toks_strictly_better",
            "kv_pool_ratio_le_0.53", "kv_capacity_ge_1.9",
            "greedy_match_or_bounded_divergence")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small dry-run shape (CI bench smoke)")
    ap.add_argument("--out", default=os.path.join(ART, "BENCH_quant.json"))
    args = ap.parse_args(argv)

    cells: dict = {}
    checks: dict = {}
    print("== low-precision serving gates (weights + paged KV int8) ==")
    roofline_section(args.smoke, cells, checks)
    cells["engine"] = engine_section(checks)
    # a cell that failed to build must fail the bench, not silently drop
    # its checks
    complete = all(k in checks for k in REQUIRED)
    out = {"cells": cells, "checks": checks,
           "ok": complete and all(checks.values())}
    os.makedirs(ART, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"  checks: {checks}")
    print(f"  -> {args.out}")
    if not out["ok"]:
        print("QUANT CHECKS FAILED", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
