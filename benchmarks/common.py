"""Shared benchmark infrastructure.

Every benchmark variant is one dry-run cell (lower + compile + roofline) run
in a subprocess with a distinct tag; results are cached as JSON under
artifacts/bench/.  This container is CPU-only, so throughput numbers are
ROOFLINE-PROJECTED for TPU v5e (step_time = max of the three terms) — the
honest stand-in for wall-clock, per EXPERIMENTS.md §Methodology.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.cell_variant import variant_key

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")
V5E_TDP_W = 170.0          # per-chip board power estimate (public v5e figure)


def cell(arch: str, shape: str, *, mesh: str = "none", policy: str = "",
         tag: str = "baseline", naive: bool = False, reduce: str = "ring",
         nofuse: bool = False, kv_dtype: str = "bfloat16",
         weight_dtype: str = "bfloat16", timeout: int = 1200) -> dict:
    """Run (or fetch cached) one dry-run cell; returns its record."""
    os.makedirs(ART, exist_ok=True)
    safe = shape.replace(":", "-")
    fname = os.path.join(ART, f"{arch}__{safe}__{mesh}__{tag}.json")
    want = variant_key(policy=policy, naive=naive, reduce_method=reduce,
                       fuse=not nofuse, kv_cache_dtype=kv_dtype,
                       weight_dtype=weight_dtype)
    if os.path.exists(fname):
        rec = json.load(open(fname))
        if rec.get("variant") == want:
            return rec
        os.remove(fname)   # tag collision or legacy cache: recompute
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--out", ART, "--tag", tag,
           "--reduce", reduce, "--kv-dtype", kv_dtype,
           "--weight-dtype", weight_dtype]
    if policy:
        cmd += ["--policy", policy]
    if naive:
        cmd += ["--naive"]
    if nofuse:
        cmd += ["--no-fuse"]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    p = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout,
                       env=env)
    if not os.path.exists(fname):
        return {"arch": arch, "shape": shape, "mesh": mesh, "tag": tag,
                "ok": False, "error": (p.stderr or "")[-1500:]}
    return json.load(open(fname))


def step_time(rec: dict) -> float:
    return rec["roofline"]["step_time_s"]


def tokens_per_step(rec: dict) -> float:
    kind, seq, batch = _shape_parts(rec["shape"])
    if kind in ("prefill", "train"):
        return seq * batch
    return batch                      # decode: one token per sequence


def _shape_parts(shape: str):
    from repro.configs import SHAPES
    if shape in SHAPES:
        s = SHAPES[shape]
        return s.kind, s.seq_len, s.global_batch
    kind, seq, batch = shape.split(":")
    return kind, int(seq), int(batch)


def throughput(rec: dict) -> float:
    """tokens/s (roofline-projected)."""
    return tokens_per_step(rec) / max(step_time(rec), 1e-12)


def write_csv(path: str, header: list, rows: list):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    print(f"  -> {path}")
