"""ViT single-chip lowering + roofline (Fig. 8 backend).

The encoder-only ViT runs one pass per classification (the paper's images/s
metric).  Lowered with jit on one device, parsed with the trip-count-aware
HLO analyzer, projected with the v5e roofline.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import ART


def vit_cell(name: str, *, batch: int = 8, tag: str = "s2_bf16",
             policy: str = "bf16", naive: bool = False,
             timeout: int = 900) -> dict:
    os.makedirs(ART, exist_ok=True)
    fname = os.path.join(ART, f"{name}__vit{batch}__{tag}.json")
    if os.path.exists(fname):
        return json.load(open(fname))
    prog = textwrap.dedent(f"""
        import os, json
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import sys
        sys.path.insert(0, {os.path.join(os.path.dirname(__file__), '..', 'src')!r})
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import PAPER_MODELS
        from repro.core.precision import get_policy
        from repro.models import vit
        from repro.sharding.plan import UNSHARDED
        from repro.analysis.hlo import parse_hlo
        from repro.analysis.roofline import roofline_from_summary

        cfg = PAPER_MODELS[{name!r}]
        policy = get_policy({policy!r})
        plan = dataclasses.replace(UNSHARDED, naive_attention={naive},
                                   gelu_impl="gelu_exact" if {naive}
                                   else "i_gelu")
        params = jax.eval_shape(
            lambda k: vit.init_vit(k, cfg, policy.param_dtype),
            jax.random.key(0))
        patches = jax.ShapeDtypeStruct(
            ({batch}, cfg.image_seq - 1, vit.PATCH_DIM), jnp.float32)

        def fwd(params, patches):
            return vit.forward_vit(params, patches, cfg=cfg, policy=policy,
                                   plan=plan)
        compiled = jax.jit(fwd).lower(params, patches).compile()
        dt = {{"float32": "f32", "bfloat16": "bf16",
              "float8_e4m3fn": "f8e4m3fn"}}[
                  np.dtype(policy.compute_dtype).name]
        s = parse_hlo(compiled.as_text(), default_dot_dtype=dt)
        r = roofline_from_summary(s)
        rec = dict(model={name!r}, tag={tag!r}, batch={batch},
                   bound=r.bound, step_time_s=r.step_time_s,
                   images_per_s={batch} / max(r.step_time_s, 1e-12),
                   roofline=r.as_dict())
        json.dump(rec, open({fname!r}, "w"), indent=1)
        print("ok")
    """)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=timeout, env=env)
    if not os.path.exists(fname):
        raise RuntimeError(f"vit cell failed: {p.stderr[-1500:]}")
    return json.load(open(fname))
