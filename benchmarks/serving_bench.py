"""Serving benchmark: a mixed-length request trace through InferenceEngine.

    PYTHONPATH=src python benchmarks/serving_bench.py [--arch phi4-mini-3.8b]

Unlike the dry-run roofline benchmarks (benchmarks/run.py), this measures
the *engine* end to end on this host: wall-clock NAR prompt-encoding tok/s,
AR decode tok/s, TTFT and decode-step p50/p95, and the paged-KV pool
telemetry (peak utilization, blocks-per-token, preemptions) over a
deterministic trace mixing prompt lengths, greedy and sampled requests.  A
warmup pass compiles every (length bucket, group size) first
(`engine.reset_stats()` then separates compile time from the measured run),
so the JSON tracks steady-state serving performance across PRs:
artifacts/bench/BENCH_serving.json.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serving import InferenceEngine, Request, SamplingParams

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def build_trace(cfg, *, requests: int, min_len: int, max_len: int,
                max_new: int, seed: int) -> list:
    """Deterministic mixed trace: lengths uniform in [min_len, max_len],
    odd uids sampled (temperature/top-k), even uids greedy."""
    rng = np.random.default_rng(seed)
    out = []
    for uid in range(requests):
        n = int(rng.integers(min_len, max_len + 1))
        out.append(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab, n, dtype=np.int32),
            max_new_tokens=max_new,
            sampling=SamplingParams(temperature=0.8, top_k=40, seed=uid)
            if uid % 2 else SamplingParams()))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--full", action="store_true",
                    help="full-size config (default: reduced)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--min-prompt-len", type=int, default=4)
    ap.add_argument("--max-prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV pool block size (tokens)")
    ap.add_argument("--kv-pool-blocks", type=int, default=0,
                    help="KV pool capacity in blocks (0 => engine default "
                         "of batch * ceil(max_seq / block_size))")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=os.path.join(ART, "BENCH_serving.json"))
    args = ap.parse_args(argv)
    if args.min_prompt_len > args.max_prompt_len:
        ap.error(f"--min-prompt-len {args.min_prompt_len} exceeds "
                 f"--max-prompt-len {args.max_prompt_len}")

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    params = lm.init_lm(jax.random.key(args.seed), cfg, jnp.bfloat16)
    engine = InferenceEngine(cfg, params, batch_size=args.batch,
                             max_seq=args.max_seq,
                             block_size=args.block_size,
                             kv_pool_blocks=args.kv_pool_blocks or None)

    trace_kw = dict(requests=args.requests, min_len=args.min_prompt_len,
                    max_len=args.max_prompt_len, max_new=args.max_new)

    # warmup: the same trace as the measured run, so every length bucket the
    # measurement hits is compiled before the clock starts
    for req in build_trace(cfg, seed=args.seed, **trace_kw):
        engine.submit(req)
    engine.run()
    warm_compiles = engine.stats().prefill_compiles
    engine.reset_stats()

    # measured run
    t0 = time.perf_counter()
    for req in build_trace(cfg, seed=args.seed, **trace_kw):
        engine.submit(req)
    done = engine.run()
    wall = time.perf_counter() - t0
    stats = engine.stats()

    record = {
        "bench": "serving",
        "arch": cfg.name,
        "host": "cpu-wallclock",
        "requests": args.requests,
        "batch": args.batch,
        "prompt_len_range": [args.min_prompt_len, args.max_prompt_len],
        "max_new": args.max_new,
        "wall_s": wall,
        "warmup_prefill_compiles": warm_compiles,
        **stats.to_dict(),
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"served {len(done)} requests in {wall:.2f}s")
    print(stats.summary())
    if stats.kv_pool_blocks:
        dense_positions = args.batch * args.max_seq
        print(f"  KV: {stats.peak_blocks_used * stats.kv_block_size} peak "
              f"pool positions vs {dense_positions} dense (B x max_seq), "
              f"{stats.blocks_per_token:.2f} block-positions/live-token, "
              f"decode step p50 {stats.decode_step_p50_ms:.2f}ms "
              f"p95 {stats.decode_step_p95_ms:.2f}ms")
    print(f"  -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
