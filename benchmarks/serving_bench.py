"""Serving benchmark: mixed-length request traces through InferenceEngine.

    PYTHONPATH=src python benchmarks/serving_bench.py [--arch phi4-mini-3.8b]

Unlike the dry-run roofline benchmarks (benchmarks/run.py), this measures
the *engine* end to end on this host: wall-clock NAR prompt-encoding tok/s,
AR decode tok/s, TTFT and decode-step p50/p95, and the paged-KV pool
telemetry (peak utilization, blocks-per-token, preemptions) over a
deterministic trace mixing prompt lengths, greedy and sampled requests.  A
warmup pass compiles every (length bucket, group size) first
(`engine.reset_stats()` then separates compile time from the measured run),
so the JSON tracks steady-state serving performance across PRs:
artifacts/bench/BENCH_serving.json.

Three scheduler/runner-split scenarios ride along in `record["scenarios"]`:

  mixed            encode + generate traffic through one engine — the
                   per-task-class throughput split (paper's encoder and
                   decoder topologies sharing the serving stack)
  chunked_prefill  a long prompt admitted while short requests decode,
                   FCFS vs ChunkedPrefillPolicy: decode-stall p95 (the gap
                   running AR slots sit idle behind the admission) must be
                   strictly lower chunked
  spec_decode      the same trace with speculative decoding on (self-draft
                   by default — greedy acceptance is then exact, isolating
                   the amortization win from draft quality): the target's
                   AR throughput (tokens committed per second of target
                   decode time) must be >= the non-spec baseline with a
                   positive acceptance rate, or the bench exits nonzero
                   (the CI gate for the subsystem)
  tree_spec        a k x branch-count sweep of token-tree speculation on a
                   rejection-heavy draft (the auto draft's params blended
                   toward a decorrelated init by --tree-alpha, so chain
                   acceptance sits mid-range instead of the self-draft
                   100%): every cell must stay token-identical to
                   non-speculative decode, and at equal k the widest tree
                   must STRICTLY raise accepted tokens per target step
                   over single-branch — with at least one sibling-branch
                   acceptance — or the bench exits nonzero (the CI gate
                   for the token-tree subsystem)
  shared_prefix    N requests sharing a long system prompt, prefix cache
                   off (cold) vs on (warm, measured after a populating
                   pass): warm must prefill strictly fewer prompt tokens
                   AND land a strictly lower TTFT p95 than cold, with
                   token-identical outputs, or the bench exits nonzero
                   (the CI gate for the prefix-cache subsystem)
  goodput          the SAME over-capacity open-loop Poisson trace
                   (serving/loadgen.py) through FCFS vs DeadlinePolicy,
                   both on the overlapped host loop, scored in goodput —
                   requests/sec meeting their TTFT SLO.  Capacity and the
                   TTFT budget are calibrated on this host first; the
                   deadline policy must strictly beat FCFS goodput at the
                   calibrated over-capacity rate, or the bench exits
                   nonzero (the CI gate for the goodput subsystem).  A
                   third, TRACED deadline run then re-plays the same load
                   with serving/trace.py attached: it must emit a
                   schema-clean non-empty Chrome trace (--trace-out, the
                   CI artifact) and land within 5% of the untraced
                   goodput, or the bench exits nonzero (the overhead gate
                   for the observability subsystem).  Per-phase MFU/MBU
                   attribution rides along in the artifact: the base
                   record's `phase_util` (from EngineStats.to_dict) and
                   the traced run's `traced.phase_util`.  Pass
                   --trace-dir to also capture per-scenario traces.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serving import (ArrivalSpec, ChunkedPrefillPolicy, DeadlinePolicy,
                           EncodeTask, FCFSPolicy, InferenceEngine, LoadSpec,
                           PromptSpec, Request, SamplingParams, SLOSpec,
                           SpecConfig, Tracer, make_policy, make_trace,
                           percentiles, replay, spec_support_reason,
                           validate_chrome_trace)

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def build_trace(cfg, *, requests: int, min_len: int, max_len: int,
                max_new: int, seed: int) -> list:
    """Deterministic mixed trace: lengths uniform in [min_len, max_len],
    odd uids sampled (temperature/top-k), even uids greedy."""
    rng = np.random.default_rng(seed)
    out = []
    for uid in range(requests):
        n = int(rng.integers(min_len, max_len + 1))
        out.append(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab, n, dtype=np.int32),
            max_new_tokens=max_new,
            sampling=SamplingParams(temperature=0.8, top_k=40, seed=uid)
            if uid % 2 else SamplingParams()))
    return out


def _mk_engine(cfg, params, args, scheduler=None, tracer=None):
    return InferenceEngine(cfg, params, batch_size=args.batch,
                           max_seq=args.max_seq,
                           block_size=args.block_size,
                           kv_pool_blocks=args.kv_pool_blocks or None,
                           scheduler=scheduler,
                           weight_dtype=args.weight_dtype,
                           kv_dtype=args.kv_dtype,
                           tracer=tracer)


def _scenario_tracer(args):
    """Per-scenario Tracer when --trace-dir is set, else None (the no-op
    fast path — scenario engines then carry zero tracing branches)."""
    return Tracer(capacity=args.trace_buffer) if args.trace_dir else None


def _write_trace(tracer, args, name: str) -> None:
    if tracer is None:
        return
    os.makedirs(args.trace_dir, exist_ok=True)
    path = os.path.join(args.trace_dir, f"TRACE_{name}.json")
    n = tracer.write(path)
    print(f"  trace[{name}]: {n} events -> {path}")


def mixed_workload(cfg, params, args, tracer=None) -> dict:
    """Encode + generate through one engine: half the trace becomes
    EncodeTasks.  Reports the per-task-class split."""
    def submit_all(engine):
        rng = np.random.default_rng(args.seed + 1)
        for uid in range(args.requests):
            n = int(rng.integers(args.min_prompt_len,
                                 args.max_prompt_len + 1))
            prompt = rng.integers(0, cfg.vocab, n, dtype=np.int32)
            if uid % 2:
                engine.submit(EncodeTask(uid=uid, prompt=prompt))
            else:
                engine.submit(Request(uid=uid, prompt=prompt,
                                      max_new_tokens=args.max_new))

    engine = _mk_engine(cfg, params, args, tracer=tracer)
    submit_all(engine)                            # warmup: compile buckets
    engine.run()
    engine.reset_stats()
    if tracer:
        tracer.clear()
    t0 = time.perf_counter()
    submit_all(engine)
    done = engine.run()
    wall = time.perf_counter() - t0
    st = engine.stats()
    return {
        "requests": len(done),
        "wall_s": wall,
        "encode_completed": st.encode_completed,
        "encode_tok_s": st.encode_tok_s,
        "encode_latency_p95_ms": st.encode_latency_p95_ms,
        "nar_tok_s": st.nar_tok_s,
        "ar_tok_s": st.ar_tok_s,
        "queue_wait_p50_ms": st.queue_wait_p50_ms,
        "queue_wait_p95_ms": st.queue_wait_p95_ms,
    }


def long_admission(cfg, params, args, scheduler, tracer=None) -> dict:
    """Long prompts arrive one at a time while a long-running request
    decodes: each admission's prefill work lands between that request's AR
    steps, and decode-stall p95 captures how long it sat idle behind it
    (whole-prompt prefill = one long stall per admission; chunked = many
    bounded ones).

    The scenario pins its own geometry rather than inheriting --batch /
    --max-seq, because the comparison is only meaningful in the regime
    chunked prefill exists for:

      batch_size=2     one stall victim + one admission slot, so each long
                       prefills ALONE (with more free slots, same-bucket
                       longs admit as one amortized group prefill and the
                       whole-prompt stall shrinks below the per-call
                       overhead chunking pays)
      max_seq>=256     a whole-prompt prefill call must cost well above
                       one chunk call; on this host a warm prefill is
                       ~2.5ms + ~0.03ms/token vs ~2.5ms per chunk call, so
                       the long prompt needs hundreds of tokens for the
                       stall gap to clear the dispatch-overhead noise
                       floor"""
    seq = max(args.max_seq, 256)
    long_len = (3 * seq) // 4
    long_len = min(long_len, seq - 2 * args.max_new - 2)

    n_long = 4
    n_slots = 2

    def run_once(engine):
        rng = np.random.default_rng(args.seed + 2)
        # slot 0: decodes for the whole scenario (the stall victim);
        # slot 1: max_new=1, freeing right after prefill so the long
        # prompts admit (serially) while slot 0 still decodes
        for uid in range(n_slots):
            n = int(rng.integers(args.min_prompt_len,
                                 args.max_prompt_len + 1))
            engine.submit(Request(
                uid=uid, prompt=rng.integers(0, cfg.vocab, n,
                                             dtype=np.int32),
                max_new_tokens=4 * args.max_new if uid == 0 else 1))
        steps = 0
        while engine.has_work():
            engine.step()
            steps += 1
            if steps == 2:
                # a stream of long admissions: each lands between slot 0's
                # AR steps (several stalls, so the p95 sees them; a single
                # admission would hide in the tail)
                for j in range(n_long):
                    engine.submit(Request(
                        uid=990 + j,
                        prompt=rng.integers(0, cfg.vocab, long_len,
                                            dtype=np.int32),
                        max_new_tokens=2))

    engine = InferenceEngine(cfg, params, batch_size=n_slots, max_seq=seq,
                             block_size=args.block_size,
                             scheduler=scheduler, tracer=tracer)
    run_once(engine)                              # warmup: compile
    engine.reset_stats()
    if tracer:
        tracer.clear()
    run_once(engine)
    st = engine.stats()
    return {
        "long_prompt_len": long_len,
        "decode_stall_p50_ms": st.decode_stall_p50_ms,
        "decode_stall_p95_ms": st.decode_stall_p95_ms,
        "prefill_chunks": st.prefill_chunks,
        "ttft_p95_ms": st.ttft_p95_ms,
        "ar_tok_s": st.ar_tok_s,
    }


def spec_workload(cfg, params, args, baseline_ar_tok_s: float,
                  tracer=None) -> dict:
    """The base trace with speculative decoding on.  AR tok/s here is
    tokens committed per second of TARGET decode (verify) time — the
    quantity speculation amortizes the per-step weight read over; the
    draft's overhead is reported separately (draft_time_ms percentiles,
    spec_draft_time_s), mirroring EngineStats' split."""
    reason = spec_support_reason(cfg)
    if reason is not None:
        return {"supported": False, "reason": reason}
    spec = SpecConfig(draft=args.spec_draft, k=args.spec_k)
    engine = InferenceEngine(cfg, params, batch_size=args.batch,
                             max_seq=args.max_seq,
                             block_size=args.block_size,
                             kv_pool_blocks=args.kv_pool_blocks or None,
                             spec=spec, tracer=tracer)
    trace_kw = dict(requests=args.requests, min_len=args.min_prompt_len,
                    max_len=args.max_prompt_len, max_new=args.max_new)
    for req in build_trace(cfg, seed=args.seed, **trace_kw):
        engine.submit(req)                        # warmup: compile
    engine.run()
    engine.reset_stats()
    if tracer:
        tracer.clear()
    t0 = time.perf_counter()
    for req in build_trace(cfg, seed=args.seed, **trace_kw):
        engine.submit(req)
    done = engine.run()
    wall = time.perf_counter() - t0
    st = engine.stats()
    # ar_tok_s (the gated metric) counts target decode time only — the
    # weight-read amortization speculation exists for; ar_tok_s_incl_draft
    # folds the propose phase back in so a draft whose overhead eats the
    # win is visible in the artifact even though the gate tracks the
    # target-step number
    decode_incl = st.ar_time_s + st.spec_draft_time_s
    return {
        "supported": True,
        "draft": engine.runner.draft_cfg.name,
        "k": args.spec_k,
        "requests": len(done),
        "wall_s": wall,
        "ar_tok_s": st.ar_tok_s,
        "ar_tok_s_incl_draft": (st.ar_tokens / decode_incl
                                if decode_incl else 0.0),
        "baseline_ar_tok_s": baseline_ar_tok_s,
        "ar_speedup": (st.ar_tok_s / baseline_ar_tok_s
                       if baseline_ar_tok_s else 0.0),
        "spec_rounds": st.spec_rounds,
        "spec_acceptance_rate": st.spec_acceptance_rate,
        "spec_tokens_per_step": st.spec_tokens_per_step,
        "spec_draft_time_s": st.spec_draft_time_s,
        "draft_time_ms_p50": st.draft_time_ms_p50,
        "draft_time_ms_p95": st.draft_time_ms_p95,
    }


def _rejection_heavy_draft(cfg, args, alpha: float):
    """A draft with tunable MID-RANGE acceptance, no training required.

    Seeded init gives bimodal drafts: the target's seed reproduces its
    own embedding / unembedding / leading layers (a truncated-target
    draft — near-100% acceptance on reduced configs, where the 2-layer
    "auto" draft IS the reduced target), while any other seed is fully
    decorrelated (~0%, and its top-k sets carry no signal, so trees
    can't show their win either).  Interpolating the two parameter trees
    by `alpha` yields a draft whose distribution is a noisy copy of the
    target's — top-1 is wrong often enough to reject, but the top-b set
    still contains the target's choice — exactly the regime real
    trained drafts occupy and the one the k x branches sweep gates on."""
    from repro.configs import make_draft
    dcfg = make_draft(cfg)
    p0 = lm.init_lm(jax.random.key(args.seed), dcfg, jnp.float32)
    p1 = lm.init_lm(jax.random.key(args.seed + 1234), dcfg, jnp.float32)
    mixed = jax.tree.map(lambda a, b: (1 - alpha) * a + alpha * b, p0, p1)
    return dcfg, jax.tree.map(lambda x: x.astype(jnp.bfloat16), mixed)


def tree_spec_workload(cfg, params, args) -> dict:
    """k x branch-count acceptance sweep on a rejection-heavy draft.

    Every cell runs the base trace through a spec engine sharing ONE
    interpolated draft (alpha = --tree-alpha) and records acceptance
    telemetry plus token identity against the non-speculative outputs.
    The gate (check_tree_spec): all cells token-identical, and at every
    k the widest tree strictly raises accepted tokens per slot-round
    over the single-branch chain — the claim the tentpole makes, on a
    draft that actually rejects (the self-draft smoke can't distinguish
    tree from chain: at 100% acceptance the chain already saturates)."""
    reason = spec_support_reason(cfg)
    if reason is not None:
        return {"supported": False, "reason": reason}
    dcfg, draft_params = _rejection_heavy_draft(cfg, args, args.tree_alpha)
    trace_kw = dict(requests=args.requests, min_len=args.min_prompt_len,
                    max_len=args.max_prompt_len, max_new=args.max_new)
    # the identity reference runs on the same default-precision engine
    # the sweep cells use (the base trace engine may be int8)
    ref = InferenceEngine(cfg, params, batch_size=args.batch,
                          max_seq=args.max_seq, block_size=args.block_size,
                          kv_pool_blocks=args.kv_pool_blocks or None)
    for req in build_trace(cfg, seed=args.seed, **trace_kw):
        ref.submit(req)
    baseline_outputs = {t.uid: list(t.output) for t in ref.run()}
    ks = sorted({2, args.spec_k})
    bs = sorted({1, 2, max(2, args.tree_branches)})
    cells = []
    for k in ks:
        for b in bs:
            spec = SpecConfig(draft="auto", k=k, branches=b)
            engine = InferenceEngine(cfg, params, batch_size=args.batch,
                                     max_seq=args.max_seq,
                                     block_size=args.block_size,
                                     kv_pool_blocks=args.kv_pool_blocks
                                     or None,
                                     spec=spec, draft_params=draft_params)
            for req in build_trace(cfg, seed=args.seed, **trace_kw):
                engine.submit(req)
            done = engine.run()
            st = engine.stats()
            outputs = {t.uid: list(t.output) for t in done}
            cells.append({
                "k": k,
                "branches": b,
                "tokens_match": outputs == baseline_outputs,
                "spec_acceptance_rate": st.spec_acceptance_rate,
                "accepted_per_round": (st.spec_accepted_tokens
                                       / st.spec_slot_steps
                                       if st.spec_slot_steps else 0.0),
                "spec_tokens_per_step": st.spec_tokens_per_step,
                "spec_tree_nodes": st.spec_tree_nodes,
                "spec_branch_hits": st.spec_branch_hits,
                "spec_branch_utilization": st.spec_branch_utilization,
                "spec_path_depth_p50": st.spec_path_depth_p50,
                "spec_path_depth_p95": st.spec_path_depth_p95,
            })
    return {"supported": True, "draft": dcfg.name,
            "alpha": args.tree_alpha, "ks": ks, "branch_counts": bs,
            "cells": cells}


def check_tree_spec(rec: dict) -> list:
    """The token-tree acceptance gate: losslessness at every cell, and
    at equal k the widest tree must STRICTLY out-accept the chain."""
    if not rec.get("supported"):
        return []
    problems = []
    by = {(c["k"], c["branches"]): c for c in rec["cells"]}
    for c in rec["cells"]:
        if not c["tokens_match"]:
            problems.append(
                f"k={c['k']} b={c['branches']}: committed outputs diverged "
                f"from non-speculative decode — tree verify is not lossless")
    b_max = max(rec["branch_counts"])
    for k in rec["ks"]:
        chain, tree = by[(k, 1)], by[(k, b_max)]
        if not tree["accepted_per_round"] > chain["accepted_per_round"]:
            problems.append(
                f"k={k}: tree (b={b_max}) accepted/round "
                f"{tree['accepted_per_round']:.3f} does not strictly beat "
                f"single-branch {chain['accepted_per_round']:.3f} on the "
                f"rejection-heavy draft (alpha={rec['alpha']})")
        if tree["spec_branch_hits"] <= 0:
            problems.append(
                f"k={k}: the b={b_max} tree never accepted through a "
                f"sibling branch — the tree is decorative at this alpha")
    return problems


def shared_prefix_workload(cfg, params, args, tracer=None) -> dict:
    """N requests share a long system prompt (each with a short unique
    tail): prefix cache off (cold) vs on (warm).  The warm engine runs two
    populating passes first — pass 1 fills the radix index (and picks up
    in-batch sharing), pass 2 hits it end to end so every warm suffix
    bucket is compiled — then `reset_stats()` and a measured third pass,
    mirroring the cold engine's warmup/measure split.  Request uids differ
    across passes but sampling seeds are keyed by trace position, so the
    measured passes must be token-identical cold vs warm.

    The scenario pins its own geometry rather than inheriting --batch /
    --kv-pool-blocks:

      batch=2            admissions interleave, so pass 1 already exercises
                         in-batch sharing (request i hits blocks request
                         i-1 indexed at prefill landing)
      max_seq>=128       the shared prefix (3/4 of max_seq) must dwarf the
                         unique tails for the TTFT gap to clear the
                         per-call dispatch-overhead noise floor
      pool = 2x default  retired blocks stay indexed only while the pool
                         has room; a pool sized for live slots alone would
                         evict the prefix between passes and the gate
                         would measure reclaim, not reuse"""
    seq = max(args.max_seq, 128)
    n_req, batch = 6, 2
    blocks = 2 * batch * (-(-seq // args.block_size))
    prefix_len = min((3 * seq) // 4, seq - args.max_new - 12)

    rng = np.random.default_rng(args.seed + 3)
    prefix = rng.integers(0, cfg.vocab, prefix_len, dtype=np.int32)
    tails = [rng.integers(0, cfg.vocab, int(rng.integers(3, 9)),
                          dtype=np.int32) for _ in range(n_req)]

    def run_pass(engine, uid0):
        for i in range(n_req):
            engine.submit(Request(
                uid=uid0 + i,
                prompt=np.concatenate([prefix, tails[i]]),
                max_new_tokens=args.max_new,
                sampling=SamplingParams(temperature=0.8, top_k=40, seed=i)
                if i % 2 else SamplingParams()))
        t0 = time.perf_counter()
        done = engine.run()
        wall = time.perf_counter() - t0
        return {r.uid - uid0: list(r.output) for r in done}, wall

    def mk(prefix_cache):
        # only the warm engine traces: its spans carry the warm_hit /
        # cow_copy instants the observability layer exists to surface
        return InferenceEngine(
            cfg, params, batch_size=batch, max_seq=seq,
            block_size=args.block_size, kv_pool_blocks=blocks,
            scheduler=make_policy("fcfs", cache_aware=prefix_cache),
            prefix_cache=prefix_cache,
            tracer=tracer if prefix_cache else None)

    cold = mk(False)
    run_pass(cold, 0)                             # warmup: compile buckets
    cold.reset_stats()
    cold_out, cold_wall = run_pass(cold, 100)
    cst = cold.stats()

    warm = mk(True)
    if warm.prefix_cache is None:
        return {"supported": False,
                "reason": warm.runner.prefix_cache_reason}
    run_pass(warm, 200)                           # populate the index
    run_pass(warm, 300)                           # compile warm buckets
    warm.reset_stats()
    if tracer:
        tracer.clear()
    warm_out, warm_wall = run_pass(warm, 400)
    wst = warm.stats()

    return {
        "supported": True,
        "requests": n_req,
        "shared_prefix_len": prefix_len,
        "tokens_match": warm_out == cold_out,
        "cold": {
            "wall_s": cold_wall,
            "prefill_tokens": cst.nar_tokens,
            "ttft_p50_ms": cst.ttft_p50_ms,
            "ttft_p95_ms": cst.ttft_p95_ms,
        },
        "warm": {
            "wall_s": warm_wall,
            "prefill_tokens": wst.nar_tokens,
            "ttft_p50_ms": wst.ttft_p50_ms,
            "ttft_p95_ms": wst.ttft_p95_ms,
            "prefix_cache_hit_rate": wst.prefix_cache_hit_rate,
            "cached_prefix_tokens": wst.cached_prefix_tokens,
            "cached_blocks": wst.cached_blocks,
            "cow_copies": wst.cow_copies,
            "evicted_blocks": wst.evicted_blocks,
        },
    }


def check_shared_prefix(rec: dict) -> list:
    """The prefix-cache acceptance gate: the warm pass must show the cache
    actually skipping prefill work — correctly — not just running."""
    if not rec.get("supported"):
        return []
    problems = []
    if not rec["tokens_match"]:
        problems.append("warm outputs diverged from cold — cached-prefix "
                        "reuse changed the sampled tokens")
    if not rec["warm"]["prefix_cache_hit_rate"] > 0:
        problems.append("prefix_cache_hit_rate is 0 — no admission ever "
                        "reused a cached prefix")
    if not rec["warm"]["prefill_tokens"] < rec["cold"]["prefill_tokens"]:
        problems.append(
            f"warm prefilled {rec['warm']['prefill_tokens']} prompt tokens, "
            f"not strictly fewer than cold's {rec['cold']['prefill_tokens']}")
    if not rec["warm"]["ttft_p95_ms"] < rec["cold"]["ttft_p95_ms"]:
        problems.append(
            f"warm TTFT p95 {rec['warm']['ttft_p95_ms']:.1f}ms is not "
            f"strictly below cold's {rec['cold']['ttft_p95_ms']:.1f}ms")
    return problems


def check_spec(spec_rec: dict) -> list:
    """The spec-decode acceptance gate: recorded numbers must show the
    subsystem actually amortizing target steps, not just running."""
    if not spec_rec.get("supported"):
        return []
    problems = []
    if not spec_rec["spec_acceptance_rate"] > 0:
        problems.append("spec_acceptance_rate is 0 — no draft token was "
                        "ever accepted")
    if spec_rec["ar_tok_s"] < spec_rec["baseline_ar_tok_s"]:
        problems.append(
            f"spec AR tok/s {spec_rec['ar_tok_s']:.1f} fell below the "
            f"non-spec baseline {spec_rec['baseline_ar_tok_s']:.1f}")
    # end-to-end guard: target-only ar_tok_s cannot see a propose phase
    # gone pathological (draft time is tracked separately), so the
    # draft-inclusive decode throughput must clear the baseline too
    if spec_rec["ar_tok_s_incl_draft"] < spec_rec["baseline_ar_tok_s"]:
        problems.append(
            f"spec AR tok/s incl. draft "
            f"{spec_rec['ar_tok_s_incl_draft']:.1f} fell below the "
            f"non-spec baseline {spec_rec['baseline_ar_tok_s']:.1f} — "
            f"the propose phase is eating the amortization win")
    return problems


def goodput_workload(cfg, params, args) -> dict:
    """Open-loop goodput comparison: the same over-capacity Poisson trace
    through FCFS vs DeadlinePolicy, both on the overlapped host loop,
    scored in goodput (requests/sec meeting their TTFT SLO).

    The regime is calibrated on this host rather than hardcoded, because
    the gate only discriminates in the middle: a too-loose TTFT budget
    lets every request win under both policies (goodput ties on wall
    noise) and a too-tight one lets none win under either.  A closed-loop
    drain (arrival clock collapsed, after a compile pass) measures
    `capacity_rps`; the Poisson rate is `--goodput-overload` times it, and
    the TTFT budget defaults to 3x the calibrated per-request service
    time.  At that operating point FCFS burns full prefill + decode on
    requests that already expired in queue, while the deadline policy
    sheds them at admission and spends the capacity on requests that can
    still meet their deadline — a structural win, not a tuning artifact.

    Both engines replay traces built from the same seeds (fresh task
    objects per engine — tasks are mutable), so arrivals, prompts, and
    per-uid sampling seeds are identical across policies."""
    n = args.goodput_requests
    prompts = PromptSpec(min_len=args.min_prompt_len,
                         max_len=args.max_prompt_len, sampled_frac=0.5)

    def mk(policy, tracer=None):
        return InferenceEngine(cfg, params, batch_size=args.batch,
                               max_seq=args.max_seq,
                               block_size=args.block_size,
                               kv_pool_blocks=args.kv_pool_blocks or None,
                               scheduler=policy, overlap=True,
                               weight_dtype=args.weight_dtype,
                               kv_dtype=args.kv_dtype, tracer=tracer)

    def trace(slo, uid0, rps):
        spec = LoadSpec(requests=n, vocab=cfg.vocab,
                        arrival=ArrivalSpec(rate_rps=rps),
                        prompts=prompts, slo=slo, max_new=args.max_new)
        return make_trace(spec, arrival_seed=args.seed,
                          prompt_seed=args.seed, uid0=uid0)

    # calibrate: closed-loop drain (time_scale=0 collapses the arrival
    # clock) after an identical compile pass = max sustainable throughput
    cal = mk(FCFSPolicy())
    replay(cal, trace(SLOSpec(), 10_000, 1.0), time_scale=0)
    done, wall = replay(cal, trace(SLOSpec(), 20_000, 1.0), time_scale=0)
    capacity_rps = len(done) / wall
    service_ms = 1e3 * wall / len(done)
    rate = args.goodput_overload * capacity_rps
    ttft_slo = args.goodput_ttft_slo_ms or 3.0 * service_ms
    slo = SLOSpec(ttft_ms=ttft_slo)

    out = {"requests": n, "capacity_rps": capacity_rps,
           "service_ms": service_ms, "overload": args.goodput_overload,
           "rate_rps": rate, "ttft_slo_ms": ttft_slo, "policies": {}}
    for policy in (FCFSPolicy(), DeadlinePolicy()):
        engine = mk(policy)
        # warmup without SLOs, closed-loop: nothing sheds, so every
        # (bucket, group size) the measured run can hit gets compiled
        replay(engine, trace(SLOSpec(), 30_000, 1.0), time_scale=0)
        engine.reset_stats()
        done, wall = replay(engine, trace(slo, 0, rate))
        st = engine.stats()
        att = percentiles(st.ttft_slo_ratio)
        out["policies"][policy.name] = {
            "completed": len(done),
            "wall_s": wall,
            "slo_met": st.slo_met,
            "slo_attainment": st.slo_attainment,
            "goodput_rps": st.slo_met / wall if wall else 0.0,
            "requests_shed": st.requests_shed,
            "requests_degraded": st.requests_degraded,
            "ttft_slo_ratio_p50": att["p50"],
            "ttft_slo_ratio_p95": att["p95"],
            "ttft_slo_ratio_p99": att["p99"],
            "host_overlap_ratio": st.host_overlap_ratio,
            "overlapped_steps": st.overlapped_steps,
        }

    # traced re-run of the winning policy: the CI trace artifact, plus the
    # overhead gate's evidence that tracing rides along for free.  Same
    # arrival/prompt seeds as the measured deadline run (uid offsets only
    # re-key per-uid sampling seeds — shapes and arrivals are identical).
    tracer = Tracer(capacity=args.trace_buffer)
    engine = mk(DeadlinePolicy(), tracer=tracer)
    replay(engine, trace(SLOSpec(), 40_000, 1.0), time_scale=0)   # warmup
    engine.reset_stats()
    tracer.clear()
    done, wall = replay(engine, trace(slo, 50_000, rate))
    st = engine.stats()
    if args.trace_out:
        os.makedirs(os.path.dirname(args.trace_out) or ".", exist_ok=True)
        tracer.write(args.trace_out)
    out["traced"] = {
        "policy": "deadline",
        "trace_out": args.trace_out,
        "trace_events": len(tracer.events),
        "trace_dropped": tracer.dropped,
        "trace_problems": validate_chrome_trace(tracer.chrome_trace()),
        "completed": len(done),
        "slo_met": st.slo_met,
        "wall_s": wall,
        "goodput_rps": st.slo_met / wall if wall else 0.0,
        "phase_util": st.phase_util(),
    }
    return out


def check_goodput(rec: dict) -> list:
    """The goodput acceptance gate: at the calibrated over-capacity rate
    the deadline policy must strictly out-goodput FCFS, and must actually
    be serving (not shedding its way to an empty win)."""
    f, d = rec["policies"]["fcfs"], rec["policies"]["deadline"]
    problems = []
    if not d["goodput_rps"] > f["goodput_rps"]:
        problems.append(
            f"deadline goodput {d['goodput_rps']:.2f} req/s does not "
            f"strictly beat FCFS {f['goodput_rps']:.2f} req/s at "
            f"{rec['overload']:.1f}x capacity "
            f"(TTFT SLO {rec['ttft_slo_ms']:.0f}ms)")
    if not d["slo_met"] > 0:
        problems.append(
            f"deadline policy met 0 of {rec['requests']} SLOs — the TTFT "
            f"budget {rec['ttft_slo_ms']:.0f}ms is unattainable on this "
            f"host (calibration broke) or shedding ate the whole trace")
    tr = rec.get("traced")
    if tr:
        if tr["trace_problems"]:
            problems.append(
                f"trace artifact failed schema validation: "
                f"{tr['trace_problems'][:3]}")
        if not tr["trace_events"] > 0:
            problems.append("traced goodput run emitted an empty trace")
        # the overhead gate: tracing must cost < 5% goodput.  slo_met is
        # integer-valued, so on short smoke traces one request stepping
        # over its deadline can alone exceed 5% — forgive the gap only
        # when a single-request discretization step fully explains it.
        if (tr["goodput_rps"] < 0.95 * d["goodput_rps"]
                and tr["slo_met"] < d["slo_met"] - 1):
            problems.append(
                f"traced goodput {tr['goodput_rps']:.2f} req/s fell more "
                f"than 5% below untraced {d['goodput_rps']:.2f} req/s "
                f"({tr['slo_met']} vs {d['slo_met']} SLOs met) — tracing "
                f"is not riding along for free")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--full", action="store_true",
                    help="full-size config (default: reduced)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--min-prompt-len", type=int, default=4)
    ap.add_argument("--max-prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="chunk budget for the chunked_prefill scenario")
    ap.add_argument("--spec-draft", default="self",
                    help="spec_decode scenario draft: 'self' (default — "
                         "exact greedy acceptance isolates the "
                         "amortization win), 'auto', or a config name")
    ap.add_argument("--spec-k", type=int, default=3,
                    help="spec_decode scenario speculation length")
    ap.add_argument("--tree-branches", type=int, default=3,
                    help="tree_spec scenario: widest tree in the "
                         "k x branches sweep")
    ap.add_argument("--tree-alpha", type=float, default=0.15,
                    help="tree_spec scenario: draft decorrelation — 0 is "
                         "the truncated-target draft (near-100%% accept), "
                         "1 a random draft (~0%%); mid values make the "
                         "rejection-heavy draft the tree gate needs")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV pool block size (tokens)")
    ap.add_argument("--kv-pool-blocks", type=int, default=0,
                    help="KV pool capacity in blocks (0 => engine default "
                         "of batch * ceil(max_seq / block_size))")
    ap.add_argument("--weight-dtype", choices=("bfloat16", "int8"),
                    default="bfloat16",
                    help="base trace + mixed scenario weight storage "
                         "(scenario engines gating other subsystems stay "
                         "bf16); int8 quantizes per output channel")
    ap.add_argument("--kv-dtype", choices=("bfloat16", "int8"),
                    default="bfloat16",
                    help="base trace + mixed scenario paged-KV pool "
                         "storage; quant-specific gates live in "
                         "benchmarks/quant_bench.py")
    ap.add_argument("--goodput-requests", type=int, default=28,
                    help="goodput scenario trace length (open-loop "
                         "arrivals; smaller in CI smoke)")
    ap.add_argument("--goodput-overload", type=float, default=3.0,
                    help="goodput scenario Poisson rate as a multiple of "
                         "the calibrated closed-loop capacity")
    ap.add_argument("--goodput-ttft-slo-ms", type=float, default=0.0,
                    help="goodput scenario per-request TTFT budget (0 => "
                         "auto: 3x the calibrated service time)")
    ap.add_argument("--trace-out",
                    default=os.path.join(ART, "TRACE_goodput.json"),
                    help="Chrome trace artifact from the traced goodput "
                         "run (Perfetto-viewable; '' disables the write "
                         "but the traced run and overhead gate still "
                         "execute)")
    ap.add_argument("--trace-buffer", type=int, default=65536,
                    help="tracer ring capacity (events); the oldest are "
                         "evicted beyond it")
    ap.add_argument("--trace-dir", default="",
                    help="also trace the base run and each scenario, "
                         "writing TRACE_<name>.json per scenario here "
                         "(default: off — scenarios run untraced)")
    ap.add_argument("--skip-scenarios", action="store_true",
                    help="base trace only (no mixed / chunked scenarios)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=os.path.join(ART, "BENCH_serving.json"))
    args = ap.parse_args(argv)
    if args.min_prompt_len > args.max_prompt_len:
        ap.error(f"--min-prompt-len {args.min_prompt_len} exceeds "
                 f"--max-prompt-len {args.max_prompt_len}")

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    params = lm.init_lm(jax.random.key(args.seed), cfg, jnp.bfloat16)
    base_tracer = _scenario_tracer(args)
    engine = _mk_engine(cfg, params, args, tracer=base_tracer)

    trace_kw = dict(requests=args.requests, min_len=args.min_prompt_len,
                    max_len=args.max_prompt_len, max_new=args.max_new)

    # warmup: the same trace as the measured run, so every length bucket the
    # measurement hits is compiled before the clock starts
    for req in build_trace(cfg, seed=args.seed, **trace_kw):
        engine.submit(req)
    engine.run()
    warm_compiles = engine.stats().prefill_compiles
    engine.reset_stats()
    if base_tracer:
        base_tracer.clear()

    # measured run
    t0 = time.perf_counter()
    for req in build_trace(cfg, seed=args.seed, **trace_kw):
        engine.submit(req)
    done = engine.run()
    wall = time.perf_counter() - t0
    stats = engine.stats()

    record = {
        "bench": "serving",
        "arch": cfg.name,
        "host": "cpu-wallclock",
        "requests": args.requests,
        "batch": args.batch,
        "prompt_len_range": [args.min_prompt_len, args.max_prompt_len],
        "max_new": args.max_new,
        "wall_s": wall,
        "warmup_prefill_compiles": warm_compiles,
        **stats.to_dict(),
    }

    _write_trace(base_tracer, args, "base")

    if not args.skip_scenarios:
        tr_mixed = _scenario_tracer(args)
        mixed = mixed_workload(cfg, params, args, tracer=tr_mixed)
        _write_trace(tr_mixed, args, "mixed")
        unchunked = long_admission(cfg, params, args, FCFSPolicy())
        tr_chunk = _scenario_tracer(args)
        chunked = long_admission(cfg, params, args,
                                 ChunkedPrefillPolicy(args.prefill_chunk),
                                 tracer=tr_chunk)
        _write_trace(tr_chunk, args, "chunked_prefill")
        tr_spec = _scenario_tracer(args)
        spec_rec = spec_workload(cfg, params, args, stats.ar_tok_s,
                                 tracer=tr_spec)
        _write_trace(tr_spec, args, "spec_decode")
        tree_rec = tree_spec_workload(cfg, params, args)
        tr_warm = _scenario_tracer(args)
        prefix_rec = shared_prefix_workload(cfg, params, args,
                                            tracer=tr_warm)
        _write_trace(tr_warm, args, "shared_prefix")
        goodput_rec = goodput_workload(cfg, params, args)
        record["scenarios"] = {
            "mixed": mixed,
            "chunked_prefill": {
                "chunk_tokens": args.prefill_chunk,
                "unchunked": unchunked,
                "chunked": chunked,
                "stall_p95_ratio": (
                    chunked["decode_stall_p95_ms"]
                    / unchunked["decode_stall_p95_ms"]
                    if unchunked["decode_stall_p95_ms"] else 0.0),
            },
            "spec_decode": spec_rec,
            "tree_spec": tree_rec,
            "shared_prefix": prefix_rec,
            "goodput": goodput_rec,
        }

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"served {len(done)} requests in {wall:.2f}s")
    print(stats.summary())
    if stats.kv_pool_blocks:
        dense_positions = args.batch * args.max_seq
        print(f"  KV: {stats.peak_blocks_used * stats.kv_block_size} peak "
              f"pool positions vs {dense_positions} dense (B x max_seq), "
              f"{stats.blocks_per_token:.2f} block-positions/live-token, "
              f"decode step p50 {stats.decode_step_p50_ms:.2f}ms "
              f"p95 {stats.decode_step_p95_ms:.2f}ms")
    print(f"  bytes: weights {stats.weight_bytes_per_device / 2**20:.1f}MiB"
          f"/device ({stats.weight_dtype}), KV pool "
          f"{stats.kv_pool_bytes / 2**20:.1f}MiB ({stats.kv_dtype})")
    if not args.skip_scenarios:
        print(f"  mixed: {mixed['encode_completed']} encode @ "
              f"{mixed['encode_tok_s']:.0f} tok/s + generate @ "
              f"{mixed['ar_tok_s']:.0f} tok/s AR")
        print(f"  long admission ({unchunked['long_prompt_len']} tokens): "
              f"decode-stall p95 {unchunked['decode_stall_p95_ms']:.1f}ms "
              f"unchunked -> {chunked['decode_stall_p95_ms']:.1f}ms chunked "
              f"({chunked['prefill_chunks']} chunks of "
              f"{args.prefill_chunk})")
        if spec_rec.get("supported"):
            print(f"  spec decode (draft={spec_rec['draft']}, "
                  f"k={spec_rec['k']}): {spec_rec['spec_acceptance_rate']:.0%}"
                  f" accept, {spec_rec['spec_tokens_per_step']:.2f} tok/step,"
                  f" AR {spec_rec['baseline_ar_tok_s']:.0f} -> "
                  f"{spec_rec['ar_tok_s']:.0f} tok/s "
                  f"({spec_rec['ar_speedup']:.2f}x target-step; "
                  f"{spec_rec['ar_tok_s_incl_draft']:.0f} tok/s incl "
                  f"draft), draft p95 "
                  f"{spec_rec['draft_time_ms_p95']:.1f}ms")
        else:
            print(f"  spec decode: skipped ({spec_rec.get('reason')})")
        if tree_rec.get("supported"):
            print(f"  tree spec sweep (draft={tree_rec['draft']}, "
                  f"alpha={tree_rec['alpha']}):")
            for c in tree_rec["cells"]:
                print(f"    k={c['k']} b={c['branches']}: "
                      f"{c['accepted_per_round']:.3f} accepted/round, "
                      f"{c['spec_tokens_per_step']:.2f} tok/step, branch "
                      f"{c['spec_branch_utilization']:.0%}, tokens "
                      f"{'identical' if c['tokens_match'] else 'DIVERGED'}")
        else:
            print(f"  tree spec: skipped ({tree_rec.get('reason')})")
        if prefix_rec.get("supported"):
            pw, pc = prefix_rec["warm"], prefix_rec["cold"]
            print(f"  shared prefix ({prefix_rec['shared_prefix_len']} "
                  f"tokens x {prefix_rec['requests']} requests): "
                  f"{pc['prefill_tokens']} -> {pw['prefill_tokens']} "
                  f"prefill tokens, TTFT p95 {pc['ttft_p95_ms']:.1f} -> "
                  f"{pw['ttft_p95_ms']:.1f}ms "
                  f"({pw['prefix_cache_hit_rate']:.0%} hit, "
                  f"{pw['cow_copies']} COW), tokens "
                  f"{'identical' if prefix_rec['tokens_match'] else 'DIVERGED'}")
        else:
            print(f"  shared prefix: skipped ({prefix_rec.get('reason')})")
        gp = goodput_rec["policies"]
        print(f"  goodput ({goodput_rec['requests']} req @ "
              f"{goodput_rec['rate_rps']:.1f} rps = "
              f"{goodput_rec['overload']:.1f}x capacity, TTFT SLO "
              f"{goodput_rec['ttft_slo_ms']:.0f}ms): fcfs "
              f"{gp['fcfs']['goodput_rps']:.2f} -> deadline "
              f"{gp['deadline']['goodput_rps']:.2f} req/s "
              f"({gp['deadline']['slo_met']}/{goodput_rec['requests']} met, "
              f"{gp['deadline']['requests_shed']} shed, "
              f"{gp['deadline']['requests_degraded']} degraded)")
        tr = goodput_rec["traced"]
        print(f"  goodput traced (deadline): {tr['goodput_rps']:.2f} req/s "
              f"({tr['slo_met']} met), {tr['trace_events']} events"
              f"{', ' + str(tr['trace_dropped']) + ' dropped' if tr['trace_dropped'] else ''}"
              f" -> {tr['trace_out'] or '(unwritten)'}")
        for ph, row in tr["phase_util"].items():
            print(f"    {ph}: MFU {row['mfu']:.2e} MBU {row['mbu']:.2e} "
                  f"({row['time_s'] * 1e3:.0f}ms, {row['tokens']:.0f} tok, "
                  f"{row['passes']:.0f} passes)")
        problems = check_spec(spec_rec)
        problems += [f"TREE: {p}" for p in check_tree_spec(tree_rec)]
        problems += [f"PREFIX: {p}" for p in check_shared_prefix(prefix_rec)]
        problems += [f"GOODPUT: {p}" for p in check_goodput(goodput_rec)]
        if problems:
            for p in problems:
                print(f"  SCENARIO CHECK FAILED: {p}", file=sys.stderr)
            return 1
    print(f"  -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
