"""Paper Fig. 9 (left): sequence-length scaling of the GPT class.

NAR tokens/s should degrade with ~constant slope (complexity growth, no
memory cliff); AR tokens/s degrades linearly in attention only.
"""
from __future__ import annotations

import os

from benchmarks.common import ART, cell, throughput, write_csv

SEQS = (128, 256, 512, 1024, 2048)


def main():
    print("== Fig.9-left: sequence scaling (roofline-projected, 1 chip) ==")
    rows = []
    for arch in ("gpt3-xl", "gpt-j"):
        for mode, shape_fmt in (("NAR", "prefill:{s}:1"),
                                ("AR", "decode:{s}:1")):
            for s in SEQS:
                rec = cell(arch, shape_fmt.format(s=s), mesh="none",
                           policy="bf16", tag=f"seqscale_{mode}_{s}")
                if not rec.get("ok"):
                    rows.append([arch, mode, s, "FAIL", ""])
                    continue
                rows.append([arch, mode, s, f"{throughput(rec):.2f}",
                             rec["roofline"]["bound"]])
    for r in rows:
        print("  " + " | ".join(f"{str(x):>14s}" for x in r))
    write_csv(os.path.join(ART, "fig9_seq_scaling.csv"),
              ["arch", "mode", "seq", "tokens_per_s", "bound"], rows)
    return rows


if __name__ == "__main__":
    main()
