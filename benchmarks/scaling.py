"""Paper Fig. 9 (right): scaling with compute-unit count.

The paper scales ViT images/s over 1->16 Snitch clusters; the TPU analog
scales one workload over mesh sizes 1 -> 256 chips (data x model) and checks
near-linear roofline-projected throughput (close-to-perfect scalability =
collective term stays subdominant).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import ART, write_csv

# weak scaling grows the DATA axis at fixed tp=4 (the paper replicates
# clusters over images the same way; the model axis adds per-layer gather
# wire ∝ tp, so tp is held at its sweet spot — DESIGN.md §4)
MESHES = [(1, 4), (2, 4), (4, 4), (16, 4), (64, 4)]


def scale_cell(arch: str, shape: str, mesh_shape, *, tag: str,
               timeout: int = 1200) -> dict:
    os.makedirs(ART, exist_ok=True)
    fname = os.path.join(
        ART, f"{arch}__{shape.replace(':', '-')}__scale{mesh_shape[0]}x"
        f"{mesh_shape[1]}__{tag}.json")
    if os.path.exists(fname):
        return json.load(open(fname))
    n = mesh_shape[0] * mesh_shape[1]
    prog = textwrap.dedent(f"""
        import os, json
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import sys
        sys.path.insert(0, {os.path.join(os.path.dirname(__file__), '..', 'src')!r})
        import numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.launch import steps
        from repro.launch.dryrun import _parse_shape
        from repro.configs import get_config
        from repro.analysis.hlo import parse_hlo
        from repro.analysis.roofline import roofline_from_summary

        cfg = get_config({arch!r})
        shape = _parse_shape({shape!r})
        mesh = (None if {n} == 1
                else make_test_mesh({mesh_shape!r}, ("data", "model")))
        bundle = steps.make_prefill_step(cfg, shape, mesh)
        compiled = bundle.lower().compile()
        dt = "bf16"
        s = parse_hlo(compiled.as_text(), default_dot_dtype=dt)
        r = roofline_from_summary(s)
        rec = dict(arch={arch!r}, shape={shape!r}, chips={n},
                   step_time_s=r.step_time_s, bound=r.bound,
                   roofline=r.as_dict())
        json.dump(rec, open({fname!r}, "w"), indent=1)
    """)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=timeout, env=env)
    if not os.path.exists(fname):
        raise RuntimeError(f"scale cell failed: {p.stderr[-1500:]}")
    return json.load(open(fname))


def main():
    """Weak scaling (the paper's regime: per-cluster work held constant as
    clusters grow): batch scales with the chip count; ideal = constant
    tokens/s/chip."""
    print("== Fig.9-right: chip-count weak scaling (gpt3-xl prefill 2048, "
          "batch = chips) ==")
    rows = []
    base = None
    for ms in MESHES:
        n = ms[0] * ms[1]
        batch = 2 * ms[0]                     # 2 sequences per data shard
        rec = scale_cell("gpt3-xl", f"prefill:2048:{batch}", ms,
                         tag="chipscale_weak2")
        tput = 2048 * batch / max(rec["step_time_s"], 1e-12)
        per_chip = tput / n
        base = base or per_chip
        rows.append(["gpt3-xl", n, f"{tput:.0f}", f"{per_chip:.0f}",
                     f"{per_chip/base:.2f}", rec["bound"]])
    for r in rows:
        print("  " + " | ".join(f"{str(x):>12s}" for x in r))
    write_csv(os.path.join(ART, "fig9_chip_scaling.csv"),
              ["arch", "chips", "tokens_per_s", "tokens_per_s_per_chip",
               "efficiency", "bound"], rows)
    return rows


if __name__ == "__main__":
    main()
