"""Paper Fig. 7 + Fig. 8: impact of SW optimizations (the speedup staircase).

GPT family (gpt3-xl, gpt-j), NAR (prefill S=1024) and AR (decode with KV
cache), single chip (the closest analog of the paper's one 16-cluster die):

  stage 0  baseline: fp32, naive full-materialization attention, exact GELU
  stage 1  + flash attention / fused kernels (i-GELU)         [paper: +ISA/c2c]
  stage 2  + bf16                                             [paper: FP32]
  stage 3  + fp8 (E4M3 operands, fp32 softmax)                [paper: FP8]

AR stage 0 is "no KV cache" (recompute the full prompt per token — the
paper's unoptimized AR analog); stages 1+ use the cache (T8).
Paper validation targets: NAR ladder ~16x, AR ladder ~35x, ViT ~13-18x.
"""
from __future__ import annotations

import os

from benchmarks.common import ART, cell, step_time, throughput, write_csv

STAGES = [
    ("s0_naive_fp32", dict(policy="fp32", naive=True)),
    ("s1_flash_fp32", dict(policy="fp32")),
    ("s2_bf16", dict(policy="bf16")),
    # fp8 *storage* for inference (paper T6: low precision cuts the memory
    # roofline too, not just the MXU term)
    ("s3_fp8", dict(policy="fp8_serve")),
]


def gpt_ablation(arch: str, seq: int = 1024):
    rows = []
    # NAR: prefill S tokens in one pass
    base_t = None
    for tag, kw in STAGES:
        rec = cell(arch, f"prefill:{seq}:1", mesh="none", tag=f"nar_{tag}",
                   **kw)
        if not rec.get("ok"):
            rows.append([arch, "NAR", tag, "FAIL", "", ""])
            continue
        tput = throughput(rec)
        base_t = base_t or tput
        rows.append([arch, "NAR", tag, f"{tput:.1f}",
                     f"{tput / base_t:.2f}x",
                     f"{rec['roofline']['bound']}"])
    # AR: decode against a full cache; stage0 = recompute (prefill per token)
    rec0 = cell(arch, f"prefill:{seq}:1", mesh="none", tag="nar_s1_flash_fp32",
                policy="fp32")
    base = 1.0 / step_time(rec0) if rec0.get("ok") else None  # tok/s recompute
    rows.append([arch, "AR", "s0_recompute_fp32",
                 f"{base:.2f}" if base else "FAIL", "1.00x", "compute"])
    for tag, kw in STAGES[1:]:
        kw = dict(kw)
        kw.pop("naive", None)
        rec = cell(arch, f"decode:{seq}:1", mesh="none", tag=f"ar_{tag}", **kw)
        if not rec.get("ok"):
            rows.append([arch, "AR", tag, "FAIL", "", ""])
            continue
        tput = throughput(rec)
        rows.append([arch, "AR", tag, f"{tput:.1f}",
                     f"{tput / base:.1f}x" if base else "",
                     rec["roofline"]["bound"]])
    return rows


def vit_ablation():
    """Fig. 8 via models/vit.py single-chip lowering (benchmarks/vit_bench)."""
    from benchmarks.vit_bench import vit_cell
    rows = []
    for name in ("vit-b", "vit-l", "vit-h"):
        base = None
        for tag, kw in STAGES:
            rec = vit_cell(name, batch=8, tag=tag, **kw)
            ips = rec["images_per_s"]
            base = base or ips
            rows.append([name, "enc", tag, f"{ips:.1f}",
                         f"{ips / base:.2f}x", rec["bound"]])
    return rows


def main():
    print("== Fig.7: GPT NAR/AR software-optimization ablation "
          "(roofline-projected, 1 chip) ==")
    rows = []
    for arch in ("gpt3-xl", "gpt-j"):
        rows += gpt_ablation(arch)
    for r in rows:
        print("  " + " | ".join(f"{str(x):>16s}" for x in r))
    write_csv(os.path.join(ART, "fig7_ablation.csv"),
              ["arch", "mode", "stage", "tokens_per_s", "speedup", "bound"],
              rows)

    print("== Fig.8: ViT ablation ==")
    vrows = vit_ablation()
    for r in vrows:
        print("  " + " | ".join(f"{str(x):>16s}" for x in r))
    write_csv(os.path.join(ART, "fig8_vit_ablation.csv"),
              ["model", "mode", "stage", "images_per_s", "speedup", "bound"],
              vrows)
    return rows + vrows


if __name__ == "__main__":
    main()
