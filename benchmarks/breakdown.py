"""Paper Fig. 10: kernel latency breakdown (GEMM vs attention vs activations).

Uses the tagged FLOP attribution from the HLO parser (attention / mlp / ce /
other=projections+embeddings) for GPT-J and GPT3-XL in fp32 and fp8, NAR and
AR modes.  Paper validation: GEMM-class work dominates; normalization /
activation layers are negligible; the attention share grows at fp8 (its
fp32 softmax doesn't scale down).
"""
from __future__ import annotations

import os

from benchmarks.common import ART, cell, write_csv


def main():
    print("== Fig.10: kernel FLOP breakdown (share of per-step FLOPs) ==")
    rows = []
    for arch in ("gpt-j", "gpt3-xl"):
        for mode, shape in (("NAR", "prefill:1024:1"),
                            ("AR", "decode:1024:1")):
            for pol in ("fp32", "fp8_e4m3"):
                rec = cell(arch, shape, mesh="none", policy=pol,
                           tag=f"breakdown_{mode}_{pol}")
                if not rec.get("ok"):
                    continue
                tags = rec["roofline"]["flops_by_tag"]
                total = max(sum(tags.values()), 1.0)
                row = [arch, mode, pol]
                for t in ("attention", "mlp", "ce", "other"):
                    row.append(f"{tags.get(t, 0.0) / total * 100:.1f}%")
                rows.append(row)
    header = ["arch", "mode", "policy", "attention", "mlp", "ce",
              "proj/other"]
    print("  " + " | ".join(f"{h:>12s}" for h in header))
    for r in rows:
        print("  " + " | ".join(f"{str(x):>12s}" for x in r))
    write_csv(os.path.join(ART, "fig10_breakdown.csv"), header, rows)
    return rows


if __name__ == "__main__":
    main()
