"""Paper Fig. 10: kernel latency breakdown (GEMM vs attention vs activations)
plus the fused-epilogue A/B comparison.

Fig. 10 uses the tagged FLOP attribution from the HLO parser (attention /
mlp / ce / other=projections+embeddings) for GPT-J and GPT3-XL in fp32 and
fp8, NAR and AR modes.  Paper validation: GEMM-class work dominates;
normalization / activation layers are negligible; the attention share grows
at fp8 (its fp32 softmax doesn't scale down).

The fusion table runs each cell twice — fused prologue/epilogue pipeline
(default) vs the discrete op chain (`--no-fuse`) — and compares the
per-step HBM-traffic proxy (`mem_bytes_per_device`), the fusion-eliminated
traffic (`mem_bytes_elided_per_device`), and the roofline step time.  The
norm/residual activation round-trips the fusion removes must make the
fused `mem_bytes` STRICTLY lower for GPT-J NAR and AR; the result (plus the
pass/fail checks) lands in artifacts/bench/BENCH_fusion.json and runs in
the CI bench smoke (--fusion-only --smoke).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import ART, cell, write_csv


def fig10():
    print("== Fig.10: kernel FLOP breakdown (share of per-step FLOPs) ==")
    rows = []
    for arch in ("gpt-j", "gpt3-xl"):
        for mode, shape in (("NAR", "prefill:1024:1"),
                            ("AR", "decode:1024:1")):
            for pol in ("fp32", "fp8_e4m3"):
                rec = cell(arch, shape, mesh="none", policy=pol,
                           tag=f"breakdown_{mode}_{pol}")
                if not rec.get("ok"):
                    continue
                tags = rec["roofline"]["flops_by_tag"]
                total = max(sum(tags.values()), 1.0)
                row = [arch, mode, pol]
                for t in ("attention", "mlp", "ce", "other"):
                    row.append(f"{tags.get(t, 0.0) / total * 100:.1f}%")
                rows.append(row)
    header = ["arch", "mode", "policy", "attention", "mlp", "ce",
              "proj/other"]
    print("  " + " | ".join(f"{h:>12s}" for h in header))
    for r in rows:
        print("  " + " | ".join(f"{str(x):>12s}" for x in r))
    write_csv(os.path.join(ART, "fig10_breakdown.csv"), header, rows)
    return rows


def fusion_table(smoke: bool = False):
    """Fused-vs-unfused HBM-traffic / step-time comparison ->
    BENCH_fusion.json."""
    archs = ("gpt-j",) if smoke else ("gpt-j", "gpt3-xl")
    seq = 64 if smoke else 1024
    shapes = (("NAR", f"prefill:{seq}:1"), ("AR", f"decode:{seq}:1"))
    print("== Fused-epilogue pipeline: HBM-traffic proxy (per device) ==")
    header = ["arch", "mode", "mem_fused", "mem_unfused", "mem_ratio",
              "elided_fused", "step_fused_us", "step_unfused_us"]
    print("  " + " | ".join(f"{h:>14s}" for h in header))
    rows, cells, checks = [], {}, {}
    for arch in archs:
        for mode, shape in shapes:
            fused = cell(arch, shape, mesh="none",
                         tag=f"fusion_{mode}_fused")
            unfused = cell(arch, shape, mesh="none",
                           tag=f"fusion_{mode}_unfused", nofuse=True)
            if not (fused.get("ok") and unfused.get("ok")):
                print(f"  {arch} {mode}: FAILED "
                      f"({fused.get('error', '')[:120]}"
                      f"{unfused.get('error', '')[:120]})")
                continue
            rf, ru = fused["roofline"], unfused["roofline"]
            mf, mu = rf["mem_bytes_per_device"], ru["mem_bytes_per_device"]
            row = [arch, mode, f"{mf/1e6:.1f}MB", f"{mu/1e6:.1f}MB",
                   f"{mf/mu:.3f}",
                   f"{rf.get('mem_bytes_elided_per_device', 0)/1e6:.1f}MB",
                   f"{rf['step_time_s']*1e6:.0f}",
                   f"{ru['step_time_s']*1e6:.0f}"]
            rows.append(row)
            print("  " + " | ".join(f"{str(x):>14s}" for x in row))
            cells[f"{arch}_{mode}"] = {
                "shape": shape,
                "mem_bytes_fused": mf,
                "mem_bytes_unfused": mu,
                "mem_ratio": mf / mu if mu else 0.0,
                "mem_bytes_elided_fused":
                    rf.get("mem_bytes_elided_per_device", 0.0),
                "step_time_fused_s": rf["step_time_s"],
                "step_time_unfused_s": ru["step_time_s"],
                "flops_fused": rf["flops_per_device"],
                "flops_unfused": ru["flops_per_device"],
            }
            if arch == "gpt-j":
                # acceptance gate: norm/residual traffic actually eliminated
                checks[f"gptj_{mode}_mem_strictly_lower"] = bool(mf < mu)
                checks[f"gptj_{mode}_flops_unchanged"] = bool(
                    abs(rf["flops_per_device"] - ru["flops_per_device"])
                    < 0.01 * max(ru["flops_per_device"], 1.0))
    # the gate requires BOTH gpt-j modes measured — a crashed cell must
    # fail the bench, not silently drop its checks
    required = [f"gptj_{mode}_mem_strictly_lower" for mode, _ in shapes]
    complete = all(k in checks for k in required)
    out = {"cells": cells, "checks": checks,
           "ok": complete and all(checks.values())}
    path = os.path.join(ART, "BENCH_fusion.json")
    os.makedirs(ART, exist_ok=True)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"  checks: {checks}")
    print(f"  -> {path}")
    write_csv(os.path.join(ART, "fusion_breakdown.csv"), header, rows)
    return out


def fusion_gate():
    """fusion_table + hard failure on unmet checks (benchmarks/run.py
    entry — raises instead of SystemExit so the harness records it)."""
    out = fusion_table()
    if not out["ok"]:
        raise RuntimeError(f"fusion checks failed: {out['checks']}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fusion-only", action="store_true",
                    help="skip Fig.10, run only the fusion comparison")
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / gpt-j only (CI bench smoke)")
    # argv=None: called programmatically (benchmarks/run.py) — defaults
    args = ap.parse_args([] if argv is None else argv)
    if not args.fusion_only:
        fig10()
    out = fusion_table(smoke=args.smoke)
    if not out["ok"]:
        raise SystemExit(f"fusion checks failed: {out['checks']}")
    return out


if __name__ == "__main__":
    main(sys.argv[1:])
