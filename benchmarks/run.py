"""Benchmark orchestrator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig7,fig9seq,...]

Each module lowers+compiles real step functions (subprocess-cached under
artifacts/bench/) and reports TPU-v5e roofline-projected numbers — see
EXPERIMENTS.md §Methodology for why wall-clock is not measurable here.
CSV outputs land next to the JSON cells in artifacts/bench/.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: fig7,fig8,fig9seq,fig9chip,fig10,"
                         "fusion,tab3,tab4")
    args = ap.parse_args(argv)
    want = set(args.only.split(",")) if args.only else None

    from benchmarks import ablation, breakdown, precision_table, scaling, \
        seq_scaling, soa_table

    jobs = [
        ("fig7+fig8", ("fig7", "fig8"), ablation.main),
        ("fig9-seq", ("fig9seq",), seq_scaling.main),
        ("fig9-chip", ("fig9chip",), scaling.main),
        ("fig10", ("fig10",), breakdown.fig10),
        ("fusion", ("fusion",), breakdown.fusion_gate),
        ("tab3", ("tab3",), precision_table.main),
        ("tab4", ("tab4",), soa_table.main),
    ]
    failures = 0
    for name, keys, fn in jobs:
        if want is not None and not (want & set(keys)):
            continue
        t0 = time.time()
        try:
            fn()
            print(f"[{name}] done in {time.time()-t0:.0f}s\n")
        except Exception as e:  # keep the harness going; report at the end
            failures += 1
            print(f"[{name}] FAILED: {e}\n")
    print("benchmarks complete" + (f" ({failures} FAILED)" if failures
                                   else ""))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
