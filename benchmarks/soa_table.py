"""Paper Table IV: comparison with SoA accelerators (context table).

The paper's own numbers are reproduced verbatim for context; our row is the
GPT3-XL NAR roofline projection on TPU v5e (this framework), reported with
the same metrics: utilization and throughput per compute unit.
"""
from __future__ import annotations

import os

from benchmarks.common import ART, cell, write_csv

# (platform, CUs, TFLOP/s(FP16) total, thr/CU TFLOPS, FPU util %), from the
# paper's Table IV (Emani et al. GPT2-XL forward-pass study)
PAPER_ROWS = [
    ("A100", 6912 + 432, 5.63, 0.0008, 14.4),
    ("MI250", 13312 + 208, 3.75, 0.0003, 7.8),
    ("SN30", 1280, 13.8, 0.0107, 16.0),
    ("Gaudi2", 26, 11.3, 0.4327, 34.6),
    ("Snitch (paper)", 128, 0.72, 0.0056, 70.6),
]


def main():
    print("== Table IV: SoA context + our v5e roofline row (GPT NAR fp16-class) ==")
    rec = cell("gpt3-xl", "prefill:1024:1", mesh="none", policy="bf16",
               tag="soa_nar_bf16")
    rows = [[p, cu, f"{t:.2f}", f"{tc:.4f}", f"{u:.1f}%"]
            for p, cu, t, tc, u in PAPER_ROWS]
    if rec.get("ok"):
        r = rec["roofline"]
        st = r["step_time_s"]
        # achieved TFLOP/s on the model's useful FLOPs, one chip, one "CU"
        useful_tflops = rec["model_flops"] / st / 1e12
        rows.append(["Ours (v5e roofline)", 1, f"{useful_tflops:.2f}",
                     f"{useful_tflops:.4f}",
                     f"{r['compute_fraction']*100:.1f}%"])
    header = ["platform", "CUs", "TFLOP/s", "TFLOP/s/CU", "util"]
    print("  " + " | ".join(f"{h:>20s}" for h in header))
    for row in rows:
        print("  " + " | ".join(f"{str(x):>20s}" for x in row))
    write_csv(os.path.join(ART, "tab4_soa.csv"), header, rows)
    return rows


if __name__ == "__main__":
    main()
