"""Gradient compression: quantizer properties + error-feedback convergence."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st

from repro.optim.compression import dequantize_int8, quantize_int8

SET = settings(max_examples=25, deadline=None)


@SET
@given(st.integers(0, 2**31 - 1))
def test_quantize_roundtrip_signs(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(128), jnp.float32)
    q, s = quantize_int8(x)
    y = np.asarray(dequantize_int8(q, s))
    big = np.abs(np.asarray(x)) > float(s)     # below one LSB sign may drop
    assert (np.sign(y)[big] == np.sign(np.asarray(x))[big]).all()


def test_quantize_zero_tensor():
    q, s = quantize_int8(jnp.zeros(16))
    assert np.asarray(q).max() == 0


def test_error_feedback_converges():
    """EF-SGD on a quadratic: with error feedback the quantized-gradient
    iterates converge; the dropped residual is re-injected next step."""
    w = np.array([5.0, -3.0, 0.5], np.float32)
    err = np.zeros_like(w)
    for _ in range(300):
        g = 2 * w
        q, s = quantize_int8(jnp.asarray(g + err))
        gq = np.asarray(dequantize_int8(q, s))
        err = (g + err) - gq
        w = w - 0.05 * gq
    assert np.abs(w).max() < 1e-2
