"""Test configuration.

NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device
(the multi-device tests spawn subprocesses that set their own flags).
"""
import os
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def assert_allclose(a, b, *, rtol=2e-2, atol=2e-2):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=rtol, atol=atol)
