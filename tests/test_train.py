"""Training integration: loss decreases; optimizer + schedule units."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data import SyntheticStream
from repro.launch import steps as steps_mod
from repro.optim import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.schedule import cosine_schedule, linear_schedule


def test_loss_decreases_single_device():
    """A few hundred params of signal: loss must fall on a repeated batch."""
    cfg = get_config("phi4-mini-3.8b").reduced()
    shape = ShapeConfig("t", "train", 64, 4)
    bundle = steps_mod.make_train_step(
        cfg, shape, None, lr_fn=lambda s: jnp.asarray(1e-3))
    state = bundle.aux["init_state"](0)
    stream = SyntheticStream(cfg, global_batch=4, seq_len=64, seed=7)
    batch = stream.batch(0)          # overfit one batch
    first = None
    for _ in range(30):
        state, metrics = bundle.fn(state, batch)
        if first is None:
            first = float(metrics["loss"])
    last = float(metrics["loss"])
    assert last < first - 0.5, (first, last)


def test_moe_aux_loss_present():
    cfg = get_config("mixtral-8x7b").reduced()
    shape = ShapeConfig("t", "train", 32, 2)
    bundle = steps_mod.make_train_step(cfg, shape, None)
    state = bundle.aux["init_state"](0)
    stream = SyntheticStream(cfg, global_batch=2, seq_len=32)
    state, metrics = bundle.fn(state, stream.batch(0))
    assert "aux" in metrics and np.isfinite(float(metrics["aux"]))


def test_adamw_moves_toward_minimum():
    params = {"w": jnp.array([10.0, -10.0])}
    opt = adamw_init(params)
    step = jnp.zeros((), jnp.int32)
    for i in range(200):
        grads = {"w": 2 * params["w"]}      # d/dw of w^2
        params, opt = adamw_update(params, grads, opt,
                                   step=step + i, lr=0.1, weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 3.0), "b": jnp.full((9,), 4.0)}
    clipped, norm = clip_by_global_norm(grads, [(), ()], 1.0)
    total = np.sqrt(sum(float(jnp.sum(g * g))
                        for g in jax.tree.leaves(clipped)))
    assert abs(total - 1.0) < 1e-5
    assert abs(float(norm) - np.sqrt(9 * 4 + 16 * 9)) < 1e-4


@pytest.mark.parametrize("mk", [cosine_schedule, linear_schedule])
def test_schedules(mk):
    lr = mk(1e-3, 10, 100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert abs(float(lr(jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.asarray(50))) < 1e-3
    assert float(lr(jnp.asarray(100))) <= float(lr(jnp.asarray(50)))


def test_gelu_impl_toggle():
    """i-GELU vs exact GELU must produce different but close losses."""
    cfg = get_config("gemma3-27b").reduced()
    shape = ShapeConfig("t", "train", 32, 2)
    stream = SyntheticStream(cfg, global_batch=2, seq_len=32)
    batch = stream.batch(0)
    losses = {}
    for impl in ("i_gelu", "gelu_exact"):
        bundle = steps_mod.make_train_step(cfg, shape, None, gelu_impl=impl)
        state = bundle.aux["init_state"](0)
        _, metrics = bundle.fn(state, batch)
        losses[impl] = float(metrics["loss"])
    assert abs(losses["i_gelu"] - losses["gelu_exact"]) < 0.05
