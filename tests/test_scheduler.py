"""Scheduler / ModelRunner split: policy-order properties, chunked-prefill
token identity, EncodeTask parity, and mixed encode+generate batches.

Policy-order properties are pure host-side logic (no model); the
end-to-end checks run the reduced phi4 config on one device like
tests/test_serving.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core.precision import FP32
from repro.models import frontends, lm
from repro.serving import (ChunkedPrefillPolicy, EncodeTask, FCFSPolicy,
                           InferenceEngine, PriorityPolicy, Request,
                           SamplingParams, make_policy)
from repro.serving.tasks import GenerateTask
from repro.sharding.plan import UNSHARDED


# --------------------------------------------------------------------------
# policy-order properties (no model)
# --------------------------------------------------------------------------

def _tasks(specs, now):
    """specs: (uid, priority, age_s) -> GenerateTasks submitted in uid
    order, `age_s` seconds ago."""
    out = []
    for uid, prio, age in specs:
        t = GenerateTask(uid=uid, priority=prio,
                         prompt=np.zeros((4,), np.int32))
        t._t_submit = now - age
        t._seq = uid
        out.append(t)
    return out


def test_fcfs_order_is_arrival_order():
    now = 1000.0
    q = _tasks([(0, 5, 1.0), (1, 0, 0.5), (2, 9, 0.1)], now)
    assert [t.uid for t in FCFSPolicy().admission_order(q, now)] == [0, 1, 2]


def test_priority_order_and_stability():
    now = 1000.0
    q = _tasks([(0, 0, 0.1), (1, 2, 0.1), (2, 1, 0.1), (3, 2, 0.1)], now)
    order = PriorityPolicy(aging_s=1e9).admission_order(q, now)
    # priority desc; equal priority keeps arrival order (stable sort)
    assert [t.uid for t in order] == [1, 3, 2, 0]


def test_priority_inversion_bounded_by_aging():
    """A low-priority task waiting longer than aging_s * delta_priority
    outranks a fresh high-priority task — no starvation."""
    now = 1000.0
    pol = PriorityPolicy(aging_s=2.0)
    fresh_hi = _tasks([(0, 3, 0.0)], now)[0]
    old_lo = _tasks([(1, 0, 7.0)], now)[0]      # 7s > 2.0 * (3 - 0)
    young_lo = _tasks([(2, 0, 1.0)], now)[0]
    assert [t.uid for t in
            pol.admission_order([fresh_hi, old_lo, young_lo], now)] == [
        1, 0, 2]


def test_priority_victim_is_least_important():
    now = 1000.0
    pol = PriorityPolicy(aging_s=1e9)
    running = _tasks([(0, 5, 0.1), (1, 0, 0.1), (2, 3, 0.1)], now)
    assert pol.select_victim(running, now).uid == 1
    # FCFS evicts the youngest admitted regardless of priority
    assert FCFSPolicy().select_victim(running, now).uid == 2


def test_deadline_boosts_urgency():
    now = 1000.0
    pol = PriorityPolicy(aging_s=1e9, deadline_boost=5.0)
    plain = _tasks([(0, 1, 0.5)], now)[0]
    urgent = _tasks([(1, 1, 0.5)], now)[0]
    urgent.deadline_ms = 600.0                   # 500ms into a 600ms budget
    assert pol.admission_order([plain, urgent], now)[0].uid == 1


def test_make_policy_factory():
    assert isinstance(make_policy("fcfs"), FCFSPolicy)
    assert isinstance(make_policy("priority"), PriorityPolicy)
    p = make_policy("chunked", chunk_tokens=24)
    assert isinstance(p, ChunkedPrefillPolicy) and p.chunk_tokens == 24
    with pytest.raises(KeyError):
        make_policy("nope")


# --------------------------------------------------------------------------
# end-to-end: policies on the reduced model
# --------------------------------------------------------------------------

def _phi4():
    cfg = get_config("phi4-mini-3.8b").reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
    return cfg, params


def _run_engine(cfg, params, reqs, **kw):
    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                             policy=FP32, **kw)
    for r in reqs:
        engine.submit(r)
    done = engine.run()
    return engine, {t.uid: t for t in done}


def _gen_reqs(cfg, lens, *, max_new=6, sampled=(), priorities=None):
    rng = np.random.default_rng(31)
    reqs = []
    for uid, n in enumerate(lens):
        reqs.append(Request(
            uid=uid, prompt=rng.integers(0, cfg.vocab, n, dtype=np.int32),
            max_new_tokens=max_new,
            priority=(priorities or [0] * len(lens))[uid],
            sampling=SamplingParams(temperature=0.8, top_k=20, seed=uid)
            if uid in sampled else SamplingParams()))
    return reqs


def test_chunked_prefill_token_identical_to_fcfs():
    """ChunkedPrefillPolicy must change WHEN prefill FLOPs run, never what
    they compute: same request set, greedy and sampled, token-for-token."""
    cfg, params = _phi4()
    lens = (5, 40, 12, 33)                       # two prompts > chunk budget
    base = _run_engine(cfg, params, _gen_reqs(cfg, lens, sampled=(1, 3)),
                       scheduler=FCFSPolicy())[1]
    eng, chunked = _run_engine(cfg, params,
                               _gen_reqs(cfg, lens, sampled=(1, 3)),
                               scheduler=ChunkedPrefillPolicy(16))
    assert eng.runner.supports_chunked
    st = eng.stats()
    assert st.prefill_chunks >= 2 + 3            # ceil(40/16) + ceil(33/16)
    assert st.chunked_prefill_tokens == 40 + 33
    assert {u: t.output for u, t in chunked.items()} == {
        u: t.output for u, t in base.items()}
    # pool fully drained afterwards — chunk bookkeeping leaks no blocks
    assert eng.allocator.num_free == eng.allocator.num_blocks


def test_chunked_policy_falls_back_without_paged_full_attention():
    """Archs whose cache cannot carry chunk state (sliding window here)
    serve chunked-policy traffic through whole-prompt prefill, outputs
    unchanged."""
    cfg = get_config("gemma3-27b").reduced()
    params = lm.init_lm(jax.random.key(1), cfg, jnp.float32)
    reqs = _gen_reqs(cfg, (30, 9), max_new=3)
    base = _run_engine(cfg, params, _gen_reqs(cfg, (30, 9), max_new=3),
                       scheduler=FCFSPolicy())[1]
    eng, got = _run_engine(cfg, params, reqs,
                           scheduler=ChunkedPrefillPolicy(8))
    assert not eng.runner.supports_chunked
    assert eng.stats().prefill_chunks == 0
    assert {u: t.output for u, t in got.items()} == {
        u: t.output for u, t in base.items()}


def test_priority_policy_reorders_admission():
    """With one slot, the high-priority late arrival is served before the
    earlier low-priority queue (and outputs stay per-request identical to
    FCFS — ordering never leaks into the math)."""
    cfg, params = _phi4()
    reqs = _gen_reqs(cfg, (8, 8, 8), max_new=4, priorities=[0, 0, 5])
    fcfs = InferenceEngine(cfg, params, batch_size=1, max_seq=64,
                           policy=FP32, scheduler=FCFSPolicy())
    prio = InferenceEngine(cfg, params, batch_size=1, max_seq=64,
                           policy=FP32,
                           scheduler=PriorityPolicy(aging_s=1e9))
    for r in _gen_reqs(cfg, (8, 8, 8), max_new=4, priorities=[0, 0, 5]):
        fcfs.submit(r)
    for r in reqs:
        prio.submit(r)
    f_done = fcfs.run()
    p_done = prio.run()
    assert [t.uid for t in f_done] == [0, 1, 2]
    assert [t.uid for t in p_done][0] == 2        # priority 5 served first
    assert ({t.uid: t.output for t in p_done}
            == {t.uid: t.output for t in f_done})


# --------------------------------------------------------------------------
# EncodeTask serving
# --------------------------------------------------------------------------

def _direct_encode(cfg, params, prompt, pooling):
    batch = {"tokens": jnp.asarray(prompt)[None]}
    return np.asarray(lm.forward_encode(params, batch, plan=UNSHARDED,
                                        cfg=cfg, policy=FP32,
                                        pooling=pooling))[0]


def test_encode_task_matches_direct_forward():
    """Engine EncodeTasks (batched, right-padded to buckets) == a direct
    unpadded forward_encode, for both pooling modes."""
    cfg, params = _phi4()
    rng = np.random.default_rng(41)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (5, 8, 19)]
    for pooling in ("last", "mean"):
        engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                                 policy=FP32)
        for uid, p in enumerate(prompts):
            engine.submit(EncodeTask(uid=uid, prompt=p, pooling=pooling))
        done = {t.uid: t for t in engine.run()}
        assert len(done) == 3
        for uid, p in enumerate(prompts):
            ref = _direct_encode(cfg, params, p, pooling)
            got = done[uid].embedding
            assert got.shape == (cfg.d_model,)
            np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)
        st = engine.stats()
        assert st.encode_tokens == 5 + 8 + 19
        assert st.encode_batches >= 1 and st.encode_tok_s > 0
        assert st.encode_latency_p95_ms >= st.encode_latency_p50_ms > 0


def test_encode_last_pooling_equals_prefill_residual():
    """pooling="last" is the hidden state a prefill would sample from: the
    greedy token from the pooled embedding must equal the prefill's."""
    cfg, params = _phi4()
    rng = np.random.default_rng(43)
    prompt = rng.integers(0, cfg.vocab, 11, dtype=np.int32)
    emb = _direct_encode(cfg, params, prompt, "last")
    from repro.core.embedding import greedy_token
    tok = int(greedy_token(jnp.asarray(emb, jnp.float32)[None],
                           params["embedding"]["unemb"], plan=UNSHARDED,
                           cfg=cfg, policy=FP32)[0])
    batch = {"tokens": jnp.asarray(prompt)[None]}
    t_ref, _, _ = lm.forward_prefill(params, batch, plan=UNSHARDED, cfg=cfg,
                                     policy=FP32, max_seq=64)
    assert tok == int(t_ref[0])


def _bert_style():
    """Encoder-only bidirectional token encoder (BERT-style): `enc` kind,
    served through the engine via exact-length encode batches (bidir
    attention would attend pad positions, so no padding)."""
    cfg = ModelConfig(
        name="bert-tiny-test", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=512,
        schedule=(("enc", 2),), causal=False, mlp_act="gelu",
        norm="layernorm", rope_theta=10_000.0, max_seq=64)
    params = lm.init_lm(jax.random.key(7), cfg, jnp.float32)
    return cfg, params


def test_encoder_only_config_serves_exact_length():
    cfg, params = _bert_style()
    rng = np.random.default_rng(47)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (6, 6, 13)]
    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                             policy=FP32)
    assert not engine.runner._encode_pad          # bidirectional: no pads
    assert engine.runner.encode_bucket_for(13) == 13
    for uid, p in enumerate(prompts):
        engine.submit(EncodeTask(uid=uid, prompt=p, pooling="mean"))
    done = {t.uid: t for t in engine.run()}
    for uid, p in enumerate(prompts):
        ref = _direct_encode(cfg, params, p, "mean")
        np.testing.assert_allclose(done[uid].embedding, ref,
                                   rtol=1e-5, atol=1e-5)
    # the two length-6 prompts shared one exact-length batch
    assert engine.stats().encode_batches == 2


def test_mixed_encode_and_generate_batches():
    """Encode and generate traffic through ONE engine: generate outputs
    match a generate-only run, encode embeddings match direct forwards."""
    cfg, params = _phi4()
    rng = np.random.default_rng(53)
    gen_reqs = _gen_reqs(cfg, (7, 21), max_new=5, sampled=(1,))
    enc_prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
                   for n in (9, 14)]

    base = _run_engine(cfg, params,
                       _gen_reqs(cfg, (7, 21), max_new=5, sampled=(1,)))[1]
    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                             policy=FP32)
    for r in gen_reqs:
        engine.submit(r)
    for j, p in enumerate(enc_prompts):
        engine.submit(EncodeTask(uid=100 + j, prompt=p))
    done = {t.uid: t for t in engine.run()}
    assert len(done) == 4
    for uid in (0, 1):
        assert done[uid].output == base[uid].output
    for j, p in enumerate(enc_prompts):
        ref = _direct_encode(cfg, params, p, "last")
        np.testing.assert_allclose(done[100 + j].embedding, ref,
                                   rtol=1e-5, atol=1e-5)
    st = engine.stats()
    assert st.requests_completed == 4
    assert st.encode_completed == 2 and st.ar_tokens > 0
    assert len(st.queue_wait_ms) == 4
    assert st.queue_wait_p95_ms >= st.queue_wait_p50_ms >= 0
    d = st.to_dict()
    assert d["encode_tok_s"] == st.encode_tok_s
    assert d["queue_wait_p95_ms"] == st.queue_wait_p95_ms


def test_chunked_with_preemption_recovers_exactly():
    """Chunked policy + an undersized pool: preempted requests (possibly
    mid-chunk) recompute to token-identical continuations."""
    cfg, params = _phi4()
    lens = (26, 26, 18)
    base = _run_engine(cfg, params,
                       _gen_reqs(cfg, lens, max_new=8, sampled=(1,)))[1]
    eng, got = _run_engine(cfg, params,
                           _gen_reqs(cfg, lens, max_new=8, sampled=(1,)),
                           scheduler=ChunkedPrefillPolicy(8),
                           block_size=8, kv_pool_blocks=8)
    assert {u: t.output for u, t in got.items()} == {
        u: t.output for u, t in base.items()}
    assert eng.allocator.num_free == eng.allocator.num_blocks
