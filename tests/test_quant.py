"""Low-precision serving: weight-only int8 GEMMs + int8 paged KV.

Three layers of evidence, mirroring how the feature is built:

  kernel parity     the Pallas fused-GEMM variants (interpret mode) and the
                    paged-attention kernels must match the pure-jnp oracles
                    bit-for-bit-close on int8 operands — the dequant scale
                    lands in the fp32 accumulator at the same point in both.
  quantization      quantize_params covers exactly the dense GEMM leaves,
                    the dims tree stays aligned leaf-for-leaf, per-channel
                    reconstruction error is bounded by scale/2, and the
                    end-of-model logit error stays small per arch.
  engine            int8 KV decodes/chunk-prefills token-identically to
                    bf16 (same math, quantize-on-write + dequant-on-read);
                    both knobs survive COW sharing, speculative rollback
                    and preemption-recompute without leaking pool blocks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.precision import FP32
from repro.kernels import ref
from repro.kernels.flash_decode import (paged_decode_attention,
                                        paged_decode_partials)
from repro.kernels.matmul import matmul, matmul_swiglu
from repro.launch import steps as steps_mod
from repro.models import lm
from repro.models.quantize import (QUANT_KEYS, quantize_params,
                                   quantize_param_dims)
from repro.optim.compression import quantize_int8_axiswise
from repro.serving import (InferenceEngine, Request, SamplingParams,
                           SpecConfig, make_policy)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.key(key), shape, dtype)


def _qweight(key, K, N):
    """A quantized weight pair the way quantize_params makes them."""
    w = _rand(key, (K, N))
    q, scale = quantize_int8_axiswise(w, axis=(1,))
    return w, q, scale


# --------------------------------------------------------------------------
# kernel parity: int8 weight tiles through the fused epilogues
# --------------------------------------------------------------------------

def test_matmul_int8_scale_vs_ref():
    a = _rand(0, (48, 96))
    _, q, scale = _qweight(1, 96, 64)
    out = matmul(a, q, b_scale=scale, block_m=32, block_n=32, block_k=32,
                 interpret=True)
    want = ref.fused_matmul_ref(a, q, w_scale=scale, compute_dtype=a.dtype,
                                out_dtype=a.dtype)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


def test_matmul_int8_full_epilogue_vs_ref():
    """norm prologue + bias + activation + residual around the int8 dot:
    the dequant multiply must land before the bias in both paths."""
    a = _rand(0, (32, 64))
    _, q, scale = _qweight(1, 64, 48)
    gamma = 1.0 + 0.1 * _rand(2, (64,))
    bias = _rand(3, (48,))
    res = _rand(4, (32, 48))
    out = matmul(a, q, b_scale=scale, norm="rmsnorm", gamma=gamma, bias=bias,
                 activation="gelu", residual=res, block_m=16, block_n=16,
                 block_k=32, interpret=True)
    want = ref.fused_matmul_ref(a, q, w_scale=scale, norm="rmsnorm",
                                gamma=gamma,
                                bias=bias, activation="gelu", residual=res,
                                out_dtype=a.dtype)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


def test_matmul_swiglu_int8_vs_ref():
    a = _rand(0, (32, 64))
    _, qg, sg = _qweight(1, 64, 48)
    _, qu, su = _qweight(2, 64, 48)
    out = matmul_swiglu(a, qg, qu, bg_scale=sg, bu_scale=su, block_m=16,
                        block_n=16, block_k=32, interpret=True)
    want = ref.fused_matmul_swiglu_ref(a, qg, qu, wg_scale=sg, wu_scale=su,
                                       out_dtype=a.dtype)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


def _int8_pool(key, NB, BS, KV, D):
    """An int8 pool + per-block-per-head scales, quantized the way the
    cache scatters write them."""
    x = _rand(key, (NB, BS, KV, D))
    amax = jnp.abs(x).max(axis=(1, 3))
    s = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / s[:, None, :, None]), -127, 127
                 ).astype(jnp.int8)
    return q, s


@pytest.mark.parametrize("B,H,KV,D", [(2, 4, 4, 32), (3, 8, 2, 16)])
def test_paged_decode_int8_vs_ref(B, H, KV, D):
    NB, BS, MB = 6, 8, 3
    kq, ks = _int8_pool(0, NB, BS, KV, D)
    vq, vs = _int8_pool(1, NB, BS, KV, D)
    q = _rand(2, (B, H, D))
    rng = np.random.default_rng(3)
    tables = jnp.asarray(np.stack([rng.permutation(NB)[:MB]
                                   for _ in range(B)]).astype(np.int32))
    lengths = jnp.asarray([BS * MB, 5, 17][:B], jnp.int32)
    out = paged_decode_attention(q, kq, vq, tables, lengths, k_scale=ks,
                                 v_scale=vs, interpret=True)
    want = ref.paged_decode_attention_ref(q, kq, vq, tables, lengths,
                                          k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


def test_paged_partials_int8_vs_ref():
    B, H, KV, D, NB, BS = 2, 4, 4, 32, 6, 8
    kq, ks = _int8_pool(0, NB, BS, KV, D)
    vq, vs = _int8_pool(1, NB, BS, KV, D)
    q = _rand(2, (B, H, D))
    tables = jnp.asarray([[0, 2, -1], [5, -1, -1]], jnp.int32)
    lengths = jnp.asarray([11, 8], jnp.int32)
    o, m, l = paged_decode_partials(q, kq, vq, tables, lengths, k_scale=ks,
                                    v_scale=vs, interpret=True)
    ow, mw, lw = ref.paged_decode_partials_ref(q, kq, vq, tables, lengths,
                                               k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(m, mw, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(l, lw, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(o, ow, rtol=2e-5, atol=2e-4)


def test_int8_pool_matches_bf16_attention():
    """End-to-end quantize-then-attend: the int8 pool's output must sit
    within quantization error of attending over the original bf16 pool."""
    B, H, KV, D, NB, BS = 2, 4, 4, 32, 4, 8
    k = _rand(0, (NB, BS, KV, D))
    v = _rand(1, (NB, BS, KV, D))
    kq, ks = _int8_pool(0, NB, BS, KV, D)   # same draws as k/v above
    vq, vs = _int8_pool(1, NB, BS, KV, D)
    q = _rand(2, (B, H, D))
    tables = jnp.asarray([[0, 1], [2, 3]], jnp.int32)
    lengths = jnp.asarray([16, 13], jnp.int32)
    exact = ref.paged_decode_attention_ref(q, k, v, tables, lengths)
    quant = ref.paged_decode_attention_ref(q, kq, vq, tables, lengths,
                                           k_scale=ks, v_scale=vs)
    err = float(jnp.abs(exact - quant).max())
    assert err < 0.05, f"int8 KV attention error {err} too large"


# --------------------------------------------------------------------------
# quantize_params: coverage, dims alignment, error bounds
# --------------------------------------------------------------------------

def _leaves_with_paths(tree):
    return jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: (isinstance(x, dict)
                                 and set(x) == {"q", "scale"}))[0]


def test_quantize_params_coverage_and_dims():
    cfg = get_config("gpt-j").reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.bfloat16)
    qp = quantize_params(params)
    quantized = stayed = 0
    for path, leaf in _leaves_with_paths(qp):
        name = str(path[-1].key if hasattr(path[-1], "key") else path[-1])
        if isinstance(leaf, dict):
            assert leaf["q"].dtype == jnp.int8
            assert leaf["scale"].dtype == jnp.float32
            assert leaf["scale"].shape == (leaf["q"].shape[:-2]
                                           + leaf["q"].shape[-1:])
            quantized += 1
        else:
            assert name not in QUANT_KEYS or leaf.ndim != 3
            stayed += 1
    assert quantized > 0 and stayed > 0      # head + blocks vs norms/embed
    assert not isinstance(qp["embedding"]["embed"], dict)  # gather, not GEMM
    assert isinstance(qp["embedding"]["unemb"], dict)
    # dims tree maps through the same transform, leaf-for-leaf
    dims = quantize_param_dims(lm.lm_param_dims(cfg))
    struct = jax.eval_shape(quantize_params, params)
    is_dim = lambda x: (isinstance(x, tuple)      # axis-name tuple, not the
                        and all(e is None or isinstance(e, str)
                                for e in x))      # segments tuple-of-dicts
    assert (jax.tree_util.tree_structure(
                jax.tree.map(lambda x: 0, dims, is_leaf=is_dim))
            == jax.tree_util.tree_structure(
                jax.tree.map(lambda x: 0, struct)))


def test_quantize_reconstruction_bound():
    """Per-output-channel symmetric quantization: every element sits within
    half a quantization step of the original."""
    w, q, scale = _qweight(0, 128, 64)
    err = jnp.abs(w - q.astype(jnp.float32) * scale)
    assert bool((err <= 0.5 * scale + 1e-7).all())


@pytest.mark.parametrize("arch", ["gpt-j", "gpt3-xl", "phi4-mini-3.8b"])
def test_logit_error_bound(arch):
    """Quantizing the real (init-distribution) head weight moves no logit
    by more than 1% of the logit range."""
    cfg = get_config(arch).reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
    unemb = params["embedding"]["unemb"]
    qleaf = quantize_params(params)["embedding"]["unemb"]
    x = _rand(1, (4, unemb.shape[0]))
    z = x @ unemb
    zq = (x @ qleaf["q"].astype(jnp.float32)) * qleaf["scale"]
    span = float(jnp.abs(z).max())
    err = float(jnp.abs(z - zq).max())
    assert err < 0.01 * span, f"{arch}: logit error {err} vs span {span}"


# --------------------------------------------------------------------------
# steps: int8 KV decode/chunk parity with bf16
# --------------------------------------------------------------------------

def test_kv_int8_decode_chunk_token_parity():
    """Chunked admission + greedy decode through the serving steps: the
    int8 pool must commit the same tokens as the bf16 pool (FP32-policy
    archs keep quantization the only perturbation; at init-weight scale it
    stays below every argmax margin on this trace)."""
    cfg = get_config("gpt-j").reduced()
    B, max_seq, bs = 2, 64, 8
    nb = B * (max_seq // bs)
    params = lm.init_lm(jax.random.key(0), cfg, jnp.bfloat16)
    dshape = ShapeConfig("tq_decode", "decode", max_seq, B)

    def run(kv_dtype):
        step = steps_mod.make_decode_step(
            cfg, dshape, None, max_seq=max_seq, with_sampling=True,
            paged=(nb, bs), kv_cache_dtype=kv_dtype)
        chunk = steps_mod.make_chunk_prefill_step(
            cfg, ShapeConfig("tq_chunk", "decode", max_seq, 1), None,
            layout=step.aux["paged"], chunk_tokens=16, max_seq=max_seq,
            with_sampling=True, kv_cache_dtype=kv_dtype)
        caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                              step.aux["cache_struct"])
        if kv_dtype == "int8":
            pools = [x for x in jax.tree.leaves(caches)
                     if x.dtype == jnp.int8]
            assert pools, "int8 cache layout did not materialize"
        layout = step.aux["paged"]
        rng = np.random.RandomState(7)
        prompt = rng.randint(10, 200, size=20).astype(np.int32)
        table = np.full((1, layout.max_blocks), -1, np.int32)
        table[0, :3] = [0, 1, 2]
        lane1 = {"temperature": jnp.zeros((1,), jnp.float32),
                 "top_k": jnp.zeros((1,), jnp.int32),
                 "seed": jnp.zeros((1,), jnp.int32),
                 "step": jnp.zeros((1,), jnp.int32)}
        tok = None
        for start in (0, 16):
            take = min(16, 20 - start)
            ch = np.zeros((1, 16), np.int32)
            ch[0, :take] = prompt[start:start + take]
            tok, caches, _ = chunk.fn(
                params, jnp.asarray(ch), jnp.asarray([start], jnp.int32),
                jnp.asarray([take], jnp.int32), caches, jnp.asarray(table),
                lane1)
        toks = [int(np.asarray(tok)[0])]
        full_table = np.full((B, layout.max_blocks), -1, np.int32)
        full_table[0] = table[0]
        pos = np.array([20, 0], np.int32)
        cur = np.array([toks[0], 0], np.int32)
        laneB = {k: jnp.zeros((B,), v.dtype) for k, v in lane1.items()}
        for _ in range(5):
            t_d, p_d, caches = step.fn(params, jnp.asarray(cur),
                                       jnp.asarray(pos), caches,
                                       jnp.asarray(full_table), laneB)
            cur = np.asarray(t_d)
            pos = np.asarray(p_d)
            toks.append(int(cur[0]))
        return toks

    assert run("int8") == run("bfloat16")


# --------------------------------------------------------------------------
# engine: both knobs end to end; COW / spec rollback / preemption
# --------------------------------------------------------------------------

_PARAMS_CACHE = {}


def _reduced(arch):
    if arch not in _PARAMS_CACHE:
        cfg = get_config(arch).reduced()
        _PARAMS_CACHE[arch] = (cfg, lm.init_lm(jax.random.key(0), cfg,
                                               jnp.float32))
    return _PARAMS_CACHE[arch]


def _trace(cfg, n=4, *, pre_len=24, max_new=6, sampled=(), seed=11):
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab, pre_len, dtype=np.int32)
    out = []
    for i in range(n):
        tail = rng.integers(0, cfg.vocab, 3 + i, dtype=np.int32)
        out.append(Request(
            uid=i,
            prompt=np.concatenate([shared, tail]) if i else shared.copy(),
            max_new_tokens=max_new,
            sampling=SamplingParams(temperature=0.8, top_k=8, seed=i)
            if i in sampled else SamplingParams()))
    return out


def _run(cfg, params, reqs, **kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("max_seq", 64)
    engine = InferenceEngine(cfg, params, policy=FP32, **kw)
    for r in reqs:
        engine.submit(r)
    done = {t.uid: t.output for t in engine.run()}
    return engine, done


def _no_leaks(engine):
    alloc, pc = engine.allocator, engine.prefix_cache
    cached = pc.cached_blocks if pc is not None else 0
    assert alloc.num_free == alloc.num_blocks - cached
    if pc is not None:
        assert all(alloc.refcount(b) == 1 for b in pc.index_blocks())
        pc.check()


def test_engine_e2e_both_knobs():
    """Both opt-ins through the full engine: requests complete, the stats
    report the dtypes and the byte shrink, and the run stays leak-free."""
    cfg, params = _reduced("gpt-j")
    base_eng, base = _run(cfg, params, _trace(cfg), prefix_cache=False)
    eng, done = _run(cfg, params, _trace(cfg), prefix_cache=False,
                     weight_dtype="int8", kv_dtype="int8")
    assert sorted(done) == sorted(base)
    assert all(len(done[u]) == len(base[u]) for u in base)
    st, bst = eng.stats(), base_eng.stats()
    assert (st.weight_dtype, st.kv_dtype) == ("int8", "int8")
    assert (bst.weight_dtype, bst.kv_dtype) == ("bfloat16", "bfloat16")
    assert 0 < st.weight_bytes_per_device < 0.62 * bst.weight_bytes_per_device
    assert 0 < st.kv_pool_bytes < 0.55 * bst.kv_pool_bytes
    assert "QUANT" in st.summary() and "QUANT" not in bst.summary()
    _no_leaks(eng)


def test_kv_int8_engine_token_identity():
    """int8 KV alone (weights bf16-exact in FP32 policy): token-identical
    to the bf16 pool across greedy AND sampled requests."""
    cfg, params = _reduced("gpt-j")
    mk = lambda: _trace(cfg, sampled=(1, 3))
    base = _run(cfg, params, mk(), prefix_cache=False)[1]
    _, got = _run(cfg, params, mk(), prefix_cache=False, kv_dtype="int8")
    assert got == base


def test_quantized_pool_cow_and_preemption():
    """Prefix sharing (COW on the partial tail) + a starved pool
    (preemption-recompute) over int8 pools: everything completes and no
    block leaks; re-quantization on recompute reproduces the same scales
    (pure function of token content), so outputs stay stable."""
    cfg, params = _reduced("gpt-j")
    mk = lambda: _trace(cfg, pre_len=20, max_new=8, sampled=(2,))
    _, base = _run(cfg, params, mk(), prefix_cache=True, kv_dtype="int8",
                   block_size=8, kv_pool_blocks=32,
                   scheduler=make_policy("fcfs", cache_aware=True))
    eng, got = _run(cfg, params, mk(), prefix_cache=True, kv_dtype="int8",
                    block_size=8, kv_pool_blocks=6,
                    scheduler=make_policy("fcfs", cache_aware=True))
    st = eng.stats()
    assert st.preemptions > 0
    assert got == base
    _no_leaks(eng)


def test_quantized_pool_spec_rollback():
    """Speculative decoding over int8 pools with a rejection-heavy draft:
    rollback truncates lengths only — rejected positions are re-quantized
    on overwrite per the offset-0 scale-reset rule — and greedy outputs
    match the non-spec int8 engine exactly."""
    cfg, params = _reduced("gpt-j")
    mk = lambda: _trace(cfg, pre_len=16, max_new=8)
    base = _run(cfg, params, mk(), prefix_cache=False, kv_dtype="int8")[1]
    spec = SpecConfig(draft="auto", k=3, draft_seed=1234)
    eng, got = _run(cfg, params, mk(), prefix_cache=False, kv_dtype="int8",
                    kv_pool_blocks=24, spec=spec)
    assert got == base
    assert eng.stats().spec_rounds > 0
    _no_leaks(eng)
