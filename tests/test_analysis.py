"""HLO analysis: trip-count weighting, collective accounting, roofline."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import parse_hlo
from repro.analysis.roofline import (model_flops, roofline_from_summary,
                                     PEAK_BF16)
from repro.configs import SHAPES, get_config


def _flops_of(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    return parse_hlo(compiled.as_text(), default_dot_dtype="f32").total_flops


def test_trip_count_weighting():
    """A scan of k matmuls must report ~k x the flops of one matmul —
    exactly what compiled.cost_analysis() gets wrong."""
    x = jnp.ones((64, 64))
    w = jnp.ones((8, 64, 64))

    def one(x):
        return x @ w[0]

    def scan(x):
        def body(c, wi):
            return c @ wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y

    f1 = _flops_of(one, x)
    f8 = _flops_of(scan, x)
    assert f1 > 0
    ratio = f8 / f1
    assert 7.0 < ratio < 9.5, ratio


def test_dot_flops_exact():
    a = jnp.ones((32, 128))
    b = jnp.ones((128, 16))
    got = _flops_of(lambda a, b: a @ b, a, b)
    assert got == 2 * 32 * 128 * 16


def test_collective_bytes_counted():
    import subprocess, sys, os, textwrap
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import sys
        sys.path.insert(0, %r)
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.analysis.hlo import parse_hlo
        from repro.core.collectives import shard_map
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh((4,), ("d",))
        def f(x):
            return jax.lax.psum(x, "d")
        sm = shard_map(f, mesh=mesh, in_specs=P("d"), out_specs=P())
        c = jax.jit(sm).lower(
            jax.ShapeDtypeStruct((1024,), jnp.float32)).compile()
        s = parse_hlo(c.as_text())
        # all-reduce of 256 floats/device: 2 * 1KiB * 3/4 wire bytes
        total = sum(s.collective_bytes.values())
        assert 1024 < total < 4096, s.collective_bytes
        print("OK", total)
    """ % os.path.join(os.path.dirname(__file__), "..", "src"))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=300, env=env)
    assert p.returncode == 0, p.stderr[-2000:]
    assert "OK" in p.stdout


def test_roofline_terms():
    from repro.analysis.hlo import HloSummary
    s = HloSummary(flops_by_dtype={"bf16": PEAK_BF16},      # 1s of compute
                   flops_by_tag={}, collective_bytes={"all-gather": 50e9},
                   mem_bytes=819e9 / 2)
    r = roofline_from_summary(s)
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.collective_s - 1.0) < 1e-9
    assert abs(r.memory_s - 0.5) < 1e-9
    assert r.bound in ("compute", "collective")
    assert abs(r.step_time_s - 1.0) < 1e-9


def test_model_flops_kinds():
    cfg = get_config("phi4-mini-3.8b")
    tr = model_flops(cfg, SHAPES["train_4k"])
    pf = model_flops(cfg, SHAPES["prefill_32k"])
    dc = model_flops(cfg, SHAPES["decode_32k"])
    assert tr > pf > dc > 0
    # train = 3x a forward over the same token count
    assert abs(tr / (SHAPES["train_4k"].global_batch
                     * SHAPES["train_4k"].seq_len)
               / (pf / (SHAPES["prefill_32k"].global_batch
                        * SHAPES["prefill_32k"].seq_len)) - 3.0) < 1e-6


def test_moe_active_params():
    cfg = get_config("mixtral-8x7b")
    assert cfg.n_active_params() < 0.4 * cfg.n_params()
