"""Speculative decoding: acceptance exactness, rollback hygiene, and the
submit-time SamplingParams validation satellite.

The load-bearing property is *token identity*: with speculation on, every
committed sequence — greedy AND sampled — must equal what step-by-step
decoding produces, because acceptance is checked against the target's own
deterministic sampler (serving/spec.py).  Identity is asserted across
draft regimes that stress different paths: draft="self" (all-accept, the
draft-lag/catch-up path), a deliberately mis-seeded draft (near-zero
acceptance, maximal rejection + KV rollback), chunked-prefill admission
mixed in, and recompute preemption under a starved pool.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, make_draft
from repro.core.embedding import TOP_K_CAP
from repro.core.precision import FP32
from repro.models import lm
from repro.serving import (ChunkedPrefillPolicy, FCFSPolicy, InferenceEngine,
                           Request, SamplingParams, SpecConfig,
                           spec_support_reason)
from repro.serving.spec import (DraftState, accept_length, resolve_draft,
                                trim_emitted)

# a draft seeded away from the target's init: its proposals are
# effectively random over the reduced vocab, so almost every round
# rejects — the KV-rollback / draft-rewind stress regime
REJECTY = SpecConfig(draft="auto", k=3, draft_seed=1234)


# --------------------------------------------------------------------------
# pure host-side pieces
# --------------------------------------------------------------------------

def test_spec_config_validation():
    with pytest.raises(ValueError, match="k must be >= 1"):
        SpecConfig(k=0)
    with pytest.raises(ValueError, match="acceptance"):
        SpecConfig(acceptance="approximate")
    with pytest.raises(ValueError, match="draft"):
        SpecConfig(draft="")
    assert SpecConfig().acceptance == "lossless"


def test_accept_length_and_trim():
    assert accept_length([1, 2, 3], [1, 2, 9, 5]) == 2
    assert accept_length([7], [1]) == 0
    assert accept_length([], [4]) == 0
    assert trim_emitted([5, 6, 7], room=2, eos_id=None) == [5, 6]
    assert trim_emitted([5, 6, 7], room=9, eos_id=6) == [5, 6]
    assert trim_emitted([5, 6, 7], room=1, eos_id=7) == [5]


def test_make_draft_shape_and_registry():
    cfg = get_config("gpt-j")
    d = make_draft(cfg)
    assert d.schedule == (("attn", 2),) and d.vocab == cfg.vocab
    assert d.n_experts == 0 and d.ssm_state == 0 and d.sliding_window == 0
    # registered paper-family drafts resolve by name and share the vocab
    assert get_config("gpt-j-draft").vocab == cfg.vocab
    assert get_config("gpt3-xl-draft").vocab == get_config("gpt3-xl").vocab
    with pytest.raises(ValueError, match="vocabulary"):
        make_draft(get_config("vit-b"))


def test_resolve_draft():
    cfg = get_config("phi4-mini-3.8b").reduced()
    assert resolve_draft(SpecConfig(draft="self"), cfg) is cfg
    auto = resolve_draft(SpecConfig(draft="auto"), cfg)
    assert auto.vocab == cfg.vocab and auto.n_layers == 2
    # a named full-size draft reduces alongside a reduced target
    named = resolve_draft(SpecConfig(draft="phi4-mini-3.8b-draft"), cfg)
    assert named.vocab == cfg.vocab
    with pytest.raises(ValueError, match="tokenizer"):
        resolve_draft(SpecConfig(draft="gpt-j-draft"),
                      get_config("gpt3-xl"))   # 50400 != 50257


def test_spec_support_reason():
    assert spec_support_reason(get_config("gpt-j")) is None
    assert spec_support_reason(get_config("phi4-mini-3.8b")) is None
    assert "ring" in spec_support_reason(get_config("gemma3-27b"))
    assert "SSM" in spec_support_reason(get_config("mamba2-2.7b"))
    assert spec_support_reason(get_config("whisper-base")) is not None
    assert spec_support_reason(get_config("vit-b")) is not None


# --------------------------------------------------------------------------
# SamplingParams validation satellite
# --------------------------------------------------------------------------

def test_sampling_params_rejects_out_of_range():
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.5)
    with pytest.raises(ValueError, match="TOP_K_CAP"):
        SamplingParams(temperature=1.0, top_k=TOP_K_CAP + 1)
    # the cap itself and 0 (full vocab) stay valid
    SamplingParams(temperature=1.0, top_k=TOP_K_CAP)
    SamplingParams(temperature=1.0, top_k=0)


def test_submit_rejects_bad_sampling():
    """Validation fires at submit even for params smuggled past
    __post_init__ (object.__setattr__ on the frozen dataclass)."""
    cfg, params = _reduced("phi4-mini-3.8b")
    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                             policy=FP32)
    bad = SamplingParams(temperature=1.0, top_k=1)
    object.__setattr__(bad, "top_k", TOP_K_CAP + 7)
    with pytest.raises(ValueError, match="TOP_K_CAP"):
        engine.submit(Request(uid=0, prompt=np.zeros(4, np.int32),
                              sampling=bad))


# --------------------------------------------------------------------------
# end-to-end identity
# --------------------------------------------------------------------------

_PARAMS_CACHE = {}


def _reduced(arch):
    if arch not in _PARAMS_CACHE:
        cfg = get_config(arch).reduced()
        _PARAMS_CACHE[arch] = (cfg, lm.init_lm(jax.random.key(0), cfg,
                                               jnp.float32))
    return _PARAMS_CACHE[arch]


def _trace(cfg, lens, *, max_new=7, sampled=(), eos=None):
    rng = np.random.default_rng(29)
    reqs = []
    for uid, n in enumerate(lens):
        reqs.append(Request(
            uid=uid, prompt=rng.integers(0, cfg.vocab, n, dtype=np.int32),
            max_new_tokens=max_new, eos_id=eos,
            sampling=SamplingParams(temperature=0.8, top_k=8, seed=uid)
            if uid in sampled else SamplingParams()))
    return reqs


def _run(cfg, params, reqs, **kw):
    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                             policy=FP32, **kw)
    for r in reqs:
        engine.submit(r)
    done = {t.uid: t.output for t in engine.run()}
    return engine, done


@pytest.mark.parametrize("arch", ["gpt-j", "gpt3-xl", "phi4-mini-3.8b",
                                  "chatglm3-6b"])
def test_greedy_token_identity(arch):
    """Greedy decode with speculation on is token-identical to speculation
    off, under both the all-accept (self) and rejection-heavy drafts."""
    cfg, params = _reduced(arch)
    lens = (5, 12, 9)
    base = _run(cfg, params, _trace(cfg, lens))[1]
    for spec in (SpecConfig(draft="self", k=3), REJECTY):
        eng, got = _run(cfg, params, _trace(cfg, lens), spec=spec)
        st = eng.stats()
        assert got == base, f"{arch} diverged under {spec.draft}"
        assert st.spec_rounds > 0
        if spec.draft == "self":
            # proposing with the target itself makes greedy acceptance
            # exact: every proposal commits, so rounds emit multiple
            # tokens (the max_new_tokens budget trims the final round
            # below the k+1 ceiling)
            assert st.spec_acceptance_rate == 1.0
            assert 1.0 < st.spec_tokens_per_step <= spec.k + 1
        # pool fully drained — verify writes + rollback leak no blocks
        assert eng.allocator.num_free == eng.allocator.num_blocks


def test_sampled_lossless_parity():
    """Sampled requests (fixed seeds) are exactly reproduced: acceptance
    compares against the target's deterministic (seed, position)-keyed
    draws, so speculation is lossless in the strongest sense — the same
    guarantee exact rejection sampling gives, with bitwise token identity
    instead of distribution equality."""
    cfg, params = _reduced("gpt-j")
    lens = (6, 14, 10, 8)
    reqs = lambda: _trace(cfg, lens, sampled=(0, 1, 3))
    base = _run(cfg, params, reqs())[1]
    for spec in (SpecConfig(draft="self", k=4), REJECTY):
        _, got = _run(cfg, params, reqs(), spec=spec)
        assert got == base


def test_spec_with_chunked_prefill_mix():
    """Speculation + ChunkedPrefillPolicy: long prompts chunk into their
    paged blocks while seated slots decode speculatively; the draft
    prefills whole at final-chunk landing.  Outputs match plain FCFS with
    speculation off."""
    cfg, params = _reduced("phi4-mini-3.8b")
    lens = (5, 40, 12, 33)
    base = _run(cfg, params, _trace(cfg, lens, sampled=(1,)),
                scheduler=FCFSPolicy())[1]
    eng, got = _run(cfg, params, _trace(cfg, lens, sampled=(1,)),
                    scheduler=ChunkedPrefillPolicy(16), spec=REJECTY)
    st = eng.stats()
    assert st.prefill_chunks >= 5 and st.spec_rounds > 0
    assert got == base
    assert eng.allocator.num_free == eng.allocator.num_blocks


def test_kv_rollback_leak_free_and_bounded():
    """A rejection-heavy draft rolls KV back every round: the pool's peak
    must stay within capacity and fully drain at the end (no block leaked
    by verify-write + trailing-block free cycles)."""
    cfg, params = _reduced("gpt-j")
    eng, _ = _run(cfg, params, _trace(cfg, (9, 17), max_new=12),
                  spec=REJECTY, block_size=4)
    st = eng.stats()
    assert st.spec_proposed_tokens > st.spec_accepted_tokens
    assert st.peak_blocks_used <= eng.allocator.num_blocks
    assert eng.allocator.num_free == eng.allocator.num_blocks


def test_preemption_then_resume_parity_with_spec():
    """Recompute preemption under a starved pool, with speculation on:
    evicted requests re-prefill (target AND draft) and continue
    token-exactly; lookahead allocation never deadlocks the pool."""
    cfg, params = _reduced("phi4-mini-3.8b")
    lens = (5, 11, 7, 16)
    reqs = lambda: _trace(cfg, lens, max_new=9, sampled=(1, 3))
    base = _run(cfg, params, reqs())[1]
    eng, got = _run(cfg, params, reqs(), spec=REJECTY,
                    block_size=8, kv_pool_blocks=5)
    st = eng.stats()
    assert st.preemptions > 0
    assert got == base
    assert eng.allocator.num_free == eng.allocator.num_blocks


def test_eos_inside_accepted_prefix_trims():
    """An EOS landing mid-round must end the sequence exactly where
    step-by-step decoding stops — committed tokens after the EOS would
    break token identity and retirement."""
    cfg, params = _reduced("gpt-j")
    base = _run(cfg, params, _trace(cfg, (6,), max_new=9))[1]
    eos = base[0][2]   # a token the greedy run emits early becomes EOS
    want = _run(cfg, params, _trace(cfg, (6,), max_new=9, eos=eos))[1]
    _, got = _run(cfg, params, _trace(cfg, (6,), max_new=9, eos=eos),
                  spec=SpecConfig(draft="self", k=4))
    assert got == want
    # the trim actually fired inside the first all-accept round: the
    # sequence ends at the first EOS, short of the max_new budget
    assert want[0][-1] == eos and len(want[0]) < 9
    assert want[0] == base[0][:len(want[0])]


def test_max_seq_cap_identity():
    """When the sequence horizon (max_seq - 1) retires requests before
    max_new_tokens, speculative lookahead must not commit past it."""
    cfg, params = _reduced("gpt-j")
    reqs = lambda: _trace(cfg, (6, 6), max_new=200)
    engine_kw = dict(batch_size=2, max_seq=32, policy=FP32)
    base_eng = InferenceEngine(cfg, params, **engine_kw)
    spec_eng = InferenceEngine(cfg, params, spec=SpecConfig(draft="self",
                                                           k=4), **engine_kw)
    for r in reqs():
        base_eng.submit(r)
    for r in reqs():
        spec_eng.submit(r)
    base = {t.uid: t.output for t in base_eng.run()}
    got = {t.uid: t.output for t in spec_eng.run()}
    assert base == got
    assert all(len(v) < 200 for v in base.values())  # the cap actually bound


def test_unsupported_arch_raises():
    cfg = get_config("gemma3-27b").reduced()     # sliding-window ring cache
    params = lm.init_lm(jax.random.key(1), cfg, jnp.float32)
    with pytest.raises(ValueError, match="unsupported"):
        InferenceEngine(cfg, params, batch_size=2, max_seq=64, policy=FP32,
                        spec=SpecConfig(draft="auto"))


def test_greedy_acceptance_mode_rejects_sampled_submissions():
    cfg, params = _reduced("phi4-mini-3.8b")
    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                             policy=FP32,
                             spec=SpecConfig(draft="self", k=2,
                                             acceptance="greedy"))
    engine.submit(Request(uid=0, prompt=np.zeros(4, np.int32)))  # greedy ok
    with pytest.raises(ValueError, match="greedy"):
        engine.submit(Request(uid=1, prompt=np.zeros(4, np.int32),
                              sampling=SamplingParams(temperature=0.7)))


def test_spec_stats_and_draft_state():
    """The telemetry satellite: acceptance/throughput/draft-latency fields
    populate, serialize, and stay internally consistent."""
    cfg, params = _reduced("gpt-j")
    eng, done = _run(cfg, params, _trace(cfg, (5, 9), max_new=8),
                     spec=SpecConfig(draft="self", k=3))
    st = eng.stats()
    assert st.spec_rounds > 0
    assert st.spec_emitted_tokens == st.ar_tokens
    assert 1.0 <= st.spec_tokens_per_step <= 4.0
    assert st.draft_time_ms_p95 >= st.draft_time_ms_p50 > 0
    assert st.spec_draft_time_s > 0
    d = st.to_dict()
    for key in ("spec_acceptance_rate", "spec_tokens_per_step",
                "draft_time_ms_p50", "draft_time_ms_p95", "spec_rounds"):
        assert key in d
    assert "SPEC" in st.summary()
    # per-slot DraftState cleared on retirement
    assert all(s is None for s in eng.runner.draft_states)
    assert isinstance(DraftState(pos=0), DraftState)
