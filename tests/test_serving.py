"""Serving engine: continuous batching correctness on one device."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.precision import FP32
from repro.models import frontends, lm
from repro.serving import Request, ServingEngine
from repro.serving.kv_cache import insert_row, zero_caches
from repro.sharding.plan import UNSHARDED


def test_engine_matches_direct_decode():
    """Tokens from the engine == tokens from a direct prefill+decode loop."""
    cfg = get_config("phi4-mini-3.8b").reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, 16, dtype=np.int32)
               for _ in range(3)]

    engine = ServingEngine(cfg, params, batch_size=2, max_seq=64,
                           prompt_len=16, policy=FP32)
    for uid, p in enumerate(prompts):
        engine.submit(Request(uid=uid, prompt=p, max_new_tokens=5))
    done = sorted(engine.run(), key=lambda r: r.uid)
    assert len(done) == 3
    assert all(len(r.output) == 5 for r in done)

    for req in done:
        batch = {"tokens": jnp.asarray(req.prompt)[None]}
        tok, caches, pos = lm.forward_prefill(params, batch, plan=UNSHARDED,
                                              cfg=cfg, policy=FP32,
                                              max_seq=64)
        toks = [int(tok[0])]
        t, p = tok, pos
        for _ in range(4):
            t, caches = lm.forward_decode(params, t, p, caches,
                                          plan=UNSHARDED, cfg=cfg,
                                          policy=FP32)
            p = p + 1
            toks.append(int(t[0]))
        assert toks == req.output, (req.uid, toks, req.output)


def test_engine_continuous_batching_refills():
    """More requests than slots: finished slots must be reused."""
    cfg = get_config("gemma3-27b").reduced()
    params = lm.init_lm(jax.random.key(1), cfg, jnp.float32)
    rng = np.random.default_rng(5)
    engine = ServingEngine(cfg, params, batch_size=2, max_seq=64,
                           prompt_len=8, policy=FP32)
    for uid in range(5):
        engine.submit(Request(uid=uid,
                              prompt=rng.integers(0, cfg.vocab, 8,
                                                  dtype=np.int32),
                              max_new_tokens=3))
    done = engine.run()
    assert len(done) == 5
    assert engine.steps_run < 5 * 3      # rows overlapped, not serialized


def test_insert_row():
    batch = {"k": jnp.zeros((2, 4, 8)), "v": jnp.zeros((2, 4, 8))}
    single = {"k": jnp.ones((2, 1, 8)), "v": 2 * jnp.ones((2, 1, 8))}
    out = insert_row(batch, single, 2)
    assert float(out["k"][:, 2].min()) == 1.0
    assert float(out["v"][:, 2].min()) == 2.0
    assert float(out["k"][:, 0].max()) == 0.0


def test_zero_caches_struct():
    st = {"a": jax.ShapeDtypeStruct((2, 3), jnp.bfloat16)}
    z = zero_caches(st)
    assert z["a"].shape == (2, 3) and z["a"].dtype == jnp.bfloat16
