"""Serving engine: session API correctness on one device.

Covers the InferenceEngine redesign: variable-length prompts across prefill
length buckets (output-exact vs direct unpadded decode), per-request
SamplingParams (greedy ≡ temperature 0 ≡ top-k 1, seeded reproducibility),
streaming-vs-batch equivalence, eos/max-new retirement under mixed lengths,
and the EngineStats telemetry counters.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.precision import FP32
from repro.models import lm
from repro.serving import (InferenceEngine, Request, SamplingParams,
                           ServingEngine)
from repro.serving import kv_cache as kv_mod
from repro.serving.kv_cache import insert_row, zero_caches
from repro.sharding.plan import UNSHARDED


def _direct_tokens(cfg, params, prompt, n_new, max_seq=64):
    """Reference: unpadded prefill + greedy decode loop."""
    batch = {"tokens": jnp.asarray(prompt)[None]}
    tok, caches, pos = lm.forward_prefill(params, batch, plan=UNSHARDED,
                                          cfg=cfg, policy=FP32,
                                          max_seq=max_seq)
    toks = [int(tok[0])]
    t, p = tok, pos
    for _ in range(n_new - 1):
        t, caches = lm.forward_decode(params, t, p, caches, plan=UNSHARDED,
                                      cfg=cfg, policy=FP32)
        p = p + 1
        toks.append(int(t[0]))
    return toks


def _phi4():
    cfg = get_config("phi4-mini-3.8b").reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
    return cfg, params


def _submit_all(engine, prompts, *, max_new=5, sampling=None, eos_id=None):
    for uid, p in enumerate(prompts):
        engine.submit(Request(uid=uid, prompt=p, max_new_tokens=max_new,
                              eos_id=eos_id,
                              sampling=sampling(uid) if sampling
                              else SamplingParams()))


def test_engine_matches_direct_decode():
    """Tokens from the engine == tokens from a direct prefill+decode loop."""
    cfg, params = _phi4()
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, 16, dtype=np.int32)
               for _ in range(3)]

    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                             policy=FP32)
    _submit_all(engine, prompts)
    done = sorted(engine.run(), key=lambda r: r.uid)
    assert len(done) == 3
    assert all(len(r.output) == 5 for r in done)
    for req in done:
        assert _direct_tokens(cfg, params, req.prompt, 5) == req.output


def test_variable_length_prompts_across_buckets():
    """Prompts of differing lengths in one run, each output-exact vs the
    direct unpadded loop (pad-to-bucket must not leak into the math).
    Buckets step by 1.5x/2x rungs (8, 12, 16, 24, 32, ...)."""
    cfg, params = _phi4()
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (5, 8, 16, 23)]

    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                             policy=FP32, min_bucket=8)
    _submit_all(engine, prompts)
    done = sorted(engine.run(), key=lambda r: r.uid)
    assert [r.bucket for r in done] == [8, 8, 16, 24]
    assert [r.prompt_len for r in done] == [5, 8, 16, 23]
    for req in done:
        assert _direct_tokens(cfg, params, req.prompt, 5) == req.output, (
            req.uid, req.prompt_len, req.bucket)
    # one compile per distinct (bucket, group size), not per request: the
    # two bucket-8 prompts prefill together in one batched call
    assert engine.stats().prefill_compiles == 3


def test_exact_length_buckets_for_recurrent_caches():
    """SSM / sliding-window archs must prefill at exact length (their state
    would absorb pad positions)."""
    cfg = get_config("gemma3-27b").reduced()          # sliding-window layers
    assert cfg.sliding_window > 0
    params = lm.init_lm(jax.random.key(1), cfg, jnp.float32)
    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                             policy=FP32)
    assert engine.bucket_for(5) == 5 and engine.bucket_for(13) == 13
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (6, 11)]
    _submit_all(engine, prompts, max_new=3)
    done = sorted(engine.run(), key=lambda r: r.uid)
    for req in done:
        assert _direct_tokens(cfg, params, req.prompt, 3) == req.output


def test_engine_continuous_batching_refills():
    """More requests than slots: finished slots must be reused."""
    cfg = get_config("gemma3-27b").reduced()
    params = lm.init_lm(jax.random.key(1), cfg, jnp.float32)
    rng = np.random.default_rng(5)
    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                             policy=FP32)
    _submit_all(engine, [rng.integers(0, cfg.vocab, 8, dtype=np.int32)
                         for _ in range(5)], max_new=3)
    done = engine.run()
    assert len(done) == 5
    assert engine.steps_run < 5 * 3      # rows overlapped, not serialized
    assert engine.stats().slot_occupancy > 0.5


def test_temperature_zero_and_topk_one_are_greedy():
    """temperature=0 ≡ greedy; top_k=1 at high temperature ≡ greedy (the
    Gumbel draw over a single candidate is deterministic)."""
    cfg, params = _phi4()
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (8, 16)]

    outs = {}
    for name, sp in (("greedy", SamplingParams()),
                     ("t0", SamplingParams(temperature=0.0, seed=9)),
                     ("top1", SamplingParams(temperature=2.0, top_k=1,
                                             seed=4))):
        engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                                 policy=FP32)
        _submit_all(engine, prompts, sampling=lambda uid: sp)
        outs[name] = [r.output for r in
                      sorted(engine.run(), key=lambda r: r.uid)]
    assert outs["greedy"] == outs["t0"] == outs["top1"]


def test_per_request_seed_reproducible():
    """Same seed => identical sampled tokens across engine runs; different
    seeds diverge."""
    cfg, params = _phi4()
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (8, 16, 12)]

    def run_with(seed_fn):
        engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                                 policy=FP32)
        _submit_all(engine, prompts, max_new=8, sampling=lambda uid:
                    SamplingParams(temperature=1.0, top_k=0,
                                   seed=seed_fn(uid)))
        return [r.output for r in sorted(engine.run(), key=lambda r: r.uid)]

    a = run_with(lambda uid: 100 + uid)
    b = run_with(lambda uid: 100 + uid)
    c = run_with(lambda uid: 500 + uid)
    assert a == b                        # reproducible
    assert a != c                        # seed actually steers the draw
    greedy = [_direct_tokens(cfg, params, p, 8) for p in prompts]
    assert a != greedy                   # and it is not secretly greedy


def test_streaming_matches_run():
    """generate() yields exactly the tokens run() accumulates, with one
    is_last per request on its final token."""
    cfg, params = _phi4()
    rng = np.random.default_rng(17)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (5, 16, 23)]
    sampling = lambda uid: (SamplingParams(temperature=0.9, seed=uid)
                            if uid % 2 else SamplingParams())

    stream_engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                                    policy=FP32)
    _submit_all(stream_engine, prompts, sampling=sampling)
    streamed, last_seen = {}, {}
    for ev in stream_engine.generate():
        streamed.setdefault(ev.uid, []).append(ev.token)
        assert ev.uid not in last_seen, "token after is_last"
        if ev.is_last:
            last_seen[ev.uid] = True

    batch_engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                                   policy=FP32)
    _submit_all(batch_engine, prompts, sampling=sampling)
    done = {r.uid: r.output for r in batch_engine.run()}

    assert streamed == done
    assert set(last_seen) == set(done)


def test_eos_and_max_new_retirement_mixed_lengths():
    """eos_id truncates generation; max_new_tokens caps it; both under
    mixed prompt lengths in one batch."""
    cfg, params = _phi4()
    rng = np.random.default_rng(19)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (5, 16, 23)]

    probe = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                            policy=FP32)
    _submit_all(probe, prompts, max_new=8)
    ref = {r.uid: r.output for r in probe.run()}
    eos = ref[0][2]                      # retire uid 0 at its 3rd token

    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                             policy=FP32)
    _submit_all(engine, prompts, max_new=8, eos_id=eos)
    done = {r.uid: r for r in engine.run()}
    assert len(done) == 3
    for uid, req in done.items():
        assert len(req.output) <= 8
        if eos in ref[uid][:7]:
            cut = ref[uid].index(eos)
            assert req.output == ref[uid][:cut + 1], uid
        else:
            assert req.output == ref[uid]
    assert done[0].output[-1] == eos and len(done[0].output) == 3


def test_engine_stats_telemetry():
    """EngineStats: NAR/AR split, true (not padded) prompt token counts,
    TTFT per request, bucket hits."""
    cfg, params = _phi4()
    rng = np.random.default_rng(23)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (5, 16, 23)]
    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                             policy=FP32, min_bucket=8)
    _submit_all(engine, prompts)
    done = engine.run()
    st = engine.stats()
    assert st.requests_submitted == st.requests_completed == 3
    assert st.nar_tokens == 5 + 16 + 23              # true lengths
    assert st.padded_nar_tokens == 8 + 16 + 24       # bucket lengths
    assert st.ar_tokens == sum(len(r.output) for r in done) - 3
    assert st.nar_time_s > 0 and st.ar_time_s > 0
    assert st.nar_tok_s > 0 and st.ar_tok_s > 0
    assert len(st.ttft_ms) == 3 and all(t > 0 for t in st.ttft_ms)
    assert st.ttft_p95_ms >= st.ttft_p50_ms > 0
    assert st.bucket_hits == {8: 1, 16: 1, 24: 1}
    assert 0 < st.slot_occupancy <= 1
    assert st.decode_step_p95_ms >= st.decode_step_p50_ms > 0
    assert st.kv_pool_blocks > 0 and st.peak_blocks_used > 0
    assert 0 < st.pool_utilization <= 1
    assert st.blocks_per_token >= 1.0
    assert st.preemptions == 0
    d = st.to_dict()
    assert d["nar_tok_s"] == st.nar_tok_s and d["bucket_hits"]["8"] == 1
    assert d["pool_utilization"] == st.pool_utilization
    engine.reset_stats()
    assert engine.stats().nar_tokens == 0
    assert engine.stats().kv_pool_blocks == st.kv_pool_blocks


def test_serving_engine_alias():
    """The pre-redesign name remains importable and is the same class."""
    assert ServingEngine is InferenceEngine


def test_insert_row():
    batch = {"k": jnp.zeros((2, 4, 8)), "v": jnp.zeros((2, 4, 8))}
    single = {"k": jnp.ones((2, 1, 8)), "v": 2 * jnp.ones((2, 1, 8))}
    out = insert_row(batch, single, 2)
    assert float(out["k"][:, 2].min()) == 1.0
    assert float(out["v"][:, 2].min()) == 2.0
    assert float(out["k"][:, 0].max()) == 0.0


def test_zero_caches_struct():
    st = {"a": jax.ShapeDtypeStruct((2, 3), jnp.bfloat16)}
    z = zero_caches(st)
    assert z["a"].shape == (2, 3) and z["a"].dtype == jnp.bfloat16


def test_zero_caches_compile_cached():
    """Repeated zero_caches over the same struct reuses the jitted zeros
    builders (one per distinct leaf) instead of re-jitting per call."""
    st = {"a": jax.ShapeDtypeStruct((4, 5), jnp.float32),
          "b": jax.ShapeDtypeStruct((4, 5), jnp.float32),
          "c": jax.ShapeDtypeStruct((2, 2), jnp.bfloat16)}
    zero_caches(st)
    n = len(kv_mod._ZEROS_CACHE)
    zero_caches(st)
    zero_caches({"d": jax.ShapeDtypeStruct((4, 5), jnp.float32)})
    assert len(kv_mod._ZEROS_CACHE) == n
