"""Fused-epilogue pipeline parity suite.

Three layers of guarantees:

  1. Kernel parity — the fused Pallas kernels (norm prologue, bias/act/
     residual epilogue, fused residual+norm, batched expert swiglu) match
     the jnp oracles in interpret mode, across norm kinds / dtypes /
     non-multiple-of-block shapes.  These tests also run under the CI
     interpret-mode job (REPRO_KERNEL_MODE=interpret).
  2. Model parity — with `fuse_epilogues` toggled on the plan, every block
     kind's forward is numerically identical on the reference dispatch
     path (the fused pipeline composes the same ops in the same order),
     and greedy generate() is token-identical end to end: prefill, decode,
     chunked prefill, encode, paged and dense caches, sampled and greedy.
  3. Analysis — the compiled-HLO roofline proxy shows strictly lower
     mem_bytes (and nonzero elided bytes) for the fused pipeline.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core import blocks
from repro.core.precision import FP32
from repro.kernels import ops, ref
from repro.kernels import matmul as mm
from repro.kernels import rmsnorm as rn
from repro.models import frontends, lm
from repro.serving import (EncodeTask, InferenceEngine, Request,
                           SamplingParams)
from repro.serving.scheduler import ChunkedPrefillPolicy
from repro.sharding.plan import UNSHARDED

FUSED = UNSHARDED
UNFUSED = dataclasses.replace(UNSHARDED, fuse_epilogues=False)

# the interpret-mode CI job reruns the kernel-level tests with every op
# dispatched through Pallas interpret; the model-level parity tests assume
# the bit-identical reference path and are skipped there
INTERPRET_JOB = os.environ.get("REPRO_KERNEL_MODE") == "interpret"
model_level = pytest.mark.skipif(
    INTERPRET_JOB, reason="ref-path bit-identity; interpret job runs "
                          "kernel parity only")


def _rand(key, shape, dtype=jnp.float32):
    return (jax.random.normal(jax.random.key(key), shape) * 0.5).astype(dtype)


# --------------------------------------------------------------------------
# 1. kernel parity (Pallas interpret vs jnp oracle)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N", [(64, 64, 48), (100, 96, 60), (8, 256, 16)])
@pytest.mark.parametrize("norm", ["rmsnorm", "layernorm"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_matmul_prologue_kernel(M, K, N, norm, dtype):
    a = _rand(0, (M, K), dtype)
    w = _rand(1, (K, N), dtype)
    g = _rand(2, (K,)) * 0.2 + 1.0
    b = _rand(3, (K,)) * 0.2
    eps = 1e-6 if norm == "rmsnorm" else 1e-5
    out = mm.matmul(a, w, norm=norm, gamma=g, nbeta=b, eps=eps,
                    block_m=32, block_n=32, block_k=32, interpret=True)
    want = ref.fused_matmul_ref(a, w, norm=norm, gamma=g, nbeta=b, eps=eps,
                                dot_dtype=jnp.float32, out_dtype=a.dtype)
    # bf16: the kernel keeps the normalized operand in f32 while the oracle
    # rounds it to bf16 before the dot — allow a couple of output ulps
    tol = dict(rtol=2e-2, atol=0.2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("activation", ["gelu", "gelu_exact", "i_gelu",
                                        "silu"])
def test_fused_matmul_epilogue_kernel(activation):
    """bias + activation + residual + output cast in the accumulator."""
    a = _rand(10, (48, 64))
    w = _rand(11, (64, 32))
    bias = _rand(12, (32,)) * 0.2
    res = _rand(13, (48, 32))
    out = mm.matmul(a, w, activation=activation, bias=bias, residual=res,
                    out_dtype=jnp.bfloat16, block_m=16, block_n=16,
                    block_k=32, interpret=True)
    want = ref.fused_matmul_ref(a, w, activation=activation, bias=bias,
                                residual=res.astype(jnp.bfloat16),
                                dot_dtype=jnp.float32,
                                out_dtype=jnp.bfloat16)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("norm", ["none", "rmsnorm", "layernorm"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_swiglu_kernel(norm, dtype):
    a = _rand(20, (40, 96), dtype)
    wg = (_rand(21, (96, 48)) * 0.2).astype(dtype)
    wu = (_rand(22, (96, 48)) * 0.2).astype(dtype)
    g = _rand(23, (96,)) * 0.2 + 1.0
    b = _rand(24, (96,)) * 0.2
    res = _rand(25, (40, 48), dtype)
    kw = dict(gamma=g if norm != "none" else None,
              nbeta=b if norm == "layernorm" else None,
              eps=1e-6 if norm != "layernorm" else 1e-5)
    out = mm.matmul_swiglu(a, wg, wu, norm=norm, residual=res,
                           block_m=16, block_n=16, block_k=32,
                           interpret=True, **kw)
    want = ref.fused_matmul_swiglu_ref(a, wg, wu, norm=norm, residual=res,
                                       **kw)
    tol = dict(rtol=2e-2, atol=0.1) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("shape", [(4, 64), (2, 17, 96)])
@pytest.mark.parametrize("kind", ["rmsnorm", "layernorm"])
def test_residual_norm_kernel(shape, kind):
    x = _rand(30, shape)
    y = _rand(31, shape)
    g = _rand(32, shape[-1:]) * 0.2 + 1.0
    b = _rand(33, shape[-1:]) * 0.2
    if kind == "rmsnorm":
        h, r = rn.residual_rmsnorm(x, y, g, interpret=True)
        h0, r0 = ref.residual_norm_ref(x, y, norm="rmsnorm", gamma=g)
    else:
        h, r = rn.residual_layernorm(x, y, g, b, interpret=True)
        h0, r0 = ref.residual_norm_ref(x, y, norm="layernorm", gamma=g,
                                       nbeta=b, eps=1e-5)
    np.testing.assert_allclose(np.asarray(r), np.asarray(r0),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h0),
                               rtol=1e-5, atol=1e-5)


def test_expert_swiglu_dispatch():
    """Batched per-expert gated GEMMs: vmapped kernel == oracle."""
    xe = _rand(40, (4, 16, 32))
    wg = _rand(41, (4, 32, 24)) * 0.2
    wu = _rand(42, (4, 32, 24)) * 0.2
    with ops.kernel_mode("interpret"):
        got = ops.expert_swiglu(xe, wg, wu)
    with ops.kernel_mode("ref"):
        want = ops.expert_swiglu(xe, wg, wu)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ops_fused_matmul_dispatch():
    """ops-level dispatch: interpret-mode kernel == forced ref, through the
    Prologue/Epilogue spec path the model code uses."""
    x = _rand(50, (2, 24, 64))           # 3-D: entry point reshapes
    w = _rand(51, (64, 48)) * 0.2
    g = _rand(52, (64,)) * 0.2 + 1.0
    res = _rand(53, (2, 24, 48))
    pro = ops.Prologue("rmsnorm", g)
    ep = ops.Epilogue(residual=res, out_dtype=jnp.float32)
    with ops.kernel_mode("interpret"):
        a = ops.fused_matmul(x, w, prologue=pro, epilogue=ep,
                             dot_dtype=jnp.float32)
    with ops.kernel_mode("ref"):
        b = ops.fused_matmul(x, w, prologue=pro, epilogue=ep,
                             dot_dtype=jnp.float32)
    assert a.shape == res.shape
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_env_mode_validated():
    """Satellite bugfix: a typo'd REPRO_KERNEL_MODE raises instead of
    silently falling through dispatch."""
    prev = os.environ.get("REPRO_KERNEL_MODE")
    os.environ["REPRO_KERNEL_MODE"] = "palas"
    try:
        with pytest.raises(ValueError, match="palas"):
            ops.get_mode()
    finally:
        if prev is None:
            os.environ.pop("REPRO_KERNEL_MODE", None)
        else:
            os.environ["REPRO_KERNEL_MODE"] = prev


# --------------------------------------------------------------------------
# 2. model parity: fused vs unfused on the reference path
# --------------------------------------------------------------------------

def _kind_cfg(kind: str, norm: str = "rmsnorm") -> ModelConfig:
    kw = dict(name=f"tiny-{kind}", family="dense", n_layers=2, d_model=64,
              n_heads=4, n_kv_heads=2, head_dim=16, d_ff=96, vocab=256,
              schedule=((kind, 2),), norm=norm, max_seq=64)
    if kind in blocks.LOCAL_KINDS:
        kw["sliding_window"] = 8
    if kind in blocks.MOE_KINDS:
        kw.update(n_experts=4, top_k=2)
    if kind in blocks.SSM_KINDS or kind == "ssm":
        kw.update(ssm_state=16, ssm_head_dim=16, d_inner=64)
    if kind == "dec":
        kw.update(n_enc_layers=1, enc_schedule=(("enc", 1),), enc_seq=12)
    return ModelConfig(**kw)


ALL_KINDS = ("attn", "local", "moe", "moe_local", "ssm", "hybrid_attn",
             "hybrid_local", "enc", "dec", "vit")


@model_level
@pytest.mark.parametrize("kind", ALL_KINDS)
@pytest.mark.parametrize("norm", ["rmsnorm", "layernorm"])
def test_block_full_parity(kind, norm):
    """block_full fused == unfused, exactly, for every layer kind and both
    norm kinds (the fused ref path composes the identical op chain)."""
    cfg = _kind_cfg(kind, norm)
    p = blocks.init_block(jax.random.key(0), kind, cfg, jnp.float32)
    x = _rand(60, (2, 16, cfg.d_model))
    memory = _rand(61, (2, 12, cfg.d_model)) if kind == "dec" else None
    out_f, cache_f, _ = blocks.block_full(
        kind, p, x, plan=FUSED, cfg=cfg, policy=FP32, with_cache=True,
        max_seq=32, memory=memory, memory_len=12)
    out_u, cache_u, _ = blocks.block_full(
        kind, p, x, plan=UNFUSED, cfg=cfg, policy=FP32, with_cache=True,
        max_seq=32, memory=memory, memory_len=12)
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_u))
    for k in cache_f:
        np.testing.assert_array_equal(np.asarray(cache_f[k]),
                                      np.asarray(cache_u[k]))


@model_level
@pytest.mark.parametrize("kind", ALL_KINDS)
def test_block_decode_parity(kind):
    """block_decode fused == unfused, exactly, including cache updates."""
    if kind in blocks.BIDIR_KINDS:
        pytest.skip("encoder-only kinds have no decode step")
    cfg = _kind_cfg(kind)
    p = blocks.init_block(jax.random.key(1), kind, cfg, jnp.float32)
    x3 = _rand(62, (2, 8, cfg.d_model))
    memory = _rand(63, (2, 12, cfg.d_model)) if kind == "dec" else None
    _, cache, _ = blocks.block_full(kind, p, x3, plan=FUSED, cfg=cfg,
                                    policy=FP32, with_cache=True, max_seq=32,
                                    memory=memory, memory_len=12)
    x = _rand(64, (2, cfg.d_model))
    pos = jnp.array([8, 8], jnp.int32)
    out_f, cf = blocks.block_decode(kind, p, x, pos, cache, plan=FUSED,
                                    cfg=cfg, policy=FP32, memory_len=12)
    out_u, cu = blocks.block_decode(kind, p, x, pos, cache, plan=UNFUSED,
                                    cfg=cfg, policy=FP32, memory_len=12)
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_u))
    for k in cf:
        np.testing.assert_array_equal(np.asarray(cf[k]), np.asarray(cu[k]))


@model_level
@pytest.mark.parametrize("arch", ["gemma3-27b", "mixtral-8x7b",
                                  "hymba-1.5b", "whisper-base",
                                  "phi4-mini-3.8b"])
def test_generate_token_identical(arch):
    """Greedy prefill + 3 decode steps: token-for-token identical when the
    fused pipeline toggles (paper configs across local/moe/hybrid/encdec/
    seq_sp attention)."""
    cfg = get_config(arch).reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
    batch = frontends.make_batch(cfg, "prefill", 2,
                                 16 + (cfg.n_patches or 0))
    trajectories = {}
    for name, plan in (("fused", FUSED), ("unfused", UNFUSED)):
        tok, caches, pos = lm.forward_prefill(params, batch, plan=plan,
                                              cfg=cfg, policy=FP32,
                                              max_seq=32)
        toks = [np.asarray(tok)]
        t, p = tok, pos
        for _ in range(3):
            t, caches = lm.forward_decode(params, t, p, caches, plan=plan,
                                          cfg=cfg, policy=FP32)
            p = p + 1
            toks.append(np.asarray(t))
        trajectories[name] = toks
    for a, b in zip(trajectories["fused"], trajectories["unfused"]):
        np.testing.assert_array_equal(a, b)


@model_level
def test_forward_train_parity():
    """Training loss identical (blocks shared between train and serve)."""
    cfg = get_config("deepseek-67b").reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
    batch = frontends.make_batch(cfg, "train", 2, 32)
    lf, mf = lm.forward_train(params, batch, plan=FUSED, cfg=cfg,
                              policy=FP32)
    lu, mu = lm.forward_train(params, batch, plan=UNFUSED, cfg=cfg,
                              policy=FP32)
    np.testing.assert_array_equal(np.asarray(lf), np.asarray(lu))
    np.testing.assert_array_equal(np.asarray(mf["ce"]), np.asarray(mu["ce"]))


@model_level
@pytest.mark.parametrize("pooling", ["last", "mean"])
def test_forward_encode_parity(pooling):
    """Encoder-only pooled forward identical under fusion (incl. the
    select-then-norm fused head for last pooling)."""
    cfg = _kind_cfg("enc")
    params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
    batch = frontends.make_batch(cfg, "prefill", 2, 16)
    plen = jnp.array([16, 11], jnp.int32)
    ef = lm.forward_encode(params, batch, plan=FUSED, cfg=cfg, policy=FP32,
                           prompt_len=plen, pooling=pooling)
    eu = lm.forward_encode(params, batch, plan=UNFUSED, cfg=cfg,
                           policy=FP32, prompt_len=plen, pooling=pooling)
    np.testing.assert_array_equal(np.asarray(ef), np.asarray(eu))


def _engine_outputs(cfg, params, prompts, *, fuse, scheduler=None,
                    sampled=False):
    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                             policy=FP32, fuse_epilogues=fuse,
                             scheduler=scheduler)
    for uid, prompt in enumerate(prompts):
        sampling = (SamplingParams(temperature=0.8, top_k=8, seed=uid)
                    if sampled and uid % 2 else SamplingParams())
        engine.submit(Request(uid=uid, prompt=prompt, max_new_tokens=5,
                              sampling=sampling))
    done = sorted(engine.run(), key=lambda r: r.uid)
    return [r.output for r in done]


@model_level
def test_engine_token_identical():
    """End-to-end serving engine (paged KV, bucketed prefill, in-jit
    sampling): fused == unfused token streams, greedy AND sampled."""
    cfg = get_config("gpt-j").reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (6, 14, 9)]
    got_f = _engine_outputs(cfg, params, prompts, fuse=True, sampled=True)
    got_u = _engine_outputs(cfg, params, prompts, fuse=False, sampled=True)
    assert got_f == got_u


@model_level
def test_chunked_prefill_token_identical():
    """Chunked-prefill admission path under fusion == unfused chunked."""
    cfg = get_config("gpt-j").reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab, 24, dtype=np.int32)
               for _ in range(2)]
    got_f = _engine_outputs(cfg, params, prompts, fuse=True,
                            scheduler=ChunkedPrefillPolicy(8))
    got_u = _engine_outputs(cfg, params, prompts, fuse=False,
                            scheduler=ChunkedPrefillPolicy(8))
    assert got_f == got_u


@model_level
def test_encode_task_parity():
    """EncodeTask batch through the engine: fused == unfused embeddings."""
    cfg = get_config("gpt-j").reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, n, dtype=np.int32)
               for n in (7, 12)]
    embs = {}
    for fuse in (True, False):
        engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                                 policy=FP32, fuse_epilogues=fuse)
        for uid, prompt in enumerate(prompts):
            engine.submit(EncodeTask(uid=uid, prompt=prompt))
        done = sorted(engine.run(), key=lambda t: t.uid)
        embs[fuse] = [t.embedding for t in done]
    for a, b in zip(embs[True], embs[False]):
        np.testing.assert_array_equal(a, b)


# --------------------------------------------------------------------------
# 3. analysis: eliminated activation traffic shows up in the roofline
# --------------------------------------------------------------------------

@model_level
def test_fusion_lowers_mem_bytes_proxy():
    """Compiled-HLO HBM proxy: fused < unfused for prefill AND decode, with
    nonzero elided bytes and unchanged dot FLOPs (the acceptance gate
    benchmarks/breakdown.py applies to full-size GPT-J)."""
    import functools
    from repro.analysis.hlo import parse_hlo
    cfg = get_config("gpt-j").reduced()
    params = lm.init_lm(jax.random.key(0), cfg, jnp.float32)
    batch = frontends.make_batch(cfg, "prefill", 2, 32)
    summaries = {}
    for name, plan in (("fused", FUSED), ("unfused", UNFUSED)):
        fn = jax.jit(functools.partial(lm.forward_prefill, plan=plan,
                                       cfg=cfg, policy=FP32, max_seq=64))
        txt = fn.lower(params, batch).compile().as_text()
        summaries[name] = parse_hlo(txt, default_dot_dtype="f32")
    assert summaries["fused"].mem_bytes < summaries["unfused"].mem_bytes
    assert summaries["fused"].elided_bytes > summaries["unfused"].elided_bytes
    assert summaries["fused"].total_flops == pytest.approx(
        summaries["unfused"].total_flops, rel=1e-6)

    tok, caches, pos = lm.forward_prefill(params, batch, plan=FUSED,
                                          cfg=cfg, policy=FP32, max_seq=64)
    for name, plan in (("fused", FUSED), ("unfused", UNFUSED)):
        fn = jax.jit(functools.partial(lm.forward_decode, plan=plan,
                                       cfg=cfg, policy=FP32))
        txt = fn.lower(params, tok, pos, caches).compile().as_text()
        summaries[name] = parse_hlo(txt, default_dot_dtype="f32")
    assert summaries["fused"].mem_bytes < summaries["unfused"].mem_bytes
