"""Data pipeline: determinism, restart-exactness, file-backed stream."""
import numpy as np

from repro.configs import get_config
from repro.data import FileStream, SyntheticStream


def test_synthetic_deterministic():
    cfg = get_config("phi4-mini-3.8b").reduced()
    a = SyntheticStream(cfg, global_batch=4, seq_len=32, seed=1)
    b = SyntheticStream(cfg, global_batch=4, seq_len=32, seed=1)
    for step in (0, 5, 17):
        np.testing.assert_array_equal(np.asarray(a.batch(step)["tokens"]),
                                      np.asarray(b.batch(step)["tokens"]))
    assert not np.array_equal(np.asarray(a.batch(0)["tokens"]),
                              np.asarray(a.batch(1)["tokens"]))


def test_synthetic_host_sharding():
    cfg = get_config("phi4-mini-3.8b").reduced()
    h0 = SyntheticStream(cfg, global_batch=4, seq_len=16, host_id=0,
                         n_hosts=2)
    h1 = SyntheticStream(cfg, global_batch=4, seq_len=16, host_id=1,
                         n_hosts=2)
    b0, b1 = h0.batch(3), h1.batch(3)
    assert b0["tokens"].shape == (2, 16)
    assert not np.array_equal(np.asarray(b0["tokens"]),
                              np.asarray(b1["tokens"]))


def test_file_stream_resume_exact(tmp_path):
    cfg = get_config("phi4-mini-3.8b").reduced()
    path = tmp_path / "tokens.bin"
    np.arange(10_000, dtype=np.uint16).tofile(path)
    a = FileStream(cfg, str(path), global_batch=2, seq_len=16)
    b = FileStream(cfg, str(path), global_batch=2, seq_len=16)
    for step in (0, 3, 9):
        np.testing.assert_array_equal(np.asarray(a.batch(step)["tokens"]),
                                      np.asarray(b.batch(step)["tokens"]))
    # labels are next-token shifted
    batch = a.batch(0)
    np.testing.assert_array_equal(np.asarray(batch["labels"][:, :-1]),
                                  np.asarray(batch["tokens"][:, 1:]))
