"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracle,
swept over shapes and dtypes (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import decode_attention
from repro.kernels.matmul import matmul, matmul_swiglu
from repro.kernels.rmsnorm import layernorm, rmsnorm
from repro.kernels.ssd import ssd, ssd_multihead

TOL = dict(rtol=2e-2, atol=2e-2)
TOL32 = dict(rtol=2e-5, atol=2e-5)


def _rand(key, shape, dtype):
    return (jax.random.normal(jax.random.key(key), shape) * 0.5).astype(dtype)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

@pytest.mark.parametrize("B,Sq,Skv,H,KV,D", [
    (1, 128, 128, 4, 4, 64),      # MHA square
    (2, 64, 256, 8, 2, 32),       # GQA, cross lengths
    (1, 200, 200, 4, 1, 64),      # MQA, non-multiple-of-block
    (2, 256, 256, 6, 2, 128),     # 3-way groups
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_oracle(B, Sq, Skv, H, KV, D, dtype):
    q = _rand(0, (B, Sq, H, D), dtype)
    k = _rand(1, (B, Skv, KV, D), dtype)
    v = _rand(2, (B, Skv, KV, D), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_kv=64,
                          interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    tol = TOL if dtype == jnp.bfloat16 else dict(rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("causal,window", [(False, 0), (True, 0), (True, 32)])
def test_flash_attention_masks(causal, window):
    q = _rand(3, (1, 96, 4, 32), jnp.float32)
    k = _rand(4, (1, 96, 4, 32), jnp.float32)
    v = _rand(5, (1, 96, 4, 32), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=32, block_kv=32, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_q_offset():
    """Sequence-parallel shards: q rows at a positive position offset."""
    q = _rand(6, (1, 32, 2, 32), jnp.float32)
    k = _rand(7, (1, 128, 2, 32), jnp.float32)
    v = _rand(8, (1, 128, 2, 32), jnp.float32)
    out = flash_attention(q, k, v, causal=True, q_offset=64,
                          block_q=32, block_kv=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, q_offset=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_ref_matches_oracle():
    """The online-softmax scan oracle == full-materialization oracle."""
    q = _rand(9, (2, 64, 4, 32), jnp.float32)
    k = _rand(10, (2, 96, 2, 32), jnp.float32)
    v = _rand(11, (2, 96, 2, 32), jnp.float32)
    a = ref.flash_attention_ref(q, k, v, causal=True, block_kv=32)
    b = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL32)


# --------------------------------------------------------------------------
# decode attention
# --------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,D,window", [
    (2, 128, 4, 4, 64, 0),
    (3, 256, 8, 2, 32, 0),
    (2, 128, 4, 2, 64, 48),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_vs_oracle(B, S, H, KV, D, window, dtype):
    q = _rand(12, (B, H, D), dtype)
    kc = _rand(13, (B, S, KV, D), dtype)
    vc = _rand(14, (B, S, KV, D), dtype)
    length = jnp.array([S - 7, S // 2, 5][:B], jnp.int32)
    out = decode_attention(q, kc, vc, length, window=window, block_kv=64,
                           interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, length, window=window)
    tol = TOL if dtype == jnp.bfloat16 else dict(rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol)


# --------------------------------------------------------------------------
# tiled GEMM + fused epilogues
# --------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N", [(128, 256, 128), (100, 100, 60),
                                   (256, 512, 384), (8, 2048, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_vs_oracle(M, K, N, dtype):
    a = _rand(15, (M, K), dtype)
    b = _rand(16, (K, N), dtype)
    out = matmul(a, b, block_m=64, block_n=64, block_k=128, interpret=True)
    want = ref.matmul_ref(a, b)
    tol = TOL if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("activation", ["gelu", "silu"])
def test_matmul_fused_activation(activation):
    a = _rand(17, (64, 128), jnp.float32)
    b = _rand(18, (128, 64), jnp.float32)
    out = matmul(a, b, activation=activation, block_m=32, block_n=32,
                 block_k=64, interpret=True)
    want = ref.matmul_ref(a, b, activation=activation)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_matmul_swiglu_fused():
    a = _rand(19, (64, 128), jnp.float32)
    bg = _rand(20, (128, 96), jnp.float32)
    bu = _rand(21, (128, 96), jnp.float32)
    out = matmul_swiglu(a, bg, bu, block_m=32, block_n=32, block_k=64,
                        interpret=True)
    g = np.asarray(a, np.float32) @ np.asarray(bg, np.float32)
    u = np.asarray(a, np.float32) @ np.asarray(bu, np.float32)
    want = g / (1 + np.exp(-g)) * u
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 64), (2, 17, 128), (1, 7, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_vs_oracle(shape, dtype):
    x = _rand(22, shape, dtype)
    g = _rand(23, shape[-1:], jnp.float32) + 1.0
    out = rmsnorm(x, g, interpret=True)
    want = ref.rmsnorm_ref(x, g)
    tol = TOL if dtype == jnp.bfloat16 else dict(rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_layernorm_vs_oracle():
    x = _rand(24, (3, 33, 64), jnp.float32)
    g = _rand(25, (64,), jnp.float32) + 1.0
    b = _rand(26, (64,), jnp.float32)
    out = layernorm(x, g, b, interpret=True)
    want = ref.layernorm_ref(x, g, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# Mamba2 SSD
# --------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 64, 2, 16, 16, 16), (2, 128, 4, 32, 32, 32), (1, 96, 1, 64, 16, 32),
])
def test_ssd_kernel_vs_sequential(B, S, H, P, N, chunk):
    x = _rand(27, (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(_rand(28, (B, S, H), jnp.float32))
    A = -jnp.exp(_rand(29, (H,), jnp.float32))
    Bm = _rand(30, (B, S, N), jnp.float32)
    Cm = _rand(31, (B, S, N), jnp.float32)
    D = _rand(32, (H,), jnp.float32)
    y, h = ssd(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
    y_ref, h_ref = ref.ssd_ref(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 64, 2, 16, 16, 16), (2, 128, 4, 32, 32, 32),
])
def test_ssd_multihead_kernel_vs_sequential(B, S, H, P, N, chunk):
    """v2 kernel (all heads per grid cell — B/C streamed once, §Perf P2)."""
    x = _rand(45, (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(_rand(46, (B, S, H), jnp.float32))
    A = -jnp.exp(_rand(47, (H,), jnp.float32))
    Bm = _rand(48, (B, S, N), jnp.float32)
    Cm = _rand(49, (B, S, N), jnp.float32)
    D = _rand(50, (H,), jnp.float32)
    y, h = ssd_multihead(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
    y0, h0 = ref.ssd_ref(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h0),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunked_ref_vs_sequential():
    B, S, H, P, N = 2, 96, 3, 16, 24
    x = _rand(33, (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(_rand(34, (B, S, H), jnp.float32))
    A = -jnp.exp(_rand(35, (H,), jnp.float32))
    Bm = _rand(36, (B, S, N), jnp.float32)
    Cm = _rand(37, (B, S, N), jnp.float32)
    D = _rand(38, (H,), jnp.float32)
    y1, h1 = ref.ssd_chunked_ref(x, dt, A, Bm, Cm, D, chunk=32)
    y0, h0 = ref.ssd_ref(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0),
                               rtol=2e-4, atol=2e-4)


def test_ssd_decode_step_matches_scan():
    """AR state stepping == one more step of the sequential scan."""
    B, S, H, P, N = 1, 33, 2, 16, 16
    x = _rand(39, (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(_rand(40, (B, S, H), jnp.float32))
    A = -jnp.exp(_rand(41, (H,), jnp.float32))
    Bm = _rand(42, (B, S, N), jnp.float32)
    Cm = _rand(43, (B, S, N), jnp.float32)
    D = _rand(44, (H,), jnp.float32)
    y_all, h_prev = ref.ssd_ref(x[:, :-1], dt[:, :-1], A, Bm[:, :-1],
                                Cm[:, :-1], D)
    y_t, h_t = ref.ssd_decode_ref(x[:, -1], dt[:, -1], A, Bm[:, -1],
                                  Cm[:, -1], D, h_prev)
    y_full, h_full = ref.ssd_ref(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_full[:, -1]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_t), np.asarray(h_full),
                               rtol=1e-4, atol=1e-4)
