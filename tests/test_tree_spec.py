"""Token-tree speculative decoding: topology, mask semantics, token
identity, rollback hygiene, degrade ladder, and the draft-checkpoint
round-trip satellite.

The tentpole property is the same one single-branch speculation carries
(tests/test_spec.py) widened to trees: with multi-branch proposals
verified in ONE tree-masked target pass, every committed sequence —
greedy AND sampled — must equal step-by-step decoding exactly, because
the accepted path is re-derived from the target's own deterministic
(seed, position)-keyed choices at every node.  Identity is asserted
across the rejection-heavy mis-seeded draft (maximal rollback + KV
compaction), a mid-acceptance interpolated draft (sibling branches
actually win), chunked admission, prefix-cache-warm starts, and
recompute preemption; b=1 must reduce to the single-branch engine
byte-for-byte.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.precision import FP32
from repro.kernels.ref import paged_chunk_partials_ref
from repro.models import lm
from repro.serving import (ChunkedPrefillPolicy, DeadlinePolicy, FCFSPolicy,
                           InferenceEngine, Request, SamplingParams,
                           SpecConfig)
from repro.serving.spec import (TokenTree, accept_tree_path, build_tree,
                                resolve_draft)

# the rejection-heavy regime: a mis-seeded draft whose proposals are
# near-random over the reduced vocab — almost every round rejects at the
# root, exercising tree rollback with zero committed nodes
REJECTY_TREE = SpecConfig(draft="auto", k=3, draft_seed=1234, branches=3)


# --------------------------------------------------------------------------
# pure host-side pieces: config, topology, path acceptance
# --------------------------------------------------------------------------

def test_tree_config_validation():
    assert SpecConfig().branches == 1
    with pytest.raises(ValueError, match="branch count"):
        SpecConfig(branches=0)
    SpecConfig(branches=4)   # any width >= 1 is valid


def test_build_tree_topology():
    """The caterpillar: a primary chain plus (b-1) sibling leaves per
    depth, flattened depth-major chain-first, every prefix
    ancestor-closed."""
    t = build_tree(10, [[1, 2, 3], [4, 5, 6]])
    assert isinstance(t, TokenTree) and t.n_nodes == 7
    assert list(t.tokens) == [10, 1, 2, 3, 4, 5, 6]
    assert list(t.depth) == [0, 1, 1, 1, 2, 2, 2]
    # depth-1 nodes hang off the root; depth-2 nodes hang off depth 1's
    # CHAIN node (node 1) — siblings are leaves, only the chain extends
    assert list(t.parent[1:]) == [0, 0, 0, 1, 1, 1]
    assert list(t.chain) == [True, True, False, False, True, False, False]
    # ancestor rows: self + the path to the root, nothing else
    anc = np.asarray(t.anc)
    assert anc[0].tolist() == [True] + [False] * 6
    assert anc[2].tolist() == [True, False, True, False, False, False, False]
    assert anc[5].tolist() == [True, True, False, False, False, True, False]
    # ancestor closure: every ancestor's ancestors are mine too
    for i in range(t.n_nodes):
        for j in np.flatnonzero(anc[i]):
            assert (anc[i] | anc[j]).tolist() == anc[i].tolist()


def test_build_tree_single_branch_degenerates_to_chain():
    """b=1 trees ARE the PR-5 chain chunk: depth[i] == i and the ancestor
    matrix is exactly lower-triangular (causal)."""
    t = build_tree(7, [[3], [9], [4]])
    assert list(t.tokens) == [7, 3, 9, 4]
    assert list(t.depth) == list(range(4))
    assert all(t.chain)
    assert np.array_equal(np.asarray(t.anc), np.tril(np.ones((4, 4), bool)))


def test_accept_tree_path():
    t = build_tree(10, [[1, 2, 3], [4, 5, 6]])
    tok, par, n = t.tokens, t.parent, t.n_nodes
    # target chooses the chain token then a sibling: descend 0 -> 1 -> 5
    choices = np.zeros(n, np.int64)
    choices[0], choices[1] = 1, 5
    assert accept_tree_path(tok, par, choices, n) == [1, 5]
    # target chooses a sibling at depth 1: siblings have no children, so
    # the path ends there even if deeper tokens would have matched
    choices[0] = 3
    assert accept_tree_path(tok, par, choices, n) == [3]
    # no child carries the target's choice: empty path (round commits
    # only the target's own bonus token)
    choices[0] = 99
    assert accept_tree_path(tok, par, choices, n) == []
    # full chain walk-through
    choices[0], choices[1], choices[4] = 1, 4, 77
    assert accept_tree_path(tok, par, choices, n) == [1, 4]


# --------------------------------------------------------------------------
# kernel oracle: tree-mask semantics
# --------------------------------------------------------------------------

def _chunk_inputs(rng, B, C, pos0, *, H=4, D=8, KV=2, BS=4, NB=8, MB=4):
    q = jnp.asarray(rng.normal(size=(B, C, H, D)), jnp.float32)
    k_pool = jnp.asarray(rng.normal(size=(NB, BS, KV, D)), jnp.float32)
    v_pool = jnp.asarray(rng.normal(size=(NB, BS, KV, D)), jnp.float32)
    tables = jnp.asarray(
        [[b * MB + i for i in range(MB)] for b in range(B)], jnp.int32)
    q_pos = jnp.asarray(np.asarray(pos0)[:, None] + np.arange(C)[None, :],
                        jnp.int32)
    lengths = jnp.asarray(np.asarray(pos0) + C, jnp.int32)
    return q, k_pool, v_pool, tables, q_pos, lengths


def test_tree_mask_chain_degeneracy_bitwise():
    """A lower-triangular tree_mask must reproduce the plain causal chunk
    mask BIT-exactly — the masked score set is identical, so every fp op
    downstream sees the same operands (the b=1 == PR-5 guarantee at the
    kernel layer)."""
    rng = np.random.default_rng(11)
    B, C = 2, 4
    args = _chunk_inputs(rng, B, C, [5, 2])
    tri = jnp.broadcast_to(jnp.tril(jnp.ones((C, C), bool)), (B, C, C))
    o0, m0, l0 = paged_chunk_partials_ref(*args)
    o1, m1, l1 = paged_chunk_partials_ref(*args, tree_mask=tri)
    assert np.array_equal(np.asarray(o0), np.asarray(o1))
    assert np.array_equal(np.asarray(m0), np.asarray(m1))
    assert np.array_equal(np.asarray(l0), np.asarray(l1))


def test_tree_mask_blinds_siblings():
    """Mask semantics: a node attends its ancestors and the committed
    prefix, NEVER a sibling — perturbing one sibling's KV row must leave
    every non-descendant node's output bit-identical."""
    rng = np.random.default_rng(12)
    B, C = 1, 4
    t = build_tree(10, [[1, 2, 3]])          # root + three depth-1 leaves
    anc = jnp.asarray(np.asarray(t.anc)[None], bool)
    q, k_pool, v_pool, tables, q_pos, lengths = _chunk_inputs(
        rng, B, C, [5])
    out = paged_chunk_partials_ref(q, k_pool, v_pool, tables, q_pos,
                                   lengths, tree_mask=anc)
    # clobber the KV rows of node 1 (position pos0+1 = 6 -> block 1 off 2)
    k2 = k_pool.at[1, 2].add(100.0)
    v2 = v_pool.at[1, 2].add(100.0)
    out2 = paged_chunk_partials_ref(q, k2, v2, tables, q_pos, lengths,
                                    tree_mask=anc)
    for a, b in zip(out, out2):
        a, b = np.asarray(a), np.asarray(b)
        # node 1 sees its own perturbed row; the root (its parent) and
        # its siblings 2, 3 must not
        assert not np.array_equal(a[:, 1], b[:, 1])
        for node in (0, 2, 3):
            assert np.array_equal(a[:, node], b[:, node]), node


# --------------------------------------------------------------------------
# end-to-end identity
# --------------------------------------------------------------------------

_PARAMS_CACHE = {}


def _reduced(arch):
    if arch not in _PARAMS_CACHE:
        cfg = get_config(arch).reduced()
        _PARAMS_CACHE[arch] = (cfg, lm.init_lm(jax.random.key(0), cfg,
                                               jnp.float32))
    return _PARAMS_CACHE[arch]


def _trace(cfg, lens, *, max_new=7, sampled=()):
    rng = np.random.default_rng(29)
    reqs = []
    for uid, n in enumerate(lens):
        reqs.append(Request(
            uid=uid, prompt=rng.integers(0, cfg.vocab, n, dtype=np.int32),
            max_new_tokens=max_new,
            sampling=SamplingParams(temperature=0.8, top_k=8, seed=uid)
            if uid in sampled else SamplingParams()))
    return reqs


def _run(cfg, params, reqs, **kw):
    engine = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                             policy=FP32, **kw)
    for r in reqs:
        engine.submit(r)
    done = {t.uid: t.output for t in engine.run()}
    return engine, done


def _midrange_draft(cfg, alpha=0.1):
    """Interpolate the truncated-target draft (seed 0 reproduces the
    target's own init -> ~100% acceptance on reduced configs) toward a
    decorrelated init: top-1 is wrong often enough to reject while the
    top-b set still carries the target's choice — the regime where
    sibling branches win (benchmarks/serving_bench.py uses the same
    construction for the tree gate)."""
    dcfg = resolve_draft(SpecConfig(draft="auto"), cfg)
    p0 = lm.init_lm(jax.random.key(0), dcfg, jnp.float32)
    p1 = lm.init_lm(jax.random.key(1234), dcfg, jnp.float32)
    return jax.tree.map(lambda a, b: (1 - alpha) * a + alpha * b, p0, p1)


@pytest.mark.parametrize("arch", ["gpt-j", "gpt3-xl", "phi4-mini-3.8b"])
def test_greedy_token_identity_tree(arch):
    """Greedy decode with tree speculation on is token-identical to
    speculation off, under the rejection-heavy draft (every round walks
    commit -> compact -> rollback)."""
    cfg, params = _reduced(arch)
    lens = (5, 12, 9)
    base = _run(cfg, params, _trace(cfg, lens))[1]
    eng, got = _run(cfg, params, _trace(cfg, lens), spec=REJECTY_TREE)
    st = eng.stats()
    assert got == base, f"{arch} diverged under tree speculation"
    assert eng.runner.tree_branches == 3
    assert st.spec_rounds > 0 and st.spec_tree_nodes > 0
    # node counts accumulate per slot-round (trees shrink near the
    # max_new horizon, but most rounds verify a root + k*b-node tree)
    assert st.spec_tree_nodes > st.spec_slot_steps
    # pool fully drained — tree verify writes + compaction + rollback
    # leak no blocks
    assert eng.allocator.num_free == eng.allocator.num_blocks


def test_sampled_lossless_parity_tree():
    """Sampled requests (fixed seeds) are exactly reproduced through the
    tree: acceptance re-derives the target's deterministic
    (seed, position)-keyed draws at every node, so a sibling only wins
    when it carries the token the target would have sampled anyway."""
    cfg, params = _reduced("gpt-j")
    lens = (6, 14, 10, 8)
    reqs = lambda: _trace(cfg, lens, sampled=(0, 1, 3))
    base = _run(cfg, params, reqs())[1]
    for b in (2, 3):
        _, got = _run(cfg, params, reqs(),
                      spec=SpecConfig(draft="auto", k=3, draft_seed=1234,
                                      branches=b))
        assert got == base, f"b={b} diverged"


def test_single_branch_engine_is_the_chain_engine():
    """branches=1 must take the single-branch code path wholesale: the
    chain steps are built, the tree steps are not, no tree telemetry
    accrues, and outputs equal the explicit PR-5 config's."""
    cfg, params = _reduced("gpt-j")
    lens = (5, 9)
    chain_spec = SpecConfig(draft="auto", k=3, draft_seed=1234)
    eng1, got1 = _run(cfg, params, _trace(cfg, lens), spec=chain_spec)
    eng2, got2 = _run(cfg, params, _trace(cfg, lens),
                      spec=SpecConfig(draft="auto", k=3, draft_seed=1234,
                                      branches=1))
    assert eng2.runner.tree_branches == 1
    assert eng2.runner.tree_verify_step is None
    assert eng2.runner.draft_topk_step is None
    assert eng2.runner.draft_decode_step is not None
    assert got1 == got2
    assert eng2.stats().spec_tree_nodes == 0


def test_tree_branches_actually_win_on_midrange_draft():
    """With a mid-acceptance draft the tree's sibling branches must
    rescue rounds the chain loses: sibling acceptances occur, identity
    holds, and accepted tokens per slot-round don't regress vs the
    chain at equal k."""
    cfg, params = _reduced("gpt-j")
    dparams = _midrange_draft(cfg)
    lens = (6, 9, 7, 11)
    base = _run(cfg, params, _trace(cfg, lens, max_new=12))[1]
    res = {}
    for b in (1, 3):
        eng, got = _run(cfg, params, _trace(cfg, lens, max_new=12),
                        spec=SpecConfig(draft="auto", k=3, branches=b),
                        draft_params=dparams)
        st = eng.stats()
        assert got == base, f"b={b} diverged"
        res[b] = (st.spec_accepted_tokens / max(1, st.spec_slot_steps),
                  st.spec_branch_hits)
        assert eng.allocator.num_free == eng.allocator.num_blocks
    assert res[3][1] > 0, "no sibling branch ever accepted"
    assert res[3][0] >= res[1][0], (
        f"tree accepted/round {res[3][0]:.3f} regressed vs chain "
        f"{res[1][0]:.3f}")


def test_tree_rollback_leak_free_and_bounded():
    """A rejection-heavy draft rolls whole trees back every round, and
    accepted paths compact KV rows within the slot's own blocks: the
    pool's peak stays within capacity and fully drains (no block leaked
    or double-freed by verify-write + compact + trailing-free cycles)."""
    cfg, params = _reduced("gpt-j")
    eng, _ = _run(cfg, params, _trace(cfg, (9, 17), max_new=12),
                  spec=REJECTY_TREE, block_size=4)
    st = eng.stats()
    assert st.spec_proposed_tokens > st.spec_accepted_tokens
    assert st.peak_blocks_used <= eng.allocator.num_blocks
    assert eng.allocator.num_free == eng.allocator.num_blocks


def test_random_tree_traces_drain_pool():
    """Property test: random traces (lengths, sampling mix, widths)
    through tree engines always drain the pool exactly and keep token
    identity — the block-accounting invariant under arbitrary
    accept/compact/rollback interleavings."""
    hyp = pytest.importorskip(
        "hypothesis", reason="hypothesis not installed in this env")
    from hypothesis import given, settings, strategies as st_

    cfg, params = _reduced("gpt-j")

    @settings(max_examples=5, deadline=None)
    @given(st_.lists(st_.integers(4, 20), min_size=1, max_size=3),
           st_.integers(2, 3), st_.booleans())
    def run(lens, branches, sample_first):
        sampled = (0,) if sample_first else ()
        base = _run(cfg, params,
                    _trace(cfg, tuple(lens), sampled=sampled))[1]
        eng, got = _run(cfg, params,
                        _trace(cfg, tuple(lens), sampled=sampled),
                        spec=SpecConfig(draft="auto", k=2, draft_seed=1234,
                                        branches=branches),
                        block_size=4)
        assert got == base
        assert eng.allocator.num_free == eng.allocator.num_blocks

    run()


def test_tree_with_chunked_prefill_mix():
    """Tree speculation + ChunkedPrefillPolicy: long prompts chunk into
    their paged blocks while seated slots run tree rounds; outputs match
    plain FCFS with speculation off."""
    cfg, params = _reduced("phi4-mini-3.8b")
    lens = (5, 40, 12, 33)
    base = _run(cfg, params, _trace(cfg, lens, sampled=(1,)),
                scheduler=FCFSPolicy())[1]
    eng, got = _run(cfg, params, _trace(cfg, lens, sampled=(1,)),
                    scheduler=ChunkedPrefillPolicy(16), spec=REJECTY_TREE)
    st = eng.stats()
    assert st.prefill_chunks >= 5 and st.spec_rounds > 0
    assert got == base
    assert eng.allocator.num_free == eng.allocator.num_blocks


def test_tree_with_prefix_cache_warm_start():
    """Prefix-cache-warm admissions + tree rounds: wave 2 reuses wave 1's
    cached prompt blocks (COW — tree verify writes must never land in a
    shared block) and still commits identical tokens."""
    cfg, params = _reduced("gpt-j")
    rng = np.random.default_rng(31)
    shared = rng.integers(0, cfg.vocab, 24, dtype=np.int32)
    prompts = [np.concatenate([shared,
                               rng.integers(0, cfg.vocab, 2 + u % 3,
                                            dtype=np.int32)])
               for u in range(3)]

    def wave():   # fresh Request objects over the same prompt arrays
        return [Request(uid=u, prompt=p, max_new_tokens=6,
                        sampling=SamplingParams())
                for u, p in enumerate(prompts)]

    base = _run(cfg, params, wave())[1]
    eng = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                          policy=FP32, spec=REJECTY_TREE,
                          prefix_cache=True)
    for r in wave():
        eng.submit(r)
    eng.run()
    for r in wave():
        eng.submit(r)
    got = {t.uid: t.output for t in eng.run()}
    st = eng.stats()
    assert st.prefix_hits > 0, "wave 2 never hit the prefix cache"
    assert got == base


def test_tree_preemption_then_resume_parity():
    """Recompute preemption under a starved pool with trees on: the
    budget-capped lookahead falls back to chain-width reservations
    instead of deadlocking, evicted requests re-prefill and continue
    token-exactly, and the pool drains."""
    cfg, params = _reduced("phi4-mini-3.8b")
    lens = (5, 11, 7, 16)
    reqs = lambda: _trace(cfg, lens, max_new=9, sampled=(1, 3))
    base = _run(cfg, params, reqs())[1]
    eng, got = _run(cfg, params, reqs(), spec=REJECTY_TREE,
                    block_size=8, kv_pool_blocks=5)
    st = eng.stats()
    assert st.preemptions > 0
    assert got == base
    assert eng.allocator.num_free == eng.allocator.num_blocks


def test_int8_kv_pool_forces_single_branch():
    """int8 paged KV pins rows to per-block scales, so tree compaction
    (raw row moves) is unsound — the runner must drop to the chain path
    rather than corrupt scales."""
    cfg, params = _reduced("gpt-j")
    eng = InferenceEngine(cfg, params, batch_size=2, max_seq=64,
                          policy=FP32, kv_dtype="int8",
                          spec=SpecConfig(draft="auto", k=2, branches=3))
    assert eng.runner.tree_branches == 1
    assert eng.runner.tree_verify_step is None


# --------------------------------------------------------------------------
# degrade ladder (DeadlinePolicy rung 1: chain-only; rung 2: spec off)
# --------------------------------------------------------------------------

def test_degrade_ladder_two_rungs():
    p = DeadlinePolicy(degrade_depth=1.0)
    # thresh = degrade_depth * n_slots = 2 for two slots
    assert p.degrade_level(0, 2) == 0
    assert p.degrade_level(2, 2) == 0      # at the threshold: full service
    assert p.degrade_level(3, 2) == 1      # rung 1: trees -> chains
    assert p.degrade_level(4, 2) == 1
    assert p.degrade_level(5, 2) == 2      # rung 2: spec off (sticky)
    # the chunk halving rides rung 1 and does not double at rung 2
    p2 = DeadlinePolicy(chunk_tokens=32, degrade_depth=1.0)
    assert p2.effective_chunk_tokens(0) == 32
    assert p2.effective_chunk_tokens(1) == 16
    assert p2.effective_chunk_tokens(2) == 16


def test_degrade_ladder_is_lossless_end_to_end():
    """A backlog deep enough to ride both rungs: requests get admitted
    chain-only and spec-off along the way, yet every committed sequence
    equals the un-degraded baseline (the ladder trades speed, never
    tokens)."""
    cfg, params = _reduced("gpt-j")
    lens = (5, 8, 6, 9, 7, 10)
    base = _run(cfg, params, _trace(cfg, lens, max_new=6))[1]
    eng, got = _run(cfg, params, _trace(cfg, lens, max_new=6),
                    spec=REJECTY_TREE,
                    scheduler=DeadlinePolicy(degrade_depth=0.25))
    st = eng.stats()
    assert got == base
    assert st.requests_degraded > 0, "backlog never tripped the ladder"
    assert eng.allocator.num_free == eng.allocator.num_blocks
    # the round-scoped rung-1 flag is reset once the backlog drains
    assert eng.runner._tree_chain_only is False


# --------------------------------------------------------------------------
# draft-checkpoint round trip (satellite: checkpoint/ -> serving)
# --------------------------------------------------------------------------

def test_draft_checkpoint_round_trip_token_identity(tmp_path):
    """save -> load -> serve: a draft restored from a Checkpointer
    directory drives byte-identical speculation to the same params passed
    in memory (and both stay lossless vs no speculation)."""
    from repro.checkpoint.checkpointer import Checkpointer

    cfg, params = _reduced("gpt-j")
    dparams = _midrange_draft(cfg)
    Checkpointer(str(tmp_path)).save(dparams, 0)
    lens = (6, 10, 8)
    spec = lambda: SpecConfig(draft="auto", k=3, branches=3)
    base = _run(cfg, params, _trace(cfg, lens, sampled=(2,)))[1]
    eng_mem, got_mem = _run(cfg, params, _trace(cfg, lens, sampled=(2,)),
                            spec=spec(), draft_params=dparams)
    eng_ckpt, got_ckpt = _run(cfg, params, _trace(cfg, lens, sampled=(2,)),
                              spec=spec(), draft_checkpoint=str(tmp_path))
    assert got_ckpt == got_mem == base
    # same draft -> same proposals -> same acceptance trajectory
    assert (eng_ckpt.stats().spec_accepted_tokens
            == eng_mem.stats().spec_accepted_tokens)


def test_draft_checkpoint_validation(tmp_path):
    cfg, params = _reduced("gpt-j")
    with pytest.raises(ValueError, match="SpecConfig"):
        InferenceEngine(cfg, params, batch_size=2, max_seq=64, policy=FP32,
                        draft_checkpoint=str(tmp_path))
    with pytest.raises(ValueError, match="not both"):
        InferenceEngine(cfg, params, batch_size=2, max_seq=64, policy=FP32,
                        spec=SpecConfig(draft="auto"),
                        draft_params=_midrange_draft(cfg),
                        draft_checkpoint=str(tmp_path))


# --------------------------------------------------------------------------
# telemetry surface
# --------------------------------------------------------------------------

def test_tree_stats_surface():
    """spec_tree_nodes / accepted-path-depth percentiles / branch
    utilization populate, serialize, and stay internally consistent."""
    cfg, params = _reduced("gpt-j")
    eng, _ = _run(cfg, params, _trace(cfg, (5, 9), max_new=8),
                  spec=SpecConfig(draft="auto", k=3, branches=3),
                  draft_params=_midrange_draft(cfg))
    st = eng.stats()
    assert st.spec_tree_nodes > 0
    assert 0.0 <= st.spec_branch_utilization <= 1.0
    # accepted-path depth is the number of accepted tree nodes: 0..k
    assert 0.0 <= st.spec_path_depth_p50 <= st.spec_path_depth_p95 <= 3.0
    d = st.to_dict()
    for key in ("spec_tree_nodes", "spec_branch_hits",
                "spec_branch_utilization", "spec_path_depth_p50",
                "spec_path_depth_p95"):
        assert key in d
    assert "tree" in st.summary()
